"""Benchmark timing helpers."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, repeat: int = 10, warmup: int = 2, **kwargs) -> float:
    """Mean wall time per call in microseconds (post-warmup)."""
    for _ in range(warmup):
        r = fn(*args, **kwargs)
        _block(r)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        r = fn(*args, **kwargs)
        _block(r)
        times.append(time.perf_counter() - t0)
    return float(np.mean(times) * 1e6)


def _block(r):
    for leaf in jax.tree.leaves(r):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()


def sym(seed: int, n: int, dtype=np.float64) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(dtype)
    return (a + a.T) / 2


def sym_stack(seed: int, b: int, n: int, dtype=np.float32) -> np.ndarray:
    """Stack of ``b`` random symmetric matrices, shape ``(b, n, n)``."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((b, n, n)).astype(dtype)
    return (a + np.swapaxes(a, 1, 2)) / 2


class Row:
    def __init__(self, name: str, us: float, derived: str = ""):
        self.name = name
        self.us = us
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"
