"""Fig. 1 reproductions.

(a) single component of one eigenvector: identity vs identity-parallelized
    vs NumPy, across sizes;
(b) one complete eigenvector: EEI (all minors) vs NumPy full eigh;
(c)/(d) the optimization ladder at fixed n: baseline -> cached -> vectorized
    -> batched -> parallel (paper variants) -> logspace -> pallas kernel
    (beyond-paper), each per-call time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sym, time_fn
from repro.core import identity, minors, numpy_ref
from repro.kernels.prod_diff import ops as pd_ops

FIG_AB_SIZES = (50, 100, 200, 300)
LADDER_N = 120


def run_fig_ab() -> list[Row]:
    rows = []
    for n in FIG_AB_SIZES:
        a = sym(n, n)
        aj = jnp.asarray(a)
        i = n // 2

        # (a) single component
        t_np = time_fn(numpy_ref.numpy_full_eigh, a, repeat=5)
        t_ident = time_fn(numpy_ref.eigen_component_optimized, a, i, 0,
                          repeat=5)

        @jax.jit
        def one_comp(a_, i_, j_):
            lam = jnp.linalg.eigvalsh(a_)
            mu = jnp.linalg.eigvalsh(minors.minor(a_, j_))
            return identity.component_parallel(lam, mu, i_, 64)

        t_par = time_fn(one_comp, aj, i, 0, repeat=5)
        rows += [
            Row(f"fig1a/numpy/n={n}", t_np, "full eigh"),
            Row(f"fig1a/identity/n={n}", t_ident,
                f"speedup={t_np / t_ident:.2f}x"),
            Row(f"fig1a/identity_parallelized/n={n}", t_par,
                f"speedup={t_np / t_par:.2f}x"),
        ]

        # (b) one full eigenvector (needs all minor spectra)
        @jax.jit
        def full_vec(a_, i_):
            lam = jnp.linalg.eigvalsh(a_)
            mu = identity.minor_spectra(a_)
            return jnp.exp(
                identity.logabs_numerator(lam, mu)[i_]
                - identity.logabs_denominator(lam)[i_]
            )

        t_vec = time_fn(full_vec, aj, i, repeat=3)
        rows += [
            Row(f"fig1b/numpy/n={n}", t_np, "full eigh"),
            Row(f"fig1b/identity_vector/n={n}", t_vec,
                f"ratio_vs_numpy={t_vec / t_np:.2f}x "
                "(EEI wins only for partial outputs — paper's conclusion)"),
        ]
    return rows


def run_ladder() -> list[Row]:
    n = LADDER_N
    a = sym(n, n)
    aj = jnp.asarray(a)
    i, j = n // 2, n // 3
    rows = []

    t = time_fn(numpy_ref.eigen_component_baseline, a, i, j, repeat=3)
    rows.append(Row(f"fig1cd/baseline/n={n}", t, "Algorithm 1"))

    lam_np = np.linalg.eigvalsh(a)
    mu_np = np.linalg.eigvalsh(np.delete(np.delete(a, j, 0), j, 1))
    t = time_fn(numpy_ref.eigen_component_cached, lam_np, mu_np, i, repeat=3)
    rows.append(Row(f"fig1cd/cached/n={n}", t, "spectra cached"))
    t = time_fn(numpy_ref.eigen_component_vectorized, lam_np, mu_np, i,
                repeat=5)
    rows.append(Row(f"fig1cd/vectorized/n={n}", t, "np products"))
    t = time_fn(numpy_ref.eigen_component_optimized, a, i, j, repeat=5)
    rows.append(Row(f"fig1cd/batched/n={n}", t, "Algorithm 2 (incl. spectra)"))

    lam = jnp.asarray(lam_np)
    mu = jnp.asarray(mu_np)
    for variant, fn in [
        ("vectorized_jax", identity.component_vectorized),
        ("batched_jax", identity.component_batched),
        ("parallel_jax", identity.component_parallel),
        ("logspace_jax", identity.component_logspace),
    ]:
        jf = jax.jit(lambda l, m, fn=fn: fn(l, m, i))
        t = time_fn(jf, lam, mu, repeat=10)
        rows.append(Row(f"fig1cd/{variant}/n={n}", t, "products only"))

    mu_all = identity.minor_spectra(aj)
    jk = jax.jit(lambda l, m: pd_ops.eei_magnitudes(l, m))
    t = time_fn(jk, lam, mu_all, repeat=3)
    rows.append(Row(f"fig1cd/pallas_full_table/n={n}", t,
                    "all n^2 components, prod_diff kernel (interpret)"))
    return rows


def run() -> list[Row]:
    return run_fig_ab() + run_ladder()
