"""Kernel benchmarks: Pallas (interpret mode on CPU — structural check, the
TPU timing claim lives in the roofline) vs pure-jnp reference, plus the full
TPU-native EEI pipeline vs LAPACK eigh."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sym, time_fn
from repro.engine import SolverEngine, SolverPlan
from repro.kernels.prod_diff import ops as pd_ops
from repro.kernels.prod_diff import ref as pd_ref
from repro.kernels.sturm import ops as st_ops
from repro.kernels.sturm import ref as st_ref


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)

    # prod_diff
    for (i_n, j_n, k_n) in [(128, 128, 127), (256, 256, 255)]:
        lam = jnp.asarray(np.sort(rng.standard_normal(i_n)), jnp.float32)
        mu = jnp.asarray(rng.standard_normal((j_n, k_n)), jnp.float32)
        t_k = time_fn(pd_ops.logabs_sum, lam, mu, 1e-6, repeat=3)
        t_r = time_fn(jax.jit(pd_ref.logabs_sum), lam, mu, 1e-6, repeat=3)
        rows.append(Row(f"kernel/prod_diff/{i_n}x{j_n}x{k_n}", t_k,
                        f"ref_us={t_r:.0f} ratio={t_k / t_r:.2f} (interpret)"))

    # sturm
    for (b, n) in [(64, 127), (128, 255)]:
        d = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
        e = jnp.asarray(rng.standard_normal((b, n - 1)), jnp.float32)
        t_k = time_fn(st_ops.sturm_eigenvalues, d, e, repeat=3)
        t_r = time_fn(st_ref.sturm_eigenvalues, d, e, repeat=3)
        rows.append(Row(f"kernel/sturm/{b}x{n}", t_k,
                        f"ref_us={t_r:.0f} ratio={t_k / t_r:.2f} (interpret)"))

    # full pipelines: top-4 eigenpairs
    n = 128
    a = jnp.asarray(sym(1, n), jnp.float32)
    for method in ("eigh", "eei_dense", "eei_tridiag"):
        eng = SolverEngine(SolverPlan(method=method))
        t = time_fn(eng.topk, a, 4, repeat=3)
        rows.append(Row(f"pipeline/topk4/{method}/n={n}", t, "signed top-4"))
    return rows
