"""Roofline report: reads artifacts/dryrun/*.json into the §Roofline table.

Rows are (cell, bound_time_us, "dominant=<term> fraction=<roofline frac>").
Derived from the compiled dry-run — no wall-clock on this container.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

ART_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def load_cells(pattern: str = "*__pod.json") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, pattern))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run() -> list[Row]:
    rows = []
    for cell in load_cells():
        name = f"roofline/{cell['arch']}/{cell['shape']}"
        if cell.get("status") == "skipped":
            rows.append(Row(name, 0.0, f"skipped: {cell.get('reason', '')}"))
            continue
        rl = cell.get("roofline")
        if not rl:
            continue
        bound_us = max(rl["t_compute_s"], rl["t_memory_s"],
                       rl["t_collective_s"]) * 1e6
        rows.append(Row(
            name, bound_us,
            f"dominant={rl['dominant']}"
            f" frac={rl['roofline_fraction']:.3f}"
            f" useful={rl['useful_flops_ratio']:.3f}"
            f" tC={rl['t_compute_s'] * 1e3:.2f}ms"
            f" tM={rl['t_memory_s'] * 1e3:.2f}ms"
            f" tX={rl['t_collective_s'] * 1e3:.2f}ms"))
    if not rows:
        rows.append(Row("roofline/missing", 0.0,
                        "run: python -m repro.launch.dryrun --all --roofline"))
    return rows
