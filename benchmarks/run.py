"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig1,kernels,roofline]

Prints ``name,us_per_call,derived`` CSV rows (paper Table 1, Fig 1(a)-(d),
kernel-vs-oracle, and the dry-run roofline report)."""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: "
                         "table1,fig1,kernels,throughput,roofline")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    suites = []
    if not only or "table1" in only:
        from benchmarks import table1
        suites.append(("table1", table1.run))
    if not only or "fig1" in only:
        from benchmarks import fig1
        suites.append(("fig1", fig1.run))
    if not only or "kernels" in only:
        from benchmarks import kernels
        suites.append(("kernels", kernels.run))
    if not only or "throughput" in only:
        from benchmarks import throughput
        suites.append(("throughput", throughput.run))
    if not only or "roofline" in only:
        from benchmarks import roofline_report
        suites.append(("roofline", roofline_report.run))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn():
                print(row.csv())
                sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc(limit=2).splitlines()[-1]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
