"""Table 1: time for calculating ONE eigenvector component — NumPy's full
eigh (always computes the entire set) vs the paper's Algorithm 2 vs our JAX
ladder.  The paper reports speedup growing with n, up to 4.5x at n=600^2
(~on a 4-core Xeon); this container is 1-core, sizes are budget-scaled and
the *trend* (speedup grows with n, >1 beyond the crossover) is the claim
validated in EXPERIMENTS.md §Paper-validation."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sym, time_fn
from repro.core import identity, minors, numpy_ref

SIZES = (50, 100, 150, 200, 300, 400)


def _jax_component(variant: str):
    @jax.jit
    def fn(a, i, j):
        lam = jnp.linalg.eigvalsh(a)
        mu_j = jnp.linalg.eigvalsh(minors.minor(a, j))
        if variant == "logspace":
            return identity.component_logspace(lam, mu_j, i)
        return identity.component_parallel(lam, mu_j, i, batch_size=64)

    return fn


def run() -> list[Row]:
    """Also validates the paper's implicit cost model: Algorithm 2 costs two
    eigvalsh calls (A and the minor) + O(n) products, so its speedup over
    numpy eigh is ~ ratio/2 where ratio = t(eigh)/t(eigvalsh).  The paper's
    4.5x at n=600 implies ratio ~ 9 in its 2020 Windows/MKL environment; the
    ``model_fit`` column shows predicted-vs-measured under the ratio we
    measure here (EXPERIMENTS.md §Paper-validation)."""
    rows = []
    for n in SIZES:
        a = sym(n, n)
        i, j = n // 2, n // 3
        t_numpy = time_fn(numpy_ref.numpy_full_eigh, a, repeat=5)
        t_vals = time_fn(np.linalg.eigvalsh, a, repeat=5)
        ratio = t_numpy / t_vals
        t_alg2 = time_fn(numpy_ref.eigen_component_optimized, a, i, j,
                         repeat=5)
        aj = jnp.asarray(a)
        jfn = _jax_component("parallel")
        t_jax = time_fn(jfn, aj, i, j, repeat=5)
        jfn_log = _jax_component("logspace")
        t_jaxl = time_fn(jfn_log, aj, i, j, repeat=5)
        measured = t_numpy / t_alg2
        predicted = ratio / 2.0
        rows.append(Row(f"table1/numpy_eigh/n={n}", t_numpy,
                        f"eigh/eigvalsh_ratio={ratio:.2f}"))
        rows.append(Row(f"table1/alg2_numpy/n={n}", t_alg2,
                        f"speedup_vs_numpy={measured:.2f}x"
                        f" model_fit:pred={predicted:.2f}x"
                        f" meas={measured:.2f}x"))
        rows.append(Row(f"table1/alg2_jax/n={n}", t_jax,
                        f"speedup_vs_numpy={t_numpy / t_jax:.2f}x"))
        rows.append(Row(f"table1/eei_logspace_jax/n={n}", t_jaxl,
                        f"speedup_vs_numpy={t_numpy / t_jaxl:.2f}x"))
    return rows
