"""Serving throughput: batched solves/sec across SolverPlan choices.

    PYTHONPATH=src python -m benchmarks.throughput [--smoke] [--out PATH]

The paper's production regime is a *stream* of top-k queries over *stacks*
of matrices.  Pre-engine, serving b queries meant a Python loop over b
single-matrix solves; the engine runs one batched program per stack.  This
suite measures both (the loop is the baseline) for each plan the planner can
emit on this host: reference / fused-jnp / pallas-interpret backends, and
the sharded backend when >1 host device is available.

It also measures the kernel-grid change this repo's PR 2 made: the pallas
magnitudes stage as one natively batched 4-D ``(b, i, j, k)`` grid
(``eei_magnitudes_batched``) against the PR-1 baseline of ``jax.vmap`` over
the per-matrix 3-D kernel, on a ``(64, 64, 64)`` stack.

It also measures the serving runtime this repo's PR 3 added: the
continuous-batching ``EeiServer`` (shape buckets + program cache + async
double-buffered dispatch) against the synchronous per-request loop on the
same pre-generated mixed-shape stream.

It also sweeps the top-k axis through the stage-graph's windowed
composition (PR 5): at fixed ``n`` and k in {1, 4, 16}, the windowed path
(index-targeted Sturm window + minor-determinant components — no
minor-spectra stage) is served against the full-spectrum composition on
the same stream, written to ``BENCH_topk.json`` and *gated*: windowed must
beat full by >= 1.5x requests/s at k=1 (measured ~10-20x on the quiet
reference container).

It also exercises the threaded linger runtime (PR 4) on a *sparse* stream:
requests arrive with inter-arrival gaps and nothing calls ``flush()`` — the
background admission thread must dispatch partial stacks and resolve every
future (gated: zero unresolved futures), with requests/s and the sparse-pass
compile count recorded in ``BENCH_serve.json``.

``--krylov`` runs the PR-6 large-n lane *instead* of the standard suite:
the Lanczos partial reduce vs the dense Householder reduce through the
same windowed top-k chain (n up to 4096 — the dense leg alone is ~10
minutes there, which is why this is a separate slow CI job), validated
against a ``jnp.linalg.eigvalsh`` oracle, written to ``BENCH_krylov.json``
and *gated*: >= 3x wall-clock at (n=4096, k=16) plus the committed
baseline ratios - 20%.  It also runs the sign-from-ratio-parities probe
(measured, not fused — see docs/ARCHITECTURE.md).

``--fleet`` runs the PR-8 multi-replica lane *instead* of the standard
suite: requests/s of an ``EeiFleet`` of 3 subprocess replicas (each worker
pinned to one XLA host thread) vs N=1 on the same no-chaos stream, written
to ``BENCH_fleet.json`` and *gated*: >= 2x scaling on hosts with >= 3
cores (loudly skipped below that — the ratio only measures time-slicing
there) plus the committed baseline ratio - 20%, and a replica-chaos lane
(~8% kills) gated on zero lost / zero unflagged-garbage results with
kills actually fired and killed replicas restarted.

``--smoke`` runs one tiny config per backend plus the kernel-grid and
serve-mode comparisons, writes the ``BENCH_throughput.json`` and
``BENCH_serve.json`` artifacts, and exits non-zero if a gated metric
regresses more than 20% against the committed numbers in
``benchmarks/baselines/``.  The gated metrics are within-run ratios of
identical work (batched-vs-vmapped kernel speedup; continuous-batching vs
sync-loop requests/s), so they transfer across CI hardware, plus a hard
zero on steady-state recompiles in the warm server; the loop-normalized
engine throughput is recorded in the artifact but not gated — the
Python-loop baseline is dispatch-bound and too load-sensitive to gate on.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sym_stack, time_fn
from repro.engine import SolverEngine, SolverPlan

FULL_CONFIGS = [  # (batch, n, k)
    (8, 32, 4),
    (16, 64, 4),
    (32, 64, 8),
    (8, 128, 4),
]
SMOKE_CONFIGS = [(4, 16, 2)]

#: The kernel-grid comparison stack (acceptance config for the batched grid).
KERNEL_GRID_B, KERNEL_GRID_N = 64, 64

#: Serve-mode comparison stream (requests, n, k, max_batch): the
#: continuous-batching server vs the synchronous per-request loop on the
#: same mixed-shape stream.
SERVE_SMOKE = (96, 16, 4, 16)
SERVE_FULL = (512, 32, 8, 32)

#: Sparse-stream linger benchmark (requests, n, k, max_batch): the threaded
#: serving runtime on a stream with inter-arrival gaps — no flush() at all,
#: the linger admission thread dispatches partial stacks.
LINGER_SMOKE = (48, 16, 4, 8)
LINGER_FULL = (256, 32, 8, 32)
LINGER_MS = 2.0
LINGER_GAP_MS = 0.5  # mean inter-arrival sleep (exponential)

#: Windowed-composition top-k sweep (requests, n, max_batch): the windowed
#: stage composition vs the full-spectrum composition on the same fixed-n
#: request stream, at each serving-bucket k.
TOPK_SMOKE = (32, 32, 16)
TOPK_FULL = (96, 64, 16)
TOPK_KS = (1, 4, 16)
#: Hard floor on the k=1 windowed/full requests/s ratio (a within-run ratio
#: of identical work — it transfers across CI hardware).  The windowed
#: composition replaces the whole minor-spectra stage, so the quiet-machine
#: ratio sits far above this (~10-20x measured on the reference container).
TOPK_WINDOWED_K1_FLOOR = 1.5

#: Hard floor on the verify-on / verify-off requests/s ratio (a within-run
#: ratio on the identical stream).  The verify stage is a handful of
#: batched einsum/reduce ops fused into an already-compiled program, so
#: its measured overhead is low-single-digit percent; 0.95 is the ISSUE 7
#: acceptance bound (<= 5% requests/s cost for on-by-default result
#: verification).
VERIFY_OVERHEAD_FLOOR = 0.95

#: Krylov reduce benchmark (PR 6): Lanczos partial tridiagonalization vs
#: the dense Householder reduce on large-n top-k, both through the engine's
#: windowed chain.  ``(n, k)`` configs; the dense leg at n >= 2048 runs
#: once (it is the ~10-minute wall the Krylov stage exists to remove) and
#: is shared across that n's k configs.
KRYLOV_FULL = ((1024, 4), (1024, 16), (4096, 4), (4096, 16))
KRYLOV_SMOKE = ((256, 4),)
#: Hard wall-clock floor (ISSUE 6 acceptance): Krylov must beat the dense
#: reduce by at least this factor at the target config on the reference
#: container.  Measured headroom is ~60-90x, so 3x only trips on a real
#: regression.
KRYLOV_TARGET = (4096, 16)
KRYLOV_RATIO_FLOOR = 3.0
#: Oracle tolerance: max |lam - lam_oracle| / spectral span, and the
#: relative eigenpair residual |A v - lam v| / max|lam|, for every config.
KRYLOV_TOL = 5e-3

#: Sign-from-ratio-parities micro-benchmark (measure, don't fuse): the
#: windowed components stage already runs the forward Sturm ratio sweep,
#: whose ratio signs determine the eigenvector signs outright — fusing
#: would drop the separate recover recurrence.  This measures both legs on
#: the same bands so the follow-up has data.  (b, n, k) per mode.
PARITY_SMOKE = (16, 64, 4)
PARITY_FULL = (64, 256, 8)

#: Fleet scaling benchmark (PR 8): requests/s of an ``EeiFleet`` of N
#: subprocess replicas vs N=1 on the same no-chaos stream.  Each worker
#: pins XLA to one host thread (see ``repro.engine.fleet_worker``), so the
#: fleet scales by process parallelism; the stream cycles through several
#: coalesce keys so rendezvous routing spreads work across replicas.
#: ``(reqs_per_key, ns)`` — every (n, largest=True) pair is one key.
FLEET_SMOKE = (3, (48, 64, 80, 96))
FLEET_FULL = (6, (40, 48, 56, 64, 72, 80, 88, 96))
FLEET_REPLICAS = 3
FLEET_K = 4
#: Hard floor on the N=3 / N=1 requests/s ratio (ISSUE 8 acceptance).
#: Only enforced when the host has >= FLEET_REPLICAS cores — on fewer
#: cores the processes time-slice one another and the ratio is
#: meaningless (the run logs the skip loudly instead).
FLEET_SCALING_FLOOR = 2.0
#: Chaos lane: replica-level fault rates for the kill/restart gate.
FLEET_CHAOS_KILL_RATE = 0.08
FLEET_CHAOS_REQUESTS = 40

#: Packed ragged dispatch benchmark (PR 9): a previously-unseen ragged
#: small-n stream (the pad-heavy regime packing exists for) served through
#: pack="never" vs pack="always" with a cold ProgramCache each, arriving in
#: bursts (flush-paced waves, so partial groups form without sleep-dominated
#: timing).  The timed window deliberately INCLUDES program compiles: on
#: ragged traffic the bucketed path compiles one program per distinct
#: (b, n, k, largest) footprint while packing collapses every small n into
#: a couple of fixed-row-shape programs, and that compile collapse — plus
#: ~5x fewer launches — is where the serving win lives.
#: ``(requests, n_lo, n_hi, burst, max_batch)`` per mode.
PACKED_SMOKE = (96, 8, 48, 24, 8)
PACKED_FULL = (192, 8, 56, 24, 8)
PACKED_ROW_N = 64
#: Hard floor on the packed/bucketed cold-stream requests/s ratio (ISSUE 9
#: acceptance: >= 1.3x on the mixed small-n stream).  Measured 2.3-4.7x on
#: the reference container (the spread depends on how much of the bucketed
#: footprint space earlier lanes in the same process already compiled).
PACKED_SPEEDUP_FLOOR = 1.3

#: Streaming update benchmark (PR 10): per-update latency of a
#: ``SpectralSession``'s warm rank-1 path vs re-solving the updated matrix
#: from scratch (what a stateless server pays per update).  Each config
#: streams small rank-1 updates through one session — every step validated
#: against a float64 ``eigvalsh`` oracle — then injects two large updates
#: that must trip the drift monitor into a full re-solve (the gate asserts
#: the monitor actually fired; a stream that never re-solves proves
#: nothing about staleness safety).  ``(n, k)`` per config.
UPDATE_CONFIGS = ((64, 4), (64, 8), (256, 4), (256, 8))
#: Warm updates in the timed stream per config (smoke halves it).
UPDATE_STREAM = 12
#: Hard floor on the scratch/warm per-update ratio at the target config
#: (ISSUE 10 acceptance asks >= 5x there; measured headroom is well above,
#: so 3x only trips on a real regression), plus the committed-baseline
#: regression gate on every config's ratio.
UPDATE_TARGET = (256, 8)
UPDATE_RATIO_FLOOR = 3.0
#: Oracle tolerance: max |lam - lam_oracle| / spectral span per update.
UPDATE_TOL = 5e-3

BASELINE_PATH = Path(__file__).parent / "baselines" / "throughput_smoke.json"
SERVE_BASELINE_PATH = Path(__file__).parent / "baselines" / "serve_smoke.json"
KRYLOV_BASELINE_PATH = Path(__file__).parent / "baselines" / "krylov.json"
FLEET_BASELINE_PATH = Path(__file__).parent / "baselines" / "fleet_smoke.json"
ROBUST_BASELINE_PATH = Path(__file__).parent / "baselines" / "robust_smoke.json"
PACKED_BASELINE_PATH = Path(__file__).parent / "baselines" / "packed_smoke.json"
UPDATE_BASELINE_PATH = Path(__file__).parent / "baselines" / "update_smoke.json"

#: Allowed relative regression against the committed baseline metrics.
REGRESSION_TOLERANCE = 0.20


def _stack(b: int, n: int) -> jax.Array:
    return jnp.asarray(sym_stack(0, b, n))


def _plans(smoke: bool):
    plans = [
        ("jnp", SolverPlan(method="eei_tridiag", backend="jnp")),
        ("reference", SolverPlan(method="eei_tridiag", backend="reference")),
        ("pallas", SolverPlan(method="eei_tridiag", backend="pallas")),
    ]
    if not smoke:
        plans.append(("jnp_dense", SolverPlan(method="eei_dense",
                                              backend="jnp")))
        plans.append(("eigh", SolverPlan(method="eigh", backend="jnp")))
    if jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
        plans.append(("sharded", SolverPlan(
            method="eei_tridiag", backend="sharded", mesh=mesh)))
    return plans


def kernel_grid_comparison(metrics: dict) -> list[Row]:
    """Natively batched 4-D grid vs vmapped legacy 3-D grid (PR-1 baseline).

    Times only the magnitudes stage (where the grids differ) on a
    ``(KERNEL_GRID_B, n, n)`` stack's spectra.  Samples are interleaved and
    best-of-N so external machine load (the usual CI hazard) degrades both
    grids' windows alike instead of skewing the gated ratio.
    """
    import time as _time

    from repro.kernels.prod_diff import ops as pd_ops

    b, n = KERNEL_GRID_B, KERNEL_GRID_N
    rng = np.random.default_rng(0)
    lam = jnp.asarray(np.sort(
        rng.standard_normal((b, n)).astype(np.float32), axis=-1))
    mu = jnp.asarray(np.sort(
        rng.standard_normal((b, n, n - 1)).astype(np.float32), axis=-1))

    vmapped = jax.jit(jax.vmap(pd_ops.eei_magnitudes))

    def _once(fn):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(lam, mu))
        return (_time.perf_counter() - t0) * 1e6

    _once(vmapped), _once(pd_ops.eei_magnitudes_batched)  # compile
    samples = [(_once(vmapped), _once(pd_ops.eei_magnitudes_batched))
               for _ in range(5)]
    us_vmapped = min(s[0] for s in samples)
    us_batched = min(s[1] for s in samples)
    ratio = us_vmapped / us_batched
    metrics["batched_vs_vmapped_kernel_ratio"] = ratio
    return [
        Row(f"kernel_grid/vmapped_3d/b={b},n={n}", us_vmapped,
            "PR-1 baseline: vmap over per-matrix pallas_call"),
        Row(f"kernel_grid/batched_4d/b={b},n={n}", us_batched,
            f"one pallas_call, batch grid axis; speedup_vs_vmapped="
            f"{ratio:.2f}x"),
    ]


def serve_mode_comparison(metrics: dict, smoke: bool = False) -> list[Row]:
    """Continuous-batching EeiServer vs the synchronous per-request loop.

    Both paths serve the *same* pre-generated mixed-shape request stream
    with the *same* explicit plan.  Compiles happen in a warmup pass for
    both (the sync loop warms one b=1 program per distinct ``(n, k)``, the
    server warms one program per shape bucket); the timed pass measures
    steady-state serving.  The gated metric is the requests/s ratio — a
    within-run ratio on identical work, so it transfers across CI hardware.
    """
    import time as _time

    from repro.engine import EeiServer, SolverEngine, SolverPlan
    from repro.engine.server import make_eei_stream

    requests, n, k, max_batch = SERVE_SMOKE if smoke else SERVE_FULL
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    stream = make_eei_stream(requests, n, k, seed=0, mixed=True)

    # -- synchronous per-request loop (the PR-2 serving shape) -------------
    engine = SolverEngine(plan)
    shapes = sorted({(a.shape[0], k_i) for a, k_i in stream})
    for n_i, k_i in shapes:  # warm one b=1 program per distinct (n, k)
        jax.block_until_ready(
            engine.topk(jnp.zeros((n_i, n_i), jnp.float32), k_i))
    t0 = _time.perf_counter()
    for a, k_i in stream:
        jax.block_until_ready(engine.topk(jnp.asarray(a), k_i))
    sync_s = _time.perf_counter() - t0

    # -- continuous-batching server ----------------------------------------
    server = EeiServer(plan, max_batch=max_batch)
    for a, k_i in stream:  # warmup pass compiles one program per bucket
        server.submit(a, k_i)
    server.flush()
    warm = server.stats()
    server.reset_stats()
    t0 = _time.perf_counter()
    futs = [server.submit(a, k_i) for a, k_i in stream]
    server.flush()
    serve_s = _time.perf_counter() - t0
    assert all(f.done() for f in futs)
    stats = server.stats()

    ratio = sync_s / serve_s
    metrics["serve_requests_per_s"] = requests / serve_s
    metrics["sync_requests_per_s"] = requests / sync_s
    metrics["serve_vs_sync_ratio"] = ratio
    metrics["serve_p50_ms"] = stats["p50_latency_ms"]
    metrics["serve_p99_ms"] = stats["p99_latency_ms"]
    metrics["serve_program_compiles"] = warm["program_compiles"]
    metrics["serve_distinct_buckets"] = warm["distinct_buckets"]
    metrics["serve_steady_state_compiles"] = stats["program_compiles"]
    return [
        Row(f"serve/sync_loop/r={requests},n={n},k={k}", sync_s * 1e6,
            f"requests_per_s={requests / sync_s:.1f} (per-request "
            f"block_until_ready, {len(shapes)} b=1 programs)"),
        Row(f"serve/continuous_batching/r={requests},n={n},k={k}",
            serve_s * 1e6,
            f"requests_per_s={requests / serve_s:.1f} "
            f"speedup_vs_sync={ratio:.2f}x "
            f"compiles={warm['program_compiles']} "
            f"buckets={warm['distinct_buckets']} "
            f"p99_ms={stats['p99_latency_ms']:.1f}"),
    ]


def robust_serve_comparison(metrics: dict, smoke: bool = False) -> list[Row]:
    """Verification overhead: verify-on vs verify-off serving.

    Both servers serve the *same* pre-generated mixed-shape stream with
    the *same* plan; the only difference is the on-by-default ``verify``
    stage compiled into each bucket program (plus the per-row flag sync
    at retirement).  The gated metric is the verify-on / verify-off
    requests/s ratio — guarded serving must cost <= 5%
    (``VERIFY_OVERHEAD_FLOOR``).  Results land in ``BENCH_robust.json``.
    """
    import time as _time

    from repro.engine import EeiServer, SolverPlan
    from repro.engine.server import make_eei_stream

    requests, n, k, max_batch = SERVE_SMOKE if smoke else SERVE_FULL
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    stream = make_eei_stream(requests, n, k, seed=5, mixed=True)

    rows, rps = [], {}
    for label, verify in (("verify_off", False), ("verify_on", True)):
        server = EeiServer(plan, max_batch=max_batch, verify=verify)
        for a, k_i in stream:  # warmup pass compiles one program per bucket
            server.submit(a, k_i)
        server.flush()
        server.reset_stats()
        t0 = _time.perf_counter()
        futs = [server.submit(a, k_i) for a, k_i in stream]
        server.flush()
        dt = _time.perf_counter() - t0
        assert all(f.done() for f in futs)
        stats = server.stats()
        assert stats["program_compiles"] == 0  # warm either way
        rps[label] = requests / dt
        rows.append(Row(
            f"robust/{label}/r={requests},n={n},k={k}", dt * 1e6,
            f"requests_per_s={requests / dt:.1f} "
            f"verify_failed={stats['verify_failed']} "
            f"degraded={stats['requests_degraded']}"))
        metrics[f"robust_{label}_requests_per_s"] = requests / dt
        if verify:
            # Observational: a healthy random stream should sail through,
            # but a rare near-degenerate draw degrading is correct
            # behavior, not a benchmark failure.
            metrics["robust_verify_failed"] = stats["verify_failed"]
            metrics["robust_requests_degraded"] = stats["requests_degraded"]
    ratio = rps["verify_on"] / rps["verify_off"]
    metrics["robust_verify_overhead_ratio"] = ratio
    rows[-1].derived += f" overhead_ratio={ratio:.3f}"
    return rows


def topk_sweep_comparison(metrics: dict, smoke: bool = False) -> list[Row]:
    """Windowed vs full-spectrum serving across the top-k axis.

    Both paths serve the *same* fixed-``n`` pre-generated stream through
    ``EeiServer`` with pinned plans differing only in ``plan.spectrum``.
    The windowed composition (index-targeted Sturm window + minor-
    determinant components — no minor-spectra stage) must beat the
    full-spectrum composition by ``TOPK_WINDOWED_K1_FLOOR`` at ``k=1``;
    the k=4/k=16 ratios are recorded ungated.  Results land in
    ``BENCH_topk.json``.
    """
    import time as _time

    from repro.engine import EeiServer, SolverPlan
    from repro.engine.server import make_eei_stream

    requests, n, max_batch = TOPK_SMOKE if smoke else TOPK_FULL
    rows = []
    for k in TOPK_KS:
        stream = make_eei_stream(requests, n, min(k, n), seed=2 + k)
        rps = {}
        for spectrum in ("full", "windowed"):
            plan = SolverPlan(method="eei_tridiag", backend="jnp",
                              spectrum=spectrum)
            server = EeiServer(plan, max_batch=max_batch)
            for a, k_i in stream:  # warmup pass compiles the bucket
                server.submit(a, k_i)
            server.flush()
            server.reset_stats()
            t0 = _time.perf_counter()
            futs = [server.submit(a, k_i) for a, k_i in stream]
            server.flush()
            dt = _time.perf_counter() - t0
            assert all(f.done() for f in futs)
            assert server.stats()["program_compiles"] == 0  # warm
            rps[spectrum] = requests / dt
            rows.append(Row(
                f"topk/{spectrum}/r={requests},n={n},k={k}", dt * 1e6,
                f"requests_per_s={requests / dt:.1f}"))
        ratio = rps["windowed"] / rps["full"]
        metrics[f"topk_windowed_vs_full_k{k}_ratio"] = ratio
        metrics[f"topk_windowed_k{k}_requests_per_s"] = rps["windowed"]
        metrics[f"topk_full_k{k}_requests_per_s"] = rps["full"]
        rows[-1].derived += f" speedup_vs_full={ratio:.2f}x"
    return rows


def linger_serve_comparison(metrics: dict, smoke: bool = False) -> list[Row]:
    """Sparse-stream serving through the threaded linger runtime.

    The stream arrives with inter-arrival gaps (exponential, mean
    ``LINGER_GAP_MS``) and nothing ever calls ``flush()``: the background
    admission thread must dispatch partial stacks after ``LINGER_MS`` and
    resolve every future.  The gated metric is liveness —
    ``linger_unresolved_futures`` must be zero (a linger/locking regression
    shows up as a stranded or timed-out future, not as a slow ratio).
    Requests/s is recorded but not gated: a sparse stream's throughput is
    dominated by the injected gaps.
    """
    import time as _time
    from concurrent.futures import TimeoutError as FuturesTimeoutError

    from repro.engine import EeiServer, SolverPlan
    from repro.engine.server import make_eei_stream

    requests, n, k, max_batch = LINGER_SMOKE if smoke else LINGER_FULL
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    stream = make_eei_stream(requests, n, k, seed=1, mixed=True)

    server = EeiServer(plan, max_batch=max_batch, linger_ms=LINGER_MS)
    gap_s = LINGER_GAP_MS / 1e3
    # Warm with the *same* sparse arrival schedule: partial-stack buckets
    # (small pow2 b) only form under sparse arrivals, so a dense warm pass
    # would leave them cold and the timed pass would measure compiles.
    rng = np.random.default_rng(0)
    for a, k_i in stream:
        _time.sleep(rng.exponential(gap_s))
        server.submit(a, k_i)
    server.flush()
    server.reset_stats()

    rng = np.random.default_rng(0)
    t0 = _time.perf_counter()
    futs = []
    for a, k_i in stream:
        _time.sleep(rng.exponential(gap_s))
        futs.append(server.submit(a, k_i))
    unresolved = failed = 0
    for f in futs:
        try:
            f.result(timeout=120)
        except FuturesTimeoutError:
            unresolved += 1  # truly stranded: linger/locking regression
        except Exception:
            failed += 1  # resolved with an error: a request failure
    dt = _time.perf_counter() - t0
    stats = server.stats()
    server.close()

    metrics["linger_requests_per_s"] = requests / dt
    metrics["linger_p50_ms"] = stats["p50_latency_ms"]
    metrics["linger_p99_ms"] = stats["p99_latency_ms"]
    metrics["linger_stacks_dispatched"] = stats["stacks_dispatched"]
    # Sparse arrivals form partial stacks the dense warm pass never shaped
    # (different pow2 b), so a few compiles are legitimate here — recorded,
    # not gated (unlike the dense steady-state gate above).
    metrics["linger_sparse_pass_compiles"] = stats["program_compiles"]
    metrics["linger_unresolved_futures"] = unresolved
    metrics["linger_failed_requests"] = failed
    return [
        Row(f"serve/linger_sparse/r={requests},n={n},k={k}", dt * 1e6,
            f"requests_per_s={requests / dt:.1f} "
            f"linger_ms={LINGER_MS} gap_ms={LINGER_GAP_MS} "
            f"stacks={stats['stacks_dispatched']} "
            f"p99_ms={stats['p99_latency_ms']:.1f} (no flush; "
            f"admission thread dispatches partial stacks)"),
    ]


def packed_serve_comparison(metrics: dict, smoke: bool = False) -> list[Row]:
    """Packed ragged dispatch vs shape-bucketed dispatch on cold ragged
    traffic.

    Both modes serve the *same* pre-generated ragged small-n stream
    (uniform n, mixed k and largest — the fragmented regime that splinters
    the bucketed path across many coalesce keys) through servers with
    *cold* ProgramCaches, arriving in flush-paced bursts.  The timed
    window includes compiles on purpose: a previously-unseen ragged
    stream is exactly where bucketing pays one compile per footprint and
    packing pays a couple total, and that — plus the launch-count
    collapse — is the packed serving win.  Gated metrics:

    - ``packed_vs_bucketed_cold_ratio`` >= :data:`PACKED_SPEEDUP_FLOOR`
      (plus the committed-baseline regression gate),
    - ``packed_program_compiles`` strictly below the bucketed count,
    - ``packed_oracle_failures`` == 0 (every packed eigenvalue set is
      checked against ``np.linalg.eigvalsh`` on the unpadded matrix).

    Pad-waste fractions are recorded, not cross-gated: the packed cell
    fraction sits *above* the bucketed one by construction (a
    block-diagonal row is charged quadratically for its structural
    off-block zeros) — see ``EeiServer.stats()``.
    """
    import time as _time

    from repro.engine import EeiServer
    from repro.engine.server import ProgramCache

    requests, n_lo, n_hi, burst, max_batch = (
        PACKED_SMOKE if smoke else PACKED_FULL)
    rng = np.random.default_rng(9)
    stream = []
    for _ in range(requests):
        n_i = int(rng.integers(n_lo, n_hi + 1))
        a = rng.standard_normal((n_i, n_i)).astype(np.float32)
        k_i = int(rng.integers(1, min(4, n_i) + 1))
        stream.append(((a + a.T) / 2, k_i, bool(rng.integers(0, 2))))

    rows, rps, oracle_failures = [], {}, 0
    for mode in ("never", "always"):
        server = EeiServer(max_batch=max_batch, pack=mode,
                           pack_row_n=PACKED_ROW_N, cache=ProgramCache())
        t0 = _time.perf_counter()
        futs = []
        for i in range(0, len(stream), burst):
            for a, k_i, lg in stream[i:i + burst]:
                futs.append(server.submit(a, k_i, largest=lg))
            server.flush()
        dt = _time.perf_counter() - t0
        assert all(f.done() for f in futs)
        stats = server.stats()
        server.close()
        if mode == "always":
            for (a, k_i, lg), f in zip(stream, futs):
                lam = np.sort(np.asarray(f.result().eigenvalues,
                                         np.float64))
                w = np.linalg.eigvalsh(np.asarray(a, np.float64))
                ref = np.sort(w[-k_i:] if lg else w[:k_i])
                scale = max(1.0, float(np.max(np.abs(w))))
                if np.max(np.abs(lam - ref)) > 5e-4 * scale:
                    oracle_failures += 1
        label = "packed" if mode == "always" else "bucketed"
        rps[label] = requests / dt
        metrics[f"{label}_cold_requests_per_s"] = requests / dt
        metrics[f"{label}_program_compiles"] = stats["program_compiles"]
        metrics[f"{label}_stacks_dispatched"] = stats["stacks_dispatched"]
        metrics[f"{label}_pad_waste_frac"] = stats["pad_waste_frac"]
        metrics[f"{label}_failed_requests"] = stats["requests_failed"]
        rows.append(Row(
            f"serve/ragged_{label}/r={requests},n={n_lo}..{n_hi}", dt * 1e6,
            f"requests_per_s={requests / dt:.1f} "
            f"compiles={stats['program_compiles']} "
            f"buckets={stats['distinct_buckets']} "
            f"stacks={stats['stacks_dispatched']} "
            f"pad_waste={stats['pad_waste_frac']:.2f} (cold cache; "
            f"burst={burst})"))
    ratio = rps["packed"] / rps["bucketed"]
    metrics["packed_vs_bucketed_cold_ratio"] = ratio
    metrics["packed_oracle_failures"] = oracle_failures
    rows[-1].derived += f" speedup_vs_bucketed={ratio:.2f}x"
    return rows


def krylov_benchmark(metrics: dict, smoke: bool = False) -> list[Row]:
    """Krylov (Lanczos) partial reduce vs dense Householder reduce.

    Both legs run the engine's windowed top-k chain end-to-end and differ
    only in the reduce stage; both are AOT-compiled (``lower().compile()``)
    so neither pays compile time in the timed window, and the dense leg at
    n >= 2048 executes exactly once (one run is ~10 minutes at n = 4096 —
    the wall this stage removes).  Every config is validated against a
    ``jnp.linalg.eigvalsh`` oracle; ``krylov_oracle_failures`` counts
    configs outside :data:`KRYLOV_TOL` and gates the run.
    """
    import time as _time

    configs = KRYLOV_SMOKE if smoke else KRYLOV_FULL
    rows = []
    failures = 0
    dense_cache = {}  # n -> seconds (shared across that n's k configs)

    def _aot(plan, a, k):
        from repro.engine.engine import topk_program

        return topk_program(plan, k, True).lower(a).compile()

    def _best_of(fn, a, repeat):
        times = []
        for _ in range(repeat):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(a))
            times.append(_time.perf_counter() - t0)
        return min(times)

    for n, k in configs:
        rng = np.random.default_rng(n + k)
        a = rng.standard_normal((n, n)).astype(np.float32)
        a = jnp.asarray((a + a.T) / 2)[None]  # (1, n, n) stack

        krylov = _aot(SolverPlan(method="eei_krylov", backend="jnp"), a, k)
        t_krylov = _best_of(krylov, a, repeat=3)

        if n not in dense_cache:
            dense = _aot(SolverPlan(method="eei_tridiag", backend="jnp",
                                    spectrum="windowed"), a, k)
            dense_cache[n] = _best_of(dense, a, repeat=1)
        t_dense = dense_cache[n]

        # Oracle validation of the krylov leg (the dense leg is covered by
        # the tier-1 conformance tests).
        out = krylov(a)
        lam = np.linalg.eigvalsh(np.asarray(a[0], np.float64))
        span = float(lam[-1] - lam[0])
        lam_k = np.asarray(out.eigenvalues[0], np.float64)
        vecs = np.asarray(out.vectors[0], np.float64)
        relerr = float(np.max(np.abs(lam_k - lam[-k:]))) / span
        res = np.linalg.norm(
            np.asarray(a[0], np.float64) @ vecs.T - vecs.T * lam_k[None, :],
            axis=0)
        res_rel = float(np.max(res)) / float(np.max(np.abs(lam)))
        ok = relerr <= KRYLOV_TOL and res_rel <= KRYLOV_TOL
        failures += 0 if ok else 1

        ratio = t_dense / t_krylov
        metrics[f"krylov_vs_dense_n{n}_k{k}_ratio"] = ratio
        metrics[f"krylov_n{n}_k{k}_s"] = t_krylov
        metrics[f"dense_n{n}_k{k}_s"] = t_dense
        metrics[f"krylov_n{n}_k{k}_relerr"] = relerr
        metrics[f"krylov_n{n}_k{k}_res_rel"] = res_rel
        rows.append(Row(
            f"krylov/dense_reduce/n={n},k={k}", t_dense * 1e6,
            "windowed chain behind the dense Householder reduce"))
        rows.append(Row(
            f"krylov/lanczos_reduce/n={n},k={k}", t_krylov * 1e6,
            f"speedup_vs_dense={ratio:.1f}x relerr={relerr:.1e} "
            f"res_rel={res_rel:.1e} oracle_ok={ok}"))
    metrics["krylov_oracle_failures"] = failures
    return rows


def update_benchmark(metrics: dict, smoke: bool = False) -> list[Row]:
    """Warm rank-1 session updates vs from-scratch re-solves (PR 10).

    Per config: open a ``SpectralSession`` over a random symmetric matrix,
    stream :data:`UPDATE_STREAM` small rank-1 updates through the warm
    path (each sized ~1% of ``||A||_F`` so the drift monitor stays green),
    and time each ``engine.update`` end-to-end — program call plus the
    host-side verify leg, i.e. what a serving caller actually pays.  The
    scratch leg times ``engine.topk(a_t, k)`` on the same accumulated
    matrices: the stateless per-update cost the session replaces (and the
    cheaper window — ``k``, not the session's ``k + buffer`` — so the
    ratio is conservative).  Every step is validated against a float64
    ``eigvalsh`` oracle; two large injected updates at the end must trip
    the drift monitor into a verified full re-solve.
    """
    import time as _time

    from repro.engine import Rank1Update, SessionConfig, SolverEngine

    stream = max(UPDATE_STREAM // 2, 4) if smoke else UPDATE_STREAM
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    rows = []
    oracle_failures = 0
    for n, k in UPDATE_CONFIGS:
        rng = np.random.default_rng(n + k)
        a_np = rng.standard_normal((n, n))
        a_np = (a_np + a_np.T) / 2
        engine = SolverEngine(plan)
        session = engine.open_session(
            a_np, k, config=SessionConfig(drift_bound=0.5))
        fro = float(np.linalg.norm(a_np))
        small = np.sqrt(0.01 * fro / n)  # |rho| ~ 1% of ||A||_F per step
        span0 = None

        def _check(res, a_now):
            nonlocal oracle_failures, span0
            lam = np.linalg.eigvalsh(a_now)
            if span0 is None:
                span0 = float(lam[-1] - lam[0])
            got = np.asarray(res.eigenvalues, np.float64)
            relerr = float(np.max(np.abs(got - lam[-k:]))) / span0
            if relerr > UPDATE_TOL:
                oracle_failures += 1
            return relerr

        # Untimed warmup: compiles the update program (the open_session
        # full solve already compiled the m_keep topk program).
        for _ in range(2):
            u = rng.standard_normal(n) * small
            a_np = a_np + np.outer(u, u)
            engine.update(session, Rank1Update(u, 1))

        fast_before = session.stats()["fast_updates"]
        warm_s, relerrs = [], []
        for _ in range(stream):
            u = rng.standard_normal(n) * small
            a_np = a_np + np.outer(u, u)
            t0 = _time.perf_counter()
            res = engine.update(session, Rank1Update(u, 1))
            warm_s.append(_time.perf_counter() - t0)
            relerrs.append(_check(res, a_np))
        assert session.stats()["fast_updates"] - fast_before == stream, \
            "the 'warm' stream fell off the fast path — timings are solves"

        # Drift trip: two updates at ~60% of ||A||_F each must force the
        # monitor into a full re-solve (staleness safety, exercised here
        # so the artifact proves the monitor fires, not just that it
        # exists).
        big = np.sqrt(0.6 * fro / n)
        for _ in range(2):
            u = rng.standard_normal(n) * big
            a_np = a_np + np.outer(u, u)
            res = engine.update(session, Rank1Update(u, 1))
            relerrs.append(_check(res, a_np))
        drift_resolves = session.stats()["resolves_by_cause"].get("drift", 0)

        # Scratch leg: per-update cost without a session, on the stream's
        # final matrix (shape-identical work; the value does not matter).
        scratch_s = []
        aj = jnp.asarray(a_np, jnp.float32)
        jax.block_until_ready(engine.topk(aj, k).eigenvalues)  # warm
        for _ in range(max(stream // 2, 3)):
            t0 = _time.perf_counter()
            jax.block_until_ready(engine.topk(aj, k).eigenvalues)
            scratch_s.append(_time.perf_counter() - t0)

        warm_us = float(np.median(warm_s) * 1e6)
        scratch_us = float(np.median(scratch_s) * 1e6)
        ratio = scratch_us / warm_us
        metrics[f"update_vs_scratch_n{n}_k{k}_ratio"] = ratio
        metrics[f"update_warm_n{n}_k{k}_us"] = warm_us
        metrics[f"scratch_n{n}_k{k}_us"] = scratch_us
        metrics[f"update_relerr_n{n}_k{k}"] = float(np.max(relerrs))
        metrics[f"update_drift_resolves_n{n}_k{k}"] = drift_resolves
        rows.append(Row(
            f"update/scratch_topk/n={n},k={k}", scratch_us,
            "stateless per-update re-solve (engine.topk on the updated "
            "matrix)"))
        rows.append(Row(
            f"update/warm_session/n={n},k={k}", warm_us,
            f"speedup_vs_scratch={ratio:.1f}x "
            f"relerr={float(np.max(relerrs)):.1e} "
            f"drift_resolves={drift_resolves}"))
    metrics["update_oracle_failures"] = oracle_failures
    return rows


def fleet_benchmark(metrics: dict, smoke: bool = False) -> list[Row]:
    """Multi-replica fleet scaling + the chaos kill/restart lane (PR 8).

    **Scaling**: the same no-chaos stream through an ``EeiFleet`` of 1 and
    of :data:`FLEET_REPLICAS` *subprocess* replicas (each worker pinned to
    one XLA host thread).  An untimed warm pass per fleet compiles every
    bucket first (``max_batch=1`` makes bucketing deterministic, so the
    timed pass is fully warm).  ``fleet_n3_vs_n1_ratio`` is a within-run
    ratio of identical work — it transfers across CI hardware, but only
    means anything with >= FLEET_REPLICAS cores.

    **Chaos lane**: an in-process N=3 fleet serving a mixed stream at
    ~8% replica kills (+hangs/slowdowns): the gates assert zero lost and
    zero unflagged-garbage results, that kills actually fired, and that
    killed replicas restarted and the tail completed.
    """
    import os as _os
    import time as _time

    from repro.engine import EeiFleet, verify_topk_host
    from repro.runtime import ChaosConfig, ChaosMonkey

    reqs_per_key, ns = FLEET_SMOKE if smoke else FLEET_FULL
    rng = np.random.default_rng(0)
    mats = {n: np.asarray((m := rng.standard_normal((n, n)).astype(
        np.float32)) + m.T) / 2 for n in ns}
    # Pick the salt that spreads the keys most evenly over the replicas
    # (deterministic: route_key is a pure function).
    from repro.runtime import route_key
    rids = list(range(FLEET_REPLICAS))

    def _imbalance(salt):
        loads = [0] * FLEET_REPLICAS
        for n in ns:
            loads[route_key((n, True), rids, salt)] += 1
        return max(loads)

    salt = min(range(64), key=_imbalance)

    def _pass(n_replicas: int) -> float:
        fleet = EeiFleet(
            n_replicas, replica_mode="subprocess", salt=salt,
            server_kwargs=dict(max_batch=1, max_inflight=2, linger_ms=2.0),
            deadline_s=600.0)
        try:
            for warm in range(2):  # pass 0 compiles; pass 1 is timed
                t0 = _time.perf_counter()
                futs = [fleet.submit(mats[n], FLEET_K)
                        for _ in range(reqs_per_key) for n in ns]
                assert fleet.flush(timeout=1200), "fleet pass wedged"
                dt = _time.perf_counter() - t0
                assert all(f.exception() is None for f in futs)
        finally:
            fleet.close(timeout=120)
        return dt

    total = reqs_per_key * len(ns)
    t1 = _pass(1)
    tn = _pass(FLEET_REPLICAS)
    rps1, rpsn = total / t1, total / tn
    ratio = rpsn / rps1
    cores = _os.cpu_count() or 1
    metrics["fleet_n1_requests_per_s"] = rps1
    metrics[f"fleet_n{FLEET_REPLICAS}_requests_per_s"] = rpsn
    metrics[f"fleet_n{FLEET_REPLICAS}_vs_n1_ratio"] = ratio
    metrics["fleet_host_cores"] = cores
    rows = [
        Row(f"fleet/n=1/r={total}", t1 / total * 1e6,
            f"requests_per_s={rps1:.1f} (subprocess replica, 1-thread XLA)"),
        Row(f"fleet/n={FLEET_REPLICAS}/r={total}", tn / total * 1e6,
            f"requests_per_s={rpsn:.1f} scaling_vs_n1={ratio:.2f}x "
            f"salt={salt} cores={cores}"),
    ]

    # -- chaos kill/restart lane (in-process: kills are driver deaths) ----
    chaos = ChaosMonkey(ChaosConfig(
        seed=7, rate=0.0, replica_kill_rate=FLEET_CHAOS_KILL_RATE,
        replica_hang_rate=FLEET_CHAOS_KILL_RATE / 2,
        replica_slow_rate=FLEET_CHAOS_KILL_RATE,
        replica_slow_s=0.005, replica_hang_s=0.3))
    fleet = EeiFleet(
        FLEET_REPLICAS, server_kwargs=dict(max_batch=4, linger_ms=2.0),
        chaos=chaos, probe_interval_s=0.01, deadline_s=60.0,
        restart_policy_kwargs=dict(max_restarts=10_000, base_delay_s=0.01,
                                   cap_s=0.1))
    n_chaos = FLEET_CHAOS_REQUESTS if not smoke else FLEET_CHAOS_REQUESTS // 2
    stream = []
    t0 = _time.perf_counter()
    try:
        for i in range(n_chaos):
            n = int(rng.integers(6, 17))
            a = rng.standard_normal((n, n)).astype(np.float32)
            a = (a + a.T) / 2
            stream.append((a, fleet.submit(a, 2)))
        garbage = 0
        for a, f in stream:
            res = f.result(timeout=300)
            flags = verify_topk_host(a, np.asarray(res.eigenvalues),
                                     np.asarray(res.vectors))
            garbage += 0 if float(flags.residual) <= 2e-2 else 1
    finally:
        stranded = fleet.close(timeout=300)
    dt = _time.perf_counter() - t0
    stats = fleet.stats()
    metrics["fleet_chaos_requests"] = n_chaos
    metrics["fleet_chaos_unresolved"] = len(stranded) + \
        stats["requests_unresolved"]
    metrics["fleet_chaos_failed"] = stats["requests_failed"]
    metrics["fleet_chaos_garbage"] = garbage
    metrics["fleet_chaos_kills"] = stats["replicas_killed"]
    metrics["fleet_chaos_restarts"] = stats["replicas_restarted"]
    metrics["fleet_chaos_redispatches"] = stats["redispatches"]
    rows.append(Row(
        f"fleet/chaos/r={n_chaos}", dt / n_chaos * 1e6,
        f"kills={stats['replicas_killed']} "
        f"restarts={stats['replicas_restarted']} "
        f"redispatches={stats['redispatches']} "
        f"unresolved={metrics['fleet_chaos_unresolved']} "
        f"garbage={garbage} (kill rate {FLEET_CHAOS_KILL_RATE})"))
    return rows


def parity_sign_probe(metrics: dict, smoke: bool = False) -> list[Row]:
    """Measure (don't fuse): recover-stage sign recurrence vs extracting
    eigenvector signs from the ratio parities of the forward Sturm sweep
    the components stage already runs.

    For a tridiagonal eigenvector at ``x``, ``w_j`` is proportional to
    ``(-1)^{j-1} f_{j-1}(x) / prod_{l<j} e_l`` (leading-principal-minor
    char polys), so ``sign(w_j) = prod_{l<j} -sign(q_l) sign(e_l)`` — a
    cumprod over the exact ratios ``q_l`` that ``tridiag_minor_logdets``
    computes anyway.
    Fusing would delete the recover recurrence; this records the measured
    headroom and the sign-agreement rate so the ROADMAP follow-up has data
    before committing.  Results summarized in ``docs/ARCHITECTURE.md``.
    """
    from repro.core import identity
    from repro.core.directions import tridiagonal_signs
    from repro.linalg import householder, sturm

    b, n, k = PARITY_SMOKE if smoke else PARITY_FULL
    a = _stack(b, n)
    d, e, _ = householder.tridiagonalize_batched(a, with_q=False)
    lam_sel = sturm.bisect_eigenvalues_windowed_batched(d, e, k, largest=True)
    mags = identity.tridiag_windowed_magnitudes_batched(d, e, lam_sel)

    def _parity_signs(d1, e1, x):
        # The same clamped forward ratio sweep as tridiag_minor_logdets.
        eps = jnp.finfo(d1.dtype).eps
        scale = jnp.maximum(jnp.max(jnp.abs(d1)), jnp.max(jnp.abs(e1)))
        pivmin = jnp.maximum(eps * eps * scale * scale,
                             jnp.finfo(d1.dtype).tiny)
        e2 = e1 * e1

        def fwd(q, de):
            dl, e2l = de
            q = dl - x - e2l / q
            q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
            return q, q

        q1 = d1[0] - x
        q1 = jnp.where(jnp.abs(q1) < pivmin, -pivmin, q1)
        _, q_rest = jax.lax.scan(fwd, q1, (d1[1:n - 1], e2[: n - 2]))
        q_all = jnp.concatenate([q1[None], q_rest])  # (n-1, kk)
        step = -jnp.sign(q_all) * jnp.sign(e1)[:, None]
        csign = jnp.cumprod(step, axis=0)
        return jnp.concatenate(
            [jnp.ones((1,) + x.shape, d1.dtype), csign]).T  # (kk, n)

    recurrence = jax.jit(
        lambda dd, ee, ll, mm: jnp.sign(jax.vmap(
            jax.vmap(tridiagonal_signs, in_axes=(None, None, 0, 0))
        )(dd, ee, ll, mm)))
    parity = jax.jit(jax.vmap(_parity_signs))

    us_rec = time_fn(recurrence, d, e, lam_sel, mags, repeat=5, warmup=1)
    us_par = time_fn(parity, d, e, lam_sel, repeat=5, warmup=1)

    # Sign agreement up to a global per-row flip, weighted to components
    # large enough for their sign to be well-defined.
    s_rec = np.asarray(recurrence(d, e, lam_sel, mags))
    s_par = np.asarray(parity(d, e, lam_sel))
    m = np.asarray(mags)
    anchor = np.argmax(m, axis=-1)
    ai = np.take_along_axis
    flip = (ai(s_rec, anchor[..., None], -1) *
            ai(s_par, anchor[..., None], -1))
    significant = m > 1e-6 * np.max(m, axis=-1, keepdims=True)
    agree = (s_rec == s_par * flip) | ~significant
    agreement = float(np.sum(agree)) / agree.size

    ratio = us_rec / us_par
    metrics["parity_vs_recurrence_sign_ratio"] = ratio
    metrics["parity_sign_agreement"] = agreement
    return [
        Row(f"signs/recover_recurrence/b={b},n={n},k={k}", us_rec,
            "the current recover stage (three-term recurrence + signs)"),
        Row(f"signs/ratio_parities/b={b},n={n},k={k}", us_par,
            f"cumprod over the components stage's own ratio sweep; "
            f"speedup_vs_recurrence={ratio:.2f}x "
            f"sign_agreement={agreement:.4f} (measured, not fused)"),
    ]


def run(smoke: bool = False) -> tuple[list[Row], dict]:
    rows = []
    metrics: dict = {}
    configs = SMOKE_CONFIGS if smoke else FULL_CONFIGS
    for b, n, k in configs:
        a = _stack(b, n)
        for name, plan in _plans(smoke):
            if plan.backend == "sharded" and b % plan.batch_axis_size:
                continue
            engine = SolverEngine(plan)
            us = time_fn(engine.topk, a, k, repeat=3, warmup=1)
            rows.append(Row(
                f"throughput/{name}/b={b},n={n},k={k}", us,
                f"solves_per_s={b / (us * 1e-6):.1f}"))
            # Gate metrics are well-defined only for the single smoke config
            # (a full run would mix throughputs across configs).
            if smoke and name == "pallas":
                metrics["pallas_solves_per_s"] = b / (us * 1e-6)
        # Baseline: the pre-engine Python loop over single-matrix solves.
        loop_engine = SolverEngine(SolverPlan(method="eei_tridiag",
                                              backend="jnp"))

        def solve_loop(stack):
            return [loop_engine.topk(stack[i], k) for i in range(b)]

        us = time_fn(solve_loop, a, repeat=3, warmup=1)
        rows.append(Row(
            f"throughput/python_loop/b={b},n={n},k={k}", us,
            f"solves_per_s={b / (us * 1e-6):.1f} (pre-engine baseline)"))
        if smoke:
            metrics["loop_solves_per_s"] = b / (us * 1e-6)
    rows.extend(kernel_grid_comparison(metrics))
    if "pallas_solves_per_s" in metrics and "loop_solves_per_s" in metrics:
        # Hardware-independent gate metric: batched pallas throughput in
        # units of the in-process Python-loop baseline.
        metrics["pallas_vs_loop_ratio"] = (
            metrics["pallas_solves_per_s"] / metrics["loop_solves_per_s"])
    return rows, metrics


def check_regression(
    metrics: dict, baseline_path: Path, keys: tuple
) -> list[str]:
    """Compare gate metrics against the committed baseline (>20% fails)."""
    if not baseline_path.is_file():
        print(f"# no baseline at {baseline_path}; skipping regression gate")
        return []
    base = json.loads(baseline_path.read_text())["metrics"]
    failures = []
    for key in keys:
        if key not in base or key not in metrics:
            continue
        floor = (1.0 - REGRESSION_TOLERANCE) * base[key]
        if metrics[key] < floor:
            failures.append(
                f"{key}: {metrics[key]:.3f} < {floor:.3f} "
                f"(baseline {base[key]:.3f} - {REGRESSION_TOLERANCE:.0%})")
    return failures


def _write_artifact(path: str, rows: list, metrics: dict) -> None:
    artifact = {
        "host": jax.default_backend(),
        "rows": [{"name": r.name, "us": r.us, "derived": r.derived}
                 for r in rows],
        "metrics": metrics,
    }
    Path(path).write_text(json.dumps(artifact, indent=2) + "\n")
    print(f"# wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config per backend + the kernel-grid and "
                    "serve-mode comparisons; writes the CI artifacts and "
                    "enforces the regression gates")
    ap.add_argument("--out", default="BENCH_throughput.json",
                    help="artifact path for --smoke (default: ./%(default)s)")
    ap.add_argument("--serve-out", default="BENCH_serve.json",
                    help="serve-mode artifact path for --smoke "
                    "(default: ./%(default)s)")
    ap.add_argument("--topk-out", default="BENCH_topk.json",
                    help="windowed top-k sweep artifact path for --smoke "
                    "(default: ./%(default)s)")
    ap.add_argument("--robust-out", default="BENCH_robust.json",
                    help="verification-overhead artifact path for --smoke "
                    "(default: ./%(default)s)")
    ap.add_argument("--krylov", action="store_true",
                    help="run ONLY the large-n Krylov-vs-dense-reduce "
                    "benchmark + the parity-sign probe (the slow CI lane; "
                    "~25 min full, seconds with --smoke), write the "
                    "artifact and enforce the oracle/ratio/baseline gates")
    ap.add_argument("--krylov-out", default="BENCH_krylov.json",
                    help="krylov benchmark artifact path "
                    "(default: ./%(default)s)")
    ap.add_argument("--fleet", action="store_true",
                    help="run ONLY the multi-replica fleet lane: N=1 vs "
                    f"N={FLEET_REPLICAS} subprocess-replica scaling plus "
                    "the chaos kill/restart gate; writes the artifact and "
                    "enforces the scaling floor (on hosts with enough "
                    "cores) and the zero-lost-result gates")
    ap.add_argument("--fleet-out", default="BENCH_fleet.json",
                    help="fleet benchmark artifact path "
                    "(default: ./%(default)s)")
    ap.add_argument("--update", action="store_true",
                    help="run ONLY the streaming rank-1 update lane: warm "
                    "SpectralSession updates vs from-scratch re-solves "
                    "across n x k configs, eigh-oracle-validated, with the "
                    "drift-monitor full-resolve exercised in-stream; "
                    "writes the artifact and enforces the ratio floor at "
                    f"the n={UPDATE_TARGET[0]},k={UPDATE_TARGET[1]} gate "
                    "point plus the committed-baseline regression gate")
    ap.add_argument("--update-out", default="BENCH_update.json",
                    help="update benchmark artifact path "
                    "(default: ./%(default)s)")
    args = ap.parse_args()
    if args.update:
        update_metrics: dict = {}
        update_rows = update_benchmark(update_metrics, smoke=args.smoke)
        print("name,us_per_call,derived")
        for row in update_rows:
            print(row.csv())
        _write_artifact(args.update_out, update_rows, update_metrics)
        failures = []
        if update_metrics.get("update_oracle_failures", 0):
            failures.append(
                "update_oracle_failures: "
                f"{update_metrics['update_oracle_failures']} update(s) "
                f"outside the eigvalsh oracle tolerance ({UPDATE_TOL})")
        for n, k in UPDATE_CONFIGS:
            if update_metrics.get(
                    f"update_drift_resolves_n{n}_k{k}", 0) < 1:
                failures.append(
                    f"update_drift_resolves_n{n}_k{k}: 0 — the injected "
                    "large updates never tripped the drift monitor (the "
                    "staleness guard is not exercised)")
        tn, tk = UPDATE_TARGET
        key = f"update_vs_scratch_n{tn}_k{tk}_ratio"
        ratio = update_metrics.get(key, 0.0)
        if ratio < UPDATE_RATIO_FLOOR:
            failures.append(
                f"{key}: {ratio:.2f} < {UPDATE_RATIO_FLOOR} (the warm "
                "rank-1 path must beat per-update re-solves at the gate "
                "point)")
        failures += check_regression(
            update_metrics, UPDATE_BASELINE_PATH,
            tuple(k for k in update_metrics
                  if k.startswith("update_vs_scratch")))
        for f in failures:
            print(f"REGRESSION: {f}", file=sys.stderr)
        sys.exit(1 if failures else 0)
    if args.fleet:
        import os as _os

        fleet_metrics: dict = {}
        fleet_rows = fleet_benchmark(fleet_metrics, smoke=args.smoke)
        print("name,us_per_call,derived")
        for row in fleet_rows:
            print(row.csv())
        _write_artifact(args.fleet_out, fleet_rows, fleet_metrics)
        failures = []
        for key, bound in (("fleet_chaos_unresolved", 0),
                           ("fleet_chaos_failed", 0),
                           ("fleet_chaos_garbage", 0)):
            if fleet_metrics.get(key, 0) > bound:
                failures.append(
                    f"{key}: {fleet_metrics[key]} > {bound} (the fleet "
                    "must never lose a request or pass garbage unflagged)")
        if fleet_metrics.get("fleet_chaos_kills", 0) < 1:
            failures.append(
                "fleet_chaos_kills: 0 — the chaos lane never exercised a "
                "replica kill (rate/seed drifted?)")
        if fleet_metrics.get("fleet_chaos_restarts", 0) < 1:
            failures.append(
                "fleet_chaos_restarts: 0 — killed replicas never "
                "restarted within the run")
        ratio_key = f"fleet_n{FLEET_REPLICAS}_vs_n1_ratio"
        cores = _os.cpu_count() or 1
        if cores >= FLEET_REPLICAS:
            ratio = fleet_metrics.get(ratio_key, 0.0)
            if ratio < FLEET_SCALING_FLOOR:
                failures.append(
                    f"{ratio_key}: {ratio:.2f} < {FLEET_SCALING_FLOOR} "
                    f"(N={FLEET_REPLICAS} subprocess replicas must scale "
                    f"requests/s on a {cores}-core host)")
            failures += check_regression(
                fleet_metrics, FLEET_BASELINE_PATH, (ratio_key,))
        else:
            print(f"# SKIPPING fleet scaling floor: host has {cores} "
                  f"core(s) < {FLEET_REPLICAS} replicas — the N="
                  f"{FLEET_REPLICAS}/N=1 ratio only measures time-slicing "
                  "here (chaos gates still enforced)", file=sys.stderr)
        for f in failures:
            print(f"REGRESSION: {f}", file=sys.stderr)
        sys.exit(1 if failures else 0)
    if args.krylov:
        krylov_metrics: dict = {}
        krylov_rows = krylov_benchmark(krylov_metrics, smoke=args.smoke)
        krylov_rows += parity_sign_probe(krylov_metrics, smoke=args.smoke)
        print("name,us_per_call,derived")
        for row in krylov_rows:
            print(row.csv())
        _write_artifact(args.krylov_out, krylov_rows, krylov_metrics)
        failures = []
        if krylov_metrics.get("krylov_oracle_failures", 0):
            failures.append(
                "krylov_oracle_failures: "
                f"{krylov_metrics['krylov_oracle_failures']} config(s) "
                f"outside the eigvalsh oracle tolerance ({KRYLOV_TOL})")
        if not args.smoke:
            tn, tk = KRYLOV_TARGET
            key = f"krylov_vs_dense_n{tn}_k{tk}_ratio"
            ratio = krylov_metrics.get(key, 0.0)
            if ratio < KRYLOV_RATIO_FLOOR:
                failures.append(
                    f"{key}: {ratio:.2f} < {KRYLOV_RATIO_FLOOR} (the "
                    "Krylov reduce must beat dense Householder wall-clock "
                    "at the target config)")
            # Gate only the krylov-vs-dense ratios (within-run ratios of
            # identical work); the parity probe is observational.
            failures += check_regression(
                krylov_metrics, KRYLOV_BASELINE_PATH,
                tuple(k for k in krylov_metrics
                      if k.startswith("krylov_vs_dense")))
        for f in failures:
            print(f"REGRESSION: {f}", file=sys.stderr)
        sys.exit(1 if failures else 0)
    rows, metrics = run(smoke=args.smoke)
    serve_metrics: dict = {}
    serve_rows = serve_mode_comparison(serve_metrics, smoke=args.smoke)
    serve_rows += linger_serve_comparison(serve_metrics, smoke=args.smoke)
    serve_rows += packed_serve_comparison(serve_metrics, smoke=args.smoke)
    topk_metrics: dict = {}
    topk_rows = topk_sweep_comparison(topk_metrics, smoke=args.smoke)
    robust_metrics: dict = {}
    robust_rows = robust_serve_comparison(robust_metrics, smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows + serve_rows + topk_rows + robust_rows:
        print(row.csv())
    if not args.smoke:
        return
    _write_artifact(args.out, rows, metrics)
    _write_artifact(args.serve_out, serve_rows, serve_metrics)
    _write_artifact(args.topk_out, topk_rows, topk_metrics)
    _write_artifact(args.robust_out, robust_rows, robust_metrics)
    failures = check_regression(
        metrics, BASELINE_PATH,
        ("pallas_vs_loop_ratio", "batched_vs_vmapped_kernel_ratio"))
    failures += check_regression(
        serve_metrics, SERVE_BASELINE_PATH, ("serve_vs_sync_ratio",))
    failures += check_regression(
        robust_metrics, ROBUST_BASELINE_PATH,
        ("robust_verify_overhead_ratio",))
    overhead = robust_metrics.get("robust_verify_overhead_ratio", 0.0)
    if overhead < VERIFY_OVERHEAD_FLOOR:
        failures.append(
            f"robust_verify_overhead_ratio: {overhead:.3f} < "
            f"{VERIFY_OVERHEAD_FLOOR} (on-by-default verification must "
            "cost <= 5% requests/s)")
    k1_ratio = topk_metrics.get("topk_windowed_vs_full_k1_ratio", 0.0)
    if k1_ratio < TOPK_WINDOWED_K1_FLOOR:
        failures.append(
            f"topk_windowed_vs_full_k1_ratio: {k1_ratio:.2f} < "
            f"{TOPK_WINDOWED_K1_FLOOR} (the windowed composition must beat "
            "full-spectrum requests/s at k=1)")
    if serve_metrics.get("serve_steady_state_compiles", 0):
        failures.append(
            "serve_steady_state_compiles: warm server recompiled "
            f"{serve_metrics['serve_steady_state_compiles']} programs "
            "(shape buckets must bound compilation)")
    if serve_metrics.get("linger_unresolved_futures", 0):
        failures.append(
            "linger_unresolved_futures: "
            f"{serve_metrics['linger_unresolved_futures']} futures did not "
            "resolve in the flushless sparse-stream pass (linger admission "
            "must complete the stream without an explicit flush)")
    packed_ratio = serve_metrics.get("packed_vs_bucketed_cold_ratio", 0.0)
    if packed_ratio < PACKED_SPEEDUP_FLOOR:
        failures.append(
            f"packed_vs_bucketed_cold_ratio: {packed_ratio:.2f} < "
            f"{PACKED_SPEEDUP_FLOOR} (packed dispatch must beat bucketed "
            "requests/s on the cold ragged small-n stream)")
    failures += check_regression(
        serve_metrics, PACKED_BASELINE_PATH,
        ("packed_vs_bucketed_cold_ratio",))
    if serve_metrics.get("packed_program_compiles", 0) >= \
            serve_metrics.get("bucketed_program_compiles", 1):
        failures.append(
            "packed_program_compiles: "
            f"{serve_metrics.get('packed_program_compiles')} >= "
            f"{serve_metrics.get('bucketed_program_compiles')} (packing "
            "must collapse the ragged stream into fewer compiled "
            "programs than shape bucketing)")
    if serve_metrics.get("packed_oracle_failures", 0):
        failures.append(
            "packed_oracle_failures: "
            f"{serve_metrics['packed_oracle_failures']} packed results "
            "outside the eigvalsh oracle tolerance")
    for key in ("packed_failed_requests", "bucketed_failed_requests"):
        if serve_metrics.get(key, 0):
            failures.append(
                f"{key}: {serve_metrics[key]} requests resolved with an "
                "error on the ragged stream")
    if serve_metrics.get("linger_failed_requests", 0):
        failures.append(
            "linger_failed_requests: "
            f"{serve_metrics['linger_failed_requests']} requests resolved "
            "with an error in the sparse-stream pass (dispatch/compile "
            "failure, not a linger-liveness problem)")
    for f in failures:
        print(f"REGRESSION: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
