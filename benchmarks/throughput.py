"""Serving throughput: batched solves/sec across SolverPlan choices.

    PYTHONPATH=src python -m benchmarks.throughput [--smoke]

The paper's production regime is a *stream* of top-k queries over *stacks*
of matrices.  Pre-engine, serving b queries meant a Python loop over b
single-matrix solves; the engine runs one batched program per stack.  This
suite measures both (the loop is the baseline) for each plan the planner can
emit on this host: reference / fused-jnp / pallas-interpret backends, and
the sharded backend when >1 host device is available.

``--smoke`` runs one tiny config per backend — the CI sanity gate.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Row, sym_stack, time_fn
from repro.engine import SolverEngine, SolverPlan

FULL_CONFIGS = [  # (batch, n, k)
    (8, 32, 4),
    (16, 64, 4),
    (32, 64, 8),
    (8, 128, 4),
]
SMOKE_CONFIGS = [(4, 16, 2)]


def _stack(b: int, n: int) -> jax.Array:
    return jnp.asarray(sym_stack(0, b, n))


def _plans(smoke: bool):
    plans = [
        ("jnp", SolverPlan(method="eei_tridiag", backend="jnp")),
        ("reference", SolverPlan(method="eei_tridiag", backend="reference")),
        ("pallas", SolverPlan(method="eei_tridiag", backend="pallas")),
    ]
    if not smoke:
        plans.append(("jnp_dense", SolverPlan(method="eei_dense",
                                              backend="jnp")))
        plans.append(("eigh", SolverPlan(method="eigh", backend="jnp")))
    if jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
        plans.append(("sharded", SolverPlan(
            method="eei_tridiag", backend="sharded", mesh=mesh)))
    return plans


def run(smoke: bool = False) -> list[Row]:
    rows = []
    configs = SMOKE_CONFIGS if smoke else FULL_CONFIGS
    for b, n, k in configs:
        a = _stack(b, n)
        for name, plan in _plans(smoke):
            if plan.backend == "sharded" and b % plan.batch_axis_size:
                continue
            engine = SolverEngine(plan)
            us = time_fn(engine.topk, a, k, repeat=3, warmup=1)
            rows.append(Row(
                f"throughput/{name}/b={b},n={n},k={k}", us,
                f"solves_per_s={b / (us * 1e-6):.1f}"))
        # Baseline: the pre-engine Python loop over single-matrix solves.
        loop_engine = SolverEngine(SolverPlan(method="eei_tridiag",
                                              backend="jnp"))

        def solve_loop(stack):
            return [loop_engine.topk(stack[i], k) for i in range(b)]

        us = time_fn(solve_loop, a, repeat=3, warmup=1)
        rows.append(Row(
            f"throughput/python_loop/b={b},n={n},k={k}", us,
            f"solves_per_s={b / (us * 1e-6):.1f} (pre-engine baseline)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny config per backend (CI sanity run)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row.csv())


if __name__ == "__main__":
    main()
