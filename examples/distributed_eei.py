"""Distributed EEI — Algorithm 2's dispatch on a device mesh (shard_map).

    PYTHONPATH=src python examples/distributed_eei.py

Uses 8 host devices to demonstrate both distributed axes:
  * minors sharded  (each device owns a slice of components j),
  * product terms sharded (the paper's batch dispatch; join == one psum).
On the production 16x16 mesh the identical code paths are exercised by the
multi-pod dry-run.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distributed, identity  # noqa: E402


def main():
    n = 64
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n))
    a = jnp.asarray((a + a.T) / 2)
    mesh = jax.make_mesh((1, min(8, jax.device_count())), ("data", "model"))
    print(f"mesh: {mesh.devices.shape} {mesh.axis_names}")

    # oracle
    lam, v = jnp.linalg.eigh(a)
    ref = (v * v).T

    # minors sharded over 'model'
    with mesh:
        mags = distributed.sharded_magnitudes(a, mesh, axis="model")
    err = float(jnp.max(jnp.abs(mags - ref)))
    print(f"minor-sharded |v|^2 table: max err vs eigh = {err:.2e}")
    print("output sharding:", mags.sharding)

    # term-sharded single component (Algorithm 2 dispatch -> psum join)
    mu = identity.minor_spectra(a)
    i, j = n // 2, 5
    with mesh:
        comp = distributed.term_sharded_component(lam, mu[j], i, mesh,
                                                  axis="model")
    print(f"term-sharded |v[{i},{j}]|^2 = {float(comp):.12f} "
          f"(eigh: {float(ref[i, j]):.12f})")


if __name__ == "__main__":
    main()
