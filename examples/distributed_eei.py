"""Distributed EEI — the engine's sharded backend on a device mesh.

    PYTHONPATH=src python examples/distributed_eei.py

Uses 8 host devices to demonstrate all three distributed axes:
  * batch axis: a stack of matrices sharded over 'data' — the serving path
    (one SolverPlan, whole pipeline per device, zero collectives);
  * minor axis: one matrix's minors sharded over 'model';
  * term axis: one component's product terms sharded (the paper's batch
    dispatch; join == one psum).
On the production 16x16 mesh the identical code paths are exercised by the
multi-pod dry-run.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distributed, identity  # noqa: E402
from repro.engine import SolverEngine, SolverPlan  # noqa: E402


def main():
    n = 64
    rng = np.random.default_rng(0)
    n_dev = min(8, jax.device_count())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    print(f"mesh: {mesh.devices.shape} {mesh.axis_names}")

    # --- batch axis: a sharded stack through the engine ----------------------
    b = 2 * n_dev
    stack = rng.standard_normal((b, n, n))
    stack = jnp.asarray((stack + np.swapaxes(stack, 1, 2)) / 2)
    plan = SolverPlan(method="eei_tridiag", backend="sharded", mesh=mesh)
    engine = SolverEngine(plan)
    lam_b, mags_b = engine.solve(stack)
    lam_ref, v_ref = jax.vmap(jnp.linalg.eigh)(stack)
    err = float(jnp.max(jnp.abs(mags_b - jnp.swapaxes(v_ref**2, -1, -2))))
    print(f"batch-sharded solve ({b}x{n}x{n} over {n_dev} devices): "
          f"max err vs eigh = {err:.2e}")

    # --- minor axis: one matrix, components sharded over 'model' -------------
    a = stack[0]
    mesh_m = jax.make_mesh((1, n_dev), ("data", "model"))
    lam, v = jnp.linalg.eigh(a)
    ref = (v * v).T
    with mesh_m:
        mags = distributed.minor_sharded_magnitudes(a, mesh_m, axis="model")
    err = float(jnp.max(jnp.abs(mags - ref)))
    print(f"minor-sharded |v|^2 table: max err vs eigh = {err:.2e}")
    print("output sharding:", mags.sharding)

    # --- term axis: single component (Algorithm 2 dispatch -> psum join) -----
    mu = identity.minor_spectra(a)
    i, j = n // 2, 5
    with mesh_m:
        comp = distributed.term_sharded_component(lam, mu[j], i, mesh_m,
                                                  axis="model")
    print(f"term-sharded |v[{i},{j}]|^2 = {float(comp):.12f} "
          f"(eigh: {float(ref[i, j]):.12f})")


if __name__ == "__main__":
    main()
