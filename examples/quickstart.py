"""Quickstart: the Eigenvector-Eigenvalue Identity in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Computes eigenvector component magnitudes three ways — LAPACK oracle, the
paper's identity (dense), and the TPU-native tridiagonal pipeline — and
recovers signed eigenvectors from magnitudes alone.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import identity
from repro.core.spectral import SpectralEngine


def main():
    rng = np.random.default_rng(0)
    n = 32
    a = rng.standard_normal((n, n))
    a = jnp.asarray((a + a.T) / 2)

    # --- oracle -------------------------------------------------------------
    lam, v = jnp.linalg.eigh(a)
    print(f"symmetric {n}x{n}; spectrum [{lam[0]:.3f}, {lam[-1]:.3f}]")

    # --- one component via the identity (paper Eq. 2, corrected) ------------
    i, j = n // 2, 3
    mag = identity.component(a, i, j, variant="logspace")
    print(f"\n|v[{i},{j}]|^2  identity = {float(mag):.12f}")
    print(f"|v[{i},{j}]|^2  eigh     = {float(v[j, i] ** 2):.12f}")

    # --- full magnitude table, all three engines ------------------------------
    ref = (v * v).T
    for method in ("eigh", "eei_dense", "eei_tridiag"):
        eng = SpectralEngine(method=method)
        mags = eng.component_magnitudes(a)
        if method == "eei_tridiag":
            # tridiagonal-basis magnitudes differ; compare top-k eigenpairs
            ev, vecs = eng.topk_eigenpairs(a, 3)
            err = min_sign_err(np.asarray(vecs), np.asarray(v[:, -3:].T))
            print(f"{method:12s} top-3 eigenvector err = {err:.2e}")
        else:
            err = float(jnp.max(jnp.abs(mags - ref)))
            print(f"{method:12s} magnitude table err  = {err:.2e}")

    # --- signed eigenvectors from magnitudes (EEI gives only |v|) ------------
    eng = SpectralEngine(method="eei_tridiag", use_kernels=True)
    ev, vecs = eng.topk_eigenpairs(a, 3)
    print("\ntop-3 eigenvalues (EEI+Sturm kernels):", np.asarray(ev).round(6))
    print("vs eigh:                              ",
          np.asarray(lam[-3:]).round(6))
    res = jnp.linalg.norm(a @ vecs.T - vecs.T * ev[None, :], axis=0)
    print("residual ||Av - λv|| per pair:", np.asarray(res).round(9))


def min_sign_err(got, ref):
    return float(np.minimum(np.abs(got - ref), np.abs(got + ref)).max())


if __name__ == "__main__":
    main()
