"""Quickstart: the Eigenvector-Eigenvalue Identity in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Computes eigenvector component magnitudes through the plan-driven
``SolverEngine`` — LAPACK oracle, the paper's identity (dense minors), and
the TPU-native tridiagonal pipeline — on a single matrix and on a batched
stack, and recovers signed eigenvectors from magnitudes alone.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import identity
from repro.engine import SolverEngine, SolverPlan, plan_for


def main():
    rng = np.random.default_rng(0)
    n = 32
    a = rng.standard_normal((n, n))
    a = jnp.asarray((a + a.T) / 2)

    # --- oracle -------------------------------------------------------------
    lam, v = jnp.linalg.eigh(a)
    print(f"symmetric {n}x{n}; spectrum [{lam[0]:.3f}, {lam[-1]:.3f}]")

    # --- one component via the identity (paper Eq. 2, corrected) ------------
    i, j = n // 2, 3
    mag = identity.component(a, i, j, variant="logspace")
    print(f"\n|v[{i},{j}]|^2  identity = {float(mag):.12f}")
    print(f"|v[{i},{j}]|^2  eigh     = {float(v[j, i] ** 2):.12f}")

    # --- full magnitude table, one engine per method --------------------------
    ref = (v * v).T
    for method in ("eigh", "eei_dense", "eei_tridiag"):
        engine = SolverEngine(SolverPlan(method=method))
        result = engine.solve(a)
        err = float(jnp.max(jnp.abs(result.magnitudes - ref)))
        print(f"{method:12s} magnitude table err  = {err:.2e}")

    # --- a *stack* of matrices in one batched program -------------------------
    b = 8
    stack = rng.standard_normal((b, n, n))
    stack = jnp.asarray((stack + np.swapaxes(stack, 1, 2)) / 2)
    plan = plan_for(stack.shape, k=3)  # planner picks method/backend
    engine = SolverEngine(plan)
    lam_b, mags_b = engine.solve(stack)
    ref_b = jax.vmap(lambda m: jnp.linalg.eigh(m)[1])(stack)
    err = float(jnp.max(jnp.abs(mags_b - jnp.swapaxes(ref_b**2, -1, -2))))
    print(f"\nbatched solve ({b}x{n}x{n}, plan: {plan.method}/{plan.backend})"
          f" table err = {err:.2e}")

    # --- signed eigenvectors from magnitudes (EEI gives only |v|) ------------
    engine = SolverEngine(SolverPlan(method="eei_tridiag", backend="pallas"))
    ev, vecs = engine.topk(a, 3)
    print("\ntop-3 eigenvalues (EEI+Sturm kernels):", np.asarray(ev).round(6))
    print("vs eigh:                              ",
          np.asarray(lam[-3:]).round(6))
    res = jnp.linalg.norm(a @ vecs.T - vecs.T * ev[None, :], axis=0)
    print("residual ||Av - λv|| per pair:", np.asarray(res).round(9))


if __name__ == "__main__":
    main()
