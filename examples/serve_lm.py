"""Batched serving example: prefill + greedy decode with sharded KV caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b]

Runs the reduced config on CPU; on a real mesh drop ``--reduced`` inside
``repro.launch.serve`` and pass ``--mesh 16x16``.
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_main([
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch),
        "--prompt-len", "32",
        "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
