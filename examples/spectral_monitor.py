"""Spectral monitoring during training — the paper's partial-eigenvector use
case in the loop.

    PYTHONPATH=src python examples/spectral_monitor.py

Trains a small LM while, every k steps, probing the top eigenpairs of each
2-D parameter's gradient gram matrix via the EEI pipeline (a few components
of a few eigenvectors — exactly the regime where the identity beats full
eigh, per the paper's Table 1).  Prints the spectral-norm trajectory and the
dominant eigenvector's top components.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, reduced_config
from repro.engine import SolverEngine, SolverPlan
from repro.data import make_synthetic
from repro.models.lm import LanguageModel
from repro.optim import AdamW
from repro.train import TrainState, make_train_step


def main():
    cfg = reduced_config(get_config("codeqwen1.5-7b"))
    model = LanguageModel(cfg)
    opt = AdamW(lr=3e-3)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(model, opt, compute_dtype=jnp.float32))
    src = make_synthetic(cfg, ShapeConfig("t", 32, 4, "train"))
    engine = SolverEngine(SolverPlan(method="eei_tridiag", backend="pallas"))

    @jax.jit
    def probe(params, batch):
        """Top-2 eigenpairs of grad-gram of the unembed matrix."""
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        g = grads["unembed"].astype(jnp.float32)
        gram = g @ g.T / g.shape[1]
        return engine.topk(gram, 2)

    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in src.global_batch_at(i).items()}
        state, metrics = step_fn(state, batch)
        if i % 5 == 0:
            ev, vecs = probe(state.params, batch)
            top = np.asarray(vecs[-1])
            comps = np.argsort(-np.abs(top))[:3]
            print(f"step {i:3d} loss {float(metrics['loss']):7.4f} "
                  f"grad-gram top eigvals {np.asarray(ev).round(6)} "
                  f"dominant dims {comps.tolist()}")
    print("\nThe probe cost is 2 tridiagonal solves + EEI products per "
          "refresh — no full eigendecomposition anywhere.")


if __name__ == "__main__":
    main()
