"""Spectral monitoring during training — the paper's partial-eigenvector use
case in the loop, on the *streaming* update API.

    PYTHONPATH=src python examples/spectral_monitor.py

Trains a small LM while maintaining the top eigenpairs of a streaming
gradient-covariance matrix ``A_t = A_{t-1} + u_t u_t^T`` (``u_t`` the step's
mean gradient direction of the unembed matrix) through a
:class:`~repro.engine.session.SpectralSession`: each training step is one
rank-1 ``engine.update()`` — a warm-started O(m n^2) refinement of the
previous window — instead of a from-scratch solve.  The session's drift
monitor forces a verified full re-solve whenever the accumulated updates
could have moved the spectrum past the warm brackets, so the printed window
is always residual-checked, never stale.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, reduced_config
from repro.data import make_synthetic
from repro.engine import Rank1Update, SessionConfig, SolverEngine, SolverPlan
from repro.models.lm import LanguageModel
from repro.optim import AdamW
from repro.train import TrainState, make_train_step


def main():
    cfg = reduced_config(get_config("codeqwen1.5-7b"))
    model = LanguageModel(cfg)
    opt = AdamW(lr=3e-3)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(model, opt, compute_dtype=jnp.float32))
    src = make_synthetic(cfg, ShapeConfig("t", 32, 4, "train"))
    engine = SolverEngine(SolverPlan(method="eei_tridiag", backend="pallas"))

    @jax.jit
    def grad_direction(params, batch):
        """The step's mean gradient direction of the unembed matrix."""
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        g = grads["unembed"].astype(jnp.float32)
        return jnp.mean(g, axis=1) * jnp.sqrt(g.shape[0])

    session = None
    warmup: list = []
    unit = None  # first gradient's norm: the stream's working unit
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in src.global_batch_at(i).items()}
        state, metrics = step_fn(state, batch)
        u = np.asarray(grad_direction(state.params, batch))
        # Monitor in units of the first gradient's norm: raw grads here are
        # ~1e-9, and float32 *squares* matrix entries in the residual check,
        # which underflows around 1e-19 — normalized, everything is O(1).
        if unit is None:
            unit = float(np.linalg.norm(u)) or 1.0
        u = u / unit
        if session is None:
            # Seed from a short warmup so the retained window spans
            # directions actually present, with a *spread* diagonal ridge
            # (an exactly-degenerate ridge cluster would pin the fast
            # path's verify residual at the tolerance edge).
            warmup.append(u)
            if len(warmup) < 8:
                continue
            n = u.shape[0]
            scale = float(np.mean([w @ w for w in warmup]))
            a0 = sum(np.outer(w, w) for w in warmup)
            a0 = a0 + 1e-3 * scale * np.diag(1.0 + np.linspace(0.0, 1.0, n))
            session = engine.open_session(
                a0, 2, config=SessionConfig(drift_bound=0.5))
        else:
            engine.update(session, Rank1Update(u, 1))
        if session is None:
            continue
        if i % 5 == 0:
            ev, vecs = session.result()
            top = np.asarray(vecs[-1])
            comps = np.argsort(-np.abs(top))[:3]
            print(f"step {i:3d} loss {float(metrics['loss']):7.4f} "
                  f"grad-cov top eigvals {np.asarray(ev).round(6)} "
                  f"dominant dims {comps.tolist()}")
    stats = session.stats()
    print(f"\n{stats['updates_total']} rank-1 updates: "
          f"{stats['fast_updates']} warm-path, "
          f"{stats['full_resolves']} drift-forced full re-solves "
          f"({stats['resolves_by_cause']}).  The steady-state probe cost is "
          "one O(m n^2) warm refinement per step — no full "
          "eigendecomposition anywhere.")


if __name__ == "__main__":
    main()
