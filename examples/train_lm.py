"""End-to-end training driver: a ~100M-parameter xLSTM for a few hundred
steps on synthetic data, with checkpoint/restart and the straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

``--small`` switches to the reduced config (CI/CPU-friendly, seconds);
the default trains the full xlstm-125m config (0.13B params) — the
"train a ~100M model" end-to-end driver.  Both paths are the production
code path: launcher -> sharded programs -> supervisor loop.
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    argv = [
        "--arch", "xlstm-125m",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt-every", "100",
        "--log-every", "10",
    ]
    if args.small:
        argv += ["--reduced"]
    train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
