"""Checkpoint manager: async atomic saves, keep-K, elastic restore.

Format: one directory per step containing ``manifest.json`` (flattened tree
paths, shapes, dtypes, iterator state, mesh metadata) and one ``.npy`` per
leaf.  Leaves are written *unsharded* (gathered to host), which makes every
checkpoint **mesh-independent**: restore resharding onto any mesh/rule set is
a ``device_put`` with the new sharding — this is the elastic-scaling path
(N pods -> M pods restarts).

Durability: writes go to ``<dir>/tmp-<step>`` and are atomically renamed to
``<dir>/step-<step>`` — a crash mid-write can never corrupt the latest
checkpoint.  Saves run on a background thread (async checkpointing);
``wait()`` joins before the next save or process exit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "§"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _to_native(arr: np.ndarray) -> np.ndarray:
    """np.save cannot serialize ml_dtypes (bf16 etc.) — widen to float32.

    Lossless for bf16 (a strict fp32 subset); restore casts back via the
    target leaf dtype.
    """
    if arr.dtype.kind not in "fiub?":
        return arr.astype(np.float32)
    return arr


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"a:{p.name}"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot to host, then write asynchronously."""
        self.wait()
        flat = _flatten(tree)
        host = {k: _to_native(np.asarray(jax.device_get(v)))
                for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "keys": sorted(host),
            "treedef": str(treedef),
            "extra": extra or {},
        }

        def write():
            tmp = os.path.join(self.directory, f"tmp-{step}")
            final = os.path.join(self.directory, f"step-{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, k in enumerate(manifest["keys"]):
                np.save(os.path.join(tmp, f"{i}.npy"), host[k])
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step-"):
                out.append(int(name.split("-", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of ``like``.

        ``shardings`` (same structure) triggers sharded ``device_put`` —
        pass the *new* mesh's shardings to reshard elastically.
        Returns (tree, extra).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = os.path.join(self.directory, f"step-{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {
            k: np.load(os.path.join(d, f"{i}.npy"))
            for i, k in enumerate(manifest["keys"])
        }
        flat_like = _flatten(like)
        missing = set(flat_like) - set(arrays)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys_in_order = [
            _SEP.join(_path_str(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
        ]
        restored_leaves = []
        flat_sh = _flatten(shardings) if shardings is not None else None
        for key, leaf in zip(keys_in_order, leaves_like):
            arr = arrays[key]
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                # non-native dtypes (bf16 opt state) round-trip via fp32
                arr = jax.numpy.asarray(arr).astype(leaf.dtype)
            if flat_sh is not None:
                restored_leaves.append(jax.device_put(arr, flat_sh[key]))
            else:
                restored_leaves.append(jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(treedef, restored_leaves)
        return tree, manifest.get("extra", {})
