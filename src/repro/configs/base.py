"""Config schema: ModelConfig (architecture) and ShapeConfig (workload)."""

from __future__ import annotations

import dataclasses

# Block kinds (a block = mixer + FFN unless self-contained):
#   attn        global causal GQA + dense MLP
#   attn_local  sliding-window GQA + dense MLP
#   attn_moe    global causal GQA + MoE
#   mla         multi-head latent attention + dense MLP
#   mla_moe     MLA + MoE
#   cross       cross-attention (kv from ctx) + dense MLP
#   dec_cross   self-attn + cross-attn + dense MLP (enc-dec decoder layer)
#   attn_bidir  bidirectional attention + dense MLP (encoder layer)
#   mamba       Mamba2 block (self-contained)
#   mlstm       xLSTM matrix-memory block (self-contained)
#   slstm       xLSTM scalar-memory block (self-contained)
#   attn_shared shared-parameter attention block (Zamba2) — listed in
#               ``shared_blocks`` so its params are not stacked

Pattern = tuple[tuple[int, tuple[str, ...]], ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Pattern
    shared_blocks: tuple[str, ...] = ()
    head_dim: int | None = None
    # attention extras
    rope_theta: float = 10000.0
    window: int | None = None
    attn_softcap: float | None = None
    final_softcap: float | None = None
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    # mla (deepseek)
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # ssm / recurrent
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # enc-dec (audio) / vlm stubs
    n_enc_layers: int = 0
    enc_seq: int = 0  # precomputed frame embeddings (assignment: frontend stub)
    img_seq: int = 0  # precomputed patch embeddings (assignment: frontend stub)
    # misc
    activation: str = "swiglu"
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    remat: bool = True
    remat_policy: str = "all"  # all | dots (save matmul outputs) | none
    unroll_groups: bool = False  # unroll layer scans (roofline lowerings)
    attn_chunk: int = 1024
    sub_quadratic: bool = False  # may run long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). Skips are recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "full-attention arch: 512k dense KV decode out of scope"
    return True, ""
