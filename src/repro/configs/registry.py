"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke configs).

Every entry reproduces the assignment's published config exactly; deviations
forced by published-source ambiguity are listed in DESIGN.md and in each
config's ``notes``.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# [ssm] xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517]
# ---------------------------------------------------------------------------
XLSTM_125M = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    pattern=((6, ("mlstm", "slstm")),),  # xLSTM[1:1] alternation
    ssm_chunk=128, sub_quadratic=True, tie_embeddings=True,
    notes="d_ff=0: blocks carry internal projections (mLSTM expand=2, "
          "sLSTM post-FFN 4/3). Gate softcap replaces running-max stabilizer.",
)

# ---------------------------------------------------------------------------
# [dense] CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]
# ---------------------------------------------------------------------------
CODEQWEN_7B = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab_size=92416, rope_theta=1_000_000.0,
    pattern=((32, ("attn",)),),
)

# ---------------------------------------------------------------------------
# [dense] StarCoder2-7B — GQA, RoPE [arXiv:2402.19173]
# ---------------------------------------------------------------------------
STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab_size=49152, activation="gelu", rope_theta=1_000_000.0,
    pattern=((32, ("attn",)),),
    notes="GeLU MLP per paper; RMSNorm used where the release uses LayerNorm.",
)

# ---------------------------------------------------------------------------
# [dense] Gemma2-2B — local/global alternation, softcaps [arXiv:2408.00118]
# ---------------------------------------------------------------------------
GEMMA2_2B = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab_size=256000, head_dim=256, window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    embed_scale=True, tie_embeddings=True, activation="gelu",
    pattern=((13, ("attn_local", "attn")),),
)

# ---------------------------------------------------------------------------
# [dense] Granite-20B — MQA llama-arch code model [arXiv:2405.04324]
# ---------------------------------------------------------------------------
GRANITE_20B = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152, activation="gelu",
    pattern=((52, ("attn",)),),
    notes="MQA (kv=1): KV-cache sharding falls back to the sequence axis "
          "(repro.sharding.rules). GeLU 2-matrix MLP (gpt-bigcode lineage) "
          "matches the published 20B count.",
)

# ---------------------------------------------------------------------------
# [moe] Kimi-K2 1T-A32B — 384 experts top-8 [arXiv:2501.kimi2, paper table]
# ---------------------------------------------------------------------------
KIMI_K2 = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=18432,
    vocab_size=163840, head_dim=128,
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    pattern=((1, ("attn",)), (60, ("attn_moe",))),
    rope_theta=50_000.0,
    notes="Assignment d_ff=2048 is the per-expert hidden (moe_d_ff); the "
          "single dense layer uses 18432. GQA per assignment (release is MLA).",
)

# ---------------------------------------------------------------------------
# [moe] DeepSeek-V3 671B — MLA, 1 shared + 256 routed top-8 [arXiv:2412.19437]
# ---------------------------------------------------------------------------
DEEPSEEK_V3 = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    pattern=((3, ("mla",)), (58, ("mla_moe",))),
    notes="First 3 layers dense-FFN (paper); assignment d_ff=2048 is the "
          "per-expert hidden. Softmax top-k router stands in for the "
          "sigmoid+bias-corrected router; MTP head not modeled.",
)

# ---------------------------------------------------------------------------
# [audio] Whisper-large-v3 — enc-dec, conv frontend stubbed [arXiv:2212.04356]
# ---------------------------------------------------------------------------
WHISPER_LARGE_V3 = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, activation="gelu",
    n_enc_layers=32, enc_seq=1500,
    pattern=((32, ("dec_cross",)),),
    notes="Frontend stub per assignment: input_specs() feeds precomputed "
          "frame embeddings (B, 1500, d). RoPE stands in for learned "
          "decoder positions.",
)

# ---------------------------------------------------------------------------
# [vlm] Llama-3.2-Vision-90B — cross-attn image layers [hf:meta-llama]
# ---------------------------------------------------------------------------
LLAMA32_VISION_90B = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, rope_theta=500_000.0,
    img_seq=1600,
    pattern=((20, ("attn", "attn", "attn", "attn", "cross")),),
    notes="Vision frontend stub per assignment: precomputed patch embeddings "
          "(B, 1600, d). Cross-attn every 5th layer, tanh-gated.",
)

# ---------------------------------------------------------------------------
# [hybrid] Zamba2-2.7B — Mamba2 + shared attention [arXiv:2411.15242]
# ---------------------------------------------------------------------------
ZAMBA2_2P7B = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, ssm_state=64, ssm_head_dim=64,
    pattern=((9, ("mamba", "mamba", "mamba", "mamba", "mamba", "attn_shared")),),
    shared_blocks=("attn_shared",),
    sub_quadratic=True,
    notes="One shared-parameter attention block applied every 6th position "
          "(the release concatenates original embeddings into the shared "
          "block; we apply it on the residual stream).",
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        XLSTM_125M, CODEQWEN_7B, STARCODER2_7B, GEMMA2_2B, GRANITE_20B,
        KIMI_K2, DEEPSEEK_V3, WHISPER_LARGE_V3, LLAMA32_VISION_90B,
        ZAMBA2_2P7B,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests (one step, no NaNs)."""
    kv = 2 if cfg.n_kv_heads < cfg.n_heads else 4
    pattern = tuple((min(r, 2), kinds) for r, kinds in cfg.pattern)
    n_layers = sum(r * len(k) for r, k in pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        pattern=pattern,
        window=16 if cfg.window else None,
        n_experts=8 if cfg.n_experts else 0,
        top_k=2 if cfg.top_k else 0,
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        ssm_state=8, ssm_head_dim=16, ssm_chunk=8,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=16 if cfg.enc_seq else 0,
        img_seq=16 if cfg.img_seq else 0,
        attn_chunk=16,
        remat=False,
    )
