"""The paper's primary contribution: the Eigenvector-Eigenvalue Identity
implemented as a production substrate — variant ladder (faithful), TPU-native
tridiagonal pipeline, and the sharded backend (``distributed``).  The
framework-facing entry point is ``repro.engine.SolverEngine``; the old
``SpectralEngine`` façade has been removed (see docs/ARCHITECTURE.md for the
migration table).
"""

from repro.core import identity, minors, directions, distributed  # noqa: F401
