"""The paper's primary contribution: the Eigenvector-Eigenvalue Identity
implemented as a production substrate — variant ladder (faithful), TPU-native
tridiagonal pipeline, distributed (shard_map) forms, and the SpectralEngine
façade consumed by the optimizer and monitoring layers.
"""

from repro.core import identity, minors, directions, distributed  # noqa: F401
from repro.core.spectral import SpectralEngine  # noqa: F401
