"""Sign recovery for EEI eigenvector components.

The identity yields only ``|v[i, j]|^2``.  Applications needing directions
(the paper cites multi-basis inference / direct inspection) get two
recoveries here:

* ``tridiagonal_signs`` — exact three-term-recurrence signs on a tridiagonal
  matrix (the TPU-native path: EEI magnitudes are computed on the
  tridiagonalized form, signed there, then back-transformed with ``Q``);
* ``inverse_iteration_signs`` — dense fallback: one shifted solve orients any
  eigenvector (standard inverse iteration, one step from a random seed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tridiagonal_signs(d: jax.Array, e: jax.Array, lam, mags: jax.Array):
    """Signed components of a tridiagonal eigenvector from magnitudes.

    For ``T w = lam w``:  ``e[j] w[j+1] = (lam - d[j]) w[j] - e[j-1] w[j-1]``.
    Starting with ``w[0] = +|w[0]|``, each next sign is the sign the
    recurrence predicts.  Where ``e[j] ~ 0`` the matrix decouples and the next
    block's seed sign is free — we restart with ``+``.

    Returns the signed eigenvector (unnormalized signs applied to
    ``sqrt(mags)``).
    """
    n = d.shape[0]
    w_abs = jnp.sqrt(jnp.maximum(mags, 0.0))
    eps = jnp.finfo(d.dtype).eps
    scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.max(jnp.abs(e)) if n > 1 else 0.0)
    tol = eps * jnp.maximum(scale, 1.0) * 10.0

    def body(carry, j):
        w_prev2, w_prev = carry  # w[j-1], w[j]
        ej = e[j]
        ejm1 = jnp.where(j > 0, e[jnp.maximum(j - 1, 0)], 0.0)
        pred = (lam - d[j]) * w_prev - ejm1 * w_prev2
        decoupled = jnp.abs(ej) <= tol
        sign = jnp.where(decoupled, 1.0, jnp.sign(pred) * jnp.sign(ej))
        sign = jnp.where(sign == 0, 1.0, sign)
        w_next = sign * w_abs[j + 1]
        return (w_prev, w_next), w_next

    w0 = w_abs[0]
    if n == 1:
        return w_abs
    (_, _), rest = jax.lax.scan(body, (jnp.zeros_like(w0), w0), jnp.arange(n - 1))
    return jnp.concatenate([w0[None], rest])


def inverse_iteration_signs_batched(
    a: jax.Array,  # (b, n, n)
    lam_sel: jax.Array,  # (b, k) selected eigenvalues
    mags_sel: jax.Array,  # (b, k, n) selected |v|^2 rows
    shift_eps: float = 1e-6,
) -> jax.Array:
    """Signed eigenvectors for all selected pairs via one batched LU program.

    Same math as :func:`inverse_iteration_signs`, restructured for the
    serving path: instead of a per-(matrix, pair) ``solve`` dispatch
    (``vmap(vmap(...))`` of factor+solve), the ``b * k`` shifted systems
    ``A_b - (lam_bk + delta_b) I`` are stacked and factored by a *single*
    batched ``lu_factor`` call, followed by one batched ``lu_solve`` — per
    matrix, all ``k`` selected pairs ride the same factorization program.
    Returns signed eigenvectors ``(b, k, n)``; bitwise-identical systems to
    the per-pair path, so results agree to solver tolerance (regression test
    in ``tests/test_engine.py``).
    """
    b_n, k = lam_sel.shape
    n = a.shape[-1]
    diag = jnp.diagonal(a, axis1=-2, axis2=-1)  # (b, n)
    scale = jnp.max(jnp.abs(diag), axis=-1) + 1.0  # (b,)
    delta = shift_eps * scale
    shifts = lam_sel + delta[:, None]  # (b, k)
    eye = jnp.eye(n, dtype=a.dtype)
    shifted = a[:, None, :, :] - shifts[:, :, None, None] * eye  # (b, k, n, n)
    rhs = jnp.ones((n,), a.dtype) / jnp.sqrt(n)
    lu, piv = jax.scipy.linalg.lu_factor(shifted.reshape(b_n * k, n, n))
    x = jax.scipy.linalg.lu_solve(
        (lu, piv), jnp.broadcast_to(rhs, (b_n * k, n))
    ).reshape(b_n, k, n)
    signs = jnp.where(jnp.sign(x) == 0, 1.0, jnp.sign(x))
    v = signs * jnp.sqrt(jnp.maximum(mags_sel, 0.0))
    # Canonical orientation: largest-|component| positive, per pair.
    vmax = jnp.take_along_axis(
        v, jnp.argmax(jnp.abs(v), axis=-1, keepdims=True), axis=-1
    )
    return v * jnp.where(vmax < 0, -1.0, 1.0)


def inverse_iteration_signs(a: jax.Array, lam, mags: jax.Array, shift_eps: float = 1e-6):
    """Signed eigenvector from magnitudes via one inverse-iteration solve.

    Solves ``(A - (lam + delta) I) x = b`` for a fixed seed ``b``; ``x`` is
    dominated by the eigenvector of the eigenvalue nearest the shift, so
    ``sign(x)`` orients the magnitudes.  O(n^3) once, exactly what the paper's
    "inference through multiple bases" costs in practice.
    """
    n = a.shape[0]
    scale = jnp.max(jnp.abs(jnp.diagonal(a))) + 1.0
    delta = shift_eps * scale
    b = jnp.ones((n,), a.dtype) / jnp.sqrt(n)
    x = jnp.linalg.solve(a - (lam + delta) * jnp.eye(n, dtype=a.dtype), b)
    signs = jnp.where(jnp.sign(x) == 0, 1.0, jnp.sign(x))
    v = signs * jnp.sqrt(jnp.maximum(mags, 0.0))
    # Canonical orientation: largest-|component| positive.
    jmax = jnp.argmax(jnp.abs(v))
    return v * jnp.where(v[jmax] < 0, -1.0, 1.0)
