"""Distributed EEI — Algorithm 2's batch dispatch mapped onto a device mesh.

Two shardings, composable:

* ``minor axis`` (components ``j``): each device owns a slice of minors,
  computes their spectra and its column-block of ``|v[i, j]|^2``.  Zero
  collectives until the final gather — the embarrassingly-parallel outer
  loop the paper could not express with CPython threads.
* ``term axis`` (product terms ``k``): the *inner* product is sharded; each
  device holds a contiguous batch of eigenvalue-difference terms and
  contributes a partial log-sum, combined with one ``psum``.  This is
  Algorithm 2's ``dispatch``/``join`` (lines 9-15) verbatim, with the batch
  boundary = the shard boundary and ``join`` = ``psum`` — thread-management
  overhead (the paper's Amdahl bottleneck) becomes a single collective.

Both are ``shard_map`` programs over an explicit mesh and lower/compile on
the production meshes (see ``launch/dryrun.py --arch paper-eei``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import identity, minors


def sharded_magnitudes(a: jax.Array, mesh: Mesh, axis: str = "model"):
    """All ``|v[i, j]|^2`` with minors sharded over ``axis``.

    ``n`` must be divisible by the axis size.  Input ``a`` is replicated;
    output is sharded over components ``j``.
    """

    def block(a_rep, j_block):
        # j_block: (n_local,) global component indices owned by this device.
        lam = jnp.linalg.eigvalsh(a_rep)
        mu = jax.vmap(
            lambda j: jnp.linalg.eigvalsh(minors.minor(a_rep, j))
        )(j_block)
        log_num = identity.logabs_numerator(lam, mu)  # (n, n_local)
        log_den = identity.logabs_denominator(lam)  # (n,)
        return jnp.exp(log_num - log_den[:, None])

    n = a.shape[0]
    j_all = jnp.arange(n)
    fn = jax.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(), P(axis)),
        out_specs=P(None, axis),
    )
    return fn(a, j_all)


def term_sharded_component(
    lam: jax.Array, mu_j: jax.Array, i: int, mesh: Mesh, axis: str = "model"
):
    """Single component with the *product terms* sharded (Algorithm 2 dispatch).

    ``lam`` (n,), ``mu_j`` (n-1,) replicated in; each device log-reduces its
    term shard; one ``psum`` joins.  Term vectors are padded with 1.0
    (``log 1 = 0``) to a multiple of the axis size.
    """

    def block(numer_terms_local, denom_terms_local):
        part = jnp.sum(jnp.log(jnp.abs(numer_terms_local))) - jnp.sum(
            jnp.log(jnp.abs(denom_terms_local))
        )
        return jax.lax.psum(part, axis)

    lam_wo_i = minors.delete_index(lam, jnp.asarray(i))
    numer_terms = lam[i] - mu_j
    denom_terms = lam[i] - lam_wo_i
    axis_size = mesh.shape[axis]
    pad = (-numer_terms.shape[0]) % axis_size
    if pad:
        ones = jnp.ones((pad,), lam.dtype)
        numer_terms = jnp.concatenate([numer_terms, ones])
        denom_terms = jnp.concatenate([denom_terms, ones])
    fn = jax.shard_map(block, mesh=mesh, in_specs=(P(axis), P(axis)), out_specs=P())
    return jnp.exp(fn(numer_terms, denom_terms))


@functools.partial(jax.jit, static_argnames=("mesh", "axis"))
def _noop(mesh=None, axis=None):  # pragma: no cover - placeholder for API parity
    return None
