"""Distributed EEI — the SolverEngine's ``sharded`` backend.

Formerly a pair of free ``shard_map`` functions; now the mesh logic is a
proper backend (``make_sharded_backend``) registered with the engine
registry, so distributed execution is chosen by a ``SolverPlan`` like any
other backend.  Three axes, composable:

* ``batch axis`` (= the mesh data axis): the matrix *stack* is sharded —
  each device runs the whole tridiagonalize -> Sturm -> EEI -> signs
  pipeline on its slice of the batch.  Zero collectives; this is the
  serving-throughput axis the engine pads/unpads for.
* ``minor axis`` (components ``j``, = the mesh model axis): within the
  dense method each device owns a slice of minors, computes their spectra
  and its column-block of ``|v[i, j]|^2`` — the embarrassingly-parallel
  outer loop the paper could not express with CPython threads
  (``minor_sharded_magnitudes``).
* ``term axis`` (product terms ``k``): the *inner* product is sharded; each
  device log-reduces a contiguous batch of eigenvalue-difference terms,
  joined with one ``psum``.  This is Algorithm 2's ``dispatch``/``join``
  (lines 9-15) verbatim with batch boundary = shard boundary — the paper's
  Amdahl bottleneck (thread management) becomes a single collective
  (``term_sharded_component``).

All programs are ``shard_map`` over an explicit mesh and lower/compile on
the production meshes (see ``launch/dryrun.py --arch paper-eei``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import identity, minors
from repro.engine.plan import SolverPlan
from repro.engine.registry import StageLibrary


def _shard_map(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` when available, else the pre-0.5 experimental API.

    The replication check is disabled on both APIs (``check_vma`` new /
    ``check_rep`` legacy): stages contain custom-call primitives (eigvalsh)
    without replication rules, and mesh axes the specs don't mention
    (e.g. ``model``) would otherwise fail the check.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:  # jax versions without the check_vma kwarg
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


# ---------------------------------------------------------------------------
# Sharded backend: batch axis = data axis
# ---------------------------------------------------------------------------


def make_sharded_backend(plan: SolverPlan) -> StageLibrary:
    """Stage library running the fused-jnp stages under ``shard_map``.

    Every stage shards its leading batch axis over ``plan.batch_axis``; the
    pipeline is batch-parallel, so no collectives are needed until a caller
    gathers.  The engine guarantees divisibility by padding the stack.
    Replicated side inputs (the windowed ``idx`` gather) carry a
    no-axis spec.
    """
    from repro.engine.backends import make_jnp_backend

    inner = make_jnp_backend(plan)
    mesh, axis = plan.mesh, plan.batch_axis

    def spec(rank) -> P:
        if rank == "r1":  # rank-1 replicated side input (e.g. idx)
            return P(None)
        return P(*((axis,) + (None,) * (rank - 1)))

    def shard(fn, in_ranks, out_ranks):
        return _shard_map(
            fn,
            mesh,
            tuple(spec(r) for r in in_ranks),
            (tuple(spec(r) for r in out_ranks)
             if isinstance(out_ranks, tuple) else spec(out_ranks)),
        )

    def tridiagonalize(a, with_q=True):
        if with_q:
            return shard(lambda x: inner.tridiagonalize(x, True),
                         (3,), (2, 2, 3))(a)
        d, e = shard(lambda x: inner.tridiagonalize(x, False)[:2],
                     (3,), (2, 2))(a)
        return d, e, None

    def tridiag_eigenvalues_windowed(d, e, k, largest):
        # k/largest are static at trace time — close over them so the
        # shard_mapped callable is array-only.
        return shard(
            lambda dd, ee: inner.tridiag_eigenvalues_windowed(
                dd, ee, k, largest),
            (2, 2), 2)(d, e)

    def tridiag_eigenvalues_bracketed(d, e, lo, hi, k, largest):
        return shard(
            lambda dd, ee, ll, hh: inner.tridiag_eigenvalues_bracketed(
                dd, ee, ll, hh, k, largest),
            (2, 2, 2, 2), 2)(d, e, lo, hi)

    def krylov_reduce(a, k, largest):
        # Batch-parallel like every other stage: each device runs the
        # Lanczos loop on its slice of the stack (k/largest static).
        return shard(lambda x: inner.krylov_reduce(x, k, largest),
                     (3,), (2, 2, 3))(a)

    def krylov_shift_invert_reduce(a, k, largest):
        return shard(
            lambda x: inner.krylov_shift_invert_reduce(x, k, largest),
            (3,), (2, 2, 3, 1))(a)

    return StageLibrary("sharded", {
        "tridiagonalize": tridiagonalize,
        "tridiag_eigenvalues": shard(inner.tridiag_eigenvalues, (2, 2), 2),
        "tridiag_eigenvalues_windowed": tridiag_eigenvalues_windowed,
        "tridiag_eigenvalues_bracketed": tridiag_eigenvalues_bracketed,
        "tridiag_minor_spectra": shard(
            inner.tridiag_minor_spectra, (2, 2), 3),
        "dense_eigenvalues": shard(inner.dense_eigenvalues, (3,), 2),
        "dense_minor_spectra": shard(inner.dense_minor_spectra, (3,), 3),
        "magnitudes": shard(inner.magnitudes, (2, 3), 3),
        "magnitudes_windowed": shard(
            inner.magnitudes_windowed, (2, 3, "r1"), 3),
        "minor_det_components": shard(
            inner.minor_det_components, (2, 2, 2), 3),
        "tridiag_signs": shard(inner.tridiag_signs, (2, 2, 2, 3), 3),
        "dense_signs": shard(inner.dense_signs, (3, 2, 3), 3),
        "krylov_reduce": krylov_reduce,
        "krylov_shift_invert_reduce": krylov_shift_invert_reduce,
        # Verification is element-wise over the batch axis with no
        # cross-shard dataflow — plain jnp under the enclosing jit lets
        # GSPMD partition it; no shard_map wrapper needed.
        "verify_topk": inner.verify_topk,
    })


# ---------------------------------------------------------------------------
# Minor / term axes (single matrix, model axis) — as before, used by the
# dense method when one matrix must spread over many devices.
# ---------------------------------------------------------------------------


def minor_sharded_magnitudes(a: jax.Array, mesh: Mesh, axis: str = "model"):
    """All ``|v[i, j]|^2`` with minors sharded over ``axis``.

    ``n`` must be divisible by the axis size.  Input ``a`` is replicated;
    output is sharded over components ``j``.
    """

    def block(a_rep, j_block):
        # j_block: (n_local,) global component indices owned by this device.
        lam = jnp.linalg.eigvalsh(a_rep)
        mu = jax.vmap(
            lambda j: jnp.linalg.eigvalsh(minors.minor(a_rep, j))
        )(j_block)
        log_num = identity.logabs_numerator(lam, mu)  # (n, n_local)
        log_den = identity.logabs_denominator(lam)  # (n,)
        return jnp.exp(log_num - log_den[:, None])

    n = a.shape[0]
    j_all = jnp.arange(n)
    fn = _shard_map(block, mesh, (P(), P(axis)), P(None, axis))
    return fn(a, j_all)


# Backwards-compatible alias (pre-engine name).
sharded_magnitudes = minor_sharded_magnitudes


def term_sharded_component(
    lam: jax.Array, mu_j: jax.Array, i: int, mesh: Mesh, axis: str = "model"
):
    """Single component with the *product terms* sharded (Algorithm 2 dispatch).

    ``lam`` (n,), ``mu_j`` (n-1,) replicated in; each device log-reduces its
    term shard; one ``psum`` joins.  Term vectors are padded with 1.0
    (``log 1 = 0``) to a multiple of the axis size.
    """

    def block(numer_terms_local, denom_terms_local):
        part = jnp.sum(jnp.log(jnp.abs(numer_terms_local))) - jnp.sum(
            jnp.log(jnp.abs(denom_terms_local))
        )
        return jax.lax.psum(part, axis)

    lam_wo_i = minors.delete_index(lam, jnp.asarray(i))
    numer_terms = lam[i] - mu_j
    denom_terms = lam[i] - lam_wo_i
    axis_size = mesh.shape[axis]
    pad = (-numer_terms.shape[0]) % axis_size
    if pad:
        ones = jnp.ones((pad,), lam.dtype)
        numer_terms = jnp.concatenate([numer_terms, ones])
        denom_terms = jnp.concatenate([denom_terms, ones])
    fn = _shard_map(block, mesh, (P(axis), P(axis)), P())
    return jnp.exp(fn(numer_terms, denom_terms))
