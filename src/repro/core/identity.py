"""The Eigenvector-Eigenvalue Identity (EEI) — all implementation variants.

For an ``n x n`` Hermitian ``A`` with eigenvalues ``lam[0] <= ... <= lam[n-1]``
and minors ``M_j`` (row+column ``j`` deleted) with eigenvalues
``mu[j, 0] <= ... <= mu[j, n-2]``:

    |v[i, j]|^2 * prod_{k != i} (lam[i] - lam[k]) = prod_k (lam[i] - mu[j, k])

(Denton-Parke-Tao-Zhang 2019, Eq. 2.  NOTE: the reproduced paper's Eq. (2)
prints the two products swapped; we implement the correct orientation and
validate against ``jnp.linalg.eigh``.)

Variant ladder (faithful reproduction of the paper's Fig. 1(c)/(d)):

    baseline     Algorithm 1 — recomputes eigenvalues for every component.
    cached       spectra computed once, scalar python-loop products.
    vectorized   spectra once, jnp array products.
    batched      Algorithm 2 — difference products split into batches of
                 paired numerator/denominator terms; per-batch *ratios* are
                 multiplied, taming fp over/underflow for n >~ 150.
    parallel     Algorithm 2 with the batch dispatch mapped to ``vmap``
                 lanes (the TPU analogue of the paper's thread pool).
    logspace     beyond-paper — sum of log|diff|, immune to over/underflow.
    pallas       beyond-paper — the ``prod_diff`` Pallas kernel (logspace,
                 VMEM-tiled).

All public functions index eigenvectors by ``i`` (eigenvalue index, ascending
order) and components by ``j``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import minors as minors_lib

# ---------------------------------------------------------------------------
# Spectra helpers
# ---------------------------------------------------------------------------


def matrix_spectrum(a: jax.Array) -> jax.Array:
    """Eigenvalues of ``a`` (ascending)."""
    return jnp.linalg.eigvalsh(a)


def minor_spectra(a: jax.Array) -> jax.Array:
    """Eigenvalues of every minor ``M_j``; shape ``(n, n-1)`` (ascending)."""
    return jax.vmap(jnp.linalg.eigvalsh)(minors_lib.all_minors(a))


# ---------------------------------------------------------------------------
# Core products from precomputed spectra
# ---------------------------------------------------------------------------


def denominator_products(lam: jax.Array) -> jax.Array:
    """``prod_{k != i} (lam[i] - lam[k])`` for every ``i``; shape ``(n,)``."""
    diff = lam[:, None] - lam[None, :]
    diff = jnp.where(jnp.eye(lam.shape[0], dtype=bool), 1.0, diff)
    return jnp.prod(diff, axis=-1)


def numerator_products(lam: jax.Array, mu: jax.Array) -> jax.Array:
    """``prod_k (lam[i] - mu[j, k])`` for every ``(i, j)``; shape ``(n, n)``.

    ``mu`` has shape ``(n_minors, n-1)``; output ``(n, n_minors)``.
    """
    diff = lam[:, None, None] - mu[None, :, :]
    return jnp.prod(diff, axis=-1)


def logabs_denominator(lam: jax.Array) -> jax.Array:
    """``sum_{k != i} log|lam[i] - lam[k]|``; shape ``(n,)``."""
    diff = lam[:, None] - lam[None, :]
    diff = jnp.where(jnp.eye(lam.shape[0], dtype=bool), 1.0, diff)
    return jnp.sum(jnp.log(jnp.abs(diff)), axis=-1)


def logabs_denominator_dot(lam: jax.Array, chunk_i: int = 1024) -> jax.Array:
    """``logabs_denominator`` as a fused ones-contraction (no (n, n) temp).

    Beyond-paper (EXPERIMENTS.md §Perf, iterations 3 & 5): after the
    numerator was fused, the replicated (n, n) denominator table dominated
    per-device HLO bytes; expressing its k-reduction as a dot fuses the
    masked log-difference producer the same way.  Chunked over ``i`` for the
    same fusion-threshold reason as ``logabs_numerator_dot``.
    """
    n = lam.shape[0]
    ones = jnp.ones((n,), lam.dtype)
    # Diagonal exclusion without masks or aux tensors: lam[i]-lam[i] is
    # bitwise zero, so log(diff + tiny) contributes exactly log(tiny) on the
    # diagonal — subtract it per row.  Off-diagonal terms see a relative
    # perturbation < tiny/gap, below f32 resolution for any resolvable gap.
    tiny = jnp.asarray(1e-30, lam.dtype)
    log_tiny = jnp.log(tiny)

    def block(i0):
        lam_blk = lam[i0:i0 + chunk_i]  # static slice (ragged-tail safe)
        diff = jnp.abs(lam_blk[:, None] - lam[None, :])
        log_d = jnp.log(diff + tiny)
        return jnp.einsum("ik,k->i", log_d, ones) - log_tiny

    if n <= chunk_i:
        return block(0)
    return jnp.concatenate([block(i0) for i0 in range(0, n, chunk_i)])


def logabs_numerator(lam: jax.Array, mu: jax.Array) -> jax.Array:
    """``sum_k log|lam[i] - mu[j, k]|``; shape ``(n, n_minors)``."""
    diff = jnp.abs(lam[:, None, None] - mu[None, :, :])
    return jnp.sum(jnp.log(diff), axis=-1)


def logabs_numerator_dot(lam: jax.Array, mu: jax.Array,
                         floor: float | jax.Array = 0.0,
                         chunk_i: int = 1024) -> jax.Array:
    """``logabs_numerator`` with the k-reduction expressed as a contraction
    with a ones-vector.

    Beyond-paper (EXPERIMENTS.md §Perf, paper-eei iterations 1 & 4): XLA
    fuses the elementwise ``log|lam - mu|`` producer into the dot, so the
    (i, j, k) tensor is never materialized — HLO bytes drop to the input
    size (~260x on the dry-run memory term) and the reduction runs on the
    MXU.  The contraction is chunked over ``i`` because past ~4e9 fused
    elements XLA stops fusing the producer (measured; iteration 4) — each
    chunk re-reads ``mu``, a ~2x input-read cost that buys back the ~20x
    materialization.  This is the jnp-level expression of what the
    ``prod_diff`` Pallas kernel does with explicit VMEM tiles.
    """
    n_i = lam.shape[0]
    ones = jnp.ones((mu.shape[-1],), lam.dtype)

    def block(lam_blk):
        d = jnp.abs(lam_blk[:, None, None] - mu[None, :, :])
        if not (isinstance(floor, float) and floor == 0.0):
            d = jnp.maximum(d, floor)
        return jnp.einsum("ijk,k->ij", jnp.log(d), ones)

    if n_i <= chunk_i:
        return block(lam)
    out = [block(lam[i0:i0 + chunk_i]) for i0 in range(0, n_i, chunk_i)]
    return jnp.concatenate(out, axis=0)


# ---------------------------------------------------------------------------
# Windowed numerators: minor determinants by ratio recurrence (beyond paper)
# ---------------------------------------------------------------------------


def tridiag_minor_logdets(d: jax.Array, e: jax.Array, x: jax.Array):
    """``log|det(M_j - x_i I)| = log prod_k |x_i - mu[j, k]|`` for all ``j``.

    The EEI numerator of row ``i`` is the characteristic polynomial of every
    minor evaluated at ``lam[i]`` — and for a *tridiagonal* matrix that
    determinant factors over the minor's two decoupled blocks,
    ``det(M_j - xI) = f_j(x) * g_{j+1}(x)`` with ``f``/``g`` the leading /
    trailing principal minors of ``T - xI``.  Both satisfy the Sturm ratio
    recurrence ``q_l = (d_l - x) - e_{l-1}^2 / q_{l-1}`` (one forward pass,
    one backward pass), so *every* minor's determinant at ``x`` falls out of
    two O(n) sweeps + prefix sums of ``log|q|`` — O(n) per eigenvalue
    instead of the O(n^2) of evaluating products over precomputed minor
    spectra, and no minor spectra are needed at all.  This is the windowed
    composition's components stage: O(n k) total for a k-window.

    ``d (n,)``, ``e (n-1,)``, ``x (k,)`` -> ``(k, n)`` log-magnitudes.
    A ``pivmin`` floor (as in Sturm counting) keeps the ratios finite when
    ``x`` grazes an eigenvalue of a leading/trailing block.
    """
    n = d.shape[0]
    k = x.shape[0]
    if n == 1:
        return jnp.zeros((k, 1), d.dtype)
    eps = jnp.finfo(d.dtype).eps
    scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.max(jnp.abs(e)))
    tiny = jnp.asarray(jnp.finfo(d.dtype).tiny, d.dtype)
    pivmin = jnp.maximum(eps * eps * scale * scale, tiny)

    def clamp(q):
        return jnp.where(jnp.abs(q) < pivmin, -pivmin, q)

    e2 = e * e

    def fwd(q, de):
        dl, e2l = de
        q = clamp(dl - x - e2l / q)
        return q, q

    q1 = clamp(d[0] - x)  # (k,)
    _, q_rest = jax.lax.scan(fwd, q1, (d[1:n - 1], e2[: n - 2]))
    q_all = jnp.concatenate([q1[None], q_rest])  # (n-1, k): q_1 .. q_{n-1}
    # logF[j] = log|f_j| = sum_{l <= j} log|q_l|, j = 0 .. n-1.
    log_f = jnp.concatenate([
        jnp.zeros((1, k), d.dtype),
        jnp.cumsum(jnp.log(jnp.abs(q_all)), axis=0),
    ])

    def bwd(p, de):
        dm, e2m = de
        p = clamp(dm - x - e2m / p)
        return p, p

    p_last = clamp(d[n - 1] - x)  # p_{n-1}
    _, p_rest = jax.lax.scan(
        bwd, p_last, (d[1:n - 1][::-1], e2[1:n - 1][::-1]))
    p_all = jnp.concatenate([p_rest[::-1], p_last[None]])  # p_1 .. p_{n-1}
    # logG[m] = log|g_m| = sum_{l >= m} log|p_l|, m = 1 .. n (logG[n] = 0).
    log_p = jnp.log(jnp.abs(p_all))
    log_g = jnp.concatenate([
        jnp.cumsum(log_p[::-1], axis=0)[::-1],
        jnp.zeros((1, k), d.dtype),
    ])
    # log|det(M_j - xI)| = logF[j] + logG[j+1], j = 0 .. n-1.
    return jnp.swapaxes(log_f + log_g, 0, 1)


def tridiag_windowed_magnitudes(d: jax.Array, e: jax.Array,
                                lam_sel: jax.Array) -> jax.Array:
    """Normalized ``|w[i, j]|^2`` rows for selected eigenvalues, ``(k, n)``.

    Each row is the EEI numerator (minor determinants at ``lam_sel[i]``,
    via :func:`tridiag_minor_logdets`) normalized by its own log-sum-exp.
    The Cauchy denominator ``prod_{k != i}(lam_i - lam_k)`` is constant in
    ``j`` and equals ``sum_j`` of the numerators (because
    ``sum_j |v[i, j]|^2 = 1``), so normalizing by the row sum *is* the
    identity — computed without the other ``n - k`` eigenvalues, which is
    what lets the windowed composition skip the full spectrum and the whole
    minor-spectra stage.
    """
    log_num = tridiag_minor_logdets(d, e, lam_sel)  # (k, n)
    log_num = log_num - jnp.max(log_num, axis=-1, keepdims=True)
    w = jnp.exp(log_num)
    return w / jnp.sum(w, axis=-1, keepdims=True)


def tridiag_windowed_magnitudes_batched(d, e, lam_sel):
    """Leading-axis batched :func:`tridiag_windowed_magnitudes`."""
    from repro.linalg.batching import vmap_leading

    return vmap_leading(tridiag_windowed_magnitudes, d.ndim - 1)(
        d, e, lam_sel)


# ---------------------------------------------------------------------------
# Variant: baseline (Algorithm 1, faithful)
# ---------------------------------------------------------------------------


def component_baseline(a: jax.Array, i: int, j: int) -> jax.Array:
    """Algorithm 1 of the paper: recompute spectra per call, scalar loops.

    Deliberately naive — this is the paper's baseline and exists to anchor the
    benchmark ladder.  Not jit-able over ``i, j`` loops by design (python
    loops, like the reference implementation of Denton et al.).
    """
    n = a.shape[0]
    lam = jnp.linalg.eigvalsh(a)
    mu = jnp.linalg.eigvalsh(minors_lib.minor(a, jnp.asarray(j)))
    numerator = jnp.asarray(1.0, dtype=a.dtype)
    for k in range(n - 1):
        numerator = numerator * (lam[i] - mu[k])
    denominator = jnp.asarray(1.0, dtype=a.dtype)
    for k in range(n):
        if k != i:
            denominator = denominator * (lam[i] - lam[k])
    return numerator / denominator


# ---------------------------------------------------------------------------
# Variant: cached (spectra once, scalar loops)
# ---------------------------------------------------------------------------


def component_cached(lam: jax.Array, mu_j: jax.Array, i: int) -> jax.Array:
    """Spectra precomputed; python-loop products (paper's first improvement)."""
    n = lam.shape[0]
    numerator = jnp.asarray(1.0, dtype=lam.dtype)
    for k in range(n - 1):
        numerator = numerator * (lam[i] - mu_j[k])
    denominator = jnp.asarray(1.0, dtype=lam.dtype)
    for k in range(n):
        if k != i:
            denominator = denominator * (lam[i] - lam[k])
    return numerator / denominator


# ---------------------------------------------------------------------------
# Variant: vectorized
# ---------------------------------------------------------------------------


def component_vectorized(lam: jax.Array, mu_j: jax.Array, i) -> jax.Array:
    """Array products over ``k`` (paper's vectorized variant)."""
    n = lam.shape[0]
    numer = jnp.prod(lam[i] - mu_j)
    denom_terms = jnp.where(jnp.arange(n) == i, 1.0, lam[i] - lam)
    return numer / jnp.prod(denom_terms)


# ---------------------------------------------------------------------------
# Variant: batched (Algorithm 2) and parallel (vmap dispatch)
# ---------------------------------------------------------------------------


def _paired_terms(lam: jax.Array, mu_j: jax.Array, i):
    """Length ``n-1`` paired numerator/denominator terms of Algorithm 2.

    Line 6 of Algorithm 2 deletes ``lam[i]`` from the matrix spectrum so both
    products have ``n-1`` terms that can be batch-paired.
    """
    lam_wo_i = minors_lib.delete_index(lam, jnp.asarray(i))
    numer_terms = lam[i] - mu_j
    denom_terms = lam[i] - lam_wo_i
    return numer_terms, denom_terms


def component_batched(lam, mu_j, i, batch_size: int = 64) -> jax.Array:
    """Algorithm 2: per-batch partial ratios, multiplied sequentially.

    Pairing each numerator term with a denominator term keeps every partial
    ratio O(1) in magnitude (interlacing makes paired terms comparable), which
    is what fixes the paper's observed overflow at ``n >~ 150``.
    """
    numer_terms, denom_terms = _paired_terms(lam, mu_j, i)
    m = numer_terms.shape[0]
    pad = (-m) % batch_size
    numer_terms = jnp.concatenate([numer_terms, jnp.ones((pad,), lam.dtype)])
    denom_terms = jnp.concatenate([denom_terms, jnp.ones((pad,), lam.dtype)])
    nb = numer_terms.shape[0] // batch_size
    num_b = jnp.prod(numer_terms.reshape(nb, batch_size), axis=1)
    den_b = jnp.prod(denom_terms.reshape(nb, batch_size), axis=1)
    # Sequential multiply of per-batch ratios (Algorithm 2 line 13-15).
    return jnp.prod(num_b / den_b)


def component_parallel(lam, mu_j, i, batch_size: int = 64) -> jax.Array:
    """Algorithm 2 with batch dispatch on ``vmap`` lanes (TPU thread-pool)."""
    numer_terms, denom_terms = _paired_terms(lam, mu_j, i)
    m = numer_terms.shape[0]
    pad = (-m) % batch_size
    numer_terms = jnp.concatenate([numer_terms, jnp.ones((pad,), lam.dtype)])
    denom_terms = jnp.concatenate([denom_terms, jnp.ones((pad,), lam.dtype)])
    nb = numer_terms.shape[0] // batch_size

    def batch_ratio(nt, dt):  # one dispatched batch
        return jnp.prod(nt) / jnp.prod(dt)

    ratios = jax.vmap(batch_ratio)(
        numer_terms.reshape(nb, batch_size), denom_terms.reshape(nb, batch_size)
    )
    return jnp.prod(ratios)


# ---------------------------------------------------------------------------
# Variant: logspace (beyond paper)
# ---------------------------------------------------------------------------


def component_logspace(lam, mu_j, i, eps: float | None = None) -> jax.Array:
    """log-domain EEI: immune to fp over/underflow at any ``n``.

    The overall sign is guaranteed non-negative by Cauchy interlacing, so only
    ``log|diff|`` is needed.  Degenerate gaps are clamped at ``eps * scale``.
    """
    n = lam.shape[0]
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    if eps is None:
        eps = float(jnp.finfo(lam.dtype).eps)
    floor = eps * scale
    numer = jnp.sum(jnp.log(jnp.maximum(jnp.abs(lam[i] - mu_j), floor)))
    denom_terms = jnp.where(
        jnp.arange(n) == i, 1.0, jnp.maximum(jnp.abs(lam[i] - lam), floor)
    )
    denom = jnp.sum(jnp.log(denom_terms))
    return jnp.exp(numer - denom)


# ---------------------------------------------------------------------------
# Full-row / full-matrix APIs (what applications actually call)
# ---------------------------------------------------------------------------


def magnitudes_from_spectra(lam: jax.Array, mu: jax.Array, logspace: bool = True,
                            reduce: str = "sum", rows=None):
    """All ``|v[i, j]|^2`` from precomputed spectra; shape ``(..., n, n)``.

    ``i`` indexes eigenvalues (rows), ``j`` components (columns).
    ``reduce="dot"`` selects the fused contraction form of the numerator
    (see ``logabs_numerator_dot``).  Degenerate gaps are clamped at
    ``eps * spectral scale`` so exactly-repeated eigenvalues stay finite.

    ``rows`` (optional, ``(k,)`` eigenvalue indices) windows the numerator:
    only the selected rows' difference products are evaluated — the
    stage-graph's windowed components path, O(n^2 k) instead of O(n^3).
    The gap floor and the Cauchy denominator are still functions of the
    *full* spectrum, computed exactly as the unwindowed path computes them
    and row-sliced, so the ``(k, n)`` result is bitwise-equal to the
    matching rows of the full table.

    Leading batch axes are supported: ``lam (..., n)``, ``mu (..., n, n-1)``
    map elementwise over the stack (the SolverEngine's batched path);
    ``rows`` is shared across the stack.
    """
    if lam.ndim > 1:
        from repro.linalg.batching import vmap_leading

        fn = lambda l, m: magnitudes_from_spectra(
            l, m, logspace=logspace, reduce=reduce, rows=rows)
        return vmap_leading(fn, lam.ndim - 1)(lam, mu)
    lam_rows = lam if rows is None else lam[rows]
    if logspace:
        scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
        floor = jnp.finfo(lam.dtype).eps * scale
        if reduce == "dot":
            log_num = logabs_numerator_dot(lam_rows, mu, floor=floor)
            log_den = logabs_denominator_dot(lam)
        else:
            diff_n = jnp.maximum(
                jnp.abs(lam_rows[:, None, None] - mu[None, :, :]), floor)
            log_num = jnp.sum(jnp.log(diff_n), axis=-1)  # (rows, n)
            diff_d = jnp.abs(lam[:, None] - lam[None, :])
            diff_d = jnp.where(jnp.eye(lam.shape[0], dtype=bool), 1.0,
                               jnp.maximum(diff_d, floor))
            log_den = jnp.sum(jnp.log(diff_d), axis=-1)  # (n,)
        if rows is not None:
            log_den = log_den[rows]
        return jnp.exp(log_num - log_den[:, None])
    den = denominator_products(lam)
    if rows is not None:
        den = den[rows]
    return numerator_products(lam_rows, mu) / den[:, None]


def eigenvector_magnitudes(a: jax.Array, i, logspace: bool = True) -> jax.Array:
    """``|v[i, :]|^2`` — one full eigenvector's component magnitudes."""
    lam = matrix_spectrum(a)
    mu = minor_spectra(a)
    fn = component_logspace if logspace else component_vectorized
    return jax.vmap(lambda j: fn(lam, mu[j], i))(jnp.arange(a.shape[0]))


def eigenmatrix_magnitudes(a: jax.Array, logspace: bool = True) -> jax.Array:
    """``|v[i, j]|^2`` for all ``(i, j)``; rows are eigenvectors."""
    lam = matrix_spectrum(a)
    mu = minor_spectra(a)
    return magnitudes_from_spectra(lam, mu, logspace=logspace)


def component(
    a: jax.Array, i, j, variant: str = "logspace", batch_size: int = 64
) -> jax.Array:
    """Single component ``|v[i, j]|^2`` via a named variant."""
    if variant == "baseline":
        return component_baseline(a, i, j)
    lam = matrix_spectrum(a)
    mu_j = jnp.linalg.eigvalsh(minors_lib.minor(a, jnp.asarray(j)))
    if variant == "cached":
        return component_cached(lam, mu_j, i)
    if variant == "vectorized":
        return component_vectorized(lam, mu_j, i)
    if variant == "batched":
        return component_batched(lam, mu_j, i, batch_size)
    if variant == "parallel":
        return component_parallel(lam, mu_j, i, batch_size)
    if variant == "logspace":
        return component_logspace(lam, mu_j, i)
    raise ValueError(f"unknown variant {variant!r}")


VARIANTS: dict[str, Callable] = {
    "baseline": component_baseline,
    "cached": component_cached,
    "vectorized": component_vectorized,
    "batched": component_batched,
    "parallel": component_parallel,
    "logspace": component_logspace,
}


@functools.partial(jax.jit, static_argnames=("variant", "batch_size"))
def component_jit(a, i, j, variant: str = "logspace", batch_size: int = 64):
    """Jitted single-component entry point (variants except baseline/cached)."""
    return component(a, i, j, variant=variant, batch_size=batch_size)
