"""Minor construction for the eigenvector-eigenvalue identity.

The identity needs the spectra of all principal minors ``M_j`` of a Hermitian
matrix ``A`` (delete row and column ``j``).  Two representations are provided:

* dense minors — a gather-based ``delete`` that works under ``vmap``/``jit``
  with a *traced* index ``j`` (``np.delete`` does not);
* tridiagonal minors — removing row/column ``j`` of a symmetric tridiagonal
  matrix ``T`` yields two *decoupled* tridiagonal blocks, expressible as a
  single tridiagonal system with a zeroed coupling entry.  This is the
  TPU-native representation: no data movement, index arithmetic only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def delete_index(x: jax.Array, j: jax.Array) -> jax.Array:
    """Remove element ``j`` from a 1-D array (traced-``j`` safe)."""
    n = x.shape[0]
    idx = jnp.arange(n - 1)
    return x[idx + (idx >= j)]


def minor(a: jax.Array, j: jax.Array) -> jax.Array:
    """Principal minor of ``a`` obtained by deleting row and column ``j``.

    Works with a traced ``j`` (uses gathers, not boolean masks), so it can be
    ``vmap``-ed over ``j`` to build all minors at once.
    """
    n = a.shape[0]
    idx = jnp.arange(n - 1)
    sel = idx + (idx >= j)
    return a[sel][:, sel]


def all_minors(a: jax.Array) -> jax.Array:
    """Stack of all ``n`` principal minors, shape ``(n, n-1, n-1)``.

    Memory is O(n^3); for large ``n`` prefer the tridiagonal representation
    (``tridiagonal_minor_bands``) which is O(n^2).
    """
    n = a.shape[0]
    return jax.vmap(lambda j: minor(a, j))(jnp.arange(n))


def tridiagonal_minor_bands(d: jax.Array, e: jax.Array, j: jax.Array):
    """Bands of the minor ``M_j`` of a symmetric tridiagonal matrix.

    Removing row/column ``j`` from ``T = tridiag(e, d, e)`` (``d``: shape
    ``(n,)`` diagonal, ``e``: shape ``(n-1,)`` off-diagonal) produces a matrix
    that is *again* tridiagonal: the leading block ``T[:j, :j]`` and the
    trailing block ``T[j+1:, j+1:]`` are decoupled.  In the new indexing the
    off-diagonal entry bridging them is exactly zero.

    Returns ``(d_minor, e_minor)`` with shapes ``(n-1,)`` and ``(n-2,)``.

    Derivation: with ``f(p) = p + (p >= j)`` the new off-diagonal is
    ``e'_p = T[f(p), f(p+1)]`` which equals ``e[f(p)]`` when the old indices
    are adjacent (``p != j-1``) and ``0`` when they straddle the removed index
    (``p == j-1``).
    """
    n = d.shape[0]
    p = jnp.arange(n - 1)
    d_minor = d[p + (p >= j)]
    q = jnp.arange(n - 2)
    e_minor = jnp.where(q == j - 1, 0.0, e[jnp.minimum(q + (q >= j), n - 2)])
    return d_minor, e_minor


def all_tridiagonal_minor_bands(d: jax.Array, e: jax.Array):
    """Bands for every minor: shapes ``(n, n-1)`` and ``(n, n-2)``."""
    n = d.shape[0]
    return jax.vmap(lambda j: tridiagonal_minor_bands(d, e, j))(jnp.arange(n))


def all_tridiagonal_minor_bands_batched(d: jax.Array, e: jax.Array):
    """``all_tridiagonal_minor_bands`` over leading batch axes.

    ``d (..., n)``, ``e (..., n-1)`` -> bands ``(..., n, n-1)``/``(..., n, n-2)``.
    """
    from repro.linalg.batching import vmap_leading

    return vmap_leading(all_tridiagonal_minor_bands, d.ndim - 1)(d, e)
