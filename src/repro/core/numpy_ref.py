"""Paper-faithful NumPy implementations (the reproduction baseline).

The paper benchmarks pure-NumPy/CPython code against ``numpy.linalg.eigh``
(LAPACK).  These functions mirror the paper's Algorithm 1 (baseline) and
Algorithm 2 (batched + thread-dispatched) exactly, including the
thread-pool dispatch whose Amdahl-limited behaviour the paper reports.

They are used by ``benchmarks/`` to reproduce Table 1 and Fig. 1 and serve as
independent oracles for the JAX implementations.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

import numpy as np


def eigen_component_baseline(matrix: np.ndarray, i: int, j: int) -> float:
    """Algorithm 1 — recompute everything per call, scalar loops."""
    n = matrix.shape[0]
    minor = np.delete(np.delete(matrix, j, axis=0), j, axis=1)
    matrix_ev = np.linalg.eigvalsh(matrix)
    minor_ev = np.linalg.eigvalsh(minor)
    numerator = 1.0
    for k in range(n - 1):
        numerator *= matrix_ev[i] - minor_ev[k]
    denominator = 1.0
    for k in range(n):
        if k != i:
            denominator *= matrix_ev[i] - matrix_ev[k]
    return numerator / denominator


def eigen_component_cached(
    matrix_ev: np.ndarray, minor_ev: np.ndarray, i: int
) -> float:
    """Spectra cached, scalar loops."""
    n = matrix_ev.shape[0]
    numerator = 1.0
    for k in range(n - 1):
        numerator *= matrix_ev[i] - minor_ev[k]
    denominator = 1.0
    for k in range(n):
        if k != i:
            denominator *= matrix_ev[i] - matrix_ev[k]
    return numerator / denominator


def eigen_component_vectorized(
    matrix_ev: np.ndarray, minor_ev: np.ndarray, i: int
) -> float:
    """Vectorized products."""
    numer = np.prod(matrix_ev[i] - minor_ev)
    denom_terms = np.delete(matrix_ev[i] - matrix_ev, i)
    return numer / np.prod(denom_terms)


def _batch_ratio(args) -> float:
    numer_terms, denom_terms = args
    return np.prod(numer_terms) / np.prod(denom_terms)


def eigen_component_optimized(
    matrix: np.ndarray,
    i: int,
    j: int,
    batch_size: int = 64,
    executor: ThreadPoolExecutor | None = None,
) -> float:
    """Algorithm 2 — batched paired ratios, optionally thread-dispatched.

    ``PrepareBatches``: the matrix spectrum with ``lam[i]`` removed is paired
    term-by-term against the minor spectrum; batches of paired terms produce
    bounded partial ratios (the paper's overflow fix), which are then joined.
    """
    minor = np.delete(np.delete(matrix, j, axis=0), j, axis=1)
    matrix_ev = np.linalg.eigvalsh(matrix)
    eigen_value = matrix_ev[i]
    matrix_ev_wo = np.delete(matrix_ev, i)
    minor_ev = np.linalg.eigvalsh(minor)

    numer_terms = eigen_value - minor_ev
    denom_terms = eigen_value - matrix_ev_wo
    batches = [
        (numer_terms[k : k + batch_size], denom_terms[k : k + batch_size])
        for k in range(0, numer_terms.shape[0], batch_size)
    ]
    if executor is not None:  # the paper's parallel dispatch (Fig 1, "parallelized")
        ratios = list(executor.map(_batch_ratio, batches))
    else:
        ratios = [_batch_ratio(b) for b in batches]
    component = 1.0
    for r in ratios:
        component *= r
    return component


def eigenvector_magnitudes(matrix: np.ndarray, i: int) -> np.ndarray:
    """|v[i, :]|^2 via Algorithm 2 applied per component."""
    n = matrix.shape[0]
    return np.array([eigen_component_optimized(matrix, i, j) for j in range(n)])


def numpy_full_eigh(matrix: np.ndarray):
    """The state-of-the-art the paper compares against (always full set)."""
    return np.linalg.eigh(matrix)
