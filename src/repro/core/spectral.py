"""DEPRECATED — ``SpectralEngine`` is now a thin shim over ``repro.engine``.

The façade's scattered dispatch (``method`` strings + a ``use_kernels`` flag
+ lazy kernel imports) is replaced by the plan-driven
:class:`repro.engine.SolverEngine`; see ``docs/ARCHITECTURE.md`` for the
layering and migration table.  The mapping is mechanical:

    SpectralEngine(method=m)                   -> SolverEngine(SolverPlan(
    SpectralEngine(method=m, use_kernels=True)      method=m,
                                                    backend="pallas"|"jnp",
                                                    bisect_iters=...))
    .component_magnitudes(a)                   -> .solve(a).magnitudes
    .topk_eigenpairs(a, k)                     -> .topk(a, k)
    .eigenvalues(a)                            -> .eigenvalues(a)

Behavioural note: ``component_magnitudes`` with ``method="eei_tridiag"``
used to return magnitudes in the *tridiagonal* basis; through the engine it
returns dense-basis magnitudes (back-transformed with ``Q``) like every
other method — strictly more useful and oracle-comparable.

New code should construct a ``SolverPlan`` (or call ``plan_for``) directly;
this shim exists only so pre-engine callers keep working and will be removed
once nothing imports it.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax

# Submodule imports (not the package) so this shim stays importable while
# ``repro.engine`` itself is mid-initialization (engine -> backends ->
# repro.core -> this module).
from repro.engine.engine import SolverEngine
from repro.engine.plan import SolverPlan

Method = Literal["eigh", "eei_dense", "eei_tridiag"]


@dataclasses.dataclass(frozen=True)
class SpectralEngine:
    """Deprecated pre-engine façade; delegates to ``repro.engine``."""

    method: Method = "eei_tridiag"
    use_kernels: bool = False  # historical flag -> backend="pallas"
    bisect_iters: int = 0  # 0 -> dtype default

    @property
    def engine(self) -> SolverEngine:
        return SolverEngine(SolverPlan(
            method=self.method,
            backend="pallas" if self.use_kernels else "jnp",
            bisect_iters=self.bisect_iters,
        ))

    def eigenvalues(self, a: jax.Array) -> jax.Array:
        return self.engine.eigenvalues(a)

    def component_magnitudes(self, a: jax.Array) -> jax.Array:
        """All ``|v[i, j]|^2`` — shape (..., n, n); rows are eigenvectors."""
        return self.engine.solve(a).magnitudes

    def topk_eigenpairs(self, a: jax.Array, k: int, largest: bool = True):
        """Top-k (eigenvalue, signed eigenvector) pairs in the dense basis."""
        lam, vecs = self.engine.topk(a, k, largest=largest)
        return lam, vecs
