"""SpectralEngine — the framework-facing façade over the EEI pipeline.

Consumers (the ``eigenpre`` optimizer, spectral monitors, examples) ask for
*partial* spectral information of symmetric matrices; the engine routes to one
of three paths:

    eigh          ``jnp.linalg.eigh`` — LAPACK-equivalent oracle (the paper's
                  "state of the art" comparison point).
    eei_dense     paper-faithful: ``eigvalsh`` of A and of every dense minor,
                  then EEI products (logspace by default).
    eei_tridiag   TPU-native: Householder tridiagonalize once -> Sturm
                  bisection for λ(A) and for all (decoupled tridiagonal)
                  minors -> EEI on the tridiagonal form -> recurrence signs ->
                  back-transform the requested components with Q.

The tridiagonal path is the beyond-paper contribution: minor spectra cost
O(n^2 · iters) *total* instead of n LAPACK calls of size n-1 (O(n^4)), and
every stage is Pallas-kernelized (``repro.kernels``).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import identity, minors
from repro.core.directions import inverse_iteration_signs, tridiagonal_signs
from repro.linalg import householder, sturm

Method = Literal["eigh", "eei_dense", "eei_tridiag"]


@dataclasses.dataclass(frozen=True)
class SpectralEngine:
    """Partial-spectrum queries over symmetric matrices."""

    method: Method = "eei_tridiag"
    use_kernels: bool = False  # route products/bisection through Pallas
    bisect_iters: int = 0  # 0 -> dtype default

    # -- eigenvalues ---------------------------------------------------------

    def eigenvalues(self, a: jax.Array) -> jax.Array:
        if self.method == "eigh" or self.method == "eei_dense":
            return jnp.linalg.eigvalsh(a)
        d, e, _ = householder.tridiagonalize(a, with_q=False)
        return self._tridiag_eigvals(d, e)

    def _tridiag_eigvals(self, d, e):
        if self.use_kernels:
            from repro.kernels.sturm import ops as sturm_ops

            return sturm_ops.sturm_eigenvalues(
                d[None], e[None], n_iter=self.bisect_iters
            )[0]
        return sturm.bisect_eigenvalues(d, e, n_iter=self.bisect_iters)

    def _tridiag_eigvals_batched(self, d, e):
        if self.use_kernels:
            from repro.kernels.sturm import ops as sturm_ops

            return sturm_ops.sturm_eigenvalues(d, e, n_iter=self.bisect_iters)
        return sturm.bisect_eigenvalues_batched(d, e, n_iter=self.bisect_iters)

    # -- component magnitudes -------------------------------------------------

    def component_magnitudes(self, a: jax.Array) -> jax.Array:
        """All ``|v[i, j]|^2`` — shape (n, n); rows are eigenvectors.

        For the tridiagonal path these are magnitudes of the *tridiagonal*
        eigenvectors ``w``; dense-basis magnitudes require the back-transform
        (see ``topk_eigenpairs``).
        """
        if self.method == "eigh":
            _, v = jnp.linalg.eigh(a)
            return (v * v).T
        if self.method == "eei_dense":
            lam = jnp.linalg.eigvalsh(a)
            mu = identity.minor_spectra(a)
            return self._magnitudes(lam, mu)
        d, e, _ = householder.tridiagonalize(a, with_q=False)
        lam, mu = self._tridiag_spectra(d, e)
        return self._magnitudes(lam, mu)

    def _tridiag_spectra(self, d, e):
        lam = self._tridiag_eigvals(d, e)
        dm, em = minors.all_tridiagonal_minor_bands(d, e)
        mu = self._tridiag_eigvals_batched(dm, em)
        return lam, mu

    def _magnitudes(self, lam, mu):
        if self.use_kernels:
            from repro.kernels.prod_diff import ops as pd_ops

            return pd_ops.eei_magnitudes(lam, mu)
        return identity.magnitudes_from_spectra(lam, mu, logspace=True)

    # -- signed eigenpairs -----------------------------------------------------

    def topk_eigenpairs(self, a: jax.Array, k: int, largest: bool = True):
        """Top-k (eigenvalue, signed eigenvector) pairs in the dense basis.

        This is the partial-spectrum query the paper's use cases (web ranking,
        signal preprocessing, spectral preconditioning) actually issue — the
        regime where EEI beats full eigh.
        """
        n = a.shape[0]
        if self.method == "eigh":
            lam, v = jnp.linalg.eigh(a)
            idx = jnp.arange(n - k, n) if largest else jnp.arange(k)
            return lam[idx], v[:, idx].T

        if self.method == "eei_dense":
            lam = jnp.linalg.eigvalsh(a)
            mu = identity.minor_spectra(a)
            mags = self._magnitudes(lam, mu)
            idx = jnp.arange(n - k, n) if largest else jnp.arange(k)

            def signed(i):
                return inverse_iteration_signs(a, lam[i], mags[i])

            vecs = jax.vmap(signed)(idx)
            return lam[idx], _renormalize(vecs)

        d, e, q = householder.tridiagonalize(a, with_q=True)
        lam, mu = self._tridiag_spectra(d, e)
        mags = self._magnitudes(lam, mu)
        idx = jnp.arange(n - k, n) if largest else jnp.arange(k)

        def signed(i):
            w = tridiagonal_signs(d, e, lam[i], mags[i])
            return q @ w  # back-transform: v = Q w

        vecs = jax.vmap(signed)(idx)
        return lam[idx], _renormalize(vecs)


def _renormalize(vecs: jax.Array) -> jax.Array:
    nrm = jnp.linalg.norm(vecs, axis=-1, keepdims=True)
    return vecs / jnp.maximum(nrm, 1e-30)
