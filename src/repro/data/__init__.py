from repro.data.pipeline import BinTokenDataset, PrefetchIterator  # noqa: F401
from repro.data.synthetic import SyntheticLM, make_synthetic  # noqa: F401
