"""Data pipeline: background prefetch + optional binary token files.

``PrefetchIterator`` overlaps host-side batch construction with device steps
(double buffering).  ``BinTokenDataset`` memory-maps a flat uint16/uint32
token file (the standard packed-LM format) and serves deterministic windows;
``SyntheticLM`` is the default source.  Iterator state is just ``step`` —
checkpointable as a single integer.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class BinTokenDataset:
    """Flat packed token file; window ``i`` = tokens[i*S : i*S + S + 1]."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 dtype=np.uint16, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.n_windows = (len(self.tokens) - 1) // seq_len
        self.seed = seed
        if self.n_windows < 1:
            raise ValueError("dataset smaller than one window")

    def shard_at(self, step: int, host: int = 0, n_hosts: int = 1) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, self.n_windows, size=self.global_batch)
        idx = idx[host::n_hosts]
        s = self.seq_len
        rows = np.stack([
            np.asarray(self.tokens[i * s: i * s + s + 1], dtype=np.int32)
            for i in idx
        ])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class PrefetchIterator:
    """Background-thread prefetch of ``source.shard_at(step, ...)``."""

    def __init__(self, source, start_step: int = 0, host: int = 0,
                 n_hosts: int = 1, depth: int = 2):
        self.source = source
        self.host = host
        self.n_hosts = n_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next_to_produce = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.source.shard_at(self._next_to_produce, self.host,
                                         self.n_hosts)
            step = self._next_to_produce
            self._next_to_produce += 1
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def close(self):
        self._stop.set()
