"""Deterministic synthetic token stream (host-sharded, restart-exact).

Every (step, host) pair maps to an independent PCG64 stream, so data is
* deterministic across restarts (fault-tolerance requirement: resuming from a
  checkpoint at step k replays exactly the batches k, k+1, ...),
* disjoint across hosts (each host draws only its shard of the global batch),
* independent of the number of hosts *for a fixed shard layout* (elastic
  restarts re-slice the same global stream).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    d_model: int = 0  # for frame/image stubs
    enc_seq: int = 0
    img_seq: int = 0

    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row])
        )

    def global_batch_at(self, step: int) -> dict:
        return self.shard_at(step, 0, 1)

    def shard_at(self, step: int, host: int, n_hosts: int) -> dict:
        """Rows [host::n_hosts] of the global batch for ``step``."""
        assert self.global_batch % n_hosts == 0
        rows = range(host, self.global_batch, n_hosts)
        toks = np.stack(
            [self._rng(step, r).integers(0, self.vocab_size,
                                         size=self.seq_len + 1,
                                         dtype=np.int32) for r in rows]
        )
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.enc_seq:
            out["frames"] = np.stack([
                self._rng(step, r).standard_normal(
                    (self.enc_seq, self.d_model)).astype(np.float32) * 0.02
                for r in rows
            ])
        if self.img_seq:
            out["images"] = np.stack([
                self._rng(step, r).standard_normal(
                    (self.img_seq, self.d_model)).astype(np.float32) * 0.02
                for r in rows
            ])
        return out


def make_synthetic(cfg, shape, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        d_model=cfg.d_model,
        enc_seq=cfg.enc_seq if cfg.family == "audio" else 0,
        img_seq=cfg.img_seq if cfg.family == "vlm" else 0,
    )
