"""The EEI SolverEngine subsystem: plan -> backend registry -> engine.

    from repro.engine import SolverEngine, SolverPlan, plan_for

    plan = plan_for(stack.shape, k=4, mesh=mesh)     # or SolverPlan(...)
    engine = SolverEngine(plan)
    lam, mags = engine.solve(stack)                  # (b, n), (b, n, n)
    top = engine.topk(stack, k=4)                    # (b, k), (b, k, n)

See ``docs/ARCHITECTURE.md`` for the layering, the batched kernel grid, and
the autotune -> plan calibration flow (``repro.engine.autotune``).
"""

from repro.engine.autotune import (  # noqa: F401
    CalibrationTable,
    calibrate,
    get_table,
    load_table,
    set_table,
)
from repro.engine.plan import (  # noqa: F401
    BackendName,
    Method,
    SolverPlan,
    Spectrum,
    fallback_chain,
    plan_for,
    resolved_crossovers,
    resolved_krylov_n_min,
    resolved_windowed_k_frac,
)
from repro.engine.registry import (  # noqa: F401
    Composition,
    StageLibrary,
    StageSig,
    available_backends,
    available_compositions,
    composition_for,
    get_backend,
    get_composition,
    register_backend,
    register_composition,
)
from repro.engine import backends as _backends  # noqa: F401  (registers defaults)
from repro.engine.engine import (  # noqa: F401
    SolveResult,
    SolverEngine,
    TopkResult,
)
from repro.engine.session import (  # noqa: F401
    Rank1Update,
    SessionConfig,
    SessionVerifyError,
    SpectralSession,
)
from repro.engine.verify import (  # noqa: F401
    VerifyFlags,
    verify_topk,
    verify_topk_host,
)
from repro.engine.server import (  # noqa: F401
    DegradedResult,
    DispatchRecord,
    EeiServer,
    ProgramCache,
    QueueFull,
    ServerClosed,
    ShapeBucket,
    VerifyFailed,
)
from repro.engine.fleet import (  # noqa: F401
    EeiFleet,
    FleetClosed,
    InProcessReplica,
    ReplicaDied,
    SubprocessReplica,
)
