"""Measured planner calibration: block shapes + method crossovers per host.

The planner's method heuristics (``plan.EIGH_CROSSOVER_N`` /
``DENSE_CROSSOVER_N``) and the Pallas kernels' tile shapes are
hardware-dependent — the paper's own Table 1 shows the eigh/EEI crossover
moving with the BLAS backing.  This module closes the loop from measurement
to dispatch:

* :func:`calibrate` sweeps kernel block shapes, method crossovers and the
  windowed-composition ``k / n`` crossover with the same timing harness as
  ``benchmarks/throughput.py`` and returns a :class:`CalibrationTable`;
* tables persist as JSON — per-host under ``~/.cache/repro/`` (or
  ``$REPRO_CALIBRATION``), with a repo-checked default
  (``calibration_default.json``) so fresh checkouts plan from measured
  numbers, not guesses;
* :func:`get_table` is the process-global resolution the planner
  (``plan.resolved_crossovers``) and the pallas backend (kernel blocks)
  consult; the static constants in ``plan.py`` remain only as the
  uncalibrated fallback when no table can be found.

Resolution order: :func:`set_table` override > ``$REPRO_CALIBRATION`` path >
``~/.cache/repro/calibration.json`` > the repo default.  Regenerate with::

    PYTHONPATH=src python -m repro.engine.autotune [--smoke] [--out PATH]
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import platform
import time
from pathlib import Path
from typing import Optional, Sequence

import jax

from repro.engine.plan import WINDOWED_K_FRAC

log = logging.getLogger("repro.autotune")

CALIBRATION_ENV = "REPRO_CALIBRATION"
CACHE_PATH = Path.home() / ".cache" / "repro" / "calibration.json"
REPO_DEFAULT_PATH = Path(__file__).with_name("calibration_default.json")

#: v1 (PR 2): jnp-only crossovers, 3-tuple prod_diff blocks (bb fixed at 1).
#: v2 (PR 3) adds the batch tile ``prod_diff_block_b`` and pallas-backend
#: crossover measurements.  v3 (PR 5) adds ``windowed_k_frac`` — the
#: measured ``k / n`` fraction at/below which the planner routes top-k
#: queries through the windowed stage composition.  v4 (PR 6) adds
#: ``krylov_n_min`` — the measured ``n`` at/above which the Lanczos partial
#: reduce beats the dense Householder reduce for narrow top-k windows.
#: v5 (PR 9) adds the packed-dispatch pair: ``pack_n_max`` — the largest
#: bucketed ``n`` whose requests are worth coalescing into segment-packed
#: rows — and ``packed_eigh_n_max`` — the packed *row width* at/below which
#: the packed chain pins the LAPACK eigh composition (above it the
#: segmented-Sturm tridiagonal chain wins).
#: Older tables still load (warn once per process + defaults for the
#: missing fields): a v2 table plans windows from the static
#: ``plan.WINDOWED_K_FRAC`` fallback exactly like an uncalibrated host, a
#: v3 table routes Krylov from the static ``plan.KRYLOV_N_MIN``, a v4
#: table packs from the static ``plan.PACK_N_MAX`` / ``PACKED_EIGH_N_MAX``.
_SCHEMA_VERSION = 5


@dataclasses.dataclass(frozen=True)
class CalibrationTable:
    """One host class's measured pipeline constants (see module docstring)."""

    eigh_crossover_n: int  # n below which LAPACK eigh wins outright (jnp)
    dense_crossover_n: int  # n up to which dense minors beat tridiag (jnp)
    prod_diff_blocks: tuple  # (block_i, block_j, block_k)
    sturm_blocks: tuple  # (block_b, block_m)
    prod_diff_block_b: int = 1  # bb — matrices per batch-grid step
    pallas_eigh_crossover_n: Optional[int] = None  # None -> use jnp value
    pallas_dense_crossover_n: Optional[int] = None  # None -> use jnp value
    windowed_k_frac: float = WINDOWED_K_FRAC  # k/n below which windowed wins
    krylov_n_min: Optional[int] = None  # n at which krylov reduce wins;
    # None -> the static plan.KRYLOV_N_MIN fallback (pre-v4 tables)
    pack_n_max: Optional[int] = None  # largest bucketed n worth packing;
    # None -> the static plan.PACK_N_MAX fallback (pre-v5 tables)
    packed_eigh_n_max: Optional[int] = None  # packed row width at/below
    # which the packed chain pins eigh; None -> plan.PACKED_EIGH_N_MAX
    host: str = ""  # host class the numbers were measured on
    backend: str = ""  # jax backend (cpu | tpu | gpu) at measurement
    measured_at: str = ""  # ISO timestamp, empty for hand-written tables
    source: str = "memory"  # where the table was loaded from

    def crossovers_for(self, backend: Optional[str] = None) -> tuple:
        """``(eigh_crossover_n, dense_crossover_n)`` for a plan backend.

        The pallas kernels amortize differently than fused jnp (the paper's
        Table 1 shows the crossover moving with the BLAS backing, and it
        moves again with kernelized EEI), so v2 tables carry a second
        measured pair; any other backend — and v1 tables, whose pallas
        fields are None — falls back to the jnp pair.
        """
        if backend == "pallas" and self.pallas_eigh_crossover_n is not None:
            return (self.pallas_eigh_crossover_n,
                    self.pallas_dense_crossover_n
                    if self.pallas_dense_crossover_n is not None
                    else self.dense_crossover_n)
        return self.eigh_crossover_n, self.dense_crossover_n

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("source")
        d["schema_version"] = _SCHEMA_VERSION
        d["prod_diff_blocks"] = list(self.prod_diff_blocks)
        d["sturm_blocks"] = list(self.sturm_blocks)
        return d

    @classmethod
    def from_dict(cls, d: dict, source: str = "memory") -> "CalibrationTable":
        version = int(d.get("schema_version", _SCHEMA_VERSION))
        if version > _SCHEMA_VERSION:
            raise ValueError(
                f"calibration table schema_version {version} is newer than "
                f"this code understands ({_SCHEMA_VERSION})")
        if version < _SCHEMA_VERSION:
            _warn_once(
                (source, version),
                "calibration table %s has schema_version %d (current %d); "
                "loading with defaults for the missing fields (v1: bb=1 + "
                "pallas crossovers from the jnp sweep; v2: windowed_k_frac "
                "from the static fallback) — re-run "
                "`python -m repro.engine.autotune` to refresh it",
                source, version, _SCHEMA_VERSION)

        def _opt_int(key):
            return int(d[key]) if d.get(key) is not None else None

        return cls(
            eigh_crossover_n=int(d["eigh_crossover_n"]),
            dense_crossover_n=int(d["dense_crossover_n"]),
            prod_diff_blocks=tuple(int(x) for x in d["prod_diff_blocks"]),
            sturm_blocks=tuple(int(x) for x in d["sturm_blocks"]),
            prod_diff_block_b=int(d.get("prod_diff_block_b", 1)),
            pallas_eigh_crossover_n=_opt_int("pallas_eigh_crossover_n"),
            pallas_dense_crossover_n=_opt_int("pallas_dense_crossover_n"),
            windowed_k_frac=float(
                d.get("windowed_k_frac", WINDOWED_K_FRAC)),
            krylov_n_min=_opt_int("krylov_n_min"),
            pack_n_max=_opt_int("pack_n_max"),
            packed_eigh_n_max=_opt_int("packed_eigh_n_max"),
            host=str(d.get("host", "")),
            backend=str(d.get("backend", "")),
            measured_at=str(d.get("measured_at", "")),
            source=source,
        )

    def save(self, path: Path) -> Path:
        path = Path(path).expanduser()
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2) + "\n")
        return path


#: (source, version) pairs already warned about — old-schema tables get
#: re-loaded freely (serve --calibration, tests, every fresh ``load_table``
#: call), and repeating the same warning every time buries real signal.
#: Deduped per process; keyed on the source too, so two *different* stale
#: files each still get their one warning.
_WARNED: set = set()


def _warn_once(key, msg: str, *args) -> None:
    if key in _WARNED:
        return
    _WARNED.add(key)
    log.warning(msg, *args)


def host_key() -> str:
    """Host class the calibration is keyed on (platform + device kind)."""
    dev = jax.devices()[0]
    return f"{platform.machine()}-{jax.default_backend()}-{dev.device_kind}"


def load_table(path: Optional[os.PathLike] = None) -> Optional[CalibrationTable]:
    """Load a table from ``path`` or the resolution chain (None if absent).

    An explicit ``path`` (or ``$REPRO_CALIBRATION``) is trusted verbatim and
    must exist.  Chain candidates (user cache, repo default) are measured
    artifacts that may have been produced on a different host class — they
    are skipped unless their recorded ``backend`` matches this process's jax
    backend, so a CPU-measured repo default never governs planning on TPU.
    """
    candidates = []  # (path, source, explicit)
    if path is not None:
        candidates.append((Path(path), f"file:{path}", True))
    else:
        env = os.environ.get(CALIBRATION_ENV)
        if env:
            candidates.append((Path(env), f"env:{env}", True))
        candidates.append((CACHE_PATH, f"cache:{CACHE_PATH}", False))
        candidates.append((REPO_DEFAULT_PATH, "repo-default", False))
    for cand, source, explicit in candidates:
        cand = cand.expanduser()
        if not cand.is_file():
            if explicit:
                raise FileNotFoundError(
                    f"calibration table not found: {cand}")
            continue
        try:
            table = CalibrationTable.from_dict(
                json.loads(cand.read_text()), source=source)
        except (json.JSONDecodeError, KeyError, ValueError) as exc:
            raise ValueError(f"malformed calibration table {cand}: {exc}")
        if (not explicit and table.backend
                and table.backend != jax.default_backend()):
            # Measured on a different host class — skipping is correct
            # (a CPU-measured table must not govern TPU planning), but a
            # silent skip reads as "calibrated" when the planner is in
            # fact running on static fallbacks.  Say so, once per source.
            _warn_once(
                (source, "backend-mismatch", table.backend),
                "calibration table %s was measured on backend %r but this "
                "process runs %r; skipping it (planning falls back to the "
                "next candidate or the static constants) — re-run "
                "`python -m repro.engine.autotune` on this host to "
                "calibrate it",
                source, table.backend, jax.default_backend())
            continue
        return table
    return None


# Process-global resolution, cached after the first lookup.  ``set_table``
# overrides (tests, serve --calibration); ``set_table(None)`` re-resolves.
# Note: the engine caches jitted programs per plan, and the pallas backend
# bakes tile shapes in at program-build time — a table change affects plans
# compiled *afterwards*, not programs already jitted in this process.
_ACTIVE: Optional[CalibrationTable] = None
_RESOLVED = False


def set_table(table: Optional[CalibrationTable]) -> None:
    global _ACTIVE, _RESOLVED
    _ACTIVE = table
    _RESOLVED = table is not None


def get_table() -> Optional[CalibrationTable]:
    """The active calibration table, or None (static-constant fallback)."""
    global _ACTIVE, _RESOLVED
    if not _RESOLVED:
        _ACTIVE = load_table()
        _RESOLVED = True
    return _ACTIVE


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------


def _time(fn, *args, repeat: int = 3, warmup: int = 1) -> float:
    """Mean wall seconds per call (post-warmup, blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeat


def _sym_stack(b: int, n: int, seed: int = 0) -> jax.Array:
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    a = rng.standard_normal((b, n, n)).astype(np.float32)
    return jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)


def _sweep_prod_diff_blocks(
    b: int, n: int, candidates: Sequence[tuple]
) -> tuple:
    """Best ``(bb, bi, bj, bk)`` over the candidate grid (bb swept too —
    b-tiling the batch axis is what recovers occupancy at small ``n``)."""
    from repro.kernels.prod_diff import ops as pd_ops

    a = _sym_stack(b, n)
    import jax.numpy as jnp

    lam = jax.vmap(jnp.linalg.eigvalsh)(a)
    mu = jnp.sort(_sym_stack(b, n, seed=1)[:, :, : n - 1], axis=-1)
    best, best_t = None, float("inf")
    for blk in candidates:
        bb, bi, bj, bk = blk

        def run(lam=lam, mu=mu, bb=bb, bi=bi, bj=bj, bk=bk):
            return pd_ops.eei_magnitudes_batched(
                lam, mu, block_b=bb, block_i=bi, block_j=bj, block_k=bk)

        t = _time(run)
        if t < best_t:
            best, best_t = blk, t
    return best


def _sweep_sturm_blocks(b: int, n: int, candidates: Sequence[tuple]) -> tuple:
    from repro.kernels.sturm import ops as sturm_ops
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.standard_normal((b, n)).astype(np.float32))
    e = jnp.asarray(rng.standard_normal((b, n - 1)).astype(np.float32))
    best, best_t = None, float("inf")
    for blk in candidates:
        bb, bm = blk

        def run(d=d, e=e, bb=bb, bm=bm):
            return sturm_ops.sturm_eigenvalues(d, e, block_b=bb, block_m=bm)

        t = _time(run)
        if t < best_t:
            best, best_t = blk, t
    return best


def _measure_crossovers(
    sizes: Sequence[int], k: int, batch: int, backend: str = "jnp"
):
    """Smallest n where each EEI method beats its cheaper alternative.

    ``backend`` picks whose stage implementations get timed: the planner's
    TPU default is pallas, where the kernelized EEI amortizes differently
    than fused jnp — sweeping only jnp would mis-place the TPU crossovers.
    (The ``eigh`` leg always runs LAPACK regardless of backend.)
    """
    from repro.engine.engine import SolverEngine
    from repro.engine.plan import SolverPlan

    eigh_x = None
    dense_x = None
    # A win at the very first swept size must record a crossover *below* it
    # (plan_for routes n <= crossover to the cheaper method).
    prev_n = max(sizes[0] - 1, 0)
    for n in sizes:
        a = _sym_stack(batch, n)
        times = {}
        for method in ("eigh", "eei_dense", "eei_tridiag"):
            eng = SolverEngine(SolverPlan(method=method, backend=backend))
            times[method] = _time(lambda eng=eng, a=a: eng.topk(a, k))
        best_eei = min(times["eei_dense"], times["eei_tridiag"])
        if eigh_x is None and best_eei < times["eigh"]:
            eigh_x = prev_n  # last size where eigh still won
        if dense_x is None and times["eei_tridiag"] < times["eei_dense"]:
            dense_x = prev_n
        prev_n = n
    # Never observed a win inside the sweep -> the crossover sits above it.
    return eigh_x if eigh_x is not None else sizes[-1], (
        dense_x if dense_x is not None else sizes[-1])


def _measure_windowed_crossover(
    n: int, batch: int, ks: Sequence[int], backend: str = "jnp"
) -> float:
    """Largest measured ``k / n`` where the windowed composition still
    beats the full-spectrum composition on a batched topk.

    Sweeps power-of-two ``k`` (the serving buckets' k axis) on the
    tridiagonal method — the composition whose windowed variant replaces
    the whole minor-spectra stage.  Returns 0.0 if windowed never wins
    (the planner then never routes through it).
    """
    from repro.engine.engine import SolverEngine
    from repro.engine.plan import SolverPlan

    a = _sym_stack(batch, n)
    frac = 0.0
    for k in ks:
        if k > n:
            break
        full = SolverEngine(SolverPlan(
            method="eei_tridiag", backend=backend, spectrum="full"))
        win = SolverEngine(SolverPlan(
            method="eei_tridiag", backend=backend, spectrum="windowed"))
        t_full = _time(lambda eng=full: eng.topk(a, k))
        t_win = _time(lambda eng=win: eng.topk(a, k))
        if t_win < t_full:
            frac = k / n
        else:
            break  # windowed work grows with k; first loss ends the sweep
    return frac


#: ``krylov_n_min`` recorded when the Krylov reduce never won the sweep —
#: far above any real n, so the planner never routes through it (mirrors
#: the sizes[-1] convention of the method-crossover sweep, which cannot be
#: reused verbatim here because the krylov sweep stops at CI-sized n while
#: the true crossover may sit well past it).
KRYLOV_NEVER = 1 << 30


def _measure_krylov_crossover(
    sizes: Sequence[int], k: int, batch: int, backend: str = "jnp"
) -> int:
    """Smallest swept ``n`` where the Krylov reduce beats dense Householder
    on a windowed batched topk, or :data:`KRYLOV_NEVER` if it never does.

    The Lanczos band is O(n^2 m) against the dense reduce's O(n^3), so the
    win is monotone in n for fixed k — the first winning size is the
    crossover.  The sweep keeps ``k`` fixed (the planner additionally
    requires ``k <= n/16``, which bounds the band width relative to n).
    """
    from repro.engine.engine import SolverEngine
    from repro.engine.plan import SolverPlan

    for n in sizes:
        if not 0 < k < n:
            continue
        a = _sym_stack(batch, n)
        dense = SolverEngine(SolverPlan(
            method="eei_tridiag", backend=backend, spectrum="windowed"))
        krylov = SolverEngine(SolverPlan(method="eei_krylov",
                                         backend=backend))
        t_dense = _time(lambda eng=dense, a=a: eng.topk(a, k))
        t_krylov = _time(lambda eng=krylov, a=a: eng.topk(a, k))
        if t_krylov < t_dense:
            return n
    return KRYLOV_NEVER


def _packed_uniform_layout(batch: int, row_n: int, seg_n: int):
    """A uniform packed stack: ``row_n // seg_n`` segments per row."""
    import jax.numpy as jnp
    import numpy as np

    slots = row_n // seg_n
    a = np.asarray(_sym_stack(batch * slots, seg_n))
    rows = np.zeros((batch, row_n, row_n), np.float32)
    off = np.zeros((batch, slots), np.int32)
    length = np.full((batch, slots), seg_n, np.int32)
    for b in range(batch):
        for s in range(slots):
            o = s * seg_n
            rows[b, o:o + seg_n, o:o + seg_n] = a[b * slots + s]
            off[b, s] = o
    return jnp.asarray(a), jnp.asarray(rows), jnp.asarray(off), \
        jnp.asarray(length)


def _measure_pack_crossovers(
    row_ns: Sequence[int], seg_ns: Sequence[int], batch: int, k: int,
    backend: str = "jnp",
) -> tuple:
    """``(pack_n_max, packed_eigh_n_max)`` measured on uniform packed rows.

    ``pack_n_max``: largest segment ``n`` where one packed launch of
    ``(batch, row)`` beats the *fragmented* bucketed service of the same
    requests — ``slots`` separate ``(batch, n)`` launches, one per
    distinct segment size.  That is the stream condition the packer
    replaces: a mixed small-n stream spreads across one coalesce queue
    per distinct ``n``, so the bucketed path pays a launch (and a
    compiled program) per ``n`` while the packed path coalesces them all
    into one row queue.  0 when packing never wins even against the
    fragmented baseline, which keeps the planner's ``"auto"`` gate shut.

    ``packed_eigh_n_max``: largest swept row width where the packed eigh
    chain still beats the packed segmented-tridiagonal chain (mirrors the
    bucketed eigh crossover, which moves again for packed rows because
    eigh pays the full O(row^3) while the segmented chain's Sturm lanes
    pay per-segment brackets).
    """
    from repro.engine.engine import packed_topk_program, topk_program
    from repro.engine.plan import SolverPlan

    eigh_plan = SolverPlan(method="eigh", backend=backend)
    row0 = row_ns[0]
    pack_n_max = 0
    for seg_n in seg_ns:
        if seg_n * 2 > row0:
            break
        a, rows, off, length = _packed_uniform_layout(batch, row0, seg_n)
        slots = row0 // seg_n
        chunks = [a[s * batch:(s + 1) * batch] for s in range(slots)]
        bucketed = topk_program(eigh_plan, k, True)
        packed = packed_topk_program(eigh_plan, k, True)
        t_b = _time(lambda: [bucketed(c) for c in chunks])
        t_p = _time(lambda: packed(rows, off, length))
        if t_p < t_b:
            pack_n_max = seg_n
    seg_n = max(seg_ns[0], 8)
    packed_eigh_n_max = row_ns[-1]
    prev = max(row_ns[0] // 2, seg_n * 2)
    tri_plan = SolverPlan(
        method="eei_tridiag", backend=backend, spectrum="windowed")
    for row_n in row_ns:
        _, rows, off, length = _packed_uniform_layout(batch, row_n, seg_n)
        t_eigh = _time(lambda: packed_topk_program(
            eigh_plan, k, True)(rows, off, length))
        t_tri = _time(lambda: packed_topk_program(
            tri_plan, k, True)(rows, off, length))
        if t_tri < t_eigh:
            packed_eigh_n_max = prev  # last width where eigh still won
            break
        prev = row_n
    return pack_n_max, packed_eigh_n_max


def calibrate(
    *,
    smoke: bool = False,
    batch: int = 16,
    k: int = 4,
) -> CalibrationTable:
    """Measure crossovers + kernel blocks on this host; return the table.

    ``smoke`` shrinks the sweep to a CI-sized sanity pass (seconds, not
    minutes); full runs sweep enough sizes to bracket both crossovers.
    """
    if smoke:
        sizes = [8, 16, 32]
        pd_candidates = [(1, 32, 32, 32), (4, 32, 32, 32), (1, 64, 64, 64)]
        st_candidates = [(8, 64), (8, 128)]
        bench_b, bench_n = 8, 32
        win_n, win_ks = 32, (1, 4, 16, 32)
        krylov_sizes, krylov_k, krylov_b = [64, 128], 4, 2
        pack_rows, pack_segs, pack_b = [32, 64], (8, 16), 2
    else:
        sizes = [8, 16, 24, 32, 48, 64, 96, 128]
        win_n, win_ks = 64, (1, 2, 4, 8, 16, 32, 64)
        pd_candidates = [
            # bb = 1 tiles (the PR-2 grid) ...
            (1, 32, 32, 32), (1, 64, 64, 64), (1, 128, 128, 128),
            (1, 128, 128, 64), (1, 64, 128, 128),
            # ... and b-tiled blocks for the small-n occupancy regime.
            (4, 32, 32, 32), (8, 32, 32, 32), (4, 64, 64, 64),
            (8, 16, 64, 64), (16, 8, 32, 32),
        ]
        st_candidates = [(4, 128), (8, 64), (8, 128), (16, 128), (8, 256)]
        bench_b, bench_n = 64, 64
        krylov_sizes, krylov_k, krylov_b = [256, 512, 1024], 8, 2
        pack_rows, pack_segs, pack_b = [64, 128, 256], (8, 16, 32), 4
    eigh_x, dense_x = _measure_crossovers(sizes, k=k, batch=batch,
                                          backend="jnp")
    # The planner's accelerator default is the pallas backend — time its
    # crossovers too instead of assuming they match fused jnp.
    pallas_eigh_x, pallas_dense_x = _measure_crossovers(
        sizes, k=k, batch=batch, backend="pallas")
    pd_blocks = _sweep_prod_diff_blocks(bench_b, bench_n, pd_candidates)
    st_blocks = _sweep_sturm_blocks(bench_b * bench_n, bench_n, st_candidates)
    windowed_frac = _measure_windowed_crossover(win_n, batch, win_ks)
    krylov_n_min = _measure_krylov_crossover(
        krylov_sizes, k=krylov_k, batch=krylov_b)
    pack_n_max, packed_eigh_n_max = _measure_pack_crossovers(
        pack_rows, pack_segs, batch=pack_b, k=k)
    return CalibrationTable(
        eigh_crossover_n=int(eigh_x),
        dense_crossover_n=int(dense_x),
        prod_diff_blocks=tuple(pd_blocks[1:]),
        sturm_blocks=tuple(st_blocks),
        prod_diff_block_b=int(pd_blocks[0]),
        pallas_eigh_crossover_n=int(pallas_eigh_x),
        pallas_dense_crossover_n=int(pallas_dense_x),
        windowed_k_frac=float(windowed_frac),
        krylov_n_min=int(krylov_n_min),
        pack_n_max=int(pack_n_max),
        packed_eigh_n_max=int(packed_eigh_n_max),
        host=host_key(),
        backend=jax.default_backend(),
        measured_at=time.strftime("%Y-%m-%dT%H:%M:%S"),
        source="measured",
    )


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (seconds, coarse)")
    ap.add_argument("--out", default=str(CACHE_PATH),
                    help="where to write the table (default: user cache)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args(argv)
    table = calibrate(smoke=args.smoke, batch=args.batch, k=args.k)
    path = table.save(Path(args.out))
    print(json.dumps(table.to_dict(), indent=2))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
