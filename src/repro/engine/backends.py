"""Default stage libraries (reference, fused-jnp, Pallas) + compositions.

Each factory bundles batched stage implementations (see ``registry``):

    reference   straightforward jnp — unfused logspace sums, pure-JAX Sturm
                bisection.  The numerical oracle the faster backends are
                tested against, and the fallback when nothing else applies.
    jnp         the optimized portable path — the numerator/denominator
                reductions expressed as fused ones-contractions
                (``identity.*_dot``: producer fuses into the MXU dot, no
                (b, n, n, n) temps).
    pallas      the kernelized path — Sturm bisection (full-spectrum and
                index-targeted windows) and the prod-diff log-sum run as
                natively batched Pallas TPU kernels (interpret mode
                off-TPU): one pallas_call per stack with batch on the
                leading grid axis, stacked minor bands flattened onto the
                Sturm row axis, and tile shapes taken from the autotune
                calibration table when present.

The ``sharded`` backend lives in ``repro.core.distributed`` (it owns the
mesh/axis logic) and is registered here lazily to avoid an import cycle.

This module also registers the **default compositions** — the stage chains
the engine's graph executors run:

    eigh                  direct LAPACK (the oracle / small-n crossover)
    eei_dense             dense minors -> full EEI table -> LU signs
    eei_tridiag           Householder -> Sturm -> full EEI -> recurrence signs
    eei_dense_windowed    as eei_dense, but the components stage evaluates
                          only the k selected rows (prod_diff I-axis = k);
                          bitwise-equal to the sliced full table
    eei_tridiag_windowed  Householder -> *windowed* Sturm (k index-targeted
                          brackets) -> minor-determinant components (ratio
                          recurrence, O(n k): no minor-spectra stage at
                          all) -> recurrence signs
    eei_krylov            Lanczos partial band (m ~ 16k << n) -> the same
                          windowed Sturm / minor-det / signs chain on the
                          m-band; components return to the dense basis
                          through the partial Q (topk / eigenvalues only —
                          a partial basis has no full-table solve)
    eei_krylov_si         as eei_krylov on (A - sigma I)^{-1} via one
                          batched LU; a final map stage undoes
                          theta = 1/(lambda - sigma)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import identity, minors
from repro.core.directions import (
    inverse_iteration_signs,
    inverse_iteration_signs_batched,
    tridiagonal_signs,
)
from repro.engine.plan import SolverPlan
from repro.engine.registry import (
    Composition,
    StageLibrary,
    StageSig,
    register_backend,
    register_composition,
)
from repro.engine.verify import verify_topk as _verify_topk
from repro.engine.verify import verify_topk_packed as _verify_topk_packed
from repro.linalg import householder, sturm

# ---------------------------------------------------------------------------
# Stage implementations shared across backends
# ---------------------------------------------------------------------------


def _tridiagonalize(a: jax.Array, with_q: bool = True):
    return householder.tridiagonalize_batched(a, with_q=with_q)


def _dense_eigenvalues(a: jax.Array):
    return jax.vmap(jnp.linalg.eigvalsh)(a)


def _dense_minor_spectra(a: jax.Array):
    return jax.vmap(identity.minor_spectra)(a)


def _tridiag_signs(d, e, lam_sel, mag_sel):
    """Selected signed tridiagonal eigenvectors, ``(b, k, n)``."""
    inner = jax.vmap(tridiagonal_signs, in_axes=(None, None, 0, 0))
    return jax.vmap(inner)(d, e, lam_sel, mag_sel)


def _dense_signs_reference(a, lam_sel, mag_sel):
    """Per-(matrix, pair) inverse-iteration solves — the sign oracle."""
    inner = jax.vmap(inverse_iteration_signs, in_axes=(None, 0, 0))
    return jax.vmap(inner)(a, lam_sel, mag_sel)


def _dense_signs(a, lam_sel, mag_sel):
    """Selected signed dense eigenvectors, one batched LU program."""
    return inverse_iteration_signs_batched(a, lam_sel, mag_sel)


def _minor_det_components(d, e, lam_sel):
    """Windowed |w|^2 rows via the minor-determinant ratio recurrence."""
    return identity.tridiag_windowed_magnitudes_batched(d, e, lam_sel)


def _make_krylov_stages(plan: SolverPlan):
    """The two Krylov reduce stages, closing over the plan's band override.

    Shared verbatim by the reference / jnp / pallas libraries: the Lanczos
    loop is a sequential ``while_loop`` of dense matvecs — XLA already fuses
    it well, and there is no tile-level parallelism for a kernel to exploit
    (the same rationale as the minor-determinant recurrence below).
    """
    from repro.linalg import lanczos

    m = plan.krylov_m

    def krylov_reduce(a, k, largest):
        return lanczos.krylov_reduce_batched(a, int(k), bool(largest), m)

    def krylov_shift_invert_reduce(a, k, largest):
        return lanczos.krylov_shift_invert_reduce_batched(
            a, int(k), bool(largest), m)

    return {
        "krylov_reduce": krylov_reduce,
        "krylov_shift_invert_reduce": krylov_shift_invert_reduce,
    }


def _make_segmented_sturm_stage(iters: int, block_b=None, block_m=None):
    """Per-segment windowed Sturm on a packed band.

    The segmented bracket/target layout is lane bookkeeping around the same
    bisection loop, and the kernels module owns that layout — so every
    backend shares the one implementation (interpret mode keeps it portable
    off-TPU; the import stays lazy per the lazy-kernel convention).
    """

    def tridiag_eigenvalues_segmented(d, e, seg_off, seg_len, k, largest):
        from repro.kernels.sturm import ops as sturm_ops

        kwargs = {}
        if block_b is not None:
            kwargs = {"block_b": block_b, "block_m": block_m}
        return sturm_ops.sturm_eigenvalues_segmented(
            d, e, seg_off, seg_len, k=int(k), largest=bool(largest),
            n_iter=iters, **kwargs)

    return tridiag_eigenvalues_segmented


# ---------------------------------------------------------------------------
# reference / jnp
# ---------------------------------------------------------------------------


def _make_jnp_like(name: str, reduce: str, plan: SolverPlan) -> StageLibrary:
    iters = plan.bisect_iters

    def tridiag_eigenvalues(d, e):
        return sturm.bisect_eigenvalues_batched(d, e, n_iter=iters)

    def tridiag_eigenvalues_windowed(d, e, k, largest):
        return sturm.bisect_eigenvalues_windowed_batched(
            d, e, k, largest=largest, n_iter=iters)

    def tridiag_eigenvalues_bracketed(d, e, lo, hi, k, largest):
        return sturm.bisect_eigenvalues_bracketed_batched(
            d, e, lo, hi, int(k), largest=bool(largest), n_iter=iters)

    def tridiag_minor_spectra(d, e):
        dm, em = minors.all_tridiagonal_minor_bands_batched(d, e)
        return sturm.bisect_eigenvalues_batched(dm, em, n_iter=iters)

    def magnitudes(lam, mu):
        return identity.magnitudes_from_spectra(
            lam, mu, logspace=True, reduce=reduce)

    def magnitudes_windowed(lam, mu, idx):
        return identity.magnitudes_from_spectra(
            lam, mu, logspace=True, reduce=reduce, rows=idx)

    return StageLibrary(name, {
        "tridiagonalize": _tridiagonalize,
        "tridiag_eigenvalues": tridiag_eigenvalues,
        "tridiag_eigenvalues_windowed": tridiag_eigenvalues_windowed,
        "tridiag_eigenvalues_bracketed": tridiag_eigenvalues_bracketed,
        "tridiag_minor_spectra": tridiag_minor_spectra,
        "dense_eigenvalues": _dense_eigenvalues,
        "dense_minor_spectra": _dense_minor_spectra,
        "magnitudes": magnitudes,
        "magnitudes_windowed": magnitudes_windowed,
        "minor_det_components": _minor_det_components,
        "tridiag_signs": _tridiag_signs,
        "dense_signs": (
            _dense_signs_reference if name == "reference" else _dense_signs),
        "tridiag_eigenvalues_segmented": _make_segmented_sturm_stage(iters),
        "verify_topk": _verify_topk,
        "verify_topk_packed": _verify_topk_packed,
        **_make_krylov_stages(plan),
    })


def make_reference_backend(plan: SolverPlan) -> StageLibrary:
    return _make_jnp_like("reference", "sum", plan)


def make_jnp_backend(plan: SolverPlan) -> StageLibrary:
    return _make_jnp_like("jnp", "dot", plan)


# ---------------------------------------------------------------------------
# pallas
# ---------------------------------------------------------------------------


def make_pallas_backend(plan: SolverPlan) -> StageLibrary:
    # Kernel modules are imported lazily (mirrors the seed's lazy-kernel
    # convention: importing the engine must not require a Pallas-capable
    # install until a pallas plan actually runs).
    from repro.engine import autotune
    from repro.kernels.prod_diff import ops as pd_ops
    from repro.kernels.sturm import ops as sturm_ops

    iters = plan.bisect_iters
    # Tile shapes come from the host calibration table when one exists
    # (autotune sweeps them with benchmarks/throughput.py's harness); the
    # kernel-side defaults are the uncalibrated fallback.
    table = autotune.get_table()
    pd_bi, pd_bj, pd_bk = table.prod_diff_blocks if table else (128, 128, 128)
    pd_bb = table.prod_diff_block_b if table else 1
    st_bb, st_bm = table.sturm_blocks if table else (8, 128)

    def tridiag_eigenvalues(d, e):
        return sturm_ops.sturm_eigenvalues(
            d, e, n_iter=iters, block_b=st_bb, block_m=st_bm)

    def tridiag_eigenvalues_windowed(d, e, k, largest):
        return sturm_ops.sturm_eigenvalues(
            d, e, n_iter=iters, block_b=st_bb, block_m=st_bm,
            window=(int(k), bool(largest)))

    def tridiag_eigenvalues_bracketed(d, e, lo, hi, k, largest):
        return sturm_ops.sturm_eigenvalues_bracketed(
            d, e, lo, hi, k=int(k), largest=bool(largest),
            n_iter=iters, block_b=st_bb, block_m=st_bm)

    def tridiag_minor_spectra(d, e):
        dm, em = minors.all_tridiagonal_minor_bands_batched(d, e)
        return sturm_ops.sturm_minor_spectra(
            dm, em, n_iter=iters, block_b=st_bb, block_m=st_bm)

    def magnitudes(lam, mu):
        return pd_ops.eei_magnitudes_batched(
            lam, mu, block_b=pd_bb,
            block_i=pd_bi, block_j=pd_bj, block_k=pd_bk)

    def magnitudes_windowed(lam, mu, idx):
        return pd_ops.eei_magnitudes_windowed(
            lam, mu, idx, block_b=pd_bb,
            block_i=pd_bi, block_j=pd_bj, block_k=pd_bk)

    # The minor-determinant recurrence is sequential over the band — a VPU
    # scan, not a tile job — so the pallas library shares the jnp stage.
    return StageLibrary("pallas", {
        "tridiagonalize": _tridiagonalize,
        "tridiag_eigenvalues": tridiag_eigenvalues,
        "tridiag_eigenvalues_windowed": tridiag_eigenvalues_windowed,
        "tridiag_eigenvalues_bracketed": tridiag_eigenvalues_bracketed,
        "tridiag_minor_spectra": tridiag_minor_spectra,
        "dense_eigenvalues": _dense_eigenvalues,
        "dense_minor_spectra": _dense_minor_spectra,
        "magnitudes": magnitudes,
        "magnitudes_windowed": magnitudes_windowed,
        "minor_det_components": _minor_det_components,
        "tridiag_signs": _tridiag_signs,
        "dense_signs": _dense_signs,
        "tridiag_eigenvalues_segmented": _make_segmented_sturm_stage(
            iters, st_bb, st_bm),
        "verify_topk": _verify_topk,
        "verify_topk_packed": _verify_topk_packed,
        **_make_krylov_stages(plan),
    })


def _sharded_factory(plan: SolverPlan) -> StageLibrary:
    from repro.core.distributed import make_sharded_backend

    return make_sharded_backend(plan)


def register_default_backends() -> None:
    register_backend("reference", make_reference_backend)
    register_backend("jnp", make_jnp_backend)
    register_backend("pallas", make_pallas_backend)
    register_backend("sharded", _sharded_factory)


# ---------------------------------------------------------------------------
# Default compositions (stage chains per program kind)
# ---------------------------------------------------------------------------

# Shared stage signatures.
_REDUCE = StageSig("reduce", "householder", ("a",), ("d", "e", "q"))
_REDUCE_NOQ = StageSig("reduce", "householder", ("a",), ("d", "e"))
_SPEC_DENSE = StageSig("spectrum", "dense_eigenvalues", ("a",), ("lam",))
_SPEC_TRI = StageSig("spectrum", "tridiag_full", ("d", "e"), ("lam",))
_SPEC_TRI_WIN = StageSig(
    "spectrum", "tridiag_windowed", ("d", "e"), ("lam_sel",))
_MINORS_DENSE = StageSig("minor_spectra", "dense_minors", ("a",), ("mu",))
_MINORS_TRI = StageSig("minor_spectra", "tridiag_minors", ("d", "e"), ("mu",))
_COMP_FULL = StageSig("components", "eei_full", ("lam", "mu"), ("mags",))
_COMP_SELECT = StageSig(
    "components", "eei_select", ("lam", "mu", "idx"), ("lam_sel", "mag_sel"))
_COMP_WIN = StageSig(
    "components", "eei_windowed", ("lam", "mu", "idx"),
    ("lam_sel", "mag_sel"))
_COMP_DET = StageSig(
    "components", "minor_det", ("d", "e", "lam_sel"), ("mag_sel",))
_REC_TRI = StageSig(
    "recover", "tridiag_signs", ("d", "e", "q", "lam_sel", "mag_sel"),
    ("vecs",))
_REC_TRI_SOLVE = StageSig(
    "recover", "tridiag_solve", ("d", "e", "q", "lam", "mags"), ("mags",))
_REC_DENSE = StageSig(
    "recover", "dense_signs", ("a", "lam_sel", "mag_sel"), ("vecs",))
# Krylov reduce: a Lanczos band (d (b, m), e (b, m-1)) plus the partial
# orthonormal basis q (b, n, m).  Every downstream tridiagonal stage is
# band-size agnostic, so the windowed Sturm / minor-determinant / sign
# chain runs on the m-band unchanged and the same back-transform through q
# lifts band eigenvectors to the dense basis (q columns are the basis —
# exactly Householder's convention with m = n).
_REDUCE_KRYLOV = StageSig("reduce", "krylov", ("a",), ("d", "e", "q"))
_REDUCE_KRYLOV_NOQ = StageSig("reduce", "krylov", ("a",), ("d", "e"))
# Shift-and-invert: the band lives in theta = 1/(lambda - sigma) space and
# the recover chain ends with a map stage undoing the transform.
_REDUCE_SI = StageSig(
    "reduce", "krylov_shift_invert", ("a",), ("d", "e", "q", "sigma"))
_REDUCE_SI_NOQ = StageSig(
    "reduce", "krylov_shift_invert", ("a",), ("d", "e", "sigma"))
_SPEC_SI_WIN = StageSig(
    "spectrum", "tridiag_windowed_si", ("d", "e"), ("lam_sel",))
_MAP_SI = StageSig(
    "recover", "shift_invert_map", ("sigma", "lam_sel", "vecs"),
    ("lam_sel", "vecs"))
_MAP_SI_EIG = StageSig(
    "recover", "shift_invert_map", ("sigma", "lam_sel"), ("lam_sel",))
# Packed (segment-stacked) chains: the input row is block-diagonal, so the
# full-chain stages apply to the packed matrix itself — the packed eigh
# chain gates LAPACK's columns per segment by mass, and the packed tridiag
# chain swaps the windowed Sturm for its segmented twin (per-lane
# bracket/target state) while the minor-det and sign-recurrence stages run
# unchanged on the flattened (b, S*k) window (a segment eigenvalue has ~0
# minor-det mass outside its block, and the sign recurrence restarts at
# every e ~= 0 junction by construction).
_SPEC_TRI_SEG = StageSig(
    "spectrum", "tridiag_segmented", ("d", "e", "seg_off", "seg_len"),
    ("lam_sel",))
_REC_PACKED_SELECT = StageSig(
    "recover", "packed_select", ("lam", "v", "seg_off", "seg_len"),
    ("lam_seg", "vecs_seg"))
_REC_PACKED_RESHAPE = StageSig(
    "recover", "packed_reshape", ("lam_sel", "vecs", "seg_off", "seg_len"),
    ("lam_seg", "vecs_seg"))
# Streaming rank-1 update chain (the ``update`` program kind), shared by
# every method: the reduce stage projects the *updated* matrix onto the
# session's retained Ritz basis augmented with the update direction and a
# few Lanczos extension vectors (so the perturbation is inside the span),
# producing a small (b, m') band whose back-transform q lifts band
# eigenvectors straight to the dense basis — after that the chain *is* the
# windowed tridiagonal chain, except the spectrum stage bisects from
# interlacing + secular warm brackets instead of Gershgorin, and a final
# select stage splits the k-window answer from the refreshed (basis, theta)
# session state.
_REDUCE_WARM = StageSig(
    "reduce", "warm_project", ("a", "basis", "u"), ("d", "e", "q", "z2"))
_SPEC_TRI_BRACKETED = StageSig(
    "spectrum", "tridiag_bracketed", ("a", "d", "e", "theta", "rho", "z2"),
    ("lam_sel",))
_REC_UPDATE_SELECT = StageSig(
    "recover", "update_select", ("lam_sel", "vecs", "idx"),
    ("lam_sel", "vecs", "basis", "theta"))
_UPDATE_CHAIN = (
    _REDUCE_WARM, _SPEC_TRI_BRACKETED, _COMP_DET, _REC_TRI,
    _REC_UPDATE_SELECT)


def register_default_compositions() -> None:
    register_composition(Composition(
        name="eigh", method="eigh", windowed=False,
        topk=(
            StageSig("spectrum", "eigh", ("a",), ("lam", "v")),
            StageSig("recover", "eigh_topk", ("lam", "v", "idx"),
                     ("lam_sel", "vecs")),
        ),
        solve=(
            StageSig("spectrum", "eigh", ("a",), ("lam", "v")),
            StageSig("recover", "eigh_solve", ("lam", "v"), ("mags",)),
        ),
        eigenvalues=(_SPEC_DENSE,),
        packed_topk=(
            StageSig("spectrum", "eigh", ("a",), ("lam", "v")),
            _REC_PACKED_SELECT,
        ),
        update=_UPDATE_CHAIN,
    ))
    register_composition(Composition(
        name="eei_dense", method="eei_dense", windowed=False,
        topk=(_SPEC_DENSE, _MINORS_DENSE, _COMP_SELECT, _REC_DENSE),
        solve=(_SPEC_DENSE, _MINORS_DENSE, _COMP_FULL),
        eigenvalues=(_SPEC_DENSE,),
        update=_UPDATE_CHAIN,
    ))
    register_composition(Composition(
        name="eei_dense_windowed", method="eei_dense", windowed=True,
        topk=(_SPEC_DENSE, _MINORS_DENSE, _COMP_WIN, _REC_DENSE),
        update=_UPDATE_CHAIN,
    ))
    register_composition(Composition(
        name="eei_tridiag", method="eei_tridiag", windowed=False,
        topk=(_REDUCE, _SPEC_TRI, _MINORS_TRI, _COMP_SELECT, _REC_TRI),
        solve=(_REDUCE, _SPEC_TRI, _MINORS_TRI, _COMP_FULL, _REC_TRI_SOLVE),
        eigenvalues=(_REDUCE_NOQ, _SPEC_TRI),
        update=_UPDATE_CHAIN,
    ))
    register_composition(Composition(
        name="eei_tridiag_windowed", method="eei_tridiag", windowed=True,
        topk=(_REDUCE, _SPEC_TRI_WIN, _COMP_DET, _REC_TRI),
        eigenvalues=(_REDUCE_NOQ, _SPEC_TRI_WIN),
        packed_topk=(
            _REDUCE, _SPEC_TRI_SEG, _COMP_DET, _REC_TRI,
            _REC_PACKED_RESHAPE),
        update=_UPDATE_CHAIN,
    ))
    # Krylov: the Lanczos partial band replaces Householder; everything
    # after the reduce is the *same* windowed chain (the stages are
    # band-size agnostic and the back-transform through q is shared).
    # There is no full-table solve — a partial basis cannot produce every
    # row by construction, so SolverEngine.solve on a krylov plan raises
    # the registry's "declares no 'solve' chain" error.
    register_composition(Composition(
        name="eei_krylov", method="eei_krylov", windowed=False,
        topk=(_REDUCE_KRYLOV, _SPEC_TRI_WIN, _COMP_DET, _REC_TRI),
        eigenvalues=(_REDUCE_KRYLOV_NOQ, _SPEC_TRI_WIN),
        update=_UPDATE_CHAIN,
    ))
    register_composition(Composition(
        name="eei_krylov_si", method="eei_krylov_si", windowed=False,
        topk=(_REDUCE_SI, _SPEC_SI_WIN, _COMP_DET, _REC_TRI, _MAP_SI),
        eigenvalues=(_REDUCE_SI_NOQ, _SPEC_SI_WIN, _MAP_SI_EIG),
        update=_UPDATE_CHAIN,
    ))


register_default_backends()
register_default_compositions()
