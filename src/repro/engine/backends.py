"""Default backends: reference, fused-jnp, Pallas.

Each factory bundles batched stage implementations (see ``registry``):

    reference   straightforward jnp — unfused logspace sums, pure-JAX Sturm
                bisection.  The numerical oracle the faster backends are
                tested against, and the fallback when nothing else applies.
    jnp         the optimized portable path — the numerator/denominator
                reductions expressed as fused ones-contractions
                (``identity.*_dot``: producer fuses into the MXU dot, no
                (b, n, n, n) temps).
    pallas      the kernelized path — Sturm bisection and the prod-diff
                log-sum run as natively batched Pallas TPU kernels
                (interpret mode off-TPU): one pallas_call per stack with
                batch on the leading grid axis, stacked minor bands flattened
                onto the Sturm row axis, and tile shapes taken from the
                autotune calibration table when present.

The ``sharded`` backend lives in ``repro.core.distributed`` (it owns the
mesh/axis logic) and is registered here lazily to avoid an import cycle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import identity, minors
from repro.core.directions import (
    inverse_iteration_signs,
    inverse_iteration_signs_batched,
    tridiagonal_signs,
)
from repro.engine.plan import SolverPlan
from repro.engine.registry import BackendStages, register_backend
from repro.linalg import householder, sturm

# ---------------------------------------------------------------------------
# Stage implementations shared across backends
# ---------------------------------------------------------------------------


def _tridiagonalize(a: jax.Array, with_q: bool = True):
    return householder.tridiagonalize_batched(a, with_q=with_q)


def _dense_eigenvalues(a: jax.Array):
    return jax.vmap(jnp.linalg.eigvalsh)(a)


def _dense_spectra(a: jax.Array):
    lam = _dense_eigenvalues(a)
    mu = jax.vmap(identity.minor_spectra)(a)
    return lam, mu


def _tridiag_signs(d, e, lam_sel, mag_sel):
    """Selected signed tridiagonal eigenvectors, ``(b, k, n)``."""
    inner = jax.vmap(tridiagonal_signs, in_axes=(None, None, 0, 0))
    return jax.vmap(inner)(d, e, lam_sel, mag_sel)


def _dense_signs_reference(a, lam_sel, mag_sel):
    """Per-(matrix, pair) inverse-iteration solves — the sign oracle."""
    inner = jax.vmap(inverse_iteration_signs, in_axes=(None, 0, 0))
    return jax.vmap(inner)(a, lam_sel, mag_sel)


def _dense_signs(a, lam_sel, mag_sel):
    """Selected signed dense eigenvectors, one batched LU program."""
    return inverse_iteration_signs_batched(a, lam_sel, mag_sel)


# ---------------------------------------------------------------------------
# reference / jnp
# ---------------------------------------------------------------------------


def _make_jnp_like(name: str, reduce: str, plan: SolverPlan) -> BackendStages:
    iters = plan.bisect_iters

    def tridiag_eigenvalues(d, e):
        return sturm.bisect_eigenvalues_batched(d, e, n_iter=iters)

    def tridiag_minor_spectra(d, e):
        dm, em = minors.all_tridiagonal_minor_bands_batched(d, e)
        return sturm.bisect_eigenvalues_batched(dm, em, n_iter=iters)

    def magnitudes(lam, mu):
        return identity.magnitudes_from_spectra(
            lam, mu, logspace=True, reduce=reduce)

    return BackendStages(
        name=name,
        tridiagonalize=_tridiagonalize,
        tridiag_eigenvalues=tridiag_eigenvalues,
        tridiag_minor_spectra=tridiag_minor_spectra,
        dense_eigenvalues=_dense_eigenvalues,
        dense_spectra=_dense_spectra,
        magnitudes=magnitudes,
        tridiag_signs=_tridiag_signs,
        dense_signs=(
            _dense_signs_reference if name == "reference" else _dense_signs),
    )


def make_reference_backend(plan: SolverPlan) -> BackendStages:
    return _make_jnp_like("reference", "sum", plan)


def make_jnp_backend(plan: SolverPlan) -> BackendStages:
    return _make_jnp_like("jnp", "dot", plan)


# ---------------------------------------------------------------------------
# pallas
# ---------------------------------------------------------------------------


def make_pallas_backend(plan: SolverPlan) -> BackendStages:
    # Kernel modules are imported lazily (mirrors the seed's lazy-kernel
    # convention: importing the engine must not require a Pallas-capable
    # install until a pallas plan actually runs).
    from repro.engine import autotune
    from repro.kernels.prod_diff import ops as pd_ops
    from repro.kernels.sturm import ops as sturm_ops

    iters = plan.bisect_iters
    # Tile shapes come from the host calibration table when one exists
    # (autotune sweeps them with benchmarks/throughput.py's harness); the
    # kernel-side defaults are the uncalibrated fallback.
    table = autotune.get_table()
    pd_bi, pd_bj, pd_bk = table.prod_diff_blocks if table else (128, 128, 128)
    pd_bb = table.prod_diff_block_b if table else 1
    st_bb, st_bm = table.sturm_blocks if table else (8, 128)

    def tridiag_eigenvalues(d, e):
        return sturm_ops.sturm_eigenvalues(
            d, e, n_iter=iters, block_b=st_bb, block_m=st_bm)

    def tridiag_minor_spectra(d, e):
        dm, em = minors.all_tridiagonal_minor_bands_batched(d, e)
        return sturm_ops.sturm_minor_spectra(
            dm, em, n_iter=iters, block_b=st_bb, block_m=st_bm)

    def magnitudes(lam, mu):
        return pd_ops.eei_magnitudes_batched(
            lam, mu, block_b=pd_bb,
            block_i=pd_bi, block_j=pd_bj, block_k=pd_bk)

    return BackendStages(
        name="pallas",
        tridiagonalize=_tridiagonalize,
        tridiag_eigenvalues=tridiag_eigenvalues,
        tridiag_minor_spectra=tridiag_minor_spectra,
        dense_eigenvalues=_dense_eigenvalues,
        dense_spectra=_dense_spectra,
        magnitudes=magnitudes,
        tridiag_signs=_tridiag_signs,
        dense_signs=_dense_signs,
    )


def _sharded_factory(plan: SolverPlan) -> BackendStages:
    from repro.core.distributed import make_sharded_backend

    return make_sharded_backend(plan)


def register_default_backends() -> None:
    register_backend("reference", make_reference_backend)
    register_backend("jnp", make_jnp_backend)
    register_backend("pallas", make_pallas_backend)
    register_backend("sharded", _sharded_factory)


register_default_backends()
