"""SolverEngine — plan-driven, batched execution of the EEI pipeline.

``SolverEngine.solve(a)`` / ``.topk(a, k)`` accept a single symmetric matrix
``(n, n)`` or a stack ``(b, n, n)`` and run the plan's method on the plan's
backend end-to-end batched — this is the serving path for streams of top-k
queries over stacks of matrices (the regime the paper's use cases issue).

Pipelines (all arrays carry the leading stack axis):

    eigh         vmapped LAPACK — the oracle / small-n fallback.
    eei_dense    dense minor spectra -> EEI products.
    eei_tridiag  Householder tridiagonalize -> Sturm bisection for λ(A) and
                 all decoupled tridiagonal minors -> EEI on the tridiagonal
                 form -> recurrence signs -> back-transform with Q, so the
                 returned tables live in the *dense* basis like the others.

Jitted programs are cached per ``(plan, n, k)``; the sharded backend's stack
is padded up to a multiple of the mesh batch axis and sliced back.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.engine import registry
from repro.engine.plan import SolverPlan, plan_for


class SolveResult(NamedTuple):
    """Full-table result: ``eigenvalues (..., n)`` ascending and the
    component-magnitude table ``magnitudes (..., n, n)`` (rows are
    eigenvectors, dense basis)."""

    eigenvalues: jax.Array
    magnitudes: jax.Array


class TopkResult(NamedTuple):
    """``eigenvalues (..., k)`` ascending and signed, unit-norm eigenvectors
    ``vectors (..., k, n)`` (rows are eigenvectors, dense basis)."""

    eigenvalues: jax.Array
    vectors: jax.Array


def _renormalize(vecs: jax.Array) -> jax.Array:
    nrm = jnp.linalg.norm(vecs, axis=-1, keepdims=True)
    return vecs / jnp.maximum(nrm, 1e-30)


def _batched_eigh(a: jax.Array):
    return jax.vmap(jnp.linalg.eigh)(a)


def _back_transform(w: jax.Array, q: jax.Array) -> jax.Array:
    """Rows ``w[.., i, :]`` of tridiagonal eigenvectors -> dense ``v = Q w``."""
    return jnp.einsum("...in,...jn->...ij", w, q)


@functools.lru_cache(maxsize=None)
def _solve_program(plan: SolverPlan):
    stages = registry.get_backend(plan)

    def fn(a):
        if plan.method == "eigh":
            lam, v = _batched_eigh(a)
            return SolveResult(lam, jnp.swapaxes(v * v, -1, -2))
        if plan.method == "eei_dense":
            lam, mu = stages.dense_spectra(a)
            return SolveResult(lam, stages.magnitudes(lam, mu))
        d, e, q = stages.tridiagonalize(a, True)
        lam = stages.tridiag_eigenvalues(d, e)
        mu = stages.tridiag_minor_spectra(d, e)
        w_mags = stages.magnitudes(lam, mu)  # tridiagonal basis
        w = stages.tridiag_signs(d, e, lam, w_mags)  # all n rows
        v = _back_transform(_renormalize(w), q)
        mags = v * v
        return SolveResult(lam, mags / jnp.sum(mags, axis=-1, keepdims=True))

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def topk_program(plan: SolverPlan, k: int, largest: bool):
    """The jitted batched top-k program for one ``(plan, k, largest)``.

    Public because the serving runtime's ``ProgramCache`` AOT-compiles it
    per shape bucket, and the stream-conformance tests replay it as the
    synchronous oracle a dispatched stack must match bitwise.  The
    ``lru_cache`` is thread-safe; the returned jitted callable is too.
    """
    stages = registry.get_backend(plan)

    def fn(a):
        n = a.shape[-1]
        idx = jnp.arange(n - k, n) if largest else jnp.arange(k)
        if plan.method == "eigh":
            lam, v = _batched_eigh(a)
            return TopkResult(
                lam[..., idx], jnp.swapaxes(v[..., :, idx], -1, -2))
        if plan.method == "eei_dense":
            lam, mu = stages.dense_spectra(a)
            mags = stages.magnitudes(lam, mu)
            lam_s, mag_s = lam[..., idx], mags[..., idx, :]
            return TopkResult(lam_s, _renormalize(
                stages.dense_signs(a, lam_s, mag_s)))
        d, e, q = stages.tridiagonalize(a, True)
        lam = stages.tridiag_eigenvalues(d, e)
        mu = stages.tridiag_minor_spectra(d, e)
        mags = stages.magnitudes(lam, mu)
        lam_s, mag_s = lam[..., idx], mags[..., idx, :]
        w = stages.tridiag_signs(d, e, lam_s, mag_s)
        return TopkResult(lam_s, _renormalize(_back_transform(w, q)))

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _eigenvalues_program(plan: SolverPlan):
    stages = registry.get_backend(plan)

    def fn(a):
        if plan.method in ("eigh", "eei_dense"):
            return stages.dense_eigenvalues(a)
        d, e, _ = stages.tridiagonalize(a, False)
        return stages.tridiag_eigenvalues(d, e)

    return jax.jit(fn)


@dataclasses.dataclass(frozen=True)
class SolverEngine:
    """Batched EEI solver executing one :class:`SolverPlan`."""

    plan: SolverPlan = SolverPlan()

    @classmethod
    def for_problem(cls, shape: tuple, **kwargs) -> "SolverEngine":
        """Engine with a plan inferred from problem shape (see ``plan_for``)."""
        return cls(plan_for(shape, **kwargs))

    # -- public batched API ---------------------------------------------------

    def solve(self, a: jax.Array) -> SolveResult:
        """Eigenvalues + the full ``|v[i, j]|^2`` table for ``a``.

        ``a`` is ``(n, n)`` or a stack ``(b, n, n)``; results carry the same
        leading axis.  On every backend the magnitudes live in the dense
        basis (the tridiagonal path back-transforms with ``Q``).
        """
        return self._run(_solve_program(self.plan), a)

    def topk(self, a: jax.Array, k: int, largest: bool = True) -> TopkResult:
        """Top-k (eigenvalue, signed unit eigenvector) pairs per matrix."""
        if k < 1 or k > a.shape[-1]:
            raise ValueError(f"k={k} out of range for n={a.shape[-1]}")
        return self._run(topk_program(self.plan, int(k), bool(largest)), a)

    def eigenvalues(self, a: jax.Array) -> jax.Array:
        """Eigenvalues only, ``(..., n)`` ascending."""
        return self._run(_eigenvalues_program(self.plan), a)

    # -- execution helpers ----------------------------------------------------

    def _run(self, program, a: jax.Array):
        a = jnp.asarray(a)
        if a.ndim not in (2, 3):
            raise ValueError(f"expected (n, n) or (b, n, n), got {a.shape}")
        squeeze = a.ndim == 2
        if squeeze:
            a = a[None]
        if self.plan.precision is not None:
            a = a.astype(jnp.dtype(
                {"float32": jnp.float32, "float64": jnp.float64}
                [self.plan.precision]))
        b = a.shape[0]
        if b == 0:
            raise ValueError("cannot solve an empty matrix stack")
        step = self.plan.max_batch if self.plan.max_batch > 0 else b
        # Every chunk runs at the full `step` shape — the ragged tail (e.g.
        # b=100, max_batch=64 -> a 36-row remainder) is padded up and sliced
        # so chunked solves reuse one compiled executable instead of
        # compiling a second program for the tail shape.
        pad_to = step if b > step else 0
        outs = [self._run_chunk(program, a[i0:i0 + step], pad_to=pad_to)
                for i0 in range(0, b, step)]
        out = outs[0] if len(outs) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *outs)
        return jax.tree.map(lambda x: x[0], out) if squeeze else out

    def _run_chunk(self, program, a: jax.Array, pad_to: int = 0):
        # Pad the stack up to `pad_to` (tail chunks of a microbatched run)
        # and to the mesh batch axis (the sharded backend needs the stack
        # divisible by it) by repeating the first matrix; slice back after.
        b = a.shape[0]
        mult = self.plan.batch_axis_size
        target = max(b, pad_to)
        target += (-target) % mult
        pad = target - b
        if pad:
            a = jnp.concatenate(
                [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])])
        out = program(a)
        if pad:
            out = jax.tree.map(lambda x: x[:b], out)
        return out
