"""SolverEngine — plan-driven, batched graph execution of the EEI pipeline.

``SolverEngine.solve(a)`` / ``.topk(a, k)`` accept a single symmetric matrix
``(n, n)`` or a stack ``(b, n, n)`` and run the plan's *composition* on the
plan's backend end-to-end batched — this is the serving path for streams of
top-k queries over stacks of matrices (the regime the paper's use cases
issue).

The engine is a generic **stage-graph executor**: a program builder resolves
``plan -> composition -> stage chain`` (``registry``), binds each stage
signature to its builder (the ``_STAGE_BUILDERS`` table below, which pulls
implementations from the backend's stage library) and jits a function that
threads a state dict through the chain.  There is no per-method branch in
here — adding a method or a windowed variant is a registry change.

Compositions currently registered (see ``backends.py``):

    eigh                  vmapped LAPACK — the oracle / small-n fallback.
    eei_dense[...]        dense minor spectra -> EEI products (optionally
                          windowed to the k selected rows, bitwise-equal to
                          the sliced full table).
    eei_tridiag           Householder -> Sturm -> full EEI on the
                          tridiagonal form -> recurrence signs ->
                          back-transform with Q.
    eei_tridiag_windowed  Householder -> index-targeted Sturm window (k
                          bisection lanes) -> minor-determinant components
                          (ratio recurrence, no minor-spectra stage) ->
                          recurrence signs.  O(n^2 k + n^3-tridiagonalize)
                          instead of the full path's O(n^3 * iters).
    eei_krylov[_si]       Lanczos partial band (m ~ 16k) -> the same
                          windowed chain on the m-band -> back-transform
                          through the partial Q.  O(n^2 m) reduce — the
                          large-n top-k path.  The _si variant iterates on
                          (A - sigma I)^{-1} and a final map stage undoes
                          theta = 1/(lambda - sigma).  topk / eigenvalues
                          only: a partial basis has no full-table solve.

Jitted programs are cached per ``(plan, kind, k)``; the sharded backend's
stack is padded up to a multiple of the mesh batch axis and sliced back.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.engine import registry
from repro.engine.plan import SolverPlan, plan_for


class SolveResult(NamedTuple):
    """Full-table result: ``eigenvalues (..., n)`` ascending and the
    component-magnitude table ``magnitudes (..., n, n)`` (rows are
    eigenvectors, dense basis)."""

    eigenvalues: jax.Array
    magnitudes: jax.Array


class TopkResult(NamedTuple):
    """``eigenvalues (..., k)`` ascending and signed, unit-norm eigenvectors
    ``vectors (..., k, n)`` (rows are eigenvectors, dense basis)."""

    eigenvalues: jax.Array
    vectors: jax.Array

    # Class-level marker so callers can test `result.degraded` uniformly;
    # the server's ``DegradedResult`` subclass overrides it per instance.
    degraded = False


class PackedTopkResult(NamedTuple):
    """Per-slot windows of a segment-packed stack: ``eigenvalues
    (b, S, k)`` ascending per slot and ``vectors (b, S, k, n)`` over the
    full packed-row width (each segment's columns live at its offset; the
    server slices them out on retire).  Slots with fewer than ``k`` real
    eigenvalues carry finite sentinel values *outside* the slice a
    ``k' <= seg_len`` request reads — at the front for ``largest`` windows,
    at the back for smallest — mirroring the bucketed guard convention."""

    eigenvalues: jax.Array
    vectors: jax.Array


class ProgramSpec(NamedTuple):
    """Static description of one jitted program: kind + window + verify.

    ``verify=True`` appends the backend's ``verify`` stage to the chain:
    the program then returns ``(TopkResult, VerifyFlags)`` instead of the
    bare result (topk / packed_topk programs only)."""

    kind: str  # solve | topk | eigenvalues | packed_topk | update
    k: int = 0  # 0 -> no window (full spectrum)
    largest: bool = True
    verify: bool = False
    # ``update`` programs only: retained Ritz pairs kept per session and the
    # number of augmentation directions (u + Lanczos extension vectors) the
    # warm-project reduce appends to the retained basis.
    m_keep: int = 0
    ext: int = 0


def _renormalize(vecs: jax.Array) -> jax.Array:
    nrm = jnp.linalg.norm(vecs, axis=-1, keepdims=True)
    return vecs / jnp.maximum(nrm, 1e-30)


def _batched_eigh(a: jax.Array):
    return jax.vmap(jnp.linalg.eigh)(a)


def _back_transform(w: jax.Array, q: jax.Array) -> jax.Array:
    """Rows ``w[.., i, :]`` of tridiagonal eigenvectors -> dense ``v = Q w``."""
    return jnp.einsum("...in,...jn->...ij", w, q)


# ---------------------------------------------------------------------------
# Stage builders: (role, name) -> builder(lib, plan, spec) -> fn(state)->dict
# ---------------------------------------------------------------------------


def _b_householder(lib, plan, spec):
    with_q = spec.kind != "eigenvalues"

    def fn(st):
        d, e, q = lib.tridiagonalize(st["a"], with_q)
        return {"d": d, "e": e, "q": q}

    return fn


def _b_eigh(lib, plan, spec):
    def fn(st):
        lam, v = _batched_eigh(st["a"])
        return {"lam": lam, "v": v}

    return fn


def _b_eigh_topk(lib, plan, spec):
    def fn(st):
        lam, v, idx = st["lam"], st["v"], st["idx"]
        return {"lam_sel": lam[..., idx],
                "vecs": jnp.swapaxes(v[..., :, idx], -1, -2)}

    return fn


def _b_eigh_solve(lib, plan, spec):
    def fn(st):
        return {"mags": jnp.swapaxes(st["v"] * st["v"], -1, -2)}

    return fn


def _b_dense_eigenvalues(lib, plan, spec):
    return lambda st: {"lam": lib.dense_eigenvalues(st["a"])}


def _b_tridiag_full(lib, plan, spec):
    return lambda st: {"lam": lib.tridiag_eigenvalues(st["d"], st["e"])}


def _b_tridiag_windowed(lib, plan, spec):
    k, largest = spec.k, spec.largest

    def fn(st):
        # spec.k == 0 (full-eigenvalues program on a windowed chain) means
        # "the whole band" — which on a Krylov reduce is the band size m,
        # not n, so the width comes from the band itself.
        return {"lam_sel": lib.tridiag_eigenvalues_windowed(
            st["d"], st["e"], k or st["d"].shape[-1], largest)}

    return fn


def _b_krylov(lib, plan, spec):
    k, largest = spec.k, spec.largest

    def fn(st):
        d, e, q = lib.krylov_reduce(st["a"], k or st["a"].shape[-1], largest)
        return {"d": d, "e": e, "q": q}

    return fn


def _b_krylov_si(lib, plan, spec):
    k, largest = spec.k, spec.largest

    def fn(st):
        d, e, q, sigma = lib.krylov_shift_invert_reduce(
            st["a"], k or st["a"].shape[-1], largest)
        return {"d": d, "e": e, "q": q, "sigma": sigma}

    return fn


def _b_tridiag_windowed_si(lib, plan, spec):
    # The shift-and-invert band lives in theta = 1/(lambda - sigma) space,
    # where the requested extreme of lambda is the *opposite* extreme of
    # theta (sigma sits outside the spectrum on the requested side, so the
    # map is order-reversing there) — hence `not largest`.
    k, largest = spec.k, spec.largest

    def fn(st):
        return {"lam_sel": lib.tridiag_eigenvalues_windowed(
            st["d"], st["e"], k or st["d"].shape[-1], not largest)}

    return fn


def _b_shift_invert_map(lib, plan, spec):
    def fn(st):
        # theta ascending maps to lambda *descending* (1/x is decreasing on
        # a sign-definite interval), so flip to restore ascending order.
        lam = st["sigma"][..., None] + 1.0 / st["lam_sel"]
        out = {"lam_sel": lam[..., ::-1]}
        if "vecs" in st:
            out["vecs"] = st["vecs"][..., ::-1, :]
        return out

    return fn


def _b_dense_minors(lib, plan, spec):
    return lambda st: {"mu": lib.dense_minor_spectra(st["a"])}


def _b_tridiag_minors(lib, plan, spec):
    return lambda st: {"mu": lib.tridiag_minor_spectra(st["d"], st["e"])}


def _b_eei_full(lib, plan, spec):
    return lambda st: {"mags": lib.magnitudes(st["lam"], st["mu"])}


def _b_eei_select(lib, plan, spec):
    def fn(st):
        mags = lib.magnitudes(st["lam"], st["mu"])
        idx = st["idx"]
        return {"lam_sel": st["lam"][..., idx],
                "mag_sel": mags[..., idx, :]}

    return fn


def _b_eei_windowed(lib, plan, spec):
    def fn(st):
        idx = st["idx"]
        return {"lam_sel": st["lam"][..., idx],
                "mag_sel": lib.magnitudes_windowed(st["lam"], st["mu"], idx)}

    return fn


def _b_minor_det(lib, plan, spec):
    def fn(st):
        return {"mag_sel": lib.minor_det_components(
            st["d"], st["e"], st["lam_sel"])}

    return fn


def _b_tridiag_signs(lib, plan, spec):
    def fn(st):
        w = lib.tridiag_signs(st["d"], st["e"], st["lam_sel"], st["mag_sel"])
        return {"vecs": _renormalize(_back_transform(w, st["q"]))}

    return fn


def _b_tridiag_solve(lib, plan, spec):
    def fn(st):
        # Sign + back-transform every row so the full table reports in the
        # dense basis like the other compositions.
        w = lib.tridiag_signs(st["d"], st["e"], st["lam"], st["mags"])
        v = _back_transform(_renormalize(w), st["q"])
        mags = v * v
        return {"mags": mags / jnp.sum(mags, axis=-1, keepdims=True)}

    return fn


def _b_dense_signs(lib, plan, spec):
    def fn(st):
        return {"vecs": _renormalize(lib.dense_signs(
            st["a"], st["lam_sel"], st["mag_sel"]))}

    return fn


def _b_verify_topk(lib, plan, spec):
    def fn(st):
        return {"flags": lib.verify_topk(st["a"], st["lam_sel"], st["vecs"])}

    return fn


# -- streaming rank-1 update stages -----------------------------------------


def _b_warm_project(lib, plan, spec):
    """Augmented-subspace reduce for the ``update`` kind.

    Projects the *updated* stack onto ``S = [basis; u; A'-Krylov ext]`` —
    the session's retained Ritz basis, the unit update direction (so the
    rank-1 perturbation acts *inside* the span) and ``spec.ext - 1`` short
    Lanczos extension directions that let escaped spectral weight re-enter
    the window.  The projected ``(b, m', m')`` compression tridiagonalizes
    through the backend's own Householder stage, and the composed
    back-transform ``q_eff = S^T q_small`` lifts band eigenvectors straight
    to the dense basis — downstream stages cannot tell this reduce from the
    full Householder one.  Cost is O(m' n^2) versus the from-scratch
    O(n^3): the entire speedup of the update path lives here.
    """
    n_aug = spec.ext  # augmentation directions (the first one is u)

    def _append_ortho(s_rows, v, seed):
        """One more orthonormal row onto ``s_rows`` (CGS2; deterministic
        fallback direction when ``v`` already lies in the span)."""
        n = s_rows.shape[-1]

        def proj_out(x):
            for _ in range(2):
                c = jnp.einsum("...rn,...n->...r", s_rows, x)
                x = x - jnp.einsum("...r,...rn->...n", c, s_rows)
            return x

        v = proj_out(v)
        nrm = jnp.linalg.norm(v, axis=-1, keepdims=True)
        fb = proj_out(jnp.broadcast_to(
            jnp.cos(jnp.arange(n, dtype=s_rows.dtype) * (seed + 2) + 0.1),
            v.shape))
        fb_nrm = jnp.linalg.norm(fb, axis=-1, keepdims=True)
        v = jnp.where(nrm > 1e-6,
                      v / jnp.maximum(nrm, 1e-30),
                      fb / jnp.maximum(fb_nrm, 1e-30))
        return jnp.concatenate([s_rows, v[..., None, :]], axis=-2)

    def fn(st):
        a, basis, u = st["a"], st["basis"], st["u"]
        m = basis.shape[-2]
        # Re-orthonormalize the retained rows (sign-recurrence vectors are
        # orthogonal only to fp accuracy; QR of a near-orthonormal frame is
        # cheap and keeps the Rayleigh-Ritz compression exact).
        qb, _ = jnp.linalg.qr(jnp.swapaxes(basis, -1, -2))
        s_rows = jnp.swapaxes(qb, -1, -2)  # (b, m, n)
        v = u
        for j in range(n_aug):
            s_rows = _append_ortho(s_rows, v, j)
            v = jnp.einsum("...nm,...m->...n", a, s_rows[..., -1, :])
        # Rayleigh-Ritz compression B = S A' S^T, symmetrized.
        t = jnp.einsum("...rn,...nm->...rm", s_rows, a)
        band = jnp.einsum("...rm,...sm->...rs", t, s_rows)
        band = 0.5 * (band + jnp.swapaxes(band, -1, -2))
        d, e, qs = lib.tridiagonalize(band, True)
        q_eff = jnp.einsum("...rn,...rt->...nt", s_rows, qs)
        # Secular weights: coefficients of u on the *retained* frame.
        z = jnp.einsum("...rn,...n->...r", s_rows[..., :m, :], u)
        return {"d": d, "e": e, "q": q_eff, "z2": z * z}

    return fn


def _b_tridiag_bracketed(lib, plan, spec):
    """Warm-bracket spectrum stage for the ``update`` kind.

    Lane brackets come from rank-1 interlacing + Weyl on the cached Ritz
    values (``repro.linalg.interlace.rank1_update_brackets``), widened by a
    slack covering what the verify tolerance lets the cached spectrum
    drift, then tightened one-sided by the secular-equation refinement
    (exact roots of the retained-frame compression: a lower bound for the
    band's ``largest`` window and an upper bound for ``smallest``, by
    Poincare on the nested frames).  The backend's bracketed bisection
    validates every lane's Sturm counts and falls back to Gershgorin where
    a bracket cannot prove containment — a stale session costs iterations,
    never correctness.
    """
    from repro.engine.verify import DEFAULT_TOL
    from repro.linalg import interlace

    k_lanes, largest = spec.m_keep, spec.largest

    def fn(st):
        theta, rho, z2, a = st["theta"], st["rho"], st["z2"], st["a"]
        scale = jnp.sqrt(jnp.sum(a * a, axis=(-2, -1)))  # (b,) ||A'||_F
        slack = (8.0 * DEFAULT_TOL) * scale
        lo, hi = interlace.rank1_update_brackets(
            theta, rho, drift_bound=slack[..., None])
        slo, shi = interlace.secular_bracket_refine(theta, z2, rho, lo, hi)
        sec_pad = (1e-5 * scale)[..., None]
        if largest:
            lo = jnp.maximum(lo, slo - sec_pad)
        else:
            hi = jnp.minimum(hi, shi + sec_pad)
        return {"lam_sel": lib.tridiag_eigenvalues_bracketed(
            st["d"], st["e"], lo, hi, k_lanes, largest)}

    return fn


def _b_update_select(lib, plan, spec):
    """Split the caller's k-window out of the refreshed m_keep-window; the
    full window becomes the session's next ``(basis, theta)``."""
    k, largest = spec.k, spec.largest

    def fn(st):
        lam, vecs = st["lam_sel"], st["vecs"]  # (b, m_keep[, n]) ascending
        if largest:
            lam_k, vecs_k = lam[..., -k:], vecs[..., -k:, :]
        else:
            lam_k, vecs_k = lam[..., :k], vecs[..., :k, :]
        return {"lam_sel": lam_k, "vecs": vecs_k,
                "basis": vecs, "theta": lam}

    return fn


# -- packed (segment-stacked) stages ----------------------------------------


def _b_packed_select(lib, plan, spec):
    """Per-slot window selection from a full eigh of the packed row.

    A packed row is block-diagonal, so eigh's eigenvectors are each
    supported on exactly one segment (or on guard slack) — in-segment mass
    is the ownership test.  Guard eigenpairs have zero mass in every slot
    and near-degenerate *cross-segment* pairs can mix (mass ~ 0.5 each);
    both fail the > 0.5 gate, leaving finite sentinels the per-segment
    verify stage flags, which escalates the affected requests through the
    server's fallback chain instead of returning mixed vectors.
    """
    k, largest = spec.k, spec.largest

    def fn(st):
        lam, v = st["lam"], st["v"]  # (b, N) asc, (b, N, N) columns = vecs
        seg_off, seg_len = st["seg_off"], st["seg_len"]  # (b, S) int32
        b, n = lam.shape
        col = jnp.arange(n, dtype=jnp.int32)
        in_seg = ((seg_off[:, :, None] <= col[None, None, :])
                  & (col[None, None, :] <
                     (seg_off + seg_len)[:, :, None]))  # (b, S, N_pos)
        mass = jnp.einsum(
            "bsp,bpj->bsj", in_seg.astype(lam.dtype), v * v)  # (b, S, N_vec)
        owned = mass > 0.5
        big = jnp.asarray(jnp.finfo(lam.dtype).max, lam.dtype) / 8
        if largest:
            vals = jnp.where(owned, lam[:, None, :], -big)
            top, idx = jax.lax.top_k(vals, k)  # descending; sentinels last
            lam_seg = top[..., ::-1]  # ascending per slot, sentinels first
            idx = idx[..., ::-1]
        else:
            vals = jnp.where(owned, -lam[:, None, :], -big)
            top, idx = jax.lax.top_k(vals, k)  # -lam desc = lam ascending
            lam_seg = -top  # ascending per slot, sentinels (+big) last
        vt = jnp.swapaxes(v, -1, -2)  # (b, N_vec, N_pos), rows = vecs
        vecs_seg = vt[jnp.arange(b)[:, None, None], idx, :]  # (b, S, k, N)
        return {"lam_seg": lam_seg, "vecs_seg": vecs_seg}

    return fn


def _b_tridiag_segmented(lib, plan, spec):
    """Per-segment windowed Sturm on the packed band (segmented kernel).

    Provides the flattened ``(b, S*k)`` window as ``lam_sel`` so the
    existing minor-determinant components stage and sign-recurrence recover
    stage run on it unchanged — both treat window lanes independently, and
    on a block-diagonal band the minor-determinant row of an eigenvalue in
    segment ``s`` normalizes to that segment's magnitudes with ~0 mass
    elsewhere (the EEI identity applied to the packed matrix itself).
    """
    k, largest = spec.k, spec.largest

    def fn(st):
        lam_seg = lib.tridiag_eigenvalues_segmented(
            st["d"], st["e"], st["seg_off"], st["seg_len"], k, largest)
        b, s, _ = lam_seg.shape
        return {"lam_sel": lam_seg.reshape(b, s * k)}

    return fn


def _b_packed_reshape(lib, plan, spec):
    k = spec.k

    def fn(st):
        lam_sel, vecs = st["lam_sel"], st["vecs"]  # (b, S*k), (b, S*k, N)
        b = lam_sel.shape[0]
        s = st["seg_off"].shape[1]
        return {"lam_seg": lam_sel.reshape(b, s, k),
                "vecs_seg": vecs.reshape(b, s, k, vecs.shape[-1])}

    return fn


def _b_verify_topk_packed(lib, plan, spec):
    def fn(st):
        return {"flags": lib.verify_topk_packed(
            st["a"], st["seg_off"], st["seg_len"], st["lam_seg"],
            st["vecs_seg"], spec.largest)}

    return fn


#: The verify stage appended to a topk chain when ``spec.verify`` is set.
#: Not part of any registered composition — the engine appends it, so every
#: method/backend pair gets verification without N new compositions.
_VERIFY_SIG = registry.StageSig(
    role="verify", name="verify_topk",
    requires=("a", "lam_sel", "vecs"), provides=("flags",))

#: Packed twin: per-slot flags ``(b, S)`` so one bad segment degrades one
#: request, not the whole packed row (the PR-7 guarantee held per request).
_PACKED_VERIFY_SIG = registry.StageSig(
    role="verify", name="verify_topk_packed",
    requires=("a", "seg_off", "seg_len", "lam_seg", "vecs_seg"),
    provides=("flags",))


_STAGE_BUILDERS = {
    ("reduce", "householder"): _b_householder,
    ("reduce", "krylov"): _b_krylov,
    ("reduce", "krylov_shift_invert"): _b_krylov_si,
    ("spectrum", "eigh"): _b_eigh,
    ("spectrum", "dense_eigenvalues"): _b_dense_eigenvalues,
    ("spectrum", "tridiag_full"): _b_tridiag_full,
    ("spectrum", "tridiag_windowed"): _b_tridiag_windowed,
    ("spectrum", "tridiag_windowed_si"): _b_tridiag_windowed_si,
    ("minor_spectra", "dense_minors"): _b_dense_minors,
    ("minor_spectra", "tridiag_minors"): _b_tridiag_minors,
    ("components", "eei_full"): _b_eei_full,
    ("components", "eei_select"): _b_eei_select,
    ("components", "eei_windowed"): _b_eei_windowed,
    ("components", "minor_det"): _b_minor_det,
    ("recover", "eigh_topk"): _b_eigh_topk,
    ("recover", "eigh_solve"): _b_eigh_solve,
    ("recover", "tridiag_signs"): _b_tridiag_signs,
    ("recover", "tridiag_solve"): _b_tridiag_solve,
    ("recover", "dense_signs"): _b_dense_signs,
    ("recover", "shift_invert_map"): _b_shift_invert_map,
    ("spectrum", "tridiag_segmented"): _b_tridiag_segmented,
    ("recover", "packed_select"): _b_packed_select,
    ("recover", "packed_reshape"): _b_packed_reshape,
    ("reduce", "warm_project"): _b_warm_project,
    ("spectrum", "tridiag_bracketed"): _b_tridiag_bracketed,
    ("recover", "update_select"): _b_update_select,
    ("verify", "verify_topk"): _b_verify_topk,
    ("verify", "verify_topk_packed"): _b_verify_topk_packed,
}


def register_stage_builder(role: str, name: str, builder) -> None:
    """Register (or replace) the builder behind a composition stage name."""
    _STAGE_BUILDERS[(role, name)] = builder


# ---------------------------------------------------------------------------
# Generic graph executor
# ---------------------------------------------------------------------------


def _resolve_chain(plan: SolverPlan, spec: ProgramSpec):
    """Pick the composition + chain a program executes.

    * ``topk`` uses the windowed composition when the plan asks for it
      (``plan.spectrum == "windowed"``);
    * ``solve`` always uses the method's full composition — a full table
      needs every spectrum row by definition;
    * ``eigenvalues`` with a window (``spec.k > 0``) prefers the windowed
      composition's eigenvalue chain (index-targeted bisection); methods
      without one run the full chain and the executor slices the window
      (bitwise-identical, since bisection lanes are index-independent).
    """
    if spec.kind in ("topk", "packed_topk", "update"):
        windowed = plan.spectrum == "windowed"
    elif spec.kind == "eigenvalues":
        windowed = spec.k > 0
    else:
        windowed = False
    comp = registry.composition_for(plan.method, windowed)
    chain = comp.chain(spec.kind)
    if chain is None:
        comp = registry.composition_for(plan.method, False)
        chain = comp.chain(spec.kind)
    if chain is None:
        raise ValueError(
            f"composition {comp.name!r} declares no {spec.kind!r} chain")
    return comp, chain


def _window_idx(n: int, k: int, largest: bool) -> jax.Array:
    return jnp.arange(n - k, n) if largest else jnp.arange(k)


def _build_program(plan: SolverPlan, spec: ProgramSpec):
    """Jitted graph executor for one ``(plan, spec)``."""
    lib = registry.get_backend(plan)
    _, chain = _resolve_chain(plan, spec)
    if spec.verify:
        if spec.kind != "topk":
            raise ValueError("verify is only supported for topk programs")
        chain = chain + (_VERIFY_SIG,)
    fns = [_STAGE_BUILDERS[(sig.role, sig.name)](lib, plan, spec)
           for sig in chain]

    def fn(a):
        n = a.shape[-1]
        state = {"a": a}
        if spec.kind in ("topk", "eigenvalues"):
            # Always present for these kinds — the registry validates
            # chains against exactly this initial state, so a validated
            # chain can never KeyError here.  k=0 (full eigenvalues) gets
            # the identity window.
            state["idx"] = _window_idx(n, spec.k or n, spec.largest)
        for f in fns:
            state.update(f(state))
        if spec.kind == "topk":
            result = TopkResult(state["lam_sel"], state["vecs"])
            return (result, state["flags"]) if spec.verify else result
        if spec.kind == "solve":
            return SolveResult(state["lam"], state["mags"])
        if "lam_sel" in state:  # windowed eigenvalue chain
            return state["lam_sel"]
        lam = state["lam"]
        # Windowed query on a full chain (eigh / dense methods): slice —
        # bitwise-identical, every lane is index-independent.
        return lam[..., state["idx"]] if spec.k else lam

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _solve_program(plan: SolverPlan):
    return _build_program(plan, ProgramSpec("solve"))


@functools.lru_cache(maxsize=None)
def topk_program(plan: SolverPlan, k: int, largest: bool,
                 verify: bool = False):
    """The jitted batched top-k program for one ``(plan, k, largest)``.

    Public because the serving runtime's ``ProgramCache`` AOT-compiles it
    per shape bucket, and the stream-conformance tests replay it as the
    synchronous oracle a dispatched stack must match bitwise.  The
    ``lru_cache`` is thread-safe; the returned jitted callable is too.

    With ``verify=True`` the program appends the backend's ``verify`` stage
    and returns ``(TopkResult, VerifyFlags)`` — the serving path's default,
    so no unverified vector reaches a caller.
    """
    return _build_program(
        plan, ProgramSpec("topk", int(k), bool(largest), bool(verify)))


@functools.lru_cache(maxsize=None)
def _eigenvalues_program(plan: SolverPlan, k: int = 0, largest: bool = True):
    return _build_program(
        plan, ProgramSpec("eigenvalues", int(k), bool(largest)))


def _build_packed_program(plan: SolverPlan, spec: ProgramSpec):
    """Jitted executor for a segment-packed stack.

    Same graph walk as :func:`_build_program`, but the program takes the
    segment layout as traced operands — ``fn(a, seg_off, seg_len)`` — and
    the final state carries per-slot windows.  The slot count ``S`` is a
    property of the operand shapes, not the cache key: lowering at a
    different ``(b, N, S)`` retraces the same python callable.
    """
    lib = registry.get_backend(plan)
    _, chain = _resolve_chain(plan, spec)
    if spec.verify:
        chain = chain + (_PACKED_VERIFY_SIG,)
    fns = [_STAGE_BUILDERS[(sig.role, sig.name)](lib, plan, spec)
           for sig in chain]

    def fn(a, seg_off, seg_len):
        state = {"a": a, "seg_off": seg_off.astype(jnp.int32),
                 "seg_len": seg_len.astype(jnp.int32)}
        for f in fns:
            state.update(f(state))
        result = PackedTopkResult(state["lam_seg"], state["vecs_seg"])
        return (result, state["flags"]) if spec.verify else result

    return jax.jit(fn)


def _build_update_program(plan: SolverPlan, spec: ProgramSpec):
    """Jitted executor for the streaming rank-1 ``update`` kind.

    ``fn(a_prev, basis, theta, u, rho)`` applies the rank-1 perturbation on
    device (``a = a_prev + rho * u u^T``, ``u`` unit, ``rho`` signed
    ``||u||^2``), walks the method's ``update`` chain and *always* appends
    the verify stage — the session's drift monitor reads the flags, so the
    fast path can never silently hand back stale eigenpairs.  Returns
    ``(TopkResult, VerifyFlags, a, basis', theta')``; the trailing state is
    what the session caches (device-resident) for the next update.
    """
    lib = registry.get_backend(plan)
    _, chain = _resolve_chain(plan, spec)
    chain = chain + (_VERIFY_SIG,)
    fns = [_STAGE_BUILDERS[(sig.role, sig.name)](lib, plan, spec)
           for sig in chain]

    def fn(a_prev, basis, theta, u, rho):
        a = a_prev + rho[..., None, None] * u[..., :, None] * u[..., None, :]
        n = a.shape[-1]
        state = {"a": a, "basis": basis, "theta": theta, "u": u, "rho": rho,
                 "idx": _window_idx(n, spec.k, spec.largest)}
        for f in fns:
            state.update(f(state))
        result = TopkResult(state["lam_sel"], state["vecs"])
        return result, state["flags"], a, state["basis"], state["theta"]

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def update_program(plan: SolverPlan, k: int, largest: bool, m_keep: int,
                   ext: int):
    """The jitted batched rank-1 update program for one session geometry.

    ``m_keep`` is the retained Ritz window (``k`` + the session's buffer)
    and ``ext`` the total augmentation directions (u + Lanczos extensions;
    0 when the basis already spans the frame).  Cached like
    :func:`topk_program`; sessions with the same geometry share compiles.
    """
    return _build_update_program(
        plan, ProgramSpec("update", int(k), bool(largest), True,
                          int(m_keep), int(ext)))


@functools.lru_cache(maxsize=None)
def packed_topk_program(plan: SolverPlan, k: int, largest: bool,
                        verify: bool = False):
    """The jitted per-slot top-k program for one segment-packed layout.

    ``k`` is the *slot window* (the packer's fixed per-slot lane count —
    every packed request's ``k`` is <= it); the serving runtime slices each
    request's ``k' <= k`` window out per slot on retire, exactly as the
    bucketed path slices its pow2-k window.  With ``verify=True`` the
    program returns ``(PackedTopkResult, flags (b, S))`` — per-slot flags,
    so one poisoned segment degrades one request, not its whole row.
    """
    return _build_packed_program(
        plan, ProgramSpec("packed_topk", int(k), bool(largest),
                          bool(verify)))


@dataclasses.dataclass(frozen=True)
class SolverEngine:
    """Batched EEI solver executing one :class:`SolverPlan`."""

    plan: SolverPlan = SolverPlan()

    @classmethod
    def for_problem(cls, shape: tuple, **kwargs) -> "SolverEngine":
        """Engine with a plan inferred from problem shape (see ``plan_for``)."""
        return cls(plan_for(shape, **kwargs))

    # -- public batched API ---------------------------------------------------

    def solve(self, a: jax.Array) -> SolveResult:
        """Eigenvalues + the full ``|v[i, j]|^2`` table for ``a``.

        ``a`` is ``(n, n)`` or a stack ``(b, n, n)``; results carry the same
        leading axis.  On every backend the magnitudes live in the dense
        basis (the tridiagonal path back-transforms with ``Q``).  Always
        runs the method's *full* composition — a full table needs every
        spectrum row, so ``plan.spectrum`` does not apply here.
        """
        return self._run(_solve_program(self.plan), a)

    def topk(self, a: jax.Array, k: int, largest: bool = True) -> TopkResult:
        """Top-k (eigenvalue, signed unit eigenvector) pairs per matrix."""
        if k < 1 or k > a.shape[-1]:
            raise ValueError(f"k={k} out of range for n={a.shape[-1]}")
        return self._run(topk_program(self.plan, int(k), bool(largest)), a)

    def eigenvalues(self, a: jax.Array, k: Optional[int] = None,
                    largest: bool = True) -> jax.Array:
        """Eigenvalues only: ``(..., n)`` ascending, or — with ``k`` — the
        ``k`` extremal eigenvalues ``(..., k)`` ascending via the windowed
        spectrum stage (index-targeted bisection on the tridiagonal path:
        ``k`` lanes instead of ``n``, bitwise-equal to the full slice)."""
        if k is not None and (k < 1 or k > a.shape[-1]):
            raise ValueError(f"k={k} out of range for n={a.shape[-1]}")
        # `largest` is dead without a window — normalize it out of the
        # program cache key so k=None never compiles twice.
        program = _eigenvalues_program(
            self.plan, int(k or 0), bool(largest) if k else True)
        return self._run(program, a)

    # -- streaming sessions ---------------------------------------------------

    def open_session(self, a: jax.Array, k: int, largest: bool = True,
                     config=None):
        """Open a :class:`~repro.engine.session.SpectralSession` on one
        ``(n, n)`` matrix: a full solve seeds the retained Ritz window and
        subsequent :meth:`update` calls maintain it under rank-1 drift."""
        from repro.engine import session as session_mod

        return session_mod.open_session(
            self, a, int(k), bool(largest), config)

    def update(self, session, delta):
        """Apply a rank-1 (or small rank-r, as r sequential rank-1) update
        ``A <- A + sign * u u^T`` to a session and return the refreshed
        :class:`TopkResult`.  ``delta`` is ``u``, ``(u, sign)``, a
        :class:`~repro.engine.session.Rank1Update`, or a sequence of those.
        The fast warm-started path runs unless the session's drift monitor
        (accumulated ``|rho|``, verify flags, update cadence) demands a
        full re-solve — it can never silently return stale eigenpairs."""
        from repro.engine import session as session_mod

        return session_mod.apply_update(self, session, delta)

    # -- execution helpers ----------------------------------------------------

    def _run(self, program, a: jax.Array):
        a = jnp.asarray(a)
        if a.ndim not in (2, 3):
            raise ValueError(f"expected (n, n) or (b, n, n), got {a.shape}")
        squeeze = a.ndim == 2
        if squeeze:
            a = a[None]
        if self.plan.precision is not None:
            a = a.astype(jnp.dtype(
                {"float32": jnp.float32, "float64": jnp.float64}
                [self.plan.precision]))
        b = a.shape[0]
        if b == 0:
            raise ValueError("cannot solve an empty matrix stack")
        step = self.plan.max_batch if self.plan.max_batch > 0 else b
        # Every chunk runs at the full `step` shape — the ragged tail (e.g.
        # b=100, max_batch=64 -> a 36-row remainder) is padded up and sliced
        # so chunked solves reuse one compiled executable instead of
        # compiling a second program for the tail shape.
        pad_to = step if b > step else 0
        outs = [self._run_chunk(program, a[i0:i0 + step], pad_to=pad_to)
                for i0 in range(0, b, step)]
        out = outs[0] if len(outs) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *outs)
        return jax.tree.map(lambda x: x[0], out) if squeeze else out

    def _run_chunk(self, program, a: jax.Array, pad_to: int = 0):
        # Pad the stack up to `pad_to` (tail chunks of a microbatched run)
        # and to the mesh batch axis (the sharded backend needs the stack
        # divisible by it) by repeating the first matrix; slice back after.
        b = a.shape[0]
        mult = self.plan.batch_axis_size
        target = max(b, pad_to)
        target += (-target) % mult
        pad = target - b
        if pad:
            a = jnp.concatenate(
                [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])])
        out = program(a)
        if pad:
            out = jax.tree.map(lambda x: x[:b], out)
        return out
