"""EeiFleet — fault-tolerant multi-replica front-end over ``EeiServer``.

PR 7 made a *single* server survive bad inputs and in-process faults; this
module makes the serving layer survive the failures scale brings: a whole
replica dying, hanging, or slowing down.  The fleet owns N replicas (each
one ``EeiServer``, in-process or in a subprocess behind the same driver
interface) and routes every request by its coalesce key:

    submit(a, k, largest) ──> route: rendezvous-hash (bucket_n, largest)
         │                    over the live replica set — each replica's
         │                    ProgramCache stays small and hot, and keys
         ▼                    remap minimally when the set changes
    replica driver ──> internal Future (the replica's own); the fleet owns
         │             the *caller-facing* Future — replica futures are an
         ▼             implementation detail
    completion ──> exactly-once resolution: the first successful attempt
                   wins; failed attempts redispatch to a healthy replica

Robustness core:

* **health** — a monitor thread probes each replica: liveness
  (``driver.alive()``), a *deadline* on the oldest unresolved request
  (the only probe that catches a hung replica: it accepts work and never
  answers), and a per-replica ``StragglerWatchdog`` over completed-request
  latencies that classifies a replica *slow* relative to its own history.
* **failover** — every request carries provenance (its input, its
  attempts); when a replica dies or misses the deadline, the death fails
  its internal futures with :class:`ReplicaDied`, and each unresolved
  request redispatches to a healthy replica (bounded by
  ``max_redispatch``).  The caller future resolves exactly once no matter
  how many attempts raced.
* **hedging** — requests stuck on a *slow* (but live) replica past
  ``hedge_age_s`` get a second attempt on a healthy replica;
  first-result-wins, the loser's internal future is cancelled.
* **restart** — a dead replica rebuilds through its
  :class:`~repro.runtime.fault_tolerance.RestartPolicy` (bounded,
  jittered delays); in-process rebuilds share the fleet's ``ProgramCache``
  so the restart is warm.  Rendezvous routing restores the replica's
  bucket ownership automatically the moment it is healthy again — there
  is no routing table to rebuild.
* **chaos** — ``ChaosMonkey.on_replica`` points (``replica_kill`` /
  ``replica_hang`` / ``replica_slow``) fire per routed dispatch, decided
  by the monkey (so the schedule is a pure function of the seed and the
  dispatch sequence) and *executed by the fleet* outside its lock.

Lock order: fleet lock → driver lock → server lock → cache lock, never the
reverse.  The fleet lock is re-entrant (a driver that fails a future
inline re-enters ``_on_internal_done`` on the same thread) and is **never
held across a blocking driver call** (kill / close / join) — the monitor
collects actions under the lock and executes them outside it.

Which single-server invariants lift to the fleet is documented in
``docs/ARCHITECTURE.md`` (fleet section).
"""

from __future__ import annotations

import itertools
import logging
import os
import pickle
import struct
import subprocess
import sys
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Optional

import numpy as np

from repro.engine import engine as engine_mod
from repro.engine.server import DegradedResult, EeiServer, ProgramCache, \
    ServerClosed
from repro.runtime.chaos import ChaosFailure, ChaosMonkey
from repro.runtime.elastic import route_key
from repro.runtime.fault_tolerance import RestartPolicy
from repro.runtime.straggler import StragglerWatchdog

log = logging.getLogger("repro.engine.fleet")

HEALTHY = "healthy"
SLOW = "slow"
DEAD = "dead"
RESTARTING = "restarting"


class FleetClosed(RuntimeError):
    """The fleet has been closed; the request was not (or will not be)
    served.  Mirrors :class:`~repro.engine.server.ServerClosed`."""


class ReplicaDied(RuntimeError):
    """An internal (replica-side) failure: the replica died, hung past its
    deadline, or was closed under a request.  Never reaches a caller —
    it is the signal that routes the request to another replica."""


def _redispatchable(exc: BaseException) -> bool:
    """Failures that indict the *replica*, not the request: another replica
    should be tried.  Anything else (a genuine per-request error that
    survived the server's own fallback chain) resolves the caller."""
    return isinstance(exc, (ReplicaDied, ServerClosed, ChaosFailure)) or \
        bool(getattr(exc, "transient", False))


class _FleetRequest:
    """Provenance for one caller request: enough to redispatch it from
    scratch on any replica, plus every attempt in flight."""

    __slots__ = ("a", "n", "k", "largest", "future", "t_submit",
                 "attempts", "redispatches", "hedged")

    def __init__(self, a, k, largest):
        self.a = a
        self.n = a.shape[0]
        self.k = int(k)
        self.largest = bool(largest)
        self.future = Future()
        self.t_submit = time.monotonic()
        self.attempts = []  # [(rid, internal Future, t_dispatch), ...]
        self.redispatches = 0
        self.hedged = False


class _FleetSession:
    """Fleet-side record for one sticky session.

    ``a_host`` is a float64 mirror of the session matrix, updated eagerly
    at submit (before dispatch): it is the failover state — when the owning
    replica dies, the session reopens on another replica *from the mirror*
    (a full solve), so no update is ever lost with the replica.
    ``generation`` counts reopens; a dispatch records the generation it ran
    under so a burst of failures triggers one reopen, not one per update.
    """

    __slots__ = ("sid", "rid", "replica_sid", "a_host", "k", "largest",
                 "config", "generation", "lock")

    def __init__(self, sid, rid, replica_sid, a_host, k, largest, config):
        self.sid = sid
        self.rid = rid
        self.replica_sid = replica_sid
        self.a_host = a_host
        self.k = k
        self.largest = largest
        self.config = config
        self.generation = 0
        self.lock = threading.Lock()


# -- replica drivers --------------------------------------------------------


class InProcessReplica:
    """One in-process ``EeiServer`` behind the driver interface.

    A forwarder thread decouples the fleet's dispatch from the replica's
    behavior (the same decoupling a network hop gives a remote replica),
    which is also where chaos *hang* (stop forwarding) and *slow* (delay
    each forward) act — the server underneath is untouched, exactly like a
    wedged or overloaded process whose internals are fine.
    """

    def __init__(self, rid: int, server_factory: Callable[[], EeiServer]):
        self.rid = rid
        self._server = server_factory()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inbox: "deque[tuple]" = deque()  # (a, k, largest, fut, t)
        self._dead = False
        self._hang_until = 0.0
        self._slow_until = 0.0
        self._slow_per_req_s = 0.0
        self._forwarder = threading.Thread(
            target=self._forward_loop, name=f"eei-replica-{rid}", daemon=True)
        self._forwarder.start()

    # fleet-facing ----------------------------------------------------------

    def submit(self, a, k: int, largest: bool) -> Future:
        fut = Future()
        with self._cv:
            if self._dead:
                fut.set_exception(ReplicaDied(
                    f"replica {self.rid} is dead"))
                return fut
            self._inbox.append((a, k, largest, fut, time.monotonic()))
            self._cv.notify_all()
        return fut

    def alive(self) -> bool:
        with self._cv:
            if self._dead:
                return False
        return self._server.alive()

    def oldest_unresolved_age_s(self) -> Optional[float]:
        now = time.monotonic()
        ages = []
        with self._cv:
            if self._inbox:
                ages.append(now - self._inbox[0][4])
        server_age = self._server.oldest_unresolved_age_s(now)
        if server_age is not None:
            ages.append(server_age)
        return max(ages) if ages else None

    def kill(self) -> None:
        """Abrupt death: fail everything queued here, close the server
        without draining (its unresolved futures fail with ServerClosed,
        which chains out to the internal futures the fleet watches)."""
        with self._cv:
            if self._dead:
                return
            self._dead = True
            inbox = list(self._inbox)
            self._inbox.clear()
            self._cv.notify_all()
        for *_, fut, _t in inbox:
            _set(fut, error=ReplicaDied(f"replica {self.rid} killed"))
        # timeout=0: don't wait on the daemon threads; stragglers that do
        # resolve later chain out normally and lose the exactly-once race.
        stranded = self._server.close(drain=False, timeout=0)
        for fut in stranded:
            _set(fut, error=ReplicaDied(f"replica {self.rid} killed"))

    def hang(self, seconds: float) -> None:
        """Wedge the forwarder: accepted work sits in the inbox unanswered.
        Only the fleet's deadline probe can see this failure mode."""
        with self._cv:
            self._hang_until = time.monotonic() + seconds
            self._cv.notify_all()

    def slow(self, per_request_s: float, duration_s: float) -> None:
        with self._cv:
            self._slow_per_req_s = per_request_s
            self._slow_until = time.monotonic() + duration_s
            self._cv.notify_all()

    def close(self, drain: bool = True, timeout: Optional[float] = None
              ) -> list:
        with self._cv:
            self._dead = True
            inbox = list(self._inbox)
            self._inbox.clear()
            self._cv.notify_all()
        for *_, fut, _t in inbox:
            _set(fut, error=ReplicaDied(
                f"replica {self.rid} closed before forwarding"))
        return self._server.close(drain=drain, timeout=timeout)

    def stats(self) -> dict:
        return self._server.stats()

    # Sessions delegate directly to the server (not through the forwarder
    # inbox): session updates are stateful and sticky, so the decoupling
    # the inbox buys stateless submits — absorb hang/slow without touching
    # the server — has nothing to protect here; a dead replica is the one
    # fault that matters, and the dead-check below catches it.

    def open_session(self, a, k: int, largest: bool = True,
                     config=None) -> str:
        with self._cv:
            if self._dead:
                raise ReplicaDied(f"replica {self.rid} is dead")
        return self._server.open_session(a, k, largest, config=config)

    def submit_update(self, session_id: str, u, sign: int = 1) -> Future:
        with self._cv:
            if self._dead:
                fut = Future()
                fut.set_exception(ReplicaDied(
                    f"replica {self.rid} is dead"))
                return fut
        return self._server.submit_update(session_id, u, sign)

    def session_result(self, session_id: str):
        return self._server.session_result(session_id)

    def close_session(self, session_id: str) -> None:
        self._server.close_session(session_id)

    # internals -------------------------------------------------------------

    def _forward_loop(self) -> None:
        while True:
            with self._cv:
                while not self._inbox and not self._dead:
                    self._cv.wait()
                if self._dead:
                    return
                now = time.monotonic()
                if now < self._hang_until:
                    self._cv.wait(timeout=self._hang_until - now)
                    continue
                delay = self._slow_per_req_s if now < self._slow_until \
                    else 0.0
                a, k, largest, fut, _t = self._inbox.popleft()
            if delay:
                time.sleep(delay)  # outside the lock
            try:
                sfut = self._server.submit(a, k, largest)
            except Exception as exc:
                _set(fut, error=exc)
                continue
            sfut.add_done_callback(
                lambda sf, fut=fut: _chain(sf, fut))
            # First-result-wins cancellation flows the other way too: a
            # cancelled internal future withdraws the server request if it
            # is still pending there.
            fut.add_done_callback(
                lambda f, sf=sfut: sf.cancel() if f.cancelled() else None)


def _set(future: Future, *, result=None, error=None) -> bool:
    try:
        if error is not None:
            future.set_exception(error)
        else:
            future.set_result(result)
        return True
    except InvalidStateError:
        return False


def _chain(src: Future, dst: Future) -> None:
    """Copy a resolved future's outcome onto another, tolerating a dst
    already resolved (hedge loser) or a cancelled src."""
    if src.cancelled():
        dst.cancel()
        return
    exc = src.exception()
    if exc is not None:
        _set(dst, error=exc)
    else:
        _set(dst, result=src.result())


# -- subprocess driver ------------------------------------------------------

def _write_frame(pipe, obj) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    pipe.write(struct.pack("<I", len(payload)))
    pipe.write(payload)
    pipe.flush()


def _read_frame(pipe):
    header = pipe.read(4)
    if len(header) < 4:
        return None
    (size,) = struct.unpack("<I", header)
    payload = pipe.read(size)
    if len(payload) < size:
        return None
    return pickle.loads(payload)


class SubprocessReplica:
    """One ``EeiServer`` in its own process (``repro.engine.fleet_worker``),
    spoken to over length-prefixed pickle frames on stdin/stdout.

    True process isolation: a kill here is ``SIGKILL``, a hang is a worker
    that stops reading, and the parent-side reader thread converts EOF
    into :class:`ReplicaDied` on every outstanding internal future — the
    same failover path the in-process driver exercises.  Each worker is
    pinned to limited XLA host threads (see ``fleet_worker``) so N workers
    scale on N cores instead of fighting over one.
    """

    def __init__(self, rid: int, server_kwargs: Optional[dict] = None,
                 env: Optional[dict] = None, start_timeout_s: float = 120.0):
        self.rid = rid
        self._lock = threading.Lock()
        self._outstanding: "dict[int, tuple[Future, float]]" = {}
        self._ids = itertools.count()
        self._dead = False
        worker_env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        worker_env["PYTHONPATH"] = src_root + os.pathsep + \
            worker_env.get("PYTHONPATH", "")
        worker_env.setdefault("JAX_PLATFORMS", "cpu")
        if env:
            worker_env.update(env)
        self._proc = subprocess.Popen(
            [sys.executable, "-m", "repro.engine.fleet_worker"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            env=worker_env)
        self._ready = threading.Event()
        self._reader = threading.Thread(
            target=self._read_loop, name=f"eei-subreplica-{rid}",
            daemon=True)
        self._reader.start()
        _write_frame(self._proc.stdin, {
            "op": "init", "server_kwargs": server_kwargs or {}})
        if not self._ready.wait(start_timeout_s):
            self.kill()
            raise ReplicaDied(
                f"replica {rid} worker failed to start in {start_timeout_s}s")

    def submit(self, a, k: int, largest: bool) -> Future:
        fut = Future()
        with self._lock:
            if self._dead:
                fut.set_exception(ReplicaDied(
                    f"replica {self.rid} is dead"))
                return fut
            req_id = next(self._ids)
            self._outstanding[req_id] = (fut, time.monotonic())
        try:
            _write_frame(self._proc.stdin, {
                "op": "submit", "id": req_id, "a": np.asarray(a),
                "k": int(k), "largest": bool(largest)})
        except (OSError, ValueError):  # broken pipe: worker died under us
            self._fail_all(ReplicaDied(f"replica {self.rid} pipe broken"))
        return fut

    def alive(self) -> bool:
        with self._lock:
            if self._dead:
                return False
        return self._proc.poll() is None

    def oldest_unresolved_age_s(self) -> Optional[float]:
        now = time.monotonic()
        with self._lock:
            if not self._outstanding:
                return None
            return now - min(t for _, t in self._outstanding.values())

    def kill(self) -> None:
        self._proc.kill()
        self._fail_all(ReplicaDied(f"replica {self.rid} killed"))

    def hang(self, seconds: float) -> None:
        try:
            _write_frame(self._proc.stdin, {"op": "hang", "s": seconds})
        except (OSError, ValueError):
            pass

    def slow(self, per_request_s: float, duration_s: float) -> None:
        try:
            _write_frame(self._proc.stdin, {
                "op": "slow", "s": per_request_s, "duration_s": duration_s})
        except (OSError, ValueError):
            pass

    def close(self, drain: bool = True, timeout: Optional[float] = None
              ) -> list:
        try:
            _write_frame(self._proc.stdin, {"op": "close", "drain": drain})
        except (OSError, ValueError):
            pass
        try:
            self._proc.wait(timeout)
        except subprocess.TimeoutExpired:
            self._proc.kill()
        with self._lock:
            stranded = [fut for fut, _ in self._outstanding.values()
                        if not fut.done()]
        self._fail_all(ReplicaDied(f"replica {self.rid} closed"))
        return stranded

    def stats(self) -> dict:
        return {"rid": self.rid, "subprocess": True,
                "pid": self._proc.pid, "alive": self.alive()}

    def open_session(self, a, k: int, largest: bool = True,
                     config=None) -> str:
        # Stateful sessions need device-resident state the frame protocol
        # doesn't ship; the fleet routes sessions to in-process replicas.
        raise NotImplementedError(
            "stateful sessions require in-process replicas")

    def _fail_all(self, exc: Exception) -> None:
        with self._lock:
            self._dead = True
            outstanding = list(self._outstanding.values())
            self._outstanding.clear()
        for fut, _t in outstanding:
            _set(fut, error=exc)

    def _read_loop(self) -> None:
        while True:
            try:
                msg = _read_frame(self._proc.stdout)
            except Exception:
                msg = None
            if msg is None:  # EOF: the worker died
                self._fail_all(ReplicaDied(
                    f"replica {self.rid} worker exited"))
                return
            op = msg.get("op")
            if op == "ready":
                self._ready.set()
            elif op == "result":
                with self._lock:
                    entry = self._outstanding.pop(msg["id"], None)
                if entry is None:
                    continue
                fut, _t = entry
                if msg.get("ok"):
                    lam, vec = msg["lam"], msg["vec"]
                    if msg.get("degraded"):
                        res = DegradedResult(
                            lam, vec, fallback=msg.get("fallback", ""))
                    else:
                        res = engine_mod.TopkResult(lam, vec)
                    _set(fut, result=res)
                else:
                    _set(fut, error=ReplicaDied(
                        f"replica {self.rid}: {msg.get('error', '?')}")
                        if msg.get("replica_fault")
                        else RuntimeError(msg.get("error", "?")))


# -- the fleet --------------------------------------------------------------


class _Replica:
    """Fleet-side bookkeeping for one replica slot."""

    __slots__ = ("rid", "driver", "state", "watchdog", "policy",
                 "outstanding", "restart_at", "last_slow_flag",
                 "kills", "restarts")

    def __init__(self, rid, driver, watchdog, policy):
        self.rid = rid
        self.driver = driver
        self.state = HEALTHY
        self.watchdog = watchdog
        self.policy = policy
        self.outstanding: "set[_FleetRequest]" = set()
        self.restart_at = 0.0
        self.last_slow_flag = 0.0
        self.kills = 0
        self.restarts = 0


class EeiFleet:
    """Front-end router over N replica ``EeiServer``s.

    ``submit(a, k, largest)`` returns a caller-facing Future that resolves
    exactly once — through whichever replica attempt wins.  See the module
    docstring for the health / failover / hedging / restart semantics.

    ``replica_mode='inprocess'`` (default) builds threaded ``EeiServer``s
    sharing one :class:`ProgramCache` (``server_factory`` overrides the
    construction); ``'subprocess'`` runs each replica in its own process
    via :class:`SubprocessReplica` — real parallelism and real process
    death, at the cost of per-process compiles.

    ``chaos`` arms the replica-level injection points; actions fire per
    routed dispatch *against the replica that dispatch routed to* and are
    executed outside the fleet lock.
    """

    def __init__(
        self,
        n_replicas: int = 3,
        *,
        replica_mode: str = "inprocess",
        server_factory: Optional[Callable[[], EeiServer]] = None,
        server_kwargs: Optional[dict] = None,
        cache: Optional[ProgramCache] = None,
        salt: int = 0,
        deadline_s: Optional[float] = 30.0,
        probe_interval_s: float = 0.02,
        hedge_age_s: float = 0.25,
        max_redispatch: int = 3,
        slow_cooldown_s: float = 1.0,
        straggler_kwargs: Optional[dict] = None,
        restart_policy_kwargs: Optional[dict] = None,
        chaos: Optional[ChaosMonkey] = None,
        subprocess_env: Optional[dict] = None,
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if replica_mode not in ("inprocess", "subprocess"):
            raise ValueError(f"unknown replica_mode {replica_mode!r}")
        self.n_replicas = n_replicas
        self.replica_mode = replica_mode
        self.salt = salt
        self.deadline_s = deadline_s
        self.probe_interval_s = probe_interval_s
        self.hedge_age_s = hedge_age_s
        self.max_redispatch = max_redispatch
        self.slow_cooldown_s = slow_cooldown_s
        self.chaos = chaos
        self._subprocess_env = subprocess_env
        self._server_kwargs = dict(server_kwargs or {})
        self._server_kwargs.setdefault("linger_ms", 2.0)
        # In-process replicas share one cache: a restarted replica's first
        # request after failover hits warm programs instead of recompiling
        # (subprocess replicas each own theirs — separate address spaces).
        self.cache = cache if cache is not None else ProgramCache()
        self._server_factory = server_factory
        self._straggler_kwargs = dict(straggler_kwargs or {})
        self._straggler_kwargs.setdefault("threshold", 3.0)
        self._straggler_kwargs.setdefault("min_samples", 8)
        self._restart_kwargs = dict(restart_policy_kwargs or {})

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._closed = False
        self._chaos_paused = False
        self._parked: "deque[_FleetRequest]" = deque()
        self._unresolved: "set[_FleetRequest]" = set()
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_failed = 0
        self.requests_rejected = 0
        self.redispatches = 0
        self.hedges = 0
        self.hedge_wasted = 0
        self.replicas_killed = 0
        self.replicas_restarted = 0
        self.deadline_deaths = 0
        self.latencies_ms: list = []
        self._sessions: "dict[str, _FleetSession]" = {}
        self._session_ids = itertools.count()
        self.sessions_opened = 0
        self.session_updates = 0
        self.session_failovers = 0

        self._replicas = {
            rid: _Replica(rid, self._build_driver(rid),
                          self._build_watchdog(), self._build_policy(rid))
            for rid in range(n_replicas)
        }
        self._monitor = threading.Thread(
            target=self._monitor_main, name="eei-fleet-monitor", daemon=True)
        self._monitor.start()

    # -- construction helpers ----------------------------------------------

    def _build_driver(self, rid: int):
        if self.replica_mode == "subprocess":
            return SubprocessReplica(rid, server_kwargs=self._server_kwargs,
                                     env=self._subprocess_env)
        factory = self._server_factory
        if factory is None:
            kwargs = dict(self._server_kwargs)
            kwargs.setdefault("cache", self.cache)
            factory = lambda: EeiServer(**kwargs)  # noqa: E731
        return InProcessReplica(rid, factory)

    def _build_watchdog(self) -> StragglerWatchdog:
        return StragglerWatchdog(**self._straggler_kwargs)

    def _build_policy(self, rid: int) -> RestartPolicy:
        kwargs = dict(self._restart_kwargs)
        kwargs.setdefault("seed", self.salt * 1000 + rid)
        return RestartPolicy(**kwargs)

    # -- routing ------------------------------------------------------------

    def _routable_locked(self) -> list:
        return [r.rid for r in self._replicas.values()
                if r.state in (HEALTHY, SLOW)]

    def _route_locked(self, freq: _FleetRequest,
                      exclude: tuple = ()) -> Optional[int]:
        candidates = [rid for rid in self._routable_locked()
                      if rid not in exclude]
        if not candidates:
            candidates = self._routable_locked()  # better a retry than a park
        if not candidates:
            return None
        key = (freq.n, freq.largest)
        return route_key(key, candidates, self.salt)

    # -- submission ----------------------------------------------------------

    def submit(self, a, k: int, largest: bool = True) -> Future:
        """Admit one ``(n, n)`` top-k query; returns a caller future that
        resolves exactly once, surviving replica death/hang/slowdown."""
        a = np.asarray(a)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected one (n, n) matrix, got {a.shape}")
        if k < 1 or k > a.shape[0]:
            raise ValueError(f"k={k} out of range for n={a.shape[0]}")
        freq = _FleetRequest(a, k, largest)
        action = rid = None
        with self._cv:
            if self._closed:
                self.requests_rejected += 1
                freq.future.set_exception(FleetClosed(
                    "EeiFleet is closed; request was rejected"))
                return freq.future
            self.requests_submitted += 1
            self._unresolved.add(freq)
            rid = self._route_locked(freq)
            if rid is None:
                self._parked.append(freq)
            if rid is not None and self.chaos is not None \
                    and not self._chaos_paused:
                action = self.chaos.on_replica(rid)
        if rid is not None:
            self._dispatch_to(freq, rid)
            if action is not None:
                self._apply_chaos(rid, action)
        return freq.future

    def _dispatch_to(self, freq: _FleetRequest, rid: int) -> None:
        replica = self._replicas[rid]
        with self._cv:
            replica.outstanding.add(freq)
        fut = replica.driver.submit(freq.a, freq.k, freq.largest)
        with self._cv:
            freq.attempts.append((rid, fut, time.monotonic()))
        fut.add_done_callback(
            lambda f, freq=freq, rid=rid: self._on_internal_done(
                freq, rid, f))

    # -- resolution (exactly-once) ------------------------------------------

    def _on_internal_done(self, freq: _FleetRequest, rid: int,
                          fut: Future) -> None:
        replica = self._replicas.get(rid)
        with self._cv:
            if replica is not None:
                replica.outstanding.discard(freq)
            already_done = freq.future.done()
        if fut.cancelled():
            return  # we cancelled a hedge loser ourselves
        if already_done:
            if fut.exception() is None:
                with self._cv:
                    self.hedge_wasted += 1
            return
        exc = fut.exception()
        if exc is None:
            result = fut.result()
            dt_ms = None
            with self._cv:
                if freq in self._unresolved:
                    dt_ms = (time.monotonic() - freq.t_submit) * 1e3
            if _set(freq.future, result=result):
                with self._cv:
                    self._unresolved.discard(freq)
                    self.requests_completed += 1
                    if dt_ms is not None:
                        self.latencies_ms.append(dt_ms)
                    self._cv.notify_all()
                self._observe_latency(rid, freq, fut)
                self._cancel_losers(freq, fut)
            return
        # A failed attempt: replica fault -> redispatch elsewhere; genuine
        # per-request error -> resolve the caller with it.
        if _redispatchable(exc):
            self._redispatch(freq, exclude_rid=rid, cause=exc)
        else:
            if _set(freq.future, error=exc):
                with self._cv:
                    self._unresolved.discard(freq)
                    self.requests_failed += 1
                    self._cv.notify_all()
                self._cancel_losers(freq, fut)

    def _observe_latency(self, rid: int, freq: _FleetRequest,
                         fut: Future) -> None:
        replica = self._replicas.get(rid)
        if replica is None:
            return
        t_dispatch = None
        with self._cv:
            for arid, afut, t in freq.attempts:
                if afut is fut:
                    t_dispatch = t
                    break
            if t_dispatch is None or replica.state not in (HEALTHY, SLOW):
                return
            dt = time.monotonic() - t_dispatch
            flagged = replica.watchdog.observe(0, dt)
            if flagged:
                replica.last_slow_flag = time.monotonic()
                if replica.state == HEALTHY:
                    replica.state = SLOW
                    log.warning("fleet: replica %d classified SLOW "
                                "(dt=%.3fs median=%.3fs)", rid, dt,
                                replica.watchdog.median)

    def _cancel_losers(self, freq: _FleetRequest, winner: Future) -> None:
        with self._cv:
            losers = [fut for _, fut, _t in freq.attempts
                      if fut is not winner and not fut.done()]
        for fut in losers:
            fut.cancel()

    def _redispatch(self, freq: _FleetRequest, exclude_rid: int,
                    cause: Exception) -> None:
        with self._cv:
            if freq.future.done():
                return
            if freq.redispatches >= self.max_redispatch:
                # An infra failure does not indict the *request* — never
                # surface a replica's death to the caller while the fleet
                # can still restart replicas.  After max_redispatch rapid
                # failovers (flapping replicas: a kill fails a whole
                # bucket's outstanding work at once), the request takes a
                # breather in the parking lot; the monitor re-routes it at
                # probe cadence with a fresh budget.
                freq.redispatches = 0
                self._parked.append(freq)
                self._cv.notify_all()
                log.warning(
                    "fleet: (n=%d k=%d) exhausted %d redispatches (%s); "
                    "parked", freq.n, freq.k, self.max_redispatch, cause)
                return
            freq.redispatches += 1
            self.redispatches += 1
            target = self._route_locked(freq, exclude=(exclude_rid,))
            if target is None:
                self._parked.append(freq)
                self._cv.notify_all()
        if target is not None:
            log.info("fleet: redispatching (n=%d k=%d) %d -> %d after %s",
                     freq.n, freq.k, exclude_rid, target, cause)
            self._dispatch_to(freq, target)

    # -- stateful sessions ----------------------------------------------------

    def _route_session_locked(self, sid: str, generation: int,
                              exclude: tuple = ()) -> Optional[int]:
        candidates = [rid for rid in self._routable_locked()
                      if rid not in exclude]
        if not candidates:
            candidates = self._routable_locked()
        if not candidates:
            return None
        # Generation in the key: each reopen rendezvouses afresh, so a
        # session whose owner died doesn't deterministically re-pick it.
        return route_key(("session", sid, generation), candidates, self.salt)

    def open_session(self, a, k: int, largest: bool = True,
                     config=None) -> str:
        """Open a sticky stateful session; returns a fleet session id.

        The session lives on one replica (rendezvous-routed); every
        update routes there until the replica dies, at which point the
        session reopens on a healthy replica from the fleet's host
        mirror — a full solve, never a silent loss of updates.
        """
        a = np.asarray(a, dtype=np.float64)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected one (n, n) matrix, got {a.shape}")
        with self._cv:
            if self._closed:
                raise FleetClosed("EeiFleet is closed")
            sid = f"fs{next(self._session_ids)}"
            rid = self._route_session_locked(sid, 0)
        if rid is None:
            raise FleetClosed("no routable replica for session")
        replica_sid = self._replicas[rid].driver.open_session(
            a, k, largest, config=config)
        rec = _FleetSession(sid, rid, replica_sid, a.copy(), int(k),
                            bool(largest), config)
        with self._cv:
            self._sessions[sid] = rec
            self.sessions_opened += 1
        return sid

    def submit_update(self, session_id: str, u, sign: int = 1) -> Future:
        """Apply a rank-1 update to a sticky session; returns a caller
        future resolving to the refreshed window.  Survives the owning
        replica's death by reopening from the host mirror."""
        with self._cv:
            rec = self._sessions.get(session_id)
        if rec is None:
            raise KeyError(f"no session {session_id!r}")
        u64 = np.asarray(u, dtype=np.float64)
        caller = Future()
        with rec.lock:
            # Mirror BEFORE dispatch: whatever happens to the replica, the
            # failover state already includes this update.
            rec.a_host += int(sign) * np.outer(u64, u64)
            rid, gen = rec.rid, rec.generation
        with self._cv:
            self.session_updates += 1
        try:
            fut = self._replicas[rid].driver.submit_update(
                rec.replica_sid, u, sign)
        except Exception as exc:
            self._session_recover(rec, gen, caller, exc)
            return caller
        fut.add_done_callback(
            lambda f, rec=rec, gen=gen, caller=caller:
                self._on_session_done(rec, gen, caller, f))
        return caller

    def _on_session_done(self, rec: _FleetSession, gen: int,
                         caller: Future, fut: Future) -> None:
        if fut.cancelled():
            caller.cancel()
            return
        exc = fut.exception()
        if exc is None:
            _set(caller, result=fut.result())
            return
        if _redispatchable(exc):
            self._session_recover(rec, gen, caller, exc)
        else:
            _set(caller, error=exc)

    def _session_recover(self, rec: _FleetSession, gen: int,
                         caller: Future, cause: Exception) -> None:
        """Reopen a session whose replica failed and resolve the caller
        from the reopened window.

        The mirror already contains every submitted update (including the
        failed one), so the reopen's seed solve *is* the correct current
        window — the caller gets it as a :class:`DegradedResult` (full
        solve instead of the warm path).  One reopen per failure burst:
        concurrent failures of the same generation reuse the first
        reopen."""
        with rec.lock:
            if rec.generation == gen:
                # First failure of this generation: reopen elsewhere.
                with self._cv:
                    if self._closed:
                        _set(caller, error=cause)
                        return
                    target = self._route_session_locked(
                        rec.sid, gen + 1, exclude=(rec.rid,))
                if target is None:
                    _set(caller, error=cause)
                    return
                log.warning("fleet: session %s failing over %d -> %d (%s)",
                            rec.sid, rec.rid, target, cause)
                try:
                    replica_sid = self._replicas[target].driver.open_session(
                        rec.a_host, rec.k, rec.largest, config=rec.config)
                except Exception:
                    _set(caller, error=cause)
                    return
                rec.rid = target
                rec.replica_sid = replica_sid
                rec.generation = gen + 1
                with self._cv:
                    self.session_failovers += 1
            rid, replica_sid = rec.rid, rec.replica_sid
        try:
            res = self._replicas[rid].driver.session_result(replica_sid)
        except Exception:
            _set(caller, error=cause)
            return
        _set(caller, result=DegradedResult(
            np.asarray(res.eigenvalues), np.asarray(res.vectors),
            fallback="session_reopen"))

    def session_result(self, session_id: str):
        with self._cv:
            rec = self._sessions.get(session_id)
        if rec is None:
            raise KeyError(f"no session {session_id!r}")
        with rec.lock:
            rid, replica_sid = rec.rid, rec.replica_sid
        return self._replicas[rid].driver.session_result(replica_sid)

    def close_session(self, session_id: str) -> None:
        with self._cv:
            rec = self._sessions.pop(session_id, None)
        if rec is None:
            return
        with rec.lock:
            rid, replica_sid = rec.rid, rec.replica_sid
        try:
            self._replicas[rid].driver.close_session(replica_sid)
        except Exception:
            pass  # the replica is gone; the session went with it

    # -- chaos ----------------------------------------------------------------

    def _apply_chaos(self, rid: int, action: str) -> None:
        cfg = self.chaos.config
        replica = self._replicas.get(rid)
        if replica is None:
            return
        log.warning("fleet: chaos %s on replica %d", action, rid)
        if action == "kill":
            self._kill_replica(rid, reason="chaos kill")
        elif action == "hang":
            replica.driver.hang(cfg.replica_hang_s)
        elif action == "slow":
            replica.driver.slow(cfg.replica_slow_s,
                                duration_s=max(10 * cfg.replica_slow_s, 0.5))

    def _kill_replica(self, rid: int, reason: str) -> None:
        """Mark a replica dead and kill its driver.  The kill fails every
        internal future the replica owed, and those failures redispatch
        through `_on_internal_done` — one failover path for chaos kills,
        organic deaths, and deadline expiries alike."""
        with self._cv:
            replica = self._replicas.get(rid)
            if replica is None or replica.state in (DEAD, RESTARTING):
                return
            replica.state = DEAD
            replica.kills += 1
            self.replicas_killed += 1
            self._cv.notify_all()
        log.warning("fleet: replica %d dead (%s)", rid, reason)
        replica.driver.kill()  # outside the fleet lock

    # -- health monitor -------------------------------------------------------

    def _monitor_main(self) -> None:
        while True:
            with self._cv:
                if self._closed and not self._unresolved:
                    return
                states = {rid: r.state for rid, r in self._replicas.items()}
            kills = []
            now = time.monotonic()
            for rid, state in states.items():
                replica = self._replicas[rid]
                if state in (HEALTHY, SLOW):
                    if not replica.driver.alive():
                        kills.append((rid, "driver died"))
                        continue
                    age = replica.driver.oldest_unresolved_age_s()
                    if self.deadline_s is not None and age is not None \
                            and age > self.deadline_s:
                        with self._cv:
                            self.deadline_deaths += 1
                        kills.append((rid, f"deadline {age:.2f}s"))
                        continue
                    if state == SLOW:
                        self._hedge_replica(replica, now)
                        with self._cv:
                            if now - replica.last_slow_flag > \
                                    self.slow_cooldown_s:
                                replica.state = HEALTHY
                                replica.watchdog.reset()
                elif state == DEAD:
                    self._schedule_restart(replica, now)
                elif state == RESTARTING and now >= replica.restart_at:
                    self._restart_replica(replica)
            for rid, reason in kills:
                self._kill_replica(rid, reason)
            self._flush_parked()
            with self._cv:
                self._cv.wait(timeout=self.probe_interval_s)

    def _hedge_replica(self, replica: _Replica, now: float) -> None:
        """Second attempt on a healthy replica for requests stuck on a
        slow one past ``hedge_age_s``; first result wins, the loser's
        internal future is cancelled at resolution."""
        to_hedge = []
        with self._cv:
            for freq in list(replica.outstanding):
                if freq.hedged or freq.future.done():
                    continue
                last_dispatch = freq.attempts[-1][2] if freq.attempts \
                    else freq.t_submit
                if now - last_dispatch < self.hedge_age_s:
                    continue
                target = self._route_locked(freq, exclude=(replica.rid,))
                if target is None or target == replica.rid:
                    continue
                freq.hedged = True
                self.hedges += 1
                to_hedge.append((freq, target))
        for freq, target in to_hedge:
            log.info("fleet: hedging (n=%d k=%d) from slow replica %d "
                     "to %d", freq.n, freq.k, replica.rid, target)
            self._dispatch_to(freq, target)

    def _schedule_restart(self, replica: _Replica, now: float) -> None:
        with self._cv:
            if replica.state != DEAD:
                return
            if replica.policy.give_up:
                return  # stays dead; rendezvous keeps it out of routing
            if self._closed and not self._unresolved:
                return  # nothing left that a restart could serve
            delay = replica.policy.next_delay()
            replica.restart_at = now + delay
            replica.state = RESTARTING
        log.warning("fleet: replica %d restarting in %.3fs (restart %d)",
                    replica.rid, delay, replica.policy.restarts)

    def _restart_replica(self, replica: _Replica) -> None:
        with self._cv:
            if self._closed and not self._unresolved:
                return  # don't spawn a replica the close will never reap
        try:
            driver = self._build_driver(replica.rid)
        except Exception as exc:
            log.error("fleet: replica %d rebuild failed: %s",
                      replica.rid, exc)
            with self._cv:
                replica.state = DEAD  # next tick reschedules (bounded)
            return
        with self._cv:
            replica.driver = driver
            replica.watchdog.reset()
            replica.state = HEALTHY
            replica.restarts += 1
            self.replicas_restarted += 1
            self._cv.notify_all()
        log.warning("fleet: replica %d restarted", replica.rid)

    def _flush_parked(self) -> None:
        """Re-route requests parked while no replica was routable."""
        while True:
            with self._cv:
                if not self._parked:
                    return
                freq = self._parked[0]
                if freq.future.done():
                    self._parked.popleft()
                    continue
                rid = self._route_locked(freq)
                if rid is None:
                    return  # still nowhere to go
                self._parked.popleft()
            self._dispatch_to(freq, rid)

    # -- lifecycle ------------------------------------------------------------

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request has resolved (or ``timeout``
        expires; returns False then)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._unresolved:
                left = None if deadline is None else \
                    deadline - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cv.wait(timeout=min(left, 0.1) if left is not None
                              else 0.1)
        return True

    def close(self, drain: bool = True, timeout: Optional[float] = None
              ) -> list:
        """Shut the fleet down.  Returns the caller futures still
        unresolved at return — empty on a clean drain (mirrors
        ``EeiServer.close``).  ``drain=False`` fails parked/queued work
        with :class:`FleetClosed` but still lets in-flight attempts land.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            first = not self._closed
            self._closed = True
            self._chaos_paused = True  # no new faults while draining
            parked = list(self._parked) if not drain else []
            if not drain:
                self._parked.clear()
            self._cv.notify_all()
        for freq in parked:
            if _set(freq.future, error=FleetClosed(
                    "EeiFleet closed before this request was dispatched")):
                with self._cv:
                    self._unresolved.discard(freq)
                    self.requests_failed += 1
        if drain and first:
            self.flush(timeout=timeout)
        with self._cv:
            self._cv.notify_all()
        self._monitor.join(
            None if deadline is None else
            max(deadline - time.monotonic(), 0.05))
        for replica in self._replicas.values():
            left = None if deadline is None else \
                max(deadline - time.monotonic(), 0.0)
            if replica.state in (HEALTHY, SLOW):
                replica.driver.close(drain=drain, timeout=left)
        with self._cv:
            stranded = [freq.future for freq in self._unresolved]
        if stranded:
            log.error("fleet: close() leaving %d future(s) unresolved",
                      len(stranded))
        return stranded

    def __enter__(self) -> "EeiFleet":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- observability --------------------------------------------------------

    def stats(self) -> dict:
        with self._cv:
            lat = sorted(self.latencies_ms)
            snap = {
                "n_replicas": self.n_replicas,
                "replica_states": {
                    r.rid: r.state for r in self._replicas.values()},
                "requests_submitted": self.requests_submitted,
                "requests_completed": self.requests_completed,
                "requests_failed": self.requests_failed,
                "requests_rejected": self.requests_rejected,
                "requests_unresolved": len(self._unresolved),
                "requests_parked": len(self._parked),
                "redispatches": self.redispatches,
                "hedges": self.hedges,
                "hedge_wasted": self.hedge_wasted,
                "replicas_killed": self.replicas_killed,
                "replicas_restarted": self.replicas_restarted,
                "deadline_deaths": self.deadline_deaths,
                "sessions_open": len(self._sessions),
                "sessions_opened": self.sessions_opened,
                "session_updates": self.session_updates,
                "session_failovers": self.session_failovers,
                "chaos_injected": (
                    self.chaos.counts() if self.chaos is not None else {}),
            }
            per_replica = {}
            for r in self._replicas.values():
                try:
                    per_replica[r.rid] = r.driver.stats()
                except Exception:
                    per_replica[r.rid] = {"unavailable": True}

        def pct(p):
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p / 100.0 * len(lat)))]

        snap.update({
            "p50_latency_ms": pct(50),
            "p99_latency_ms": pct(99),
            "per_replica": per_replica,
        })
        return snap
