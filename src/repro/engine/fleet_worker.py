"""Subprocess replica worker: one ``EeiServer`` behind a pipe protocol.

Launched by :class:`repro.engine.fleet.SubprocessReplica` as
``python -m repro.engine.fleet_worker``.  Speaks length-prefixed pickle
frames on stdin/stdout:

    parent -> worker:  {"op": "init", "server_kwargs": {...}}
                       {"op": "submit", "id": int, "a": ndarray,
                        "k": int, "largest": bool}
                       {"op": "hang", "s": float}
                       {"op": "slow", "s": float, "duration_s": float}
                       {"op": "close", "drain": bool}
    worker -> parent:  {"op": "ready"}
                       {"op": "result", "id": int, "ok": True,
                        "lam": ndarray, "vec": ndarray,
                        "degraded": bool, "fallback": str}
                       {"op": "result", "id": int, "ok": False,
                        "error": str, "replica_fault": bool}

The stdin reader thread only *enqueues*; a processor thread forwards to
the server — so a chaos ``hang`` (processor sleeps) never backs the pipe
up into the parent's dispatch path, and a chaos ``slow`` delays each
forward like an overloaded process would.  Results are written from the
server's retire-thread callbacks under one write lock.

The worker pins XLA's CPU backend to a small thread pool unless the
parent overrides it: a fleet of N workers on an N-core host should scale
by *process* parallelism, not have each worker's eigensolver fight over
every core.
"""

from __future__ import annotations

import os
import queue
import sys
import threading
import time


def _configure_host() -> None:
    # Must run before jax import: XLA reads these at backend init.
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "intra_op_parallelism_threads" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_cpu_multi_thread_eigen=false "
            "intra_op_parallelism_threads=1").strip()


def main() -> int:
    _configure_host()
    # Imports after host config so the XLA backend sees the flags.
    import numpy as np

    from repro.engine.fleet import _read_frame, _write_frame
    from repro.engine.server import EeiServer, ServerClosed

    stdin = sys.stdin.buffer
    stdout = sys.stdout.buffer
    write_lock = threading.Lock()

    def send(obj) -> None:
        with write_lock:
            _write_frame(stdout, obj)

    init = _read_frame(stdin)
    if init is None or init.get("op") != "init":
        return 2
    kwargs = dict(init.get("server_kwargs") or {})
    kwargs.setdefault("linger_ms", 2.0)
    server = EeiServer(**kwargs)
    send({"op": "ready"})

    inbox: "queue.Queue" = queue.Queue()
    state = {"hang_until": 0.0, "slow_until": 0.0, "slow_s": 0.0}

    def on_done(req_id: int, fut) -> None:
        if fut.cancelled():
            send({"op": "result", "id": req_id, "ok": False,
                  "error": "cancelled", "replica_fault": True})
            return
        exc = fut.exception()
        if exc is not None:
            send({"op": "result", "id": req_id, "ok": False,
                  "error": f"{type(exc).__name__}: {exc}",
                  "replica_fault": isinstance(exc, ServerClosed)})
            return
        res = fut.result()
        send({"op": "result", "id": req_id, "ok": True,
              "lam": np.asarray(res.eigenvalues),
              "vec": np.asarray(res.vectors),
              "degraded": bool(getattr(res, "degraded", False)),
              "fallback": str(getattr(res, "fallback", ""))})

    def process_loop() -> None:
        while True:
            msg = inbox.get()
            if msg is None or msg.get("op") == "close":
                drain = bool(msg.get("drain", True)) if msg else False
                server.close(drain=drain, timeout=30.0)
                os._exit(0)
            now = time.monotonic()
            if now < state["hang_until"]:
                time.sleep(state["hang_until"] - now)
            if time.monotonic() < state["slow_until"]:
                time.sleep(state["slow_s"])
            req_id = msg["id"]
            try:
                fut = server.submit(msg["a"], msg["k"], msg["largest"])
            except Exception as exc:
                send({"op": "result", "id": req_id, "ok": False,
                      "error": f"{type(exc).__name__}: {exc}",
                      "replica_fault": False})
                continue
            fut.add_done_callback(
                lambda f, req_id=req_id: on_done(req_id, f))

    processor = threading.Thread(target=process_loop, daemon=True)
    processor.start()

    while True:
        msg = _read_frame(stdin)
        if msg is None:  # parent went away: shut down
            inbox.put(None)
            processor.join(timeout=60.0)
            return 0
        op = msg.get("op")
        if op == "submit" or op == "close":
            inbox.put(msg)
            if op == "close":
                processor.join(timeout=60.0)
                return 0
        elif op == "hang":
            state["hang_until"] = time.monotonic() + float(msg["s"])
        elif op == "slow":
            state["slow_s"] = float(msg["s"])
            state["slow_until"] = time.monotonic() + \
                float(msg.get("duration_s", 1.0))


if __name__ == "__main__":
    sys.exit(main())
