"""SolverPlan — one immutable record of every EEI pipeline choice.

Before this subsystem the choice of implementation was scattered across four
dispatch sites (the ``identity.VARIANTS`` string ladder, the
``method``/``use_kernels`` flags of ``SpectralEngine``, the free ``shard_map``
functions in ``core.distributed`` and per-kernel ``interpret`` plumbing).  A
``SolverPlan`` captures all of it in one hashable value:

    method        eigh | eei_dense | eei_tridiag   (what maths runs)
    spectrum      full | windowed   (which composition top-k programs run:
                  the full-spectrum chain, or the k-windowed chain that
                  computes only the selected extremal rows)
    backend       reference | jnp | pallas | sharded   (who runs each stage)
    mesh / axes   device topology for the sharded backend
    precision     None (keep input dtype) | "float32" | "float64"
    bisect_iters  Sturm bisection iterations (0 -> dtype default)
    max_batch     microbatch bound for very long query stacks (0 -> no bound)

Plans are produced by :func:`plan_for` from problem shape + device topology,
or constructed explicitly.  The registry resolves ``(plan.method,
plan.spectrum)`` to a stage composition and ``plan.backend`` to the stage
library that implements it; ``SolverEngine`` executes the plan.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax

Method = Literal[
    "eigh", "eei_dense", "eei_tridiag", "eei_krylov", "eei_krylov_si"]
BackendName = Literal["reference", "jnp", "pallas", "sharded"]
Spectrum = Literal["full", "windowed"]

#: ``n`` below which a full LAPACK ``eigh`` beats any EEI pipeline (the
#: paper's crossover regime; Table 1 shows speedup < 1 for small n).
#: Uncalibrated fallback — :func:`resolved_crossovers` prefers the measured
#: calibration table (``repro.engine.autotune``) when one is available.
EIGH_CROSSOVER_N = 24

#: ``n`` up to which dense minor spectra (n LAPACK calls of size n-1) are
#: cheaper than tridiagonalize + Sturm on this class of hardware.
#: Uncalibrated fallback — see :func:`resolved_crossovers`.
DENSE_CROSSOVER_N = 64

#: ``k / n`` at/below which a top-k query plans the *windowed* composition
#: (windowed Sturm + windowed components) instead of the full-spectrum
#: chain.  Uncalibrated fallback — schema-v3 calibration tables carry the
#: measured crossover (:func:`resolved_windowed_k_frac`); the windowed
#: chain does strictly less work, so the measured value normally sits at
#: the top of the sweep.
WINDOWED_K_FRAC = 0.5

#: ``n`` at/above which a top-k query plans the Krylov (Lanczos partial
#: tridiagonalization) reduce stage instead of the dense Householder reduce.
#: Uncalibrated fallback — schema-v4 calibration tables carry the measured
#: crossover (:func:`resolved_krylov_n_min`).  The Krylov band costs
#: O(n^2 m) for m ~ 16k versus O(n^3) dense, so the crossover sits where
#: m << n, i.e. large n with a narrow window.
KRYLOV_N_MIN = 1024

#: ``k / n`` at/below which the Krylov band (m ~ 16k) is meaningfully
#: narrower than the matrix and the partial reduce can win.  Above this the
#: band approaches n and dense Householder is strictly better.
KRYLOV_K_FRAC = 1.0 / 16.0

#: Largest *bucketed* ``n`` whose requests the serving packer coalesces
#: into segment-packed rows under ``pack="auto"``.  Uncalibrated fallback —
#: schema-v5 calibration tables carry the measured value
#: (:func:`resolved_pack_n_max`).  Packing wins where per-launch overhead
#: and pad waste dominate the solve, i.e. well below the eigh crossover.
PACK_N_MAX = 32

#: Packed *row width* at/below which the packed composition pins the LAPACK
#: eigh chain; wider rows take the segmented-Sturm tridiagonal chain.
#: Mirrors the bucketed eigh crossover (the packed row is one matrix as far
#: as LAPACK is concerned), measured separately because the segmented chain
#: pays per-segment bracket work, not per-row.  Uncalibrated fallback — see
#: :func:`resolved_packed_eigh_n_max`.
PACKED_EIGH_N_MAX = 128


def resolved_krylov_n_min() -> int:
    """The measured ``n`` at which the Krylov reduce starts winning here.

    Reads the calibration table (see ``repro.engine.autotune``); the static
    :data:`KRYLOV_N_MIN` fallback applies when no table resolves or the
    table predates schema v4.
    """
    from repro.engine import autotune

    table = autotune.get_table()
    if table is None or table.krylov_n_min is None:
        return KRYLOV_N_MIN
    return table.krylov_n_min


def resolved_windowed_k_frac() -> float:
    """The measured ``k / n`` windowed-composition crossover for this host.

    Reads the calibration table (see ``repro.engine.autotune``); the static
    :data:`WINDOWED_K_FRAC` fallback applies when no table resolves or the
    table predates schema v3 (``windowed_k_frac`` then loads as the same
    fallback value).
    """
    from repro.engine import autotune

    table = autotune.get_table()
    if table is None:
        return WINDOWED_K_FRAC
    return table.windowed_k_frac


def resolved_pack_n_max() -> int:
    """The measured largest bucketed ``n`` worth segment-packing here.

    Reads the calibration table (see ``repro.engine.autotune``); the static
    :data:`PACK_N_MAX` fallback applies when no table resolves or the table
    predates schema v5.
    """
    from repro.engine import autotune

    table = autotune.get_table()
    if table is None or table.pack_n_max is None:
        return PACK_N_MAX
    return table.pack_n_max


def resolved_packed_eigh_n_max() -> int:
    """The measured packed row width at/below which eigh wins the packed
    chain (static :data:`PACKED_EIGH_N_MAX` fallback for pre-v5 tables)."""
    from repro.engine import autotune

    table = autotune.get_table()
    if table is None or table.packed_eigh_n_max is None:
        return PACKED_EIGH_N_MAX
    return table.packed_eigh_n_max


def packed_plan_for(
    row_n: int,
    *,
    backend: Optional[BackendName] = None,
    precision: Optional[str] = None,
) -> SolverPlan:
    """Pick the plan a segment-packed row stack executes.

    The packed row is block-diagonal, so both packed compositions apply to
    it directly; the choice is the packed twin of the bucketed eigh/EEI
    crossover, keyed on the *row width* (what LAPACK sees) rather than any
    single request's ``n``: at/below :func:`resolved_packed_eigh_n_max`
    the eigh chain (one LAPACK call + mass-gated per-slot selection) wins;
    above it the segmented-Sturm windowed tridiagonal chain takes over.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if row_n <= resolved_packed_eigh_n_max():
        return SolverPlan(
            method="eigh", backend=backend, precision=precision)
    return SolverPlan(
        method="eei_tridiag", backend=backend, spectrum="windowed",
        precision=precision)


def resolved_crossovers(backend: Optional[str] = None) -> tuple:
    """``(eigh_crossover_n, dense_crossover_n)`` the planner dispatches on.

    Reads the measured calibration table (env > user cache > repo default;
    see ``repro.engine.autotune``); the static module constants above are
    used only when no table can be found.  ``backend`` selects the
    backend-specific measurement when the table carries one (schema v2
    times the pallas backend separately — the kernelized EEI crosses over
    at a different ``n`` than fused jnp); v1 tables fall back to their
    single jnp-measured pair.
    """
    from repro.engine import autotune

    table = autotune.get_table()
    if table is None:
        return EIGH_CROSSOVER_N, DENSE_CROSSOVER_N
    return table.crossovers_for(backend)


def fallback_chain() -> tuple:
    """``((name, SolverPlan), ...)`` — the per-request escalation chain.

    The serving runtime walks this after a request is isolated (a
    single-request stack that still fails, or a stack row failing
    verification), re-solving the *unpadded* matrix under each plan in turn
    and host-verifying the result before it may resolve the future.
    Ordered cheap-to-certain:

    1. the windowed EEI path (the request's own fast path minus the
       co-batch — isolates co-batch/padding interactions);
    2. the full-spectrum EEI chain (index-targeted Sturm windows are the
       first casualty of clustered spectra; the full bisection is sturdier);
    3. shift-and-invert Krylov — the proven escape hatch for clustered
       *extremal* groups (the regime where the EEI denominators collapse);
    4. the LAPACK eigh oracle.

    A terminal pure-numpy ``eigh`` link (no XLA at all) is appended by the
    server itself, so even a wedged device path cannot strand a caller.
    Built lazily (not a module constant) so it is cheap to import and easy
    to monkeypatch in tests.
    """
    return (
        ("eei_windowed", SolverPlan(
            method="eei_tridiag", backend="jnp", spectrum="windowed")),
        ("eei_full", SolverPlan(method="eei_tridiag", backend="jnp")),
        ("eei_krylov_si", SolverPlan(
            method="eei_krylov_si", backend="jnp", spectrum="windowed")),
        ("eigh", SolverPlan(method="eigh", backend="jnp")),
    )


@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """Immutable, hashable description of one way to run the EEI pipeline."""

    method: Method = "eei_tridiag"
    backend: BackendName = "jnp"
    spectrum: Spectrum = "full"
    mesh: Optional[jax.sharding.Mesh] = None
    batch_axis: str = "data"
    minor_axis: Optional[str] = "model"
    precision: Optional[str] = None  # None -> keep input dtype
    bisect_iters: int = 0  # 0 -> dtype default
    max_batch: int = 0  # 0 -> solve the whole stack in one program
    krylov_m: int = 0  # Krylov band size; 0 -> default_m(n, k) at trace time

    def __post_init__(self):
        if self.method not in (
                "eigh", "eei_dense", "eei_tridiag", "eei_krylov",
                "eei_krylov_si"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.backend not in ("reference", "jnp", "pallas", "sharded"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.spectrum not in ("full", "windowed"):
            raise ValueError(f"unknown spectrum {self.spectrum!r}")
        if self.precision not in (None, "float32", "float64"):
            raise ValueError(f"unknown precision {self.precision!r}")
        if self.backend == "sharded":
            if self.mesh is None:
                raise ValueError("backend='sharded' requires a mesh")
            if self.batch_axis not in self.mesh.axis_names:
                raise ValueError(
                    f"batch_axis {self.batch_axis!r} not in mesh axes "
                    f"{self.mesh.axis_names}")

    @property
    def batch_axis_size(self) -> int:
        """Devices along the batch (data) axis; 1 for unsharded backends."""
        if self.backend != "sharded" or self.mesh is None:
            return 1
        return self.mesh.shape[self.batch_axis]


def plan_for(
    shape: tuple,
    *,
    k: Optional[int] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    method: Optional[Method] = None,
    backend: Optional[BackendName] = None,
    spectrum: Optional[Spectrum] = None,
    precision: Optional[str] = None,
    bisect_iters: int = 0,
) -> SolverPlan:
    """Pick a plan from problem shape + device topology.

    ``shape`` is ``(n, n)`` or ``(b, n, n)``; ``k`` is the number of
    eigenpairs the caller will ask for (``None`` = the full table).  Explicit
    ``method``/``backend`` keywords override the heuristics; everything else
    is derived:

    * tiny matrices (or full-spectrum queries on small ones) route to the
      LAPACK oracle — the paper's own conclusion is that EEI wins only for
      *partial* outputs past a crossover size; the crossover sizes come from
      the per-host measured calibration table when one exists
      (:func:`resolved_crossovers`), else the static fallback constants;
    * small matrices keep dense minors (n eigvalsh calls beat the
      tridiagonalization constant); larger ones take the tridiagonal path;
    * a known top-k window (``k`` given, below the measured
      ``windowed_k_frac`` fraction of ``n``) plans the *windowed*
      composition — top-k programs then compute only the selected extremal
      rows instead of the full spectrum table
      (:func:`resolved_windowed_k_frac`; ``spectrum`` overrides);
    * a *narrow* window on a *large* matrix (``k <= n/16`` and ``n`` past
      the measured :func:`resolved_krylov_n_min` crossover) swaps the dense
      Householder reduce for the Krylov (Lanczos) partial band — the whole
      downstream chain is band-size agnostic, so only the reduce changes;
    * a mesh with >1 device along its batch axis picks the sharded backend
      whenever the stack puts at least one matrix on every device —
      divisibility is *not* required, because both ``SolverEngine._run_chunk``
      and the serving runtime pad indivisible stacks up to the batch axis
      and slice back (pow2 serving buckets meet non-pow2 meshes here); a
      real TPU picks Pallas kernels; the fused-jnp backend is the portable
      default.
    """
    if len(shape) not in (2, 3):
        raise ValueError(f"expected (n, n) or (b, n, n), got {shape}")
    n = shape[-1]
    b = shape[0] if len(shape) == 3 else 1

    # Backend first: the method crossovers are backend-specific (the
    # calibration table times the pallas kernels separately from fused jnp).
    if backend is None:
        if (mesh is not None and "data" in mesh.axis_names
                and mesh.shape["data"] > 1 and b >= mesh.shape["data"]):
            backend = "sharded"
        elif jax.default_backend() == "tpu":
            backend = "pallas"
        else:
            backend = "jnp"
    if backend != "sharded":
        mesh = None

    if method is None:
        eigh_x, dense_x = resolved_crossovers(backend)
        if n <= eigh_x or (k is not None and k >= n):
            method = "eigh"
        elif n <= dense_x:
            method = "eei_dense"
        else:
            method = "eei_tridiag"
        # Narrow top-k window on a large matrix: replace the dense O(n^3)
        # Householder reduce with the Lanczos partial band (O(n^2 m),
        # m ~ 16k).  Only for genuinely narrow windows — the band must be
        # much smaller than n — and past the measured size crossover.
        # Shift-and-invert ("eei_krylov_si") is never planned implicitly;
        # it is an explicit choice for clustered-spectrum matrices.
        if (method == "eei_tridiag" and k is not None and 0 < k < n
                and k <= KRYLOV_K_FRAC * n and n >= resolved_krylov_n_min()):
            method = "eei_krylov"

    if spectrum is None:
        spectrum = "full"
        if (method != "eigh" and k is not None and 0 < k < n
                and k <= resolved_windowed_k_frac() * n):
            spectrum = "windowed"

    minor_axis = None
    if mesh is not None and "model" in mesh.axis_names:
        minor_axis = "model"

    return SolverPlan(
        method=method,
        backend=backend,
        spectrum=spectrum,
        mesh=mesh,
        batch_axis="data",
        minor_axis=minor_axis,
        precision=precision,
        bisect_iters=bisect_iters,
    )
