"""Stage-graph registry — the single dispatch point of the EEI pipeline.

The pipeline used to be a closed ``method in {eigh, eei_dense, eei_tridiag}``
if-ladder in the engine over a fixed ``BackendStages`` struct.  It is now a
*stage graph* with three pieces:

* a **stage library** per backend (:class:`StageLibrary`) — an open, named
  bundle of batched stage implementations (``registry.get_backend(plan)``
  resolves ``plan.backend`` to its library);
* **compositions** (:class:`Composition`) — named stage chains
  ``reduce -> spectrum -> [minor_spectra] -> components -> recover`` per
  program kind (``solve`` / ``topk`` / ``eigenvalues``), every stage
  declaring its dataflow signature (``requires``/``provides`` state keys),
  validated at registration;
* the engine's program builders are generic **graph executors**: they walk
  the resolved chain threading a state dict through the stage functions.
  Adding a method — or a windowed variant, or a future Lanczos reduce — is
  a new composition plus library entries, not a new engine branch.

Every stage is batched: arrays carry a leading stack axis ``b`` end-to-end.
The shared state keys (see the default compositions in ``backends.py``):

    a         (b, n, n)    the input stack
    idx       (k,) int32   selected eigenvalue indices (topk programs)
    d, e, q   band + Q     from the tridiagonalize reduce stage
    lam       (b, n)       full spectrum, ascending
    lam_sel   (b, k)       selected window of the spectrum, ascending
    mu        (b, n, n-1)  minor spectra
    mags      (b, n, n)    full |v[i, j]|^2 table (dense basis after recover)
    mag_sel   (b, k, n)    selected |v|^2 rows (pre-sign basis)
    v         (b, n, n)    LAPACK eigenvectors (eigh composition only)
    vecs      (b, k, n)    signed, unit-norm selected eigenvectors
    seg_off   (b, S) int32 segment start columns (packed_topk programs)
    seg_len   (b, S) int32 segment lengths, 0 = empty slot (packed_topk)
    lam_seg   (b, S, k)    per-slot eigenvalue windows, ascending per slot
    vecs_seg  (b, S, k, n) per-slot eigenvectors (full packed-row width;
                           the server slices each segment's columns out)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.engine.plan import SolverPlan

#: Stage roles in pipeline order.  A chain may skip roles (the windowed
#: tridiagonal composition has no ``minor_spectra`` stage at all — its
#: components stage evaluates minor determinants directly), but may not
#: reorder them.  ``verify`` is a post-solve checking role appended to a
#: chain by the engine when the caller asks for verified output; it
#: consumes the final state and provides per-row ``VerifyFlags``.
STAGE_ROLES = (
    "reduce", "spectrum", "minor_spectra", "components", "recover", "verify")

#: Program kinds a composition can serve, with the state each starts from
#: and the keys its final state must provide.  ``packed_topk`` is the
#: segment-packed serving kind: the input stack is a batch of block-diagonal
#: rows, each carrying up to ``S`` independent request matrices (segments)
#: described by ``seg_off``/``seg_len`` ``(b, S)`` int32 operands, and the
#: program returns per-slot windows instead of per-row windows.
PROGRAM_KINDS = ("solve", "topk", "eigenvalues", "packed_topk", "update")
_INITIAL_KEYS = {
    "solve": frozenset({"a"}),
    "topk": frozenset({"a", "idx"}),
    "eigenvalues": frozenset({"a", "idx"}),
    "packed_topk": frozenset({"a", "seg_off", "seg_len"}),
    # ``update`` is the streaming rank-1 maintenance kind: ``a`` is the
    # *already-updated* stack, ``basis``/``theta`` (b, m, n)/(b, m) are the
    # session's retained Ritz pairs from the previous solve, ``u`` (b, n)
    # the unit update direction and ``rho`` (b,) its signed squared norm.
    "update": frozenset({"a", "basis", "theta", "u", "rho", "idx"}),
}
_FINAL_KEYS = {
    "solve": ({"lam", "mags"},),
    "topk": ({"lam_sel", "vecs"},),
    # windowed eigenvalue chains end at the window; full chains at the
    # spectrum — either terminal is a valid eigenvalues program.
    "eigenvalues": ({"lam"}, {"lam_sel"}),
    "packed_topk": ({"lam_seg", "vecs_seg"},),
    # the refreshed session state rides out with the answer: the engine
    # caches ``basis``/``theta`` device-side for the next update.
    "update": ({"lam_sel", "vecs", "basis", "theta"},),
}


@dataclasses.dataclass(frozen=True)
class StageSig:
    """One stage of a chain: role + implementation name + dataflow keys."""

    role: str
    name: str
    requires: Tuple[str, ...]
    provides: Tuple[str, ...]

    def __post_init__(self):
        if self.role not in STAGE_ROLES:
            raise ValueError(
                f"unknown stage role {self.role!r}; expected one of "
                f"{STAGE_ROLES}")


@dataclasses.dataclass(frozen=True)
class Composition:
    """A named, validated stage chain per program kind.

    ``solve`` / ``eigenvalues`` may be ``None``: windowed compositions have
    no full-table solve (the engine falls back to the method's full
    composition — a full table needs every row by definition).
    """

    name: str
    method: str
    windowed: bool
    topk: Tuple[StageSig, ...]
    solve: Optional[Tuple[StageSig, ...]] = None
    eigenvalues: Optional[Tuple[StageSig, ...]] = None
    packed_topk: Optional[Tuple[StageSig, ...]] = None
    update: Optional[Tuple[StageSig, ...]] = None

    def chain(self, kind: str) -> Optional[Tuple[StageSig, ...]]:
        if kind not in PROGRAM_KINDS:
            raise ValueError(f"unknown program kind {kind!r}")
        return getattr(self, kind)

    def validate(self) -> None:
        """Check every declared chain for role order and dataflow.

        Each stage's ``requires`` must be satisfied by the accumulated
        ``provides`` of the stages before it (plus the program kind's
        initial state), and the final state must carry the kind's outputs.
        This is what the stage-graph unit test asserts for every registered
        composition — a chain that type-checks here cannot KeyError inside
        a jitted executor.
        """
        for kind in PROGRAM_KINDS:
            chain = self.chain(kind)
            if chain is None:
                continue
            have = set(_INITIAL_KEYS[kind])
            last_role = -1
            for sig in chain:
                role_i = STAGE_ROLES.index(sig.role)
                if role_i < last_role:
                    raise ValueError(
                        f"composition {self.name!r} ({kind}): stage "
                        f"{sig.name!r} role {sig.role!r} out of order")
                last_role = role_i
                missing = set(sig.requires) - have
                if missing:
                    raise ValueError(
                        f"composition {self.name!r} ({kind}): stage "
                        f"{sig.name!r} requires {sorted(missing)} not "
                        f"provided upstream (have {sorted(have)})")
                have |= set(sig.provides)
            if not any(alt <= have for alt in _FINAL_KEYS[kind]):
                raise ValueError(
                    f"composition {self.name!r} ({kind}): final state "
                    f"{sorted(have)} provides none of "
                    f"{[sorted(a) for a in _FINAL_KEYS[kind]]}")


class StageLibrary:
    """Open bundle of batched stage implementations for one backend.

    Replaces the former frozen ``BackendStages`` struct: stages live in a
    name -> callable mapping, so a backend (or a plugin) can add stage
    implementations without touching a shared struct definition.  Stage
    functions are reachable as attributes (``lib.tridiagonalize``) for
    ergonomic access from stage builders and tests.
    """

    def __init__(self, name: str, stages: Dict[str, Callable]):
        self.name = name
        self._stages = dict(stages)

    def __getattr__(self, key: str) -> Callable:
        try:
            return self._stages[key]
        except KeyError:
            raise AttributeError(
                f"backend {self.name!r} has no stage {key!r}; available: "
                f"{sorted(self._stages)}") from None

    def get(self, key: str) -> Callable:
        return getattr(self, key)

    def stage_names(self) -> list:
        return sorted(self._stages)

    def extended(self, **overrides: Callable) -> "StageLibrary":
        """A copy with stages added/replaced — composition over mutation."""
        stages = dict(self._stages)
        stages.update(overrides)
        return StageLibrary(self.name, stages)


BackendFactory = Callable[[SolverPlan], StageLibrary]

_REGISTRY: Dict[str, BackendFactory] = {}
_COMPOSITIONS: Dict[str, Composition] = {}
_BY_METHOD: Dict[Tuple[str, bool], str] = {}


def register_backend(name: str, factory: BackendFactory) -> None:
    """Register (or replace) the stage-library factory for ``name``."""
    _REGISTRY[name] = factory


def get_backend(plan: SolverPlan) -> StageLibrary:
    """Resolve ``plan.backend`` to its stage library."""
    try:
        factory = _REGISTRY[plan.backend]
    except KeyError:
        raise KeyError(
            f"no backend {plan.backend!r} registered; "
            f"available: {sorted(_REGISTRY)}") from None
    return factory(plan)


def available_backends() -> list:
    return sorted(_REGISTRY)


def register_composition(comp: Composition) -> None:
    """Validate and register (or replace) a composition."""
    comp.validate()
    _COMPOSITIONS[comp.name] = comp
    # Re-registering a name under a different (method, windowed) pair must
    # not leave the old reverse-index entry pointing at it.
    for key in [k for k, name in _BY_METHOD.items() if name == comp.name]:
        del _BY_METHOD[key]
    _BY_METHOD[(comp.method, comp.windowed)] = comp.name


def get_composition(name: str) -> Composition:
    try:
        return _COMPOSITIONS[name]
    except KeyError:
        raise KeyError(
            f"no composition {name!r} registered; available: "
            f"{sorted(_COMPOSITIONS)}") from None


def available_compositions() -> list:
    return sorted(_COMPOSITIONS)


def composition_for(method: str, windowed: bool = False) -> Composition:
    """The composition serving ``method`` (windowed variant if asked).

    A method with no windowed variant (``eigh`` computes everything in one
    LAPACK call — there is nothing to window) falls back to its full
    composition, so a windowed plan is always executable.
    """
    name = _BY_METHOD.get((method, windowed))
    if name is None and windowed:
        name = _BY_METHOD.get((method, False))
    if name is None:
        raise KeyError(
            f"no composition registered for method {method!r}; "
            f"available: {sorted(_BY_METHOD)}")
    return _COMPOSITIONS[name]


def has_windowed(method: str) -> bool:
    """Whether ``method`` registers a dedicated windowed composition."""
    return (method, True) in _BY_METHOD
