"""Backend registry — the single dispatch point of the EEI pipeline.

A *backend* is a named bundle of stage implementations.  Every stage is
batched: arrays carry a leading stack axis ``b`` end-to-end.

    tridiagonalize(a, with_q)        (b, n, n) -> d (b, n), e (b, n-1), q|None
    tridiag_eigenvalues(d, e)        (b, n), (b, n-1) -> lam (b, n)
    tridiag_minor_spectra(d, e)      (b, n), (b, n-1) -> mu (b, n, n-1)
    dense_eigenvalues(a)             (b, n, n) -> lam (b, n)
    dense_spectra(a)                 (b, n, n) -> lam (b, n), mu (b, n, n-1)
    magnitudes(lam, mu)              -> |v[i, j]|^2 table (b, n, n)
    tridiag_signs(d, e, lam_s, mag_s)  selected rows -> signed w (b, k, n)
    dense_signs(a, lam_s, mag_s)       selected rows -> signed v (b, k, n)

Backends register a *factory* taking the ``SolverPlan`` (the sharded backend
needs the mesh; stateless backends ignore it).  This replaces the former
string/flag dispatch scattered over ``identity.VARIANTS``,
``SpectralEngine(method=..., use_kernels=...)`` and the free functions of
``core.distributed``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.engine.plan import SolverPlan


@dataclasses.dataclass(frozen=True)
class BackendStages:
    """Stage implementations one backend provides (all batched)."""

    name: str
    tridiagonalize: Callable
    tridiag_eigenvalues: Callable
    tridiag_minor_spectra: Callable
    dense_eigenvalues: Callable
    dense_spectra: Callable
    magnitudes: Callable
    tridiag_signs: Callable
    dense_signs: Callable


BackendFactory = Callable[[SolverPlan], BackendStages]

_REGISTRY: Dict[str, BackendFactory] = {}


def register_backend(name: str, factory: BackendFactory) -> None:
    """Register (or replace) the factory for backend ``name``."""
    _REGISTRY[name] = factory


def get_backend(plan: SolverPlan) -> BackendStages:
    """Resolve ``plan.backend`` to its stage bundle."""
    try:
        factory = _REGISTRY[plan.backend]
    except KeyError:
        raise KeyError(
            f"no backend {plan.backend!r} registered; "
            f"available: {sorted(_REGISTRY)}") from None
    return factory(plan)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)
