"""EeiServer — continuous-batching serving runtime for EEI top-k queries.

``launch/serve.py --eei`` (and anything else serving the paper's workload —
streams of partial eigenpair queries over many small symmetric matrices)
used to run a static, synchronous loop: one fixed ``(b, n, k)`` per process,
``block_until_ready`` after every request, and a fresh XLA compile for every
distinct shape.  This module replaces that loop with a serving runtime:

    submit() ──> request queue (heterogeneous n, k, largest)
                     │  coalesce: FIFO groups sharing a coalesce key
                     ▼           (bucket_n, bucket_k, largest)
                dynamic stacks (up to SolverPlan.max_batch requests)
                     │  pad to a ShapeBucket: b -> next power of two,
                     ▼  n -> the kernel block grid, k -> next power of two
                ProgramCache (bucket -> AOT-compiled executable;
                     │         hit / miss / compile counters)
                     ▼
                async double-buffered dispatch (stack i+1 enqueues while
                     │                          i computes on device)
                     ▼
                completion futures (per-request slices out of the padded
                                    stack; guard rows never escape)

Shape bucketing is what bounds compilation: every request executes through
one of a small set of padded shapes, so a 100-request mixed stream compiles
at most one program per distinct bucket instead of one per distinct request
shape.  ``n`` rounds up to the kernel block-grid granule
(``kernels/blocks.clamp_block``'s align-8 sublane grid that the calibrated
tile shapes clamp to); ``b`` and ``k`` round to powers of two.

Matrices are padded from ``(n, n)`` to ``(bn, bn)`` as ``diag(A, c * I)``
with the guard value ``c`` placed strictly outside the spectrum (Gershgorin
bound) on the side *away* from the requested extreme, so the guard
eigenvalues can never enter a top-k (or bottom-k) window and the A-block
eigenpairs are preserved exactly (the padded block decouples: Householder,
Sturm and the sign recurrence all see an exactly-zero junction, which
``tridiagonal_signs`` handles as a restart).

Async dispatch exploits JAX's asynchronous execution: a compiled program
call returns immediately with device buffers in flight, so the server keeps
up to ``max_inflight`` stacks outstanding and only blocks when retiring the
oldest — stack ``i+1`` is enqueued while ``i`` computes, removing the
per-request ``block_until_ready`` barrier of the synchronous loop.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import engine as engine_mod
from repro.engine.plan import SolverPlan, plan_for
from repro.kernels import blocks

log = logging.getLogger("repro.engine.server")

#: Default matrix-size granule for shape buckets — the f32 sublane granule
#: the Pallas block clamp aligns to (``kernels/blocks.clamp_block``).
N_ALIGN = 8


def _bucket_n(n: int, align: int) -> int:
    """Matrix-size bucket: ``n`` rounded up to the block-grid granule."""
    return -(-n // align) * align


def make_eei_stream(
    requests: int, n: int, k: int, seed: int = 0, mixed: bool = False
) -> list:
    """Pre-generated request stream: ``[(a (n_i, n_i) np.float32, k_i), ...]``.

    Generated *outside* any timed region — host-side data synthesis used to
    run inside ``serve.py``'s timed loop and deflate reported solves/s.
    ``mixed`` samples ``n_i`` and ``k_i`` per request (the heterogeneous
    stream shape buckets exist for); otherwise every request is ``(n, k)``.
    """
    rng = np.random.default_rng(seed)
    sizes = sorted({max(8, n // 2), n, n + max(8, n // 2)}) if mixed else [n]
    stream = []
    for _ in range(requests):
        n_i = int(rng.choice(sizes))
        k_i = int(rng.integers(1, k + 1)) if mixed else k
        a = rng.standard_normal((n_i, n_i)).astype(np.float32)
        stream.append(((a + a.T) / 2, min(k_i, n_i)))
    return stream


class ShapeBucket(NamedTuple):
    """One padded program shape: every request executes through one of these."""

    b: int  # stack size (power of two)
    n: int  # matrix size (block-grid aligned)
    k: int  # top-k (power of two, <= n)
    largest: bool

    @classmethod
    def for_requests(cls, count: int, n: int, k: int, largest: bool,
                     n_align: int = N_ALIGN) -> "ShapeBucket":
        bn = _bucket_n(n, n_align)
        return cls(
            b=blocks.pow2_bucket(count),
            n=bn,
            k=min(blocks.pow2_bucket(k), bn),
            largest=bool(largest),
        )


class ProgramCache:
    """Bucket -> AOT-compiled executable, with observable counters.

    Replaces the engine's implicit ``lru_cache``-plus-XLA-shape-cache
    behavior for serving: compiles are an explicit, countable event (tests
    and the serve log assert a mixed stream compiles at most once per
    distinct bucket), and entries hold the *compiled* executable — lookup
    on the hot path is one dict probe, no retracing.
    """

    def __init__(self):
        self._programs: "OrderedDict[tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @property
    def compiles(self) -> int:
        """Number of programs compiled (== misses: one compile per miss)."""
        return self.misses

    def __len__(self) -> int:
        return len(self._programs)

    def buckets(self) -> list:
        """The distinct buckets compiled so far (insertion order)."""
        return [key[0] for key in self._programs]

    def get(self, bucket: ShapeBucket, plan: SolverPlan, dtype) -> object:
        key = (bucket, plan, jnp.dtype(dtype).name)
        prog = self._programs.get(key)
        if prog is not None:
            self.hits += 1
            return prog
        self.misses += 1
        fn = engine_mod._topk_program(plan, bucket.k, bucket.largest)
        sds = jax.ShapeDtypeStruct((bucket.b, bucket.n, bucket.n),
                                   jnp.dtype(dtype))
        prog = fn.lower(sds).compile()
        self._programs[key] = prog
        return prog


@dataclasses.dataclass(eq=False)  # identity equality: queue removal by object
class _Request:
    a: np.ndarray  # (n, n) symmetric, already cast to the server dtype
    n: int
    k: int
    largest: bool
    future: Future
    t_submit: float


@dataclasses.dataclass
class _InflightStack:
    result: object  # TopkResult of device arrays, possibly still computing
    requests: list  # the _Requests whose slices ride in this stack
    bucket: ShapeBucket


class EeiServer:
    """Continuous-batching server for heterogeneous EEI top-k queries.

    ``submit(a, k, largest)`` enqueues one query over a single symmetric
    matrix and returns a ``concurrent.futures.Future`` resolving to a
    ``TopkResult`` of numpy arrays with the *request's* shapes
    (``(k,)`` eigenvalues, ``(k, n)`` vectors) — bucket padding never leaks.
    Dispatch is driven by ``pump()`` (dispatches every coalesce group that
    fills a whole ``max_batch`` stack) and ``flush()`` (drains everything,
    partial stacks included, and blocks until all futures resolve).

    ``plan`` pins one :class:`SolverPlan` for every bucket; by default each
    bucket gets ``plan_for((b, n, n), k=...)`` so small-n buckets may route
    to ``eigh`` while large-n buckets take the kernelized EEI pipeline,
    exactly like per-request planning would.
    """

    def __init__(
        self,
        plan: Optional[SolverPlan] = None,
        *,
        max_batch: int = 64,
        max_inflight: int = 2,
        n_align: int = N_ALIGN,
        dtype=jnp.float32,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self._plan = plan
        # Stack buckets are powers of two, so a non-pow2 bound would round
        # *up* past the operator's memory/latency limit — floor it instead
        # (a max_batch of 48 serves stacks of at most 32).
        self.max_batch = 1 << (max_batch.bit_length() - 1)
        self.max_inflight = max_inflight
        self.n_align = n_align
        self.dtype = jnp.dtype(dtype)
        self.cache = ProgramCache()
        # Admission is bucketed at submit time: coalesce key -> FIFO deque.
        # Keys are independent, so a partial group in one key never blocks a
        # full stack forming in another, and group take-off is O(group)
        # instead of a full-queue scan.
        self._queues: "OrderedDict[tuple, deque]" = OrderedDict()
        self._inflight: "deque[_InflightStack]" = deque()
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_failed = 0
        self.stacks_dispatched = 0
        self.latencies_ms: list = []

    # -- admission ---------------------------------------------------------

    def submit(self, a, k: int, largest: bool = True) -> Future:
        """Admit one ``(n, n)`` top-k query; returns its completion future."""
        a = np.asarray(a, dtype=self.dtype)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected one (n, n) matrix, got {a.shape}")
        n = a.shape[0]
        if k < 1 or k > n:
            raise ValueError(f"k={k} out of range for n={n}")
        req = _Request(a=a, n=n, k=int(k), largest=bool(largest),
                       future=Future(), t_submit=time.monotonic())
        self._queues.setdefault(self._coalesce_key(req), deque()).append(req)
        self.requests_submitted += 1
        self.pump()
        return req.future

    def _coalesce_key(self, req: _Request) -> tuple:
        # k is deliberately NOT part of the key: requests with different k
        # stack together (the program runs the group's max k rounded to a
        # power of two and each future slices its own k back out), so a
        # mixed-k stream coalesces into full stacks instead of fragmenting
        # into near-empty per-k groups.
        return (_bucket_n(req.n, self.n_align), req.largest)

    def _pop_group(self, key: tuple) -> list:
        q = self._queues[key]
        group = [q.popleft() for _ in range(min(len(q), self.max_batch))]
        if not q:
            del self._queues[key]
        return group

    # -- dispatch ----------------------------------------------------------

    def _guard_value(self, a: np.ndarray, largest: bool) -> float:
        """Diagonal guard for the padded block: strictly outside the
        spectrum, on the side away from the requested extreme."""
        radius = np.sum(np.abs(a), axis=1) - np.abs(np.diagonal(a))
        diag = np.diagonal(a)
        lo = float(np.min(diag - radius))
        hi = float(np.max(diag + radius))
        margin = max(1.0, 0.01 * (hi - lo))
        return lo - margin if largest else hi + margin

    def _assemble(self, group: list, bucket: ShapeBucket) -> np.ndarray:
        stack = np.zeros((bucket.b, bucket.n, bucket.n), dtype=self.dtype)
        for row, req in enumerate(group):
            stack[row, : req.n, : req.n] = req.a
            if req.n < bucket.n:
                guard = self._guard_value(req.a, req.largest)
                idx = np.arange(req.n, bucket.n)
                stack[row, idx, idx] = guard
        # Batch padding repeats the first padded row: real matrices, so the
        # program never sees degenerate all-zero inputs; sliced off below.
        stack[len(group):] = stack[0]
        return stack

    def _dispatch(self, group: list) -> None:
        bucket = ShapeBucket.for_requests(
            len(group), max(r.n for r in group), max(r.k for r in group),
            group[0].largest, n_align=self.n_align)
        # The plan is a pure function of the bucket (never of raw group
        # values), so one bucket can never compile under two plans.
        plan = self._plan
        if plan is None:
            plan = plan_for((bucket.b, bucket.n, bucket.n), k=bucket.k)
        # The sharded backend needs the stack divisible by the mesh batch
        # axis (SolverEngine._run_chunk pads for the same reason) — round
        # the pow2 bucket up to the next multiple.
        mult = plan.batch_axis_size
        if bucket.b % mult:
            bucket = bucket._replace(b=bucket.b + (-bucket.b) % mult)
        stack = self._assemble(group, bucket)
        # Keep at most max_inflight stacks of device buffers live: retire
        # the oldest *before* launching when at capacity.
        while len(self._inflight) >= self.max_inflight:
            self._retire(self._inflight.popleft())
        try:
            program = self.cache.get(bucket, plan, self.dtype)
            result = program(jnp.asarray(stack))  # async: returns at once
        except Exception as exc:  # compile/launch failure: fail the group,
            self._fail(group, exc)  # not the whole serving process
            return
        self._inflight.append(_InflightStack(result, list(group), bucket))
        self.stacks_dispatched += 1

    def _fail(self, requests: list, exc: Exception) -> None:
        """Resolve a group's futures with the error — a failed dispatch
        must never strand callers blocked on ``future.result()``."""
        log.error("EEI stack dispatch failed for %d request(s): %s",
                  len(requests), exc)
        for req in requests:
            req.future.set_exception(exc)
            self.requests_failed += 1

    def _retire(self, inflight: _InflightStack) -> None:
        """Block on one stack and resolve its requests' futures."""
        try:
            lam = np.asarray(inflight.result.eigenvalues)  # sync point
            vec = np.asarray(inflight.result.vectors)
        except Exception as exc:  # device-side failure surfaces here
            self._fail(inflight.requests, exc)
            return
        t_done = time.monotonic()
        for row, req in enumerate(inflight.requests):
            # The program returns `bucket.k` ascending pairs at the requested
            # extreme.  Guards were placed on the far side of the spectrum,
            # so the request's k pairs are the window's own extreme end:
            # the *last* k for largest, the *first* k for smallest.
            if req.largest:
                lam_r = lam[row, -req.k:]
                vec_r = vec[row, -req.k:, : req.n]
            else:
                lam_r = lam[row, : req.k]
                vec_r = vec[row, : req.k, : req.n]
            req.future.set_result(
                engine_mod.TopkResult(lam_r, vec_r))
            self.latencies_ms.append((t_done - req.t_submit) * 1e3)
            self.requests_completed += 1

    # -- draining ----------------------------------------------------------

    def pump(self) -> None:
        """Dispatch every coalesce group that fills a whole stack.

        Partial groups keep accumulating (so the stream batches instead of
        degenerating to per-request programs), but only within their own
        key — a partial group never delays a full stack of another shape.
        """
        for key in [k for k, q in self._queues.items()
                    if len(q) >= self.max_batch]:
            while len(self._queues.get(key, ())) >= self.max_batch:
                self._dispatch(self._pop_group(key))

    def flush(self) -> None:
        """Dispatch all queued requests (partial stacks too) and block
        until every in-flight stack has retired."""
        while self._queues:
            key = next(iter(self._queues))
            self._dispatch(self._pop_group(key))
        while self._inflight:
            self._retire(self._inflight.popleft())

    # -- observability -----------------------------------------------------

    def reset_stats(self) -> None:
        """Zero request/stack/latency counters and the cache's hit counter,
        keeping compiled programs — benchmarks warm the cache with one pass,
        reset, then time a steady-state pass (compiles then stay 0)."""
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_failed = 0
        self.stacks_dispatched = 0
        self.latencies_ms = []
        self.cache.hits = 0
        self.cache.misses = 0

    def stats(self) -> dict:
        lat = sorted(self.latencies_ms)

        def pct(p):
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p / 100.0 * len(lat)))]

        return {
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "requests_failed": self.requests_failed,
            "stacks_dispatched": self.stacks_dispatched,
            "program_compiles": self.cache.compiles,
            "program_hits": self.cache.hits,
            "distinct_buckets": len(self.cache),
            "p50_latency_ms": pct(50),
            "p99_latency_ms": pct(99),
        }
