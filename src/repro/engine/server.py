"""EeiServer — concurrent continuous-batching serving runtime for EEI top-k.

``launch/serve.py --eei`` (and anything else serving the paper's workload —
streams of partial eigenpair queries over many small symmetric matrices)
used to run a static, synchronous loop: one fixed ``(b, n, k)`` per process,
``block_until_ready`` after every request, and a fresh XLA compile for every
distinct shape.  This module replaces that loop with a serving runtime:

    submit() ──> request queues (heterogeneous n, k, largest; thread-safe
         │       from any number of producer threads, with optional
         │       ``max_pending`` backpressure: block or raise QueueFull)
         ▼  coalesce: FIFO groups sharing a key (bucket_n, largest)
    admission ──> dynamic stacks (full stacks immediately; *partial* stacks
         │        once their oldest request has lingered ``linger_ms`` —
         ▼        sparse streams drain with no explicit flush())
    ProgramCache (bucket -> AOT-compiled executable; hit / miss / compile
         │        counters; internally locked, shareable between servers)
         ▼
    async dispatch (≤ max_inflight stacks of device buffers outstanding)
         │
         ▼
    retire ──> completion futures (per-request slices out of the padded
               stack; guard rows never escape; a failed dispatch or a
               closed server resolves futures with the error — callers
               blocked on ``future.result()`` are never stranded)

Two run modes share every dispatch/retire/cache path:

* **caller-driven** (``linger_ms=None``, the default): no background
  threads.  ``submit()`` dispatches full stacks inline (via ``pump()``)
  and ``flush()`` drains partial stacks and blocks until every future
  resolves — the PR-3 behavior, still thread-safe under the server lock.
* **threaded** (``linger_ms`` set): a background *admission* thread forms
  stacks — full groups immediately, partial groups once their oldest
  request has waited ``linger_ms`` — and a *retire* thread blocks on the
  oldest in-flight stack and resolves futures, so producers never block on
  device sync.  ``flush()`` becomes a drain barrier; ``close()`` drains
  everything, joins both threads, and resolves any late ``submit()`` with
  an already-set :class:`ServerClosed` error.

Threading model (see ``docs/ARCHITECTURE.md`` for the full write-up):
one re-entrant server lock guards queues, the in-flight deque and all
counters; a single condition variable (``_cv``) carries every wakeup
(new work, linger deadline, in-flight capacity, drain progress).  The
``ProgramCache`` lock is a *leaf*: the cache never calls back into the
server, so lock order is server lock -> cache lock and never the reverse.
The admission thread compiles and launches *outside* the server lock, so a
multi-second XLA compile never blocks ``submit()``.

Shape bucketing is what bounds compilation: every request executes through
one of a small set of padded shapes, so a 100-request mixed stream compiles
at most one program per distinct bucket instead of one per distinct request
shape.  ``n`` rounds up to the kernel block-grid granule
(``kernels/blocks.clamp_block``'s align-8 sublane grid that the calibrated
tile shapes clamp to); ``b`` and ``k`` round to powers of two.

Matrices are padded from ``(n, n)`` to ``(bn, bn)`` as ``diag(A, c * I)``
with the guard value ``c`` placed strictly outside the spectrum (Gershgorin
bound) on the side *away* from the requested extreme, so the guard
eigenvalues can never enter a top-k (or bottom-k) window and the A-block
eigenpairs are preserved exactly (the padded block decouples: Householder,
Sturm and the sign recurrence all see an exactly-zero junction, which
``tridiagonal_signs`` handles as a restart).

The ``sharded`` backend serves through the same path: pow2 stack buckets
are rounded up to the mesh batch axis, so a serve mode runs on a
multi-device mesh (``serve.py --eei --sharded``; tests force a 2-device
host mesh via ``XLA_FLAGS=--xla_force_host_platform_device_count``).
"""

from __future__ import annotations

import dataclasses
import itertools
import logging
import threading
import time
import weakref
from collections import OrderedDict, deque
from concurrent.futures import Future, InvalidStateError
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import engine as engine_mod
from repro.engine import registry
from repro.engine.plan import (
    SolverPlan,
    fallback_chain,
    packed_plan_for,
    plan_for,
    resolved_pack_n_max,
)
from repro.engine.verify import verify_topk_host
from repro.kernels import blocks
from repro.runtime.chaos import ChaosError, ChaosFailure, ChaosMonkey
from repro.runtime.fault_tolerance import decorrelated_jitter

log = logging.getLogger("repro.engine.server")

#: Default matrix-size granule for shape buckets — the f32 sublane granule
#: the Pallas block clamp aligns to (``kernels/blocks.clamp_block``).
N_ALIGN = 8


class ServerClosed(RuntimeError):
    """The server has been closed; the request was not (or will not be)
    served.  Late ``submit()`` calls get a future with this error already
    set rather than an exception at the call site, so producer loops that
    race ``close()`` observe a uniformly-resolved future either way."""


class QueueFull(RuntimeError):
    """``max_pending`` backpressure bound hit under ``pending_policy
    ='except'``."""


class VerifyFailed(RuntimeError):
    """A served result failed post-solve verification (non-finite entries,
    residual above tolerance, broken norm or bracket order).  Never reaches
    a caller while the fallback chain is enabled — it is the *cause* that
    routes a request down the chain; it only resolves a future when every
    fallback (including the eigh oracle) also failed."""


class DegradedResult(engine_mod.TopkResult):
    """A :class:`~repro.engine.engine.TopkResult` served by the fallback
    chain instead of the request's primary bucket program.

    Still a 2-tuple (``eigenvalues, vectors`` unpack as usual) so existing
    callers are oblivious; ``degraded`` is ``True`` (the base class carries
    ``False``) and ``fallback`` names the chain link that produced it
    (e.g. ``"eigh_oracle"``).  Quality is verified before resolution, so a
    degraded result is a *correct* result that took the slow path.
    """

    degraded = True

    def __new__(cls, eigenvalues, vectors, fallback: str = ""):
        self = super().__new__(cls, eigenvalues, vectors)
        self.fallback = fallback
        return self


def _is_transient(exc: BaseException) -> bool:
    """Whether a dispatch failure is worth retrying in place.

    Injected :class:`ChaosFailure` models the class: transient compile /
    launch / allocation errors.  Anything carrying a truthy ``transient``
    attribute opts in; everything else goes straight to split/fallback
    (retrying a deterministic error just burns the backoff budget).
    """
    return isinstance(exc, ChaosFailure) or bool(
        getattr(exc, "transient", False))


def _eigh_oracle(a: np.ndarray, k: int, largest: bool):
    """Terminal fallback: pure-numpy float64 LAPACK eigh on the host.

    No XLA, no device, no compile — the one link that cannot share a
    failure mode with the serving path.  Returns ``(lam (k,), vecs (k, n))``
    ascending at the requested extreme, rows as eigenvectors.
    """
    lam, v = np.linalg.eigh(np.asarray(a, dtype=np.float64))
    if largest:
        return lam[-k:], v[:, -k:].T
    return lam[:k], v[:, :k].T


def _bucket_n(n: int, align: int) -> int:
    """Matrix-size bucket: ``n`` rounded up to the block-grid granule."""
    return -(-n // align) * align


def make_eei_stream(
    requests: int, n: int, k: int, seed: int = 0, mixed: bool = False
) -> list:
    """Pre-generated request stream: ``[(a (n_i, n_i) np.float32, k_i), ...]``.

    Generated *outside* any timed region — host-side data synthesis used to
    run inside ``serve.py``'s timed loop and deflate reported solves/s.
    ``mixed`` samples ``n_i`` and ``k_i`` per request (the heterogeneous
    stream shape buckets exist for); otherwise every request is ``(n, k)``.
    """
    rng = np.random.default_rng(seed)
    sizes = sorted({max(8, n // 2), n, n + max(8, n // 2)}) if mixed else [n]
    stream = []
    for _ in range(requests):
        n_i = int(rng.choice(sizes))
        k_i = int(rng.integers(1, k + 1)) if mixed else k
        a = rng.standard_normal((n_i, n_i)).astype(np.float32)
        stream.append(((a + a.T) / 2, min(k_i, n_i)))
    return stream


class ShapeBucket(NamedTuple):
    """One padded program shape: every request executes through one of these."""

    b: int  # stack size (power of two)
    n: int  # matrix size (block-grid aligned)
    k: int  # top-k (power of two, <= n)
    largest: bool

    @classmethod
    def for_requests(cls, count: int, n: int, k: int, largest: bool,
                     n_align: int = N_ALIGN) -> "ShapeBucket":
        bn = _bucket_n(n, n_align)
        return cls(
            b=blocks.pow2_bucket(count),
            n=bn,
            k=min(blocks.pow2_bucket(k), bn),
            largest=bool(largest),
        )


class PackedBucket(NamedTuple):
    """One segment-packed program shape: ``b`` block-diagonal rows of width
    ``n``, each carrying up to ``s`` request segments, solved through the
    engine's ``packed_topk`` program kind (``k`` lanes per slot).

    A distinct class from :class:`ShapeBucket` on purpose: the two are
    tuples of different arity, so a packed key can never collide with a
    bucketed key in the :class:`ProgramCache` and ``isinstance`` routes the
    lowering (the packed program takes three operands, not one).
    """

    b: int  # stack size (power of two)
    n: int  # packed row width (block-grid aligned)
    s: int  # slot lanes per row (power of two)
    k: int  # per-slot window (power of two, >= every rider's k)
    largest: bool


def _bucket_label(bucket) -> str:
    """Human-readable stats key for either bucket type."""
    tail = "L" if bucket.largest else "S"
    if isinstance(bucket, PackedBucket):
        return f"pack:b{bucket.b}n{bucket.n}s{bucket.s}k{bucket.k}{tail}"
    return f"b{bucket.b}n{bucket.n}k{bucket.k}{tail}"


class _PendingProgram:
    """In-flight compile: later same-bucket getters wait on the event."""

    __slots__ = ("event", "program", "error")

    def __init__(self):
        self.event = threading.Event()
        self.program = None
        self.error = None


class ProgramCache:
    """Bucket -> AOT-compiled executable, with observable counters.

    Replaces the engine's implicit ``lru_cache``-plus-XLA-shape-cache
    behavior for serving: compiles are an explicit, countable event (tests
    and the serve log assert a mixed stream compiles at most once per
    distinct bucket), and entries hold the *compiled* executable — lookup
    on the hot path is one dict probe, no retracing.

    Thread-safe, and the lock is never held across a compile: a miss
    installs a per-key placeholder under the lock and compiles outside it,
    so concurrent gets for *other* buckets stay one-dict-probe fast while
    same-bucket racers wait on the placeholder's event (a *successful*
    compile happens at most once per bucket, so ``compiles == distinct
    buckets`` whenever every compile succeeds, and ``hits + misses``
    always equals the number of ``get()`` calls — a waiter counts as a
    hit).  A failed compile is re-raised to every waiter and evicted, so
    the next ``get()`` retries — that retry counts a fresh miss, so after
    transient compile failures ``compiles`` may exceed the distinct
    bucket count.
    The lock is a leaf in the server's lock order — nothing under it ever
    calls back into an ``EeiServer``.  One cache instance may be shared
    between servers (pass it as ``EeiServer(cache=...)``) to reuse
    compiles across server restarts or fuzzer iterations.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._programs: "OrderedDict[tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @property
    def compiles(self) -> int:
        """Number of programs compiled (== misses: one compile per miss)."""
        return self.misses

    def __len__(self) -> int:
        return len(self._programs)

    def buckets(self) -> list:
        """The distinct buckets compiled so far (insertion order)."""
        with self._lock:
            return [key[0] for key in self._programs]

    def reset_counters(self) -> None:
        """Zero hit/miss counters, keeping compiled programs (benchmarks
        warm the cache, reset, then time a steady-state pass)."""
        with self._lock:
            self.hits = 0
            self.misses = 0

    def get(self, bucket, plan: SolverPlan, dtype, *,
            verify: bool = False) -> object:
        key = (bucket, plan, jnp.dtype(dtype).name, bool(verify))
        with self._lock:
            found = self._programs.get(key)
            if found is None:
                self.misses += 1  # this caller owns the compile
                entry = _PendingProgram()
                self._programs[key] = entry
            else:
                self.hits += 1
                if not isinstance(found, _PendingProgram):
                    return found
        if found is not None:
            # Same-bucket racer: wait for the owner's compile.
            found.event.wait()
            if found.error is not None:
                raise found.error
            return found.program
        try:
            sds = jax.ShapeDtypeStruct((bucket.b, bucket.n, bucket.n),
                                       jnp.dtype(dtype))
            if isinstance(bucket, PackedBucket):
                fn = engine_mod.packed_topk_program(
                    plan, bucket.k, bucket.largest, bool(verify))
                seg_sds = jax.ShapeDtypeStruct(
                    (bucket.b, bucket.s), jnp.dtype(jnp.int32))
                prog = fn.lower(sds, seg_sds, seg_sds).compile()
            else:
                fn = engine_mod.topk_program(
                    plan, bucket.k, bucket.largest, bool(verify))
                prog = fn.lower(sds).compile()
        except BaseException as exc:
            entry.error = exc
            with self._lock:
                if self._programs.get(key) is entry:
                    del self._programs[key]  # next get() retries the compile
            entry.event.set()
            raise
        entry.program = prog
        with self._lock:
            self._programs[key] = prog
        entry.event.set()
        return prog


@dataclasses.dataclass(eq=False)  # identity equality: queue removal by object
class _Request:
    a: np.ndarray  # (n, n) symmetric, already cast to the server dtype
    n: int
    k: int
    largest: bool
    future: Future
    t_submit: float


@dataclasses.dataclass
class _InflightStack:
    result: object  # TopkResult of device arrays, possibly still computing
    requests: list  # the _Requests whose slices ride in this stack
    bucket: object  # ShapeBucket, or PackedBucket for segment-packed stacks
    # Packed stacks only: per-request ``(row, slot, offset)`` parallel to
    # ``requests`` — retire slices each request's window out of its slot.
    layout: Optional[list] = None


@dataclasses.dataclass
class DispatchRecord:
    """One dispatched stack, as the conformance tests replay it: the exact
    padded input, the plan/bucket it compiled under, and the requests whose
    futures were resolved from its rows.  Recorded only when the server is
    constructed with ``record_dispatches=True``."""

    bucket: object  # ShapeBucket, or PackedBucket for packed dispatches
    plan: SolverPlan
    stack: np.ndarray  # the assembled (bucket.b, bucket.n, bucket.n) input
    requests: list  # [_Request, ...] in row (packed: layout) order
    #: ``time.monotonic()`` when the group left the admission queue —
    #: before assembly/compile, so ``t_dispatch - request.t_submit`` is the
    #: pure admission (linger) latency.
    t_dispatch: float = 0.0
    # Packed dispatches only: the (b, s) int32 segment layout operands and
    # the per-request (row, slot, offset) triples parallel to ``requests``.
    seg_off: Optional[np.ndarray] = None
    seg_len: Optional[np.ndarray] = None
    layout: Optional[list] = None


@dataclasses.dataclass(eq=False)
class _ServerSession:
    """Server-side record for one stateful spectral session.

    ``a_host`` is a float64 numpy mirror of the session matrix, updated on
    every submitted update *before* the fast path runs — it is what the
    degrade rung (and a fleet failover) rebuilds from, so it must never
    lag the stream.  ``lock`` serializes update execution and snapshot
    reads per session (the engine-side ``SpectralSession`` is not
    thread-safe)."""

    sid: str
    engine: object  # SolverEngine
    session: object  # repro.engine.session.SpectralSession
    a_host: np.ndarray
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    closed: bool = False


class EeiServer:
    """Concurrent continuous-batching server for heterogeneous EEI queries.

    ``submit(a, k, largest)`` enqueues one query over a single symmetric
    matrix and returns a ``concurrent.futures.Future`` resolving to a
    ``TopkResult`` of numpy arrays with the *request's* shapes
    (``(k,)`` eigenvalues, ``(k, n)`` vectors) — bucket padding never leaks.
    ``submit`` is safe from any number of producer threads in both modes.

    With ``linger_ms=None`` (default) dispatch is caller-driven: ``pump()``
    dispatches every coalesce group that fills a whole ``max_batch`` stack
    (``submit`` pumps automatically) and ``flush()`` drains everything,
    partial stacks included, and blocks until all futures resolve.

    With ``linger_ms`` set, a background admission thread dispatches full
    stacks immediately and partial stacks once their oldest request has
    waited ``linger_ms`` — sparse streams complete with no ``flush()`` at
    all — and a retire thread resolves futures off the producers' path.
    ``flush()`` is then a drain barrier, and ``close()`` (or using the
    server as a context manager) drains and joins the threads.

    ``max_pending`` bounds the number of queued-but-undispatched requests:
    ``pending_policy='block'`` makes ``submit`` wait for space (in
    caller-driven mode it drains inline instead, which keeps single-threaded
    callers live), ``'except'`` makes it raise :class:`QueueFull`.

    ``plan`` pins one :class:`SolverPlan` for every bucket; by default each
    bucket gets ``plan_for((b, n, n), k=..., mesh=mesh)`` so small-n buckets
    may route to ``eigh`` while large-n buckets take the kernelized EEI
    pipeline — and, when a ``mesh`` with a multi-device data axis is given,
    large stacks route to the ``sharded`` backend (pow2 stack buckets round
    up to the mesh batch axis).

    **Fault tolerance** (on by default): ``verify=True`` appends the
    engine's ``verify`` stage to every bucket program, so each stack row is
    checked (finiteness, residual, norm, bracket order) before its future
    resolves.  A dispatch failure retries transients up to ``max_retries``
    with exponential backoff, then bisection-splits the stack to isolate
    the poisoned request(s); an isolated failing request (and any row
    failing verification) escalates through the per-request fallback chain
    (``plan.fallback_chain()`` + a pure-numpy eigh oracle) and resolves as
    a :class:`DegradedResult` — garbage never reaches a caller, and no
    future is ever stranded.  ``fallback=False`` restores fail-fast
    semantics (the group's futures get the error).  ``chaos`` arms
    deterministic fault injection (:class:`~repro.runtime.chaos
    .ChaosMonkey`) for the conformance suite and ``serve.py --chaos`` soak
    runs.
    """

    def __init__(
        self,
        plan: Optional[SolverPlan] = None,
        *,
        max_batch: int = 64,
        max_inflight: int = 2,
        n_align: int = N_ALIGN,
        dtype=jnp.float32,
        linger_ms: Optional[float] = None,
        max_pending: int = 0,
        pending_policy: str = "block",
        mesh: Optional[jax.sharding.Mesh] = None,
        cache: Optional[ProgramCache] = None,
        record_dispatches: bool = False,
        pack: str = "never",
        pack_row_n: int = 64,
        pack_k: int = 8,
        verify: bool = True,
        fallback: bool = True,
        max_retries: int = 2,
        retry_backoff_s: float = 0.005,
        retry_backoff_cap_s: float = 1.0,
        retry_jitter_seed: Optional[int] = None,
        chaos: Optional[ChaosMonkey] = None,
        adaptive_linger: bool = True,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if linger_ms is not None and linger_ms < 0:
            raise ValueError(f"linger_ms must be >= 0, got {linger_ms}")
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        if pending_policy not in ("block", "except"):
            raise ValueError(
                f"pending_policy must be 'block' or 'except', "
                f"got {pending_policy!r}")
        self._plan = plan
        self._mesh = mesh
        # Stack buckets are powers of two, so a non-pow2 bound would round
        # *up* past the operator's memory/latency limit — floor it instead
        # (a max_batch of 48 serves stacks of at most 32).
        self.max_batch = 1 << (max_batch.bit_length() - 1)
        self.max_inflight = max_inflight
        self.n_align = n_align
        self.dtype = jnp.dtype(dtype)
        self.linger_ms = linger_ms
        self.max_pending = max_pending
        self.pending_policy = pending_policy
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if pack not in ("auto", "never", "always"):
            raise ValueError(
                f"pack must be 'auto', 'never' or 'always', got {pack!r}")
        if pack_row_n < n_align:
            raise ValueError(
                f"pack_row_n must be >= n_align ({n_align}), got {pack_row_n}")
        if pack_k < 1:
            raise ValueError(f"pack_k must be >= 1, got {pack_k}")
        self.pack = pack
        self.pack_row_n = _bucket_n(pack_row_n, n_align)
        self.pack_k = int(pack_k)
        # Slot lanes per packed row: bounded by the smallest footprint a
        # segment can occupy (one align granule).
        self._pack_max_slots = max(1, self.pack_row_n // n_align)
        self.cache = cache if cache is not None else ProgramCache()
        self.record_dispatches = record_dispatches
        self.dispatch_log: "list[DispatchRecord]" = []
        self.verify = bool(verify)
        self.fallback = bool(fallback)
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_cap_s = float(retry_backoff_cap_s)
        # Decorrelated-jitter retry schedule: deterministic exponential
        # backoff makes stacks that failed *together* (same transient device
        # hiccup) retry together and re-collide every attempt.  The jitter
        # generator is seedable so tests can replay a schedule; draws are
        # serialized under the server lock (numpy Generators are not
        # thread-safe) and recorded in ``retry_delays_s`` for inspection.
        self._retry_rng = np.random.default_rng(retry_jitter_seed)
        self.retry_delays_s: list = []
        self.chaos = chaos

        # One re-entrant lock guards queues, in-flight state and counters;
        # one condition variable carries every wakeup (new work, linger
        # deadline, capacity, drain progress) — notify_all on any state
        # change, so no waiter class can miss its wakeup.  Re-entrant so a
        # future callback that re-enters submit() from a server thread
        # cannot self-deadlock.
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        # Admission is bucketed at submit time: coalesce key -> FIFO deque.
        # Keys are independent, so a partial group in one key never blocks a
        # full stack forming in another, and group take-off is O(group)
        # instead of a full-queue scan.
        self._queues: "OrderedDict[tuple, deque]" = OrderedDict()
        self._inflight: "deque[_InflightStack]" = deque()
        # Every admitted-but-unresolved caller future -> its submit time.
        # Maintained by the universal done-callback, so it is correct
        # across every resolution path (retire, fallback, fail, cancel).
        # ``close(timeout=...)`` returns its keys when a drain wedges, and
        # the fleet's health probe reads the oldest age for its deadline.
        self._unresolved: "dict[Future, float]" = {}
        self._pending = 0  # queued, not yet popped for dispatch
        self._dispatching = 0  # groups popped but not yet in-flight/failed
        self._retiring = 0  # stacks popped by the retire thread, syncing
        self._draining = 0  # flush() barriers forcing partial dispatch
        self._closed = False
        self._admission_done = False
        self.requests_submitted = 0
        self.requests_completed = 0
        self.requests_failed = 0
        self.requests_rejected = 0  # late submits after close()
        self.requests_cancelled = 0  # caller-cancelled while still pending
        self.stacks_dispatched = 0
        self.packed_stacks_dispatched = 0
        self.packed_requests_completed = 0
        # Pad-waste accounting: every grid cell of a stack (b * n^2) versus
        # the cells carrying real request data (sum of the group's n_i^2).
        # The complement is what guard diagonals and batch-repeat padding
        # burn.  Cells are counted once per *successfully retired* stack —
        # at launch time they would double-count under retries, bisection
        # splits and fleet redispatch (the same request's cells landing
        # again with every relaunch), which is exactly the over-reporting
        # bug this accounting replaces; see ``stats()`` for the contract.
        self.grid_cells_total = 0
        self.grid_cells_real = 0
        self._pad_cells_by_bucket: dict = {}  # bucket -> [real, total]
        self.latencies_ms: list = []
        # Robustness counters (see stats()): verification failures routed
        # to the fallback chain, transient retries, bisection splits of
        # failed stacks, requests resolved degraded, and which fallback
        # link resolved them.
        self.verify_failed = 0
        self.retries = 0
        self.stack_splits = 0
        self.requests_degraded = 0
        self.fallbacks_by_plan: dict = {}  # chain link name -> resolutions

        # Adaptive linger: per-coalesce-key EWMA of inter-arrival gaps.
        # A key that runs hot (small gaps) shrinks its *effective* linger
        # toward the time its stack would plausibly still fill, so a hot
        # stream's partial stacks stop waiting out the full base timeout
        # when the stream hiccups.  Shrink-only: ``linger_ms`` stays the
        # upper bound, so cold/sparse keys keep the configured window.
        # Rate state survives reset_stats() (it describes the *stream*,
        # not a measurement pass); the trim counter does not.
        self.adaptive_linger = bool(adaptive_linger)
        self._key_rate: dict = {}  # key -> [ewma_gap_s, last_t, gap_samples]
        self.linger_trims = 0

        # Stateful spectral sessions (engine/session.py behind submit()'s
        # style of Future API).  Threaded mode executes updates on a lazy
        # dedicated session thread (serial per server, so per-session order
        # is dispatch order); caller-driven mode runs them inline.
        self._sessions: dict = {}  # sid -> _ServerSession
        self._session_ids = itertools.count()
        self._session_ops: "deque[tuple]" = deque()
        self._session_busy = 0
        self._session_thread: Optional[threading.Thread] = None
        self.sessions_opened = 0
        self.session_updates = 0
        self.session_fast_updates = 0
        self.session_full_resolves = 0
        self.session_degraded = 0

        # Snapshot the mode: _threaded must not flip if a caller mutates
        # linger_ms later (the linger *value* is re-read each admission
        # round; the thread topology is fixed at construction).
        self._threaded_mode = linger_ms is not None
        self._admission_thread: Optional[threading.Thread] = None
        self._retire_thread: Optional[threading.Thread] = None
        if self._threaded:
            self._admission_thread = threading.Thread(
                target=self._admission_main, name="eei-admission", daemon=True)
            self._retire_thread = threading.Thread(
                target=self._retire_main, name="eei-retire", daemon=True)
            self._admission_thread.start()
            self._retire_thread.start()

    @property
    def _threaded(self) -> bool:
        return self._threaded_mode

    # -- admission ---------------------------------------------------------

    def submit(self, a, k: int, largest: bool = True) -> Future:
        """Admit one ``(n, n)`` top-k query; returns its completion future.

        Thread-safe.  After ``close()`` the returned future already carries
        a :class:`ServerClosed` error.  With ``max_pending`` set, blocks or
        raises :class:`QueueFull` per ``pending_policy``.
        """
        a = np.asarray(a, dtype=self.dtype)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected one (n, n) matrix, got {a.shape}")
        n = a.shape[0]
        if k < 1 or k > n:
            raise ValueError(f"k={k} out of range for n={n}")
        req = _Request(a=a, n=n, k=int(k), largest=bool(largest),
                       future=Future(), t_submit=time.monotonic())
        with self._cv:
            if self._closed:
                return self._reject_locked(req)
            if self.max_pending and self._pending >= self.max_pending:
                if self.pending_policy == "except":
                    raise QueueFull(
                        f"{self._pending} requests pending "
                        f"(max_pending={self.max_pending})")
                if not self._threaded:
                    # Caller-driven mode has no admission thread to make
                    # space — drain inline so single-threaded producers
                    # stay live instead of self-deadlocking.
                    self.flush()
                else:
                    while self._pending >= self.max_pending:
                        self._cv.wait()
                        if self._closed:
                            return self._reject_locked(req)
            key = self._coalesce_key(req)
            self._queues.setdefault(key, deque()).append(req)
            self._pending += 1
            self.requests_submitted += 1
            req.t_submit = time.monotonic()  # linger clock starts at enqueue
            self._unresolved[req.future] = req.t_submit
            self._observe_arrival_locked(key, req.t_submit)
            self._cv.notify_all()
        # Caller-side cancellation: while the request is still pending
        # (undispatched) a cancel() pulls it out of its coalesce group, so
        # an abandoned future never pads a stack.  Attached outside the
        # lock — an already-cancelled future runs the callback inline.
        # The callback holds only a weakref: Future never clears its done
        # callbacks, so a strong capture would pin every request's input
        # matrix alongside the result for as long as the caller retains
        # the future.
        req_ref = weakref.ref(req)
        req.future.add_done_callback(
            lambda fut, ref=req_ref: self._on_future_done(ref, fut))
        if not self._threaded:
            self.pump()
        return req.future

    def _on_future_done(self, req_ref, fut: Future) -> None:
        """Dequeue a request whose caller cancelled it while still pending.

        Runs for every resolved future (the done callback cannot filter):
        every resolution retires the future from the ``_unresolved`` map
        (the fleet's liveness probe and ``close(timeout=...)``'s return
        value read it); anything but a cancellation then returns.  A cancel
        that lands after the group was popped is left alone: its row is
        already part of an assembled stack (the device work is spent either
        way) and retirement tolerates the pre-resolved future.  A dead
        weakref means the request already left the pipeline entirely.
        """
        with self._cv:
            self._unresolved.pop(fut, None)
        if not fut.cancelled():
            return
        req = req_ref()
        if req is None:
            return
        with self._cv:
            q = self._queues.get(self._coalesce_key(req))
            if q is None or req not in q:
                return  # already dispatched (or being popped): rides along
            q.remove(req)
            if not q:
                del self._queues[self._coalesce_key(req)]
            self._pending -= 1
            self.requests_cancelled += 1
            self._cv.notify_all()  # backpressure space; linger re-evaluates

    def _reject_locked(self, req: _Request) -> Future:
        self.requests_rejected += 1
        req.future.set_exception(ServerClosed(
            "EeiServer is closed; request was rejected"))
        return req.future

    def _coalesce_key(self, req: _Request) -> tuple:
        # k is deliberately NOT part of the key: requests with different k
        # stack together (the program runs the group's max k rounded to a
        # power of two and each future slices its own k back out), so a
        # mixed-k stream coalesces into full stacks instead of fragmenting
        # into near-empty per-k groups.  Packable small-n requests coalesce
        # into ONE key per extreme regardless of their n — that collapse is
        # the core of the packing win on mixed streams: one queue fills a
        # stack as fast as all the per-n queues did together, so linger
        # windows stop fragmenting sparse traffic into padded singletons.
        if self._packable(req):
            return ("pack", req.largest)
        return (_bucket_n(req.n, self.n_align), req.largest)

    def _packable(self, req: _Request) -> bool:
        """Whether a request rides the segment-packed path.

        ``"auto"`` packs requests whose aligned footprint is at most the
        calibrated :func:`~repro.engine.plan.resolved_pack_n_max` (and at
        most half a row, so every packed row carries >= 2 segments);
        ``"always"`` relaxes to anything that fits a row.  ``k`` stays
        bounded by ``pack_k`` so the per-slot window never explodes the
        packed program's lane count.
        """
        if self.pack == "never" or req.k > self.pack_k:
            return False
        footprint = _bucket_n(req.n, self.n_align)
        if self.pack == "always":
            return footprint <= self.pack_row_n
        return footprint <= min(resolved_pack_n_max(), self.pack_row_n // 2)

    def _group_cap(self, key: tuple) -> int:
        """Requests forming a *full* stack for this coalesce key: packed
        keys fill ``max_batch`` rows of up to ``_pack_max_slots`` segments,
        bucketed keys one request per row."""
        if key[0] == "pack":
            return self.max_batch * self._pack_max_slots
        return self.max_batch

    def _pop_group_locked(self, key: tuple) -> list:
        q = self._queues[key]
        group = [q.popleft()
                 for _ in range(min(len(q), self._group_cap(key)))]
        if not q:
            del self._queues[key]
        self._pending -= len(group)
        self._cv.notify_all()  # space for backpressured producers
        return group

    def _pop_all_locked(self) -> list:
        """Every queued group (each at most ``max_batch``), queue emptied."""
        groups = []
        while self._queues:
            groups.append(self._pop_group_locked(next(iter(self._queues))))
        return groups

    # -- dispatch ----------------------------------------------------------

    def _guard_value(self, a: np.ndarray, largest: bool) -> float:
        """Diagonal guard for the padded block: strictly outside the
        spectrum, on the side away from the requested extreme."""
        radius = np.sum(np.abs(a), axis=1) - np.abs(np.diagonal(a))
        diag = np.diagonal(a)
        lo = float(np.min(diag - radius))
        hi = float(np.max(diag + radius))
        margin = max(1.0, 0.01 * (hi - lo))
        return lo - margin if largest else hi + margin

    def _assemble(self, group: list, bucket: ShapeBucket) -> np.ndarray:
        stack = np.zeros((bucket.b, bucket.n, bucket.n), dtype=self.dtype)
        for row, req in enumerate(group):
            stack[row, : req.n, : req.n] = req.a
            if req.n < bucket.n:
                guard = self._guard_value(req.a, req.largest)
                idx = np.arange(req.n, bucket.n)
                stack[row, idx, idx] = guard
        # Batch padding repeats the first padded row: real matrices, so the
        # program never sees degenerate all-zero inputs; sliced off below.
        stack[len(group):] = stack[0]
        return stack

    def _plan_bucket(self, group: list) -> tuple:
        bucket = ShapeBucket.for_requests(
            len(group), max(r.n for r in group), max(r.k for r in group),
            group[0].largest, n_align=self.n_align)
        # The plan is a pure function of the bucket (never of raw group
        # values), so one bucket can never compile under two plans.
        plan = self._plan
        if plan is None:
            plan = plan_for((bucket.b, bucket.n, bucket.n), k=bucket.k,
                            mesh=self._mesh)
        # The sharded backend needs the stack divisible by the mesh batch
        # axis (SolverEngine._run_chunk pads for the same reason) — round
        # the pow2 bucket up to the next multiple.
        mult = plan.batch_axis_size
        if bucket.b % mult:
            bucket = bucket._replace(b=bucket.b + (-bucket.b) % mult)
        return bucket, plan

    def _launch(self, bucket, plan: SolverPlan, operands: tuple):
        """Fetch the bucket program and launch it over ``operands`` (one
        stack for bucketed programs; stack + the two ``(b, s)`` segment
        arrays for packed ones), retrying *transient* failures (see
        :func:`_is_transient`) up to ``max_retries`` with
        decorrelated-jitter backoff.  Chaos compile/launch injection points
        live here — upstream of the retry logic, exactly like the real
        failures they model."""
        prev_delay = self.retry_backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                if self.chaos is not None:
                    self.chaos.on_compile()
                program = self.cache.get(
                    bucket, plan, self.dtype, verify=self.verify)
                if self.chaos is not None:
                    self.chaos.on_launch()
                return program(*operands)  # async: returns at once
            except Exception as exc:
                if attempt >= self.max_retries or not _is_transient(exc):
                    raise
                with self._cv:
                    self.retries += 1
                    prev_delay = decorrelated_jitter(
                        self._retry_rng, self.retry_backoff_s, prev_delay,
                        self.retry_backoff_cap_s)
                    self.retry_delays_s.append(prev_delay)
                    self._cv.notify_all()
                log.warning("EEI dispatch retry %d/%d after transient: %s",
                            attempt + 1, self.max_retries, exc)
                time.sleep(prev_delay)  # outside the lock

    def _dispatch(self, group: list) -> None:
        """Assemble, fetch the program, launch.  Never raises: any failure
        (planning, assembly, compile, launch) is retried / split / escalated
        down the fallback chain (``fallback=True``) or resolves the group's
        futures with the error — never stranding callers or killing a
        server thread.  Appends to ``_inflight`` under the lock.

        Groups whose every member is packable take the segment-packed path;
        packability is re-derived here (not trusted from the coalesce key)
        so bisection halves of a failed packed stack re-pack consistently.

        Pad-waste cell accounting deliberately does NOT happen here: cells
        are counted once per *successfully retired* stack (see
        ``_account_retired_locked``), because counting at launch double- or
        triple-counted every request that rode a retried, bisected or
        fleet-redispatched stack."""
        t_disp = time.monotonic()
        if group and all(self._packable(req) for req in group):
            self._dispatch_packed(group, t_disp)
            return
        try:
            bucket, plan = self._plan_bucket(group)
            stack = self._assemble(group, bucket)
            result = self._launch(bucket, plan, (jnp.asarray(stack),))
        except Exception as exc:  # compile/launch failure after retries:
            self._handle_group_failure(group, exc)  # split / fallback / fail
            return
        with self._cv:
            self._inflight.append(_InflightStack(result, list(group), bucket))
            self.stacks_dispatched += 1
            if self.record_dispatches:
                self.dispatch_log.append(DispatchRecord(
                    bucket=bucket, plan=plan, stack=stack,
                    requests=list(group), t_dispatch=t_disp))
            self._cv.notify_all()

    def _packed_plan(self) -> SolverPlan:
        """The plan packed stacks compile under.

        A pinned ``plan=`` is honored when its method registers a
        ``packed_topk`` chain; otherwise (and in the default auto-plan
        mode) :func:`~repro.engine.plan.packed_plan_for` picks the
        calibrated eigh-vs-tridiag packed chain for ``pack_row_n``.
        """
        plan = self._plan
        if plan is not None:
            # Mirror engine._resolve_chain's packed_topk lookup (windowed
            # comp when the plan asks, falling back to the full comp).
            try:
                chain = registry.composition_for(
                    plan.method, plan.spectrum == "windowed").packed_topk
                if chain is None:
                    chain = registry.composition_for(
                        plan.method, False).packed_topk
            except KeyError:
                chain = None
            if chain is not None:
                return plan
            log.debug("pinned plan %s has no packed chain; using "
                      "packed_plan_for(%d)", plan, self.pack_row_n)
        return packed_plan_for(self.pack_row_n)

    def _dispatch_packed(self, group: list, t_disp: float = 0.0) -> None:
        """Segment-packed dispatch: first-fit pack the group's matrices
        into block-diagonal rows of width ``pack_row_n``, chunk the rows
        into stacks of at most ``max_batch``, and launch each chunk through
        the engine's ``packed_topk`` program — three operands: the packed
        stack plus the ``(b, s)`` segment-layout arrays.  Each chunk is its
        own in-flight stack, so a failure bisects/escalates only its own
        riders, exactly like a bucketed stack."""
        try:
            rows = blocks.pack_segments(
                [req.n for req in group], self.pack_row_n,
                self._pack_max_slots, align=self.n_align)
            plan = self._packed_plan()
        except Exception as exc:
            self._handle_group_failure(group, exc)
            return
        for start in range(0, len(rows), self.max_batch):
            chunk = rows[start:start + self.max_batch]
            sub = [group[i] for row in chunk for i, _, _ in row]
            try:
                bucket, stack, seg_off, seg_len, layout = \
                    self._assemble_packed(group, chunk)
                result = self._launch(
                    bucket, plan,
                    (jnp.asarray(stack), jnp.asarray(seg_off),
                     jnp.asarray(seg_len)))
            except Exception as exc:
                self._handle_group_failure(sub, exc)
                continue
            with self._cv:
                self._inflight.append(_InflightStack(
                    result, sub, bucket, layout=layout))
                self.stacks_dispatched += 1
                self.packed_stacks_dispatched += 1
                if self.record_dispatches:
                    self.dispatch_log.append(DispatchRecord(
                        bucket=bucket, plan=plan, stack=stack,
                        requests=sub, seg_off=seg_off, seg_len=seg_len,
                        layout=layout, t_dispatch=t_disp))
                self._cv.notify_all()

    def _assemble_packed(self, group: list, chunk: list):
        """Build one packed stack from ``chunk``: a list of packed rows,
        each ``[(group_index, offset, length), ...]`` from
        :func:`~repro.kernels.blocks.pack_segments`.

        Returns ``(bucket, stack, seg_off, seg_len, layout)``; ``layout``
        holds per-request ``(row, slot, offset)`` triples in the same order
        the sub-requests ride the stack.  Diagonal cells outside every
        segment carry *spaced, distinct* guard values strictly outside the
        row's union Gershgorin interval, on the side away from the
        requested extreme: outside the union so no guard eigenvalue can
        enter any slot's window, distinct so the packed row's spectrum
        stays simple enough for the tridiagonal minor-determinant chain.
        Batch-pad rows repeat row 0's matrix with every ``seg_len`` zero —
        empty slots verify vacuously and retire nothing."""
        largest = group[0].largest
        b = blocks.pow2_bucket(len(chunk))
        s = blocks.pow2_bucket(max(len(row) for row in chunk))
        kmax = max(group[i].k for row in chunk for i, _, _ in row)
        n = self.pack_row_n
        bucket = PackedBucket(
            b=b, n=n, s=s, k=min(blocks.pow2_bucket(kmax), n),
            largest=largest)
        stack = np.zeros((b, n, n), dtype=self.dtype)
        seg_off = np.zeros((b, s), dtype=np.int32)
        seg_len = np.zeros((b, s), dtype=np.int32)
        layout = []
        for row, segs in enumerate(chunk):
            lo, hi = np.inf, -np.inf
            covered = np.zeros(n, dtype=bool)
            for slot, (i, off, length) in enumerate(segs):
                a = group[i].a
                stack[row, off:off + length, off:off + length] = a
                seg_off[row, slot] = off
                seg_len[row, slot] = length
                layout.append((row, slot, off))
                radius = np.sum(np.abs(a), axis=1) - np.abs(np.diagonal(a))
                diag = np.diagonal(a)
                lo = min(lo, float(np.min(diag - radius)))
                hi = max(hi, float(np.max(diag + radius)))
                covered[off:off + length] = True
            idx = np.where(~covered)[0]
            if idx.size:
                margin = max(1.0, 0.01 * (hi - lo))
                step = margin / idx.size
                if largest:
                    vals = lo - margin - step * np.arange(idx.size)
                else:
                    vals = hi + margin + step * np.arange(idx.size)
                stack[row, idx, idx] = vals
        # Batch padding repeats row 0's matrix (real data, never an all-zero
        # degenerate input) but with zero seg_len: nothing is selected,
        # verified or retired from a pad row.
        stack[len(chunk):] = stack[0]
        return bucket, stack, seg_off, seg_len, layout

    @staticmethod
    def _set(future: Future, *, result=None, error=None) -> bool:
        """Resolve a future, tolerating caller-side ``cancel()``: a
        cancelled future is already resolved, and raising out of a server
        thread here would poison every *other* request that thread owns."""
        try:
            if error is not None:
                future.set_exception(error)
            else:
                future.set_result(result)
            return True
        except InvalidStateError:
            return False

    def _fail(self, requests: list, exc: Exception) -> None:
        """Resolve a group's futures with the error — a failed dispatch
        must never strand callers blocked on ``future.result()``.
        Counters update before the futures resolve (see ``_retire``)."""
        log.error("EEI stack dispatch failed for %d request(s): %s",
                  len(requests), exc)
        with self._cv:
            self.requests_failed += len(requests)
            self._cv.notify_all()
        for req in requests:
            self._set(req.future, error=exc)

    def _handle_group_failure(self, group: list, exc: Exception) -> None:
        """A stack failed (dispatch error after retries, or a device-side
        error at retire).  With the fallback chain enabled, bisection-split
        multi-request groups to isolate the poisoned request(s) — each half
        re-dispatches through the normal path, so healthy halves ride a
        fresh stack — and escalate isolated requests down the per-request
        chain.  With ``fallback=False``, fail-fast as before."""
        if not self.fallback:
            self._fail(group, exc)
            return
        if len(group) > 1:
            log.warning("EEI stack of %d failed (%s); bisecting",
                        len(group), exc)
            with self._cv:
                self.stack_splits += 1
                self._cv.notify_all()
            mid = len(group) // 2
            self._dispatch(group[:mid])
            self._dispatch(group[mid:])
            return
        self._fallback_request(group[0], exc)

    def _fallback_request(self, req: _Request, cause: Exception) -> None:
        """Escalate one isolated request down the fallback chain.

        Each link re-solves the request's *unpadded* matrix and is
        host-verified before it may resolve the future; the terminal link
        is the pure-numpy eigh oracle.  Resolves the future with a
        :class:`DegradedResult` on the first verified link, or with the
        original cause if every link fails (non-finite input, say)."""
        a = req.a
        for name, plan in fallback_chain():
            try:
                res = engine_mod.SolverEngine(plan).topk(
                    jnp.asarray(a), req.k, req.largest)
                lam = np.asarray(res.eigenvalues)
                vec = np.asarray(res.vectors)
            except Exception as exc:
                log.debug("fallback %s raised for n=%d k=%d: %s",
                          name, req.n, req.k, exc)
                continue
            if not bool(verify_topk_host(a, lam, vec).ok):
                log.debug("fallback %s failed verification (n=%d k=%d)",
                          name, req.n, req.k)
                continue
            self._resolve_degraded(req, lam, vec, name, cause)
            return
        try:
            lam, vec = _eigh_oracle(a, req.k, req.largest)
        except Exception as exc:
            self._fail([req], exc)
            return
        if not bool(verify_topk_host(a, lam, vec).ok):
            # Even LAPACK could not produce a verifiable answer — the input
            # itself is poisoned (non-finite, say).  Surface the original
            # cause, not garbage.
            self._fail([req], cause)
            return
        self._resolve_degraded(req, lam, vec, "eigh_oracle", cause)

    def _resolve_degraded(self, req: _Request, lam: np.ndarray,
                          vec: np.ndarray, name: str,
                          cause: Exception) -> None:
        log.info("EEI request (n=%d, k=%d) resolved degraded via %s "
                 "(cause: %s)", req.n, req.k, name, cause)
        t_done = time.monotonic()
        with self._cv:
            self.requests_degraded += 1
            self.requests_completed += 1
            self.fallbacks_by_plan[name] = \
                self.fallbacks_by_plan.get(name, 0) + 1
            self.latencies_ms.append((t_done - req.t_submit) * 1e3)
            self._cv.notify_all()
        self._set(req.future, result=DegradedResult(
            lam.astype(self.dtype), vec.astype(self.dtype), fallback=name))

    def _retire(self, inflight: _InflightStack) -> None:
        """Block on one stack, verify, and resolve its requests' futures.

        Called with the lock held in caller-driven mode (the device sync is
        the caller's own flush) and without it from the retire thread (the
        sync must not block producers).

        With ``verify`` on, the program returned ``(TopkResult,
        VerifyFlags)``: rows whose flags fail — or whose host slices carry
        non-finite values (the chaos NaN injection lands on the host copy,
        exactly like a corrupted transfer would) — escalate down the
        per-request fallback chain instead of resolving with garbage.  A
        device-side failure at the sync point re-enters the split/fallback
        path like a dispatch failure."""
        result = inflight.result
        flags_ok = None
        try:
            if self.verify:
                result, flags = result
                flags_ok = np.asarray(flags.ok)  # sync point
            lam = np.asarray(result.eigenvalues)  # sync point (verify off)
            vec = np.asarray(result.vectors)
        except Exception as exc:  # device-side failure surfaces here
            self._handle_group_failure(inflight.requests, exc)
            return
        if self.chaos is not None:
            vec = self.chaos.on_result(vec)
            self.chaos.on_retire_sleep()
        t_done = time.monotonic()
        results = []
        escalate = []
        if inflight.layout is not None:
            # Packed stack: lam (b, S, K), vec (b, S, K, N), flags (b, S).
            # Each request slices its k pairs out of its own slot's window
            # and its n columns out of its segment's offset.
            for req, (row, slot, off) in zip(inflight.requests,
                                             inflight.layout):
                if req.largest:
                    lam_r = lam[row, slot, -req.k:]
                    vec_r = vec[row, slot, -req.k:, off:off + req.n]
                else:
                    lam_r = lam[row, slot, : req.k]
                    vec_r = vec[row, slot, : req.k, off:off + req.n]
                if flags_ok is not None and not (
                        bool(flags_ok[row, slot])
                        and np.all(np.isfinite(lam_r))
                        and np.all(np.isfinite(vec_r))):
                    escalate.append(req)
                    continue
                results.append((req, engine_mod.TopkResult(lam_r, vec_r)))
        else:
            for row, req in enumerate(inflight.requests):
                # The program returns `bucket.k` ascending pairs at the
                # requested extreme.  Guards were placed on the far side of
                # the spectrum, so the request's k pairs are the window's
                # own extreme end: the *last* k for largest, the *first* k
                # for smallest.
                if req.largest:
                    lam_r = lam[row, -req.k:]
                    vec_r = vec[row, -req.k:, : req.n]
                else:
                    lam_r = lam[row, : req.k]
                    vec_r = vec[row, : req.k, : req.n]
                if flags_ok is not None and not (
                        bool(flags_ok[row])
                        and np.all(np.isfinite(lam_r))
                        and np.all(np.isfinite(vec_r))):
                    escalate.append(req)
                    continue
                results.append((req, engine_mod.TopkResult(lam_r, vec_r)))
        # Counters update BEFORE futures resolve: a caller woken by
        # future.result() may read stats() immediately and must see this
        # stack's requests already accounted for.
        with self._cv:
            self.latencies_ms.extend(
                (t_done - req.t_submit) * 1e3 for req, _ in results)
            self.requests_completed += len(results)
            if inflight.layout is not None:
                self.packed_requests_completed += len(results)
            self.verify_failed += len(escalate)
            self._account_retired_locked(inflight)
            self._cv.notify_all()
        for req, res in results:
            self._set(req.future, result=res)
        for req in escalate:
            cause = VerifyFailed(
                f"result for (n={req.n}, k={req.k}) failed verification")
            if self.fallback:
                self._fallback_request(req, cause)
            else:
                self._fail([req], cause)

    def _account_retired_locked(self, inflight: _InflightStack) -> None:
        """Pad-waste cell accounting, exactly once per *successfully
        retired* stack.

        Counting used to happen at dispatch, which over-reported whenever
        the same request rode more than one launch: in-place transient
        retries were safe (one count per successful launch), but a stack
        that failed at its retire sync re-entered ``_dispatch`` through
        bisection, and a fleet failover redispatched a dead replica's
        requests through a second replica's dispatch path — every such
        request's cells were counted two or more times, so chaos runs
        reported inflated ``grid_cells_*`` relative to the identical clean
        stream.  Counting at retire makes the counters mean "cells the
        serving programs actually computed and handed back": each retired
        stack counts once, and requests that escalate to the per-request
        fallback chain (whose solves are unpadded) add nothing."""
        bucket = inflight.bucket
        total = bucket.b * bucket.n * bucket.n
        real = sum(req.n * req.n for req in inflight.requests)
        self.grid_cells_total += total
        self.grid_cells_real += real
        cells = self._pad_cells_by_bucket.setdefault(bucket, [0, 0])
        cells[0] += real
        cells[1] += total

    def _make_room_locked(self) -> None:
        """Caller-driven mode: retire the oldest stack(s) until a launch
        keeps at most ``max_inflight`` stacks of device buffers live."""
        while len(self._inflight) >= self.max_inflight:
            self._retire(self._inflight.popleft())

    # -- background threads ------------------------------------------------

    #: Inter-arrival gaps a key must show before its EWMA can shrink the
    #: linger window — below this the estimate is noise, and sparse tests /
    #: streams that submit a handful of requests keep the configured linger.
    _LINGER_MIN_SAMPLES = 4
    #: EWMA smoothing factor for inter-arrival gaps.
    _LINGER_EWMA_ALPHA = 0.3
    #: Effective linger = ``_LINGER_GAP_FACTOR * ewma_gap * remaining
    #: slots`` — the time the stack would plausibly still take to fill if
    #: the stream kept its observed rate, with 2x slack for jitter.
    _LINGER_GAP_FACTOR = 2.0

    def _observe_arrival_locked(self, key: tuple, t: float) -> None:
        rate = self._key_rate.get(key)
        if rate is None:
            self._key_rate[key] = [0.0, t, 0]
            return
        # Clamp each observed gap to the base linger window: a longer gap
        # means the key went *idle* (its previous stack long since
        # dispatched), not that the arrival rate is that slow — and an
        # estimate at/above the base can never trim, so one clamped idle
        # gap also instantly heals a stale-hot estimate after a burst.
        gap = max(t - rate[1], 0.0)
        if self.linger_ms:
            gap = min(gap, self.linger_ms / 1e3)
        alpha = self._LINGER_EWMA_ALPHA
        rate[0] = gap if rate[2] == 0 else (1 - alpha) * rate[0] + alpha * gap
        rate[1] = t
        rate[2] += 1

    def _effective_linger_locked(self, key: tuple, qlen: int,
                                 base_s: float) -> float:
        """Per-key linger window: the base, shrunk for hot keys.

        A hot key's partial stack only ever waits about as long as the
        stack would take to *fill* at the observed arrival rate — once the
        stream pauses longer than that, waiting out the rest of the base
        window buys nothing (the stack was not going to fill) and just
        adds latency.  Shrink-only, so the base stays an upper bound and
        an idle/sparse key is untouched.
        """
        if not self.adaptive_linger:
            return base_s
        rate = self._key_rate.get(key)
        if rate is None or rate[2] < self._LINGER_MIN_SAMPLES:
            return base_s
        remaining = max(self._group_cap(key) - qlen, 1)
        return min(base_s, self._LINGER_GAP_FACTOR * rate[0] * remaining)

    def _ready_key_locked(self, now: float):
        """Dispatchable coalesce key, or ``(None, deadline)`` where
        ``deadline`` is the next linger expiry (``None`` if no queue).

        Among ready keys (full, linger-expired, or force-drained) the one
        with the *oldest head request* wins — FIFO across keys.  Picking
        the first ready key in insertion order would let a continuously
        full key starve another key's expired partial group indefinitely;
        with oldest-head order the starved key's fixed, aging head
        eventually outranks the hot key's ever-renewing one, so the
        linger bound stays a real latency bound.

        Each key lingers under its own *effective* window (see
        :meth:`_effective_linger_locked`): hot keys trim toward their
        observed fill time, and a dispatch that happened strictly earlier
        than the base window because of the trim counts in
        ``linger_trims``.
        """
        force = self._closed or self._draining > 0
        linger_s = (self.linger_ms or 0.0) / 1e3
        best_key = best_t = deadline = None
        best_trim = False
        for key, q in self._queues.items():
            head_t = q[0].t_submit
            eff = self._effective_linger_locked(key, len(q), linger_s)
            expiry = head_t + eff
            full = len(q) >= self._group_cap(key)
            if full or force or now >= expiry:
                if best_t is None or head_t < best_t:
                    best_key, best_t = key, head_t
                    best_trim = (not full and not force
                                 and eff < linger_s
                                 and now < head_t + linger_s)
            elif best_key is None:
                deadline = expiry if deadline is None else \
                    min(deadline, expiry)
        if best_key is not None and best_trim:
            self.linger_trims += 1
        return best_key, (None if best_key is not None else deadline)

    def _admission_loop(self) -> None:
        while True:
            if self.chaos is not None:
                # Injected at the loop top, before any group is held, so a
                # chaos crash kills the thread between stacks — the restart
                # wrapper in _admission_main resumes with nothing stranded.
                self.chaos.on_thread("admission")
            with self._cv:
                while True:
                    key, deadline = self._ready_key_locked(time.monotonic())
                    if key is not None:
                        group = self._pop_group_locked(key)
                        self._dispatching += 1
                        break
                    if self._closed and not self._queues:
                        return
                    timeout = None
                    if deadline is not None:
                        timeout = max(deadline - time.monotonic(), 0.0) + 1e-4
                    self._cv.wait(timeout)
            try:
                with self._cv:
                    # Capacity gate: at most max_inflight stacks of device
                    # buffers outstanding (on device + being retired).
                    while len(self._inflight) + self._retiring >= \
                            self.max_inflight:
                        if not self._retire_thread.is_alive():
                            # Retirement is permanently gone (bounded
                            # restarts exhausted): capacity will never
                            # free — fail the held group instead of
                            # waiting forever (close() must not hang).
                            raise ServerClosed(
                                "retire thread died; cannot dispatch")
                        self._cv.wait(timeout=0.1)
                # Outside the lock: assembly, cache lookup (possibly a
                # multi-second compile) and the async launch never block
                # producers or the retire thread.
                self._dispatch(group)
            except BaseException as exc:
                # _dispatch absorbs Exceptions, so this is a BaseException
                # (crash) or the capacity gate's dead-retirement escape.
                # The popped group is in no queue anymore — resolve its
                # futures before the crash handler takes over (_set
                # tolerates rows _dispatch already resolved).
                self._fail(group, ServerClosed(
                    f"admission thread crashed: {exc!r}"))
                raise
            finally:
                with self._cv:
                    self._dispatching -= 1
                    self._cv.notify_all()

    def _admission_main(self) -> None:
        try:
            while True:
                try:
                    self._admission_loop()
                    return
                except ChaosError:
                    # Injected crash, fired between stacks: nothing is
                    # held, so restart in place.  Bounded by the injection
                    # schedule itself (deterministic, finite in tests) —
                    # real crashes below keep their fail-everything path.
                    log.warning(
                        "EEI admission thread: injected crash; restarting")
        except BaseException as exc:  # never die silently: fail the queue
            log.exception("EEI admission thread crashed")
            with self._cv:
                # Nothing will drain the queues anymore — close so later
                # submits are rejected instead of silently stranded.
                self._closed = True
                groups = self._pop_all_locked()
            for group in groups:
                self._fail(group, ServerClosed(
                    f"admission thread crashed: {exc!r}"))
        finally:
            with self._cv:
                self._admission_done = True
                self._cv.notify_all()

    def _retire_loop(self) -> None:
        while True:
            if self.chaos is not None:
                # Before any stack is popped: a chaos crash here exercises
                # the bounded-restart machinery with nothing stranded.
                self.chaos.on_thread("retire")
            with self._cv:
                while not self._inflight:
                    if self._admission_done and not self._dispatching:
                        return
                    self._cv.wait()
                stack = self._inflight.popleft()
                self._retiring += 1
                self._cv.notify_all()
            try:
                self._retire(stack)
            except BaseException as exc:
                # _retire absorbs Exceptions; a BaseException here would
                # otherwise strand the popped stack (it is no longer in
                # _inflight, so the crash handler cannot see it).
                self._fail(stack.requests, ServerClosed(
                    f"retire thread crashed: {exc!r}"))
                raise
            finally:
                with self._cv:
                    self._retiring -= 1
                    self._cv.notify_all()

    def _retire_main(self) -> None:
        # A handful of restarts: a crash drains + fails the stacks it held,
        # then keeps retiring whatever the admission thread still launches,
        # so one bad stack never strands later ones.  Persistent crashing
        # gives up after the bounded retries (close() joins regardless).
        # Injected ChaosError crashes fire between stacks (nothing held),
        # so they restart in place without closing the server or burning
        # the real-crash budget — the schedule is deterministic and finite.
        crashes = 0
        while crashes < 8:
            try:
                self._retire_loop()
                return
            except ChaosError:
                log.warning("EEI retire thread: injected crash; restarting")
            except BaseException as exc:
                crashes += 1
                log.exception("EEI retire thread crashed")
                with self._cv:
                    self._closed = True  # stop admitting: retirement is sick
                    stacks = list(self._inflight)
                    self._inflight.clear()
                    self._cv.notify_all()
                for stack in stacks:
                    self._fail(stack.requests, ServerClosed(
                        f"retire thread crashed: {exc!r}"))

    # -- stateful sessions -------------------------------------------------

    def _session_plan(self, n: int, k: int) -> SolverPlan:
        plan = self._plan
        if plan is None:
            bn = _bucket_n(n, self.n_align)
            plan = plan_for((1, bn, bn), k=k, mesh=self._mesh)
        return plan

    def open_session(self, a, k: int, largest: bool = True,
                     config=None) -> str:
        """Open a stateful spectral session over one ``(n, n)`` matrix.

        Seeds the session with a full solve (synchronous — it is a setup
        call, like the compile it triggers) and returns a session id for
        :meth:`submit_update` / :meth:`session_result` /
        :meth:`close_session`.
        """
        from repro.engine import session as session_mod

        a = np.asarray(a, dtype=np.float64)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected one (n, n) matrix, got {a.shape}")
        with self._cv:
            if self._closed:
                raise ServerClosed("EeiServer is closed")
        eng = engine_mod.SolverEngine(self._session_plan(a.shape[0], k))
        session = eng.open_session(a, k, largest, config=config)
        with self._cv:
            if self._closed:
                raise ServerClosed("EeiServer is closed")
            sid = f"s{next(self._session_ids)}"
            self._sessions[sid] = _ServerSession(
                sid=sid, engine=eng, session=session, a_host=a.copy())
            self.sessions_opened += 1
            if self._threaded and self._session_thread is None:
                self._session_thread = threading.Thread(
                    target=self._session_main, name="eei-session",
                    daemon=True)
                self._session_thread.start()
            self._cv.notify_all()
        return sid

    def _get_session(self, session_id: str) -> _ServerSession:
        with self._cv:
            rec = self._sessions.get(session_id)
        if rec is None:
            raise KeyError(f"no session {session_id!r}")
        return rec

    def submit_update(self, session_id: str, u, sign: int = 1) -> Future:
        """Apply ``A <- A + sign * u u^T`` to a session; returns a future
        resolving to the refreshed top-k window (request-shaped numpy
        arrays, like :meth:`submit`).

        Per-session updates resolve in submission order.  A fast-path
        failure degrades to a full solve from the host mirror (the PR-7
        terminal rung) instead of erroring — the future then resolves
        with a :class:`DegradedResult`.
        """
        rec = self._get_session(session_id)
        u = np.asarray(u, dtype=self.dtype)
        fut = Future()
        with self._cv:
            if self._closed or rec.closed:
                fut.set_exception(ServerClosed(
                    f"session {session_id!r} is closed"))
                return fut
            if self._threaded:
                self._session_ops.append((rec, u, int(sign), fut))
                self._cv.notify_all()
                return fut
        self._session_exec_update(rec, u, int(sign), fut)
        return fut

    def session_result(self, session_id: str):
        """Snapshot of a session's current top-k window (numpy arrays)."""
        rec = self._get_session(session_id)
        with rec.lock:
            res = rec.session.result()
            return engine_mod.TopkResult(
                np.asarray(res.eigenvalues), np.asarray(res.vectors))

    def session_stats(self, session_id: str) -> dict:
        rec = self._get_session(session_id)
        with rec.lock:
            return rec.session.stats()

    def close_session(self, session_id: str) -> None:
        """Drop a session.  Updates already queued for it resolve with
        :class:`ServerClosed`; in-execution updates finish normally."""
        with self._cv:
            rec = self._sessions.pop(session_id, None)
            if rec is not None:
                rec.closed = True
                self._cv.notify_all()

    def _session_exec_update(self, rec: _ServerSession, u: np.ndarray,
                             sign: int, fut: Future) -> None:
        """Run one update under the session lock; never raises.

        Request errors (bad shape / non-finite input) fail the future
        directly — degrading cannot fix a malformed request.  Anything
        else (a broken fast path, a sick backend) degrades to a host
        full solve from the mirror, so the session survives every fault
        the PR-7 chain survives."""
        from repro.engine import session as session_mod

        u64 = np.asarray(u, dtype=np.float64)
        with rec.lock:
            try:
                before = rec.session.full_resolves
                res = rec.engine.update(
                    rec.session, session_mod.Rank1Update(u, sign))
                rec.a_host += sign * np.outer(u64, u64)
            except ValueError as exc:  # malformed request: fail, don't mask
                with self._cv:
                    self.requests_failed += 1
                    self._cv.notify_all()
                self._set(fut, error=exc)
                return
            except Exception as exc:
                self._session_degrade(rec, u64, sign, fut, exc)
                return
            lam = np.asarray(res.eigenvalues)
            vec = np.asarray(res.vectors)
            full = rec.session.full_resolves > before
        with self._cv:
            self.session_updates += 1
            if full:
                self.session_full_resolves += 1
            else:
                self.session_fast_updates += 1
            self._cv.notify_all()
        self._set(fut, result=engine_mod.TopkResult(lam, vec))

    def _session_degrade(self, rec: _ServerSession, u64: np.ndarray,
                         sign: int, fut: Future, cause: Exception) -> None:
        """Terminal session rung: host eigh full solve from the mirror.

        Called with ``rec.lock`` held, mirror NOT yet updated for this
        ``u`` (the engine commits state only on success, so the mirror
        and the session agree at entry)."""
        from repro.engine import session as session_mod

        log.warning("session %s update degrading to host solve (%s)",
                    rec.sid, cause)
        if not self.fallback:
            with self._cv:
                self.requests_failed += 1
                self._cv.notify_all()
            self._set(fut, error=cause)
            return
        try:
            rec.a_host += sign * np.outer(u64, u64)
            session_mod.host_reseed(rec.session, rec.a_host)
            res = rec.session.result()
            lam = np.asarray(res.eigenvalues)
            vec = np.asarray(res.vectors)
        except Exception:
            with self._cv:
                self.requests_failed += 1
                self._cv.notify_all()
            self._set(fut, error=cause)
            return
        with self._cv:
            self.session_updates += 1
            self.session_full_resolves += 1
            self.session_degraded += 1
            self._cv.notify_all()
        self._set(fut, result=DegradedResult(
            lam, vec, fallback="host_reseed"))

    def _session_main(self) -> None:
        """Session executor (threaded mode): drains ``_session_ops``
        serially.  Exits once the server is closed and the queue is empty
        (queued ops still execute on a draining close — ``close()`` fails
        them first when ``drain=False``)."""
        while True:
            with self._cv:
                while not self._session_ops:
                    if self._closed:
                        return
                    self._cv.wait()
                rec, u, sign, fut = self._session_ops.popleft()
                self._session_busy += 1
                self._cv.notify_all()
            try:
                if rec.closed:
                    self._set(fut, error=ServerClosed(
                        f"session {rec.sid!r} is closed"))
                else:
                    self._session_exec_update(rec, u, sign, fut)
            finally:
                with self._cv:
                    self._session_busy -= 1
                    self._cv.notify_all()

    # -- draining ----------------------------------------------------------

    def pump(self) -> None:
        """Dispatch every coalesce group that fills a whole stack.

        Partial groups keep accumulating (so the stream batches instead of
        degenerating to per-request programs), but only within their own
        key — a partial group never delays a full stack of another shape.
        In threaded mode this is just a wakeup for the admission thread.
        """
        if self._threaded:
            with self._cv:
                self._cv.notify_all()
            return
        with self._cv:
            for key in [k for k, q in self._queues.items()
                        if len(q) >= self._group_cap(k)]:
                while len(self._queues.get(key, ())) >= self._group_cap(key):
                    self._make_room_locked()
                    self._dispatch(self._pop_group_locked(key))

    def flush(self) -> None:
        """Drain: dispatch all queued requests (partial stacks too) and
        block until every in-flight stack has retired.

        Idempotent and re-callable: a second ``flush()`` (including one
        racing the first from another thread) finds nothing left and
        returns immediately.  In threaded mode this is a barrier — the
        admission thread does the dispatching; the caller just waits.
        """
        if self._threaded:
            with self._cv:
                self._draining += 1
                self._cv.notify_all()
                try:
                    while (self._queues or self._dispatching
                           or self._inflight or self._retiring
                           or self._session_ops or self._session_busy):
                        if self._admission_done and not (
                                self._retire_thread
                                and self._retire_thread.is_alive()):
                            break  # threads gone; nothing will drain more
                        self._cv.wait(timeout=0.1)
                finally:
                    self._draining -= 1
                    self._cv.notify_all()
            return
        with self._cv:
            while self._queues:
                self._make_room_locked()
                key = next(iter(self._queues))
                self._dispatch(self._pop_group_locked(key))
            while self._inflight:
                self._retire(self._inflight.popleft())

    def close(self, drain: bool = True, timeout: Optional[float] = None
              ) -> list:
        """Shut the server down.  Idempotent.  Returns the list of caller
        futures still unresolved when it returns — **empty on a clean
        drain**.

        ``drain=True`` (default) dispatches everything still queued and
        blocks until every future has resolved; ``drain=False`` resolves
        queued requests' futures with :class:`ServerClosed` instead (all
        futures still resolve — never stranded), but always retires stacks
        already on device.  After ``close()``, ``submit()`` returns futures
        with :class:`ServerClosed` already set.

        In threaded mode ``timeout`` bounds the *whole* call: the two
        thread joins share one deadline, and if the drain is wedged (a
        stuck device sync, a straggler-injected retire) ``close`` returns
        the still-unresolved futures instead of hanging or raising — the
        caller (a fleet failing over, an operator shutting down) decides
        whether to redispatch or abandon them.  The background threads are
        daemons; a later resolution still flows to the futures normally.
        """
        with self._cv:
            first = not self._closed
            self._closed = True
            groups = self._pop_all_locked() if first and not drain else []
            session_ops = []
            if first and not drain:
                session_ops = list(self._session_ops)
                self._session_ops.clear()
            self._cv.notify_all()
        for group in groups:
            self._fail(group, ServerClosed(
                "EeiServer closed before this request was dispatched"))
        for _rec, _u, _sign, fut in session_ops:
            self._set(fut, error=ServerClosed(
                "EeiServer closed before this update was applied"))
        if self._threaded:
            deadline = None if timeout is None else \
                time.monotonic() + timeout
            threads = [self._admission_thread, self._retire_thread]
            if self._session_thread is not None:
                threads.append(self._session_thread)
            for thread in threads:
                left = None if deadline is None else \
                    max(deadline - time.monotonic(), 0.0)
                thread.join(left)
            if (self._admission_thread.is_alive()
                    or self._retire_thread.is_alive()):
                with self._cv:
                    stranded = list(self._unresolved)
                log.error(
                    "EeiServer.close(): drain did not finish within %ss; "
                    "%d future(s) still unresolved", timeout, len(stranded))
                return stranded
        elif first:
            if drain:
                self.flush()
            else:
                # Stacks already on device must still retire — their device
                # work is spent either way, and their futures must resolve.
                with self._cv:
                    while self._inflight:
                        self._retire(self._inflight.popleft())
        return []

    def __enter__(self) -> "EeiServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- replica introspection (read by EeiFleet) --------------------------

    def alive(self) -> bool:
        """Whether this server can still make progress on admitted work.
        A closed server, or a threaded server whose service threads died
        (bounded restarts exhausted), is not alive."""
        with self._cv:
            if self._closed:
                return False
        if self._threaded:
            return (self._admission_thread.is_alive()
                    and self._retire_thread.is_alive())
        return True

    def unresolved_futures(self) -> list:
        """Snapshot of every admitted caller future not yet resolved, in
        submit order.  The fleet walks this on replica death to redispatch
        exactly the work the replica still owed."""
        with self._cv:
            return sorted(self._unresolved, key=self._unresolved.get)

    def oldest_unresolved_age_s(self, now: Optional[float] = None
                                ) -> Optional[float]:
        """Age of the oldest admitted-but-unresolved request (None when
        idle) — the fleet's deadline probe: a hung replica accepts work
        and never answers, so *only* this age keeps growing."""
        with self._cv:
            if not self._unresolved:
                return None
            oldest = min(self._unresolved.values())
        return (time.monotonic() if now is None else now) - oldest

    def pending_manifest(self) -> list:
        """Queued-but-undispatched requests: ``[{n, k, largest, age_s}]``."""
        now = time.monotonic()
        with self._cv:
            return [
                {"n": r.n, "k": r.k, "largest": r.largest,
                 "age_s": now - r.t_submit}
                for q in self._queues.values() for r in q
            ]

    def inflight_manifest(self) -> list:
        """Dispatched-but-unretired stacks: ``[{bucket, rows, oldest_age_s}]``."""
        now = time.monotonic()
        with self._cv:
            return [
                {"bucket": f"b{s.bucket.b}n{s.bucket.n}k{s.bucket.k}"
                           + ("L" if s.bucket.largest else "S"),
                 "rows": len(s.requests),
                 "oldest_age_s": now - min(r.t_submit for r in s.requests)}
                for s in self._inflight
            ]

    # -- observability -----------------------------------------------------

    def reset_stats(self) -> None:
        """Zero request/stack/latency counters and the cache's hit counter,
        keeping compiled programs — benchmarks warm the cache with one pass,
        reset, then time a steady-state pass (compiles then stay 0)."""
        with self._cv:
            self.requests_submitted = 0
            self.requests_completed = 0
            self.requests_failed = 0
            self.requests_rejected = 0
            self.requests_cancelled = 0
            self.stacks_dispatched = 0
            self.packed_stacks_dispatched = 0
            self.packed_requests_completed = 0
            self.grid_cells_total = 0
            self.grid_cells_real = 0
            self._pad_cells_by_bucket = {}
            self.latencies_ms = []
            self.dispatch_log = []
            self.verify_failed = 0
            self.retries = 0
            self.retry_delays_s = []
            self.stack_splits = 0
            self.requests_degraded = 0
            self.fallbacks_by_plan = {}
            # _key_rate survives: it describes the stream's arrival shape,
            # which a stats reset (a benchmark pass boundary) doesn't change.
            self.linger_trims = 0
            self.sessions_opened = 0
            self.session_updates = 0
            self.session_fast_updates = 0
            self.session_full_resolves = 0
            self.session_degraded = 0
        self.cache.reset_counters()

    def stats(self) -> dict:
        """Counter snapshot.

        Pad-waste semantics (``grid_cells_*``, ``pad_waste_*``): cells are
        counted exactly once per *successfully retired* stack — a stack
        that is retried, bisection-split or redispatched by a fleet
        failover contributes once, when (and only when) a program's result
        is actually handed back; requests served by the per-request
        fallback chain contribute nothing.  ``stacks_dispatched`` counts
        *launches* instead, so ``stacks_dispatched`` can exceed the number
        of retired stacks under chaos while the cell counters match the
        equivalent clean run.  ``pad_waste_bucketed_frac`` /
        ``pad_waste_packed_frac`` split the waste by dispatch path.  The
        packed fraction counts every cell of the ``(b, n, n)`` packed
        rows, which charges a block-diagonal row quadratically for its
        structural off-block zeros — so the packed fraction sits *above*
        the bucketed one by construction, pricing the trade packing
        makes: more guard cells per launch in exchange for far fewer
        launches and compiled programs on fragmented ragged traffic.
        Compare each fraction against its own history, not against the
        other path's."""
        with self._cv:
            lat = sorted(self.latencies_ms)
            packed_real = packed_total = buck_real = buck_total = 0
            for bk, (real, total) in self._pad_cells_by_bucket.items():
                if isinstance(bk, PackedBucket):
                    packed_real += real
                    packed_total += total
                else:
                    buck_real += real
                    buck_total += total
            snap = {
                "requests_submitted": self.requests_submitted,
                "requests_completed": self.requests_completed,
                "requests_failed": self.requests_failed,
                "requests_rejected": self.requests_rejected,
                "requests_cancelled": self.requests_cancelled,
                "requests_pending": self._pending,
                "requests_unresolved": len(self._unresolved),
                "stacks_dispatched": self.stacks_dispatched,
                "packed_stacks_dispatched": self.packed_stacks_dispatched,
                "packed_requests_completed": self.packed_requests_completed,
                "grid_cells_total": self.grid_cells_total,
                "grid_cells_real": self.grid_cells_real,
                "pad_waste_frac": (
                    1.0 - self.grid_cells_real / self.grid_cells_total
                    if self.grid_cells_total else 0.0),
                "pad_waste_bucketed_frac": (
                    1.0 - buck_real / buck_total if buck_total else 0.0),
                "pad_waste_packed_frac": (
                    1.0 - packed_real / packed_total
                    if packed_total else 0.0),
                "pad_waste_by_bucket": {
                    _bucket_label(bk):
                        round(1.0 - real / total, 6) if total else 0.0
                    for bk, (real, total)
                    in sorted(self._pad_cells_by_bucket.items(),
                              key=lambda kv: _bucket_label(kv[0]))},
                "verify_failed": self.verify_failed,
                "retries": self.retries,
                "stack_splits": self.stack_splits,
                "requests_degraded": self.requests_degraded,
                "fallbacks_by_plan": dict(self.fallbacks_by_plan),
                "linger_trims": self.linger_trims,
                "sessions_open": len(self._sessions),
                "sessions_opened": self.sessions_opened,
                "session_updates": self.session_updates,
                "session_fast_updates": self.session_fast_updates,
                "session_full_resolves": self.session_full_resolves,
                "session_degraded": self.session_degraded,
                "chaos_injected": (
                    self.chaos.counts() if self.chaos is not None else {}),
            }

        def pct(p):
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p / 100.0 * len(lat)))]

        snap.update({
            "program_compiles": self.cache.compiles,
            "program_hits": self.cache.hits,
            "distinct_buckets": len(self.cache),
            "p50_latency_ms": pct(50),
            "p99_latency_ms": pct(99),
        })
        return snap
