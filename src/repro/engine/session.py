"""SpectralSession — stateful streaming maintenance of a top-k window.

Production traffic is rarely i.i.d. fresh matrices: a covariance / Gram
matrix drifts by rank-1 data updates (``A <- A + sign * u u^T``) and the
caller wants the *same* top-k window back after every step.  Re-solving
from scratch pays O(n^3) (or an m-step Lanczos) per update; this module
pays O(m' n^2) with ``m' ~ k + buffer + ext << n`` by maintaining a session:

* the current matrix ``a`` (device-resident),
* a retained Ritz window: ``basis (m_keep, n)`` / ``theta (m_keep,)`` —
  the ``m_keep = k + buffer`` extremal eigenpairs from the last solve,
* a **drift monitor**: accumulated ``|rho| / ||A||_F`` since the last full
  solve, an update-count cadence cap, and the verify flags of every fast
  update (``engine/verify.py`` runs inside the update program).

The fast path is the engine's ``update`` program kind (see
``backends._UPDATE_CHAIN``): project the updated matrix onto the retained
basis augmented with the update direction + a few Lanczos extensions,
tridiagonalize the small compression, bisect its spectrum from
interlacing/secular warm brackets, and recover vectors through the shared
minor-determinant + sign-recurrence stages.  Any of the monitor's three
triggers — drift past ``drift_bound``, a failed verify, ``max_updates``
updates since the last solve — forces a **full re-solve** through
``engine.topk`` that rebuilds the retained window from scratch.  The fast
path can therefore never silently return stale eigenpairs: every answer is
either residual-verified against the updated matrix or freshly solved.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.verify import verify_topk_host


class SessionVerifyError(RuntimeError):
    """A session's *full re-solve* failed residual verification — the
    matrix itself is pathological (non-finite / non-symmetric drift), not
    just the warm start.  The serving layer maps this onto its fallback
    chain; direct engine callers see the error."""


class Rank1Update(NamedTuple):
    """One symmetric rank-1 perturbation ``A <- A + sign * u u^T``."""

    u: np.ndarray
    sign: int = 1


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Tuning knobs of a session (all static per session).

    ``buffer``       extra Ritz pairs retained beyond ``k`` — the guard
                     band that lets eigenvalues rotate into the window
                     between full solves.
    ``ext``          Lanczos extension directions appended to the basis
                     per update (beyond the update direction itself).
    ``drift_bound``  accumulated ``sum |rho_i| / ||A||_F`` since the last
                     full solve that forces a re-solve.
    ``max_updates``  fast updates allowed between full solves (cadence
                     cap — bounds worst-case staleness even when drift
                     and verify stay green).
    ``verify``       host-check the update program's verify flags every
                     fast update (on by default; the drift monitor's
                     residual leg).
    """

    buffer: int = 4
    ext: int = 3
    drift_bound: float = 0.25
    max_updates: int = 128
    verify: bool = True

    def __post_init__(self):
        if self.buffer < 0:
            raise ValueError(f"buffer must be >= 0, got {self.buffer}")
        if self.ext < 0:
            raise ValueError(f"ext must be >= 0, got {self.ext}")
        if self.drift_bound <= 0:
            raise ValueError(
                f"drift_bound must be > 0, got {self.drift_bound}")
        if self.max_updates < 1:
            raise ValueError(
                f"max_updates must be >= 1, got {self.max_updates}")


class SpectralSession:
    """Mutable session state; create via ``SolverEngine.open_session``.

    All heavy state (matrix, basis) stays device-resident between updates.
    Not thread-safe — the serving layer serializes per-session access.
    """

    def __init__(self, k: int, largest: bool, config: SessionConfig,
                 n: int, m_keep: int, n_aug: int, dtype):
        self.k = k
        self.largest = largest
        self.config = config
        self.n = n
        self.m_keep = m_keep
        self.n_aug = n_aug
        self.dtype = dtype
        # Device state, refreshed by every update / re-solve.
        self.a: Optional[jax.Array] = None
        self.basis: Optional[jax.Array] = None  # (m_keep, n)
        self.theta: Optional[jax.Array] = None  # (m_keep,)
        self.lam: Optional[jax.Array] = None  # (k,)
        self.vecs: Optional[jax.Array] = None  # (k, n)
        # Drift monitor.
        self.scale = 0.0  # ||A||_F at the last full solve
        self.drift = 0.0  # sum |rho| / scale since the last full solve
        self.updates_since_resolve = 0
        # Counters.
        self.updates_total = 0
        self.fast_updates = 0
        self.full_resolves = 0
        self.resolves_by_cause: dict = {}

    def result(self):
        """The current top-k window as a ``TopkResult``."""
        from repro.engine.engine import TopkResult

        return TopkResult(self.lam, self.vecs)

    def stats(self) -> dict:
        return {
            "k": self.k, "n": self.n, "m_keep": self.m_keep,
            "updates_total": self.updates_total,
            "fast_updates": self.fast_updates,
            "full_resolves": self.full_resolves,
            "resolves_by_cause": dict(self.resolves_by_cause),
            "drift": self.drift,
            "updates_since_resolve": self.updates_since_resolve,
        }


def _plan_dtype(plan):
    if plan.precision is not None:
        return jnp.dtype({"float32": jnp.float32,
                          "float64": jnp.float64}[plan.precision])
    return jnp.dtype(jnp.float32)


def open_session(engine, a, k: int, largest: bool = True,
                 config: Optional[SessionConfig] = None) -> SpectralSession:
    """Seed a session with a full solve of the ``m_keep`` retained window."""
    cfg = config if config is not None else SessionConfig()
    dtype = _plan_dtype(engine.plan)
    a = jnp.asarray(a, dtype)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"expected one (n, n) matrix, got {a.shape}")
    n = a.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"k={k} out of range for n={n}")
    m_keep = min(n, k + cfg.buffer)
    # Augmentation directions: u plus `ext` Lanczos extensions, clipped so
    # the augmented frame never exceeds n (m_keep == n means the retained
    # basis is already the whole space and the update is exact).
    n_aug = min(n - m_keep, 1 + cfg.ext)
    session = SpectralSession(int(k), bool(largest), cfg, n, m_keep, n_aug,
                              dtype)
    _full_resolve(engine, session, a, cause="open")
    return session


def _slice_window(session, lam_m, vecs_m):
    k = session.k
    if session.largest:
        return lam_m[..., -k:], vecs_m[..., -k:, :]
    return lam_m[..., :k], vecs_m[..., :k, :]


def _host_eigh_window(session, a_new):
    """Last-rung exact solve: float64 LAPACK eigh on the host."""
    from repro.engine.engine import TopkResult

    lam, v = np.linalg.eigh(np.asarray(a_new, np.float64))
    m = session.m_keep
    if session.largest:
        lam, v = lam[-m:], v[:, -m:]
    else:
        lam, v = lam[:m], v[:, :m]
    return TopkResult(jnp.asarray(lam, session.dtype),
                      jnp.asarray(v.T, session.dtype))


def _commit_resolve(session, a_new, res, cause: str) -> None:
    """Install a fresh full-solve window and reset the drift monitor."""
    session.a = jnp.asarray(a_new, session.dtype)
    session.basis = res.vectors
    session.theta = res.eigenvalues
    session.lam, session.vecs = _slice_window(
        session, res.eigenvalues, res.vectors)
    session.scale = float(np.linalg.norm(np.asarray(a_new)))
    session.drift = 0.0
    session.updates_since_resolve = 0
    if cause != "open":
        session.full_resolves += 1
        session.resolves_by_cause[cause] = \
            session.resolves_by_cause.get(cause, 0) + 1


def host_reseed(session, a_new, cause: str = "degrade") -> None:
    """Rebuild the session entirely on the host (float64 LAPACK eigh).

    The serving layer's terminal degrade rung: no engine, no XLA, no
    compile — usable even when the fast path's whole backend is broken.
    Raises :class:`SessionVerifyError` only when LAPACK itself cannot
    produce a verifiable window (a genuinely pathological matrix).
    """
    res = _host_eigh_window(session, a_new)
    flags = verify_topk_host(
        np.asarray(a_new), np.asarray(res.eigenvalues),
        np.asarray(res.vectors))
    if not bool(np.all(flags.ok)):
        raise SessionVerifyError(
            f"session host re-solve (cause={cause!r}) failed residual "
            "verification; the session matrix is pathological")
    _commit_resolve(session, a_new, res, cause)


def _full_resolve(engine, session, a_new, cause: str) -> None:
    """Rebuild the retained window from scratch and reset the monitor."""
    res = engine.topk(a_new, session.m_keep, session.largest)
    if session.config.verify:
        flags = verify_topk_host(
            np.asarray(a_new), np.asarray(res.eigenvalues),
            np.asarray(res.vectors))
        if not bool(np.all(flags.ok)):
            # The plan's method missed tolerance on this matrix (e.g. a
            # dominant spike at float32) — escalate to host eigh rather
            # than surface a method artifact as a session failure.
            host_reseed(session, a_new, cause)
            return
    _commit_resolve(session, a_new, res, cause)


def _pad_batch(engine, x):
    """Lift session state to the program's batch shape (mesh-divisible)."""
    mult = engine.plan.batch_axis_size
    x = x[None]
    if mult > 1:
        x = jnp.broadcast_to(x, (mult,) + x.shape[1:])
    return x


def _normalize_deltas(delta):
    if isinstance(delta, Rank1Update):
        return [delta]
    if isinstance(delta, tuple) and len(delta) == 2 and \
            np.ndim(delta[1]) == 0:
        return [Rank1Update(delta[0], int(delta[1]))]
    if isinstance(delta, (list,)) or (
            isinstance(delta, Sequence) and not hasattr(delta, "shape")):
        out = []
        for item in delta:
            out.extend(_normalize_deltas(item))
        return out
    return [Rank1Update(delta, 1)]


def apply_update(engine, session: SpectralSession,
                 delta: Union[Rank1Update, tuple, Sequence, np.ndarray]):
    """Apply rank-1 update(s) to a session; returns the refreshed window.

    Rank-r updates decompose into r sequential rank-1 applications — each
    one re-verified, so a large compound update degrades to full re-solves
    exactly like a large single one.
    """
    if session.a is None:
        raise ValueError("session is not seeded; use engine.open_session")
    for upd in _normalize_deltas(delta):
        _apply_rank1(engine, session, upd)
    return session.result()


def _apply_rank1(engine, session, upd: Rank1Update) -> None:
    from repro.engine.engine import update_program

    cfg = session.config
    sign = int(upd.sign)
    if sign not in (-1, 1):
        raise ValueError(f"sign must be +1 or -1, got {upd.sign}")
    u = jnp.asarray(upd.u, session.dtype)
    if u.shape != (session.n,):
        raise ValueError(
            f"expected update vector of shape ({session.n},), got {u.shape}")
    nrm2 = float(jnp.vdot(u, u))
    if not np.isfinite(nrm2):
        raise ValueError("update vector is not finite")
    session.updates_total += 1
    if nrm2 == 0.0:
        return  # A + 0 = A: nothing to do, nothing drifts
    rho = sign * nrm2
    new_drift = session.drift + abs(rho) / max(session.scale, 1e-30)

    # Drift monitor, legs 1+2: accumulated movement bound and cadence cap.
    if new_drift > cfg.drift_bound or \
            session.updates_since_resolve + 1 > cfg.max_updates:
        cause = "drift" if new_drift > cfg.drift_bound else "cadence"
        a_new = session.a + (sign * u)[:, None] * u[None, :]
        _full_resolve(engine, session, a_new, cause=cause)
        return

    # Fast path: the warm-started update program.
    program = update_program(
        engine.plan, session.k, session.largest, session.m_keep,
        session.n_aug)
    u_hat = u / jnp.sqrt(nrm2)
    operands = [session.a, session.basis, session.theta, u_hat,
                jnp.asarray(rho, session.dtype)]
    padded = [_pad_batch(engine, x) for x in operands[:4]]
    rho_b = jnp.broadcast_to(
        operands[4][None], (padded[0].shape[0],))
    result, flags, a_new, basis, theta = program(*padded, rho_b)
    take = lambda t: jax.tree.map(lambda x: x[0], t)
    result, flags, a_new, basis, theta = (
        take(result), take(flags), take(a_new), take(basis), take(theta))

    # Drift monitor, leg 3: residual verification of the fast answer.
    if cfg.verify and not bool(np.asarray(flags.ok)):
        _full_resolve(engine, session, a_new, cause="verify")
        return

    session.a = a_new
    session.basis = basis
    session.theta = theta
    session.lam, session.vecs = result.eigenvalues, result.vectors
    session.drift = new_drift
    session.updates_since_resolve += 1
    session.fast_updates += 1
