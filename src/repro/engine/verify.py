"""Post-solve result verification — the ``verify`` stage role.

The EEI identity degrades exactly where traffic is nastiest: (near-)
degenerate spectra collapse the product-difference denominators.  The
kernels clamp those denominators at ``eps * spectral scale`` so nothing
overflows, but clamping only guarantees *finite* garbage — nothing checked
that the emitted vectors are eigenvectors.  This module is that check: a
cheap batched stage appended after ``recover`` that scores every row of a
topk result and emits per-matrix boolean flags, so the serving layer can
route failing requests down the fallback chain instead of returning
garbage to a caller.

Checks (per matrix in the stack):

* **finite** — every selected eigenvalue and vector entry is finite.
* **residual** — ``max_i ||A v_i - lam_i v_i||_2 <= tol * scale(A)`` where
  ``scale(A) = max(||A||_F, tiny)`` — the Frobenius norm never vanishes on
  the guard-padded server stacks, and measured float32 EEI residuals track
  it with an n-independent constant (~3e-4 of ``||A||_F`` from n=16 to
  n=128), so one tolerance covers every bucket size.
* **unit norm** — ``| ||v_i||_2 - 1 | <= norm_tol`` for every row.
* **bracket order** — selected eigenvalues ascend: ``lam[j+1] >= lam[j] -
  tol * scale``.  A collapsed or crossed Sturm bracket shows up here.

``verify_topk`` is pure jnp on purpose: it runs inside the jitted program
on every backend (under GSPMD the post-recover arrays are already global,
so no shard_map wrapper is needed), and ``verify_topk_host`` is the same
math on numpy for checking host-side fallback solves.

Tolerances default to ``DEFAULT_TOL`` — >= 3x above the worst measured
healthy float32 EEI residual, while a clamped-denominator garbage vector
(residual ``O(|lam|_max)``, i.e. >= ``||A||_F / sqrt(n)``) sits >= 50x
*above* it at serving sizes — and an exactly-degenerate collapse shows up
as NaN, which the finiteness check catches outright.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

#: Default residual tolerance, in units of ``||A||_F``.  Measured healthy
#: float32 EEI residuals sit at ~1e-4..4e-4 of ``||A||_F`` across n=16..128
#: (the bisection tolerance dominates and tracks the Frobenius norm);
#: garbage from a clamped denominator is O(||A||_F / sqrt(n)) or NaN.
DEFAULT_TOL = 2e-3

#: Default unit-norm tolerance.  Recover stages renormalize explicitly, so
#: a healthy row is 1 +/- a few ulp; a NaN-poisoned or zero row is not.
DEFAULT_NORM_TOL = 1e-3


class VerifyFlags(NamedTuple):
    """Per-matrix verification verdict for a batched topk result.

    All fields carry the stack's leading batch axis ``(b,)``.  ``ok`` is
    the conjunction of the individual checks; ``residual`` is the worst
    relative residual (units of ``||A||_F``) for observability / debugging.
    """

    ok: jax.Array          # (b,) bool — all checks passed
    finite: jax.Array      # (b,) bool — no NaN/Inf in lam or vecs
    residual_ok: jax.Array # (b,) bool — max_i ||A v - lam v|| <= tol*scale
    norm_ok: jax.Array     # (b,) bool — rows unit-norm within norm_tol
    ordered: jax.Array     # (b,) bool — selected eigenvalues ascend
    residual: jax.Array    # (b,) float — worst relative residual


def _spectral_scale(a: jnp.ndarray) -> jnp.ndarray:
    """Per-matrix scale ``max(||A||_F, tiny)`` — never vanishes, so
    relative tolerances stay meaningful for near-zero matrices."""
    fro = jnp.sqrt(jnp.sum(a * a, axis=(-2, -1)))
    return jnp.maximum(fro, jnp.asarray(1e-30, a.dtype))


def verify_topk(a: jnp.ndarray, lam_sel: jnp.ndarray, vecs: jnp.ndarray,
                tol: float = DEFAULT_TOL,
                norm_tol: float = DEFAULT_NORM_TOL) -> VerifyFlags:
    """Batched verification of a topk result.

    ``a`` is the input stack ``(b, n, n)``, ``lam_sel`` the selected
    eigenvalues ``(b, k)`` ascending, ``vecs`` the selected eigenvectors
    ``(b, k, n)`` (rows).  Pure jnp — safe inside jit on every backend.
    """
    scale = _spectral_scale(a)  # (b,)

    finite = (jnp.all(jnp.isfinite(lam_sel), axis=-1)
              & jnp.all(jnp.isfinite(vecs), axis=(-2, -1)))

    # Residual ||A v_i - lam_i v_i|| per selected row; rows of `vecs` are
    # eigenvectors, so A acts on the last axis.
    av = jnp.einsum("...ij,...kj->...ki", a, vecs)
    res = av - lam_sel[..., :, None] * vecs
    res_norm = jnp.sqrt(jnp.sum(res * res, axis=-1))  # (b, k)
    worst = jnp.max(res_norm, axis=-1) / scale         # (b,)
    # NaN comparisons are False, so a poisoned row fails residual_ok too —
    # but report the raw worst for observability.
    residual_ok = worst <= tol

    norms = jnp.sqrt(jnp.sum(vecs * vecs, axis=-1))    # (b, k)
    norm_ok = jnp.all(jnp.abs(norms - 1.0) <= norm_tol, axis=-1)

    # Ascending within tol*scale: a collapsed bracket (repeated lam is
    # fine) passes, a crossed one fails.
    dif = lam_sel[..., 1:] - lam_sel[..., :-1]
    ordered = jnp.all(dif >= -tol * scale[..., None], axis=-1)
    if lam_sel.shape[-1] < 2:
        ordered = jnp.ones_like(finite)

    ok = finite & residual_ok & norm_ok & ordered
    return VerifyFlags(ok=ok, finite=finite, residual_ok=residual_ok,
                       norm_ok=norm_ok, ordered=ordered, residual=worst)


def verify_topk_packed(a: jnp.ndarray, seg_off: jnp.ndarray,
                       seg_len: jnp.ndarray, lam_seg: jnp.ndarray,
                       vecs_seg: jnp.ndarray, largest: bool = True,
                       tol: float = DEFAULT_TOL,
                       norm_tol: float = DEFAULT_NORM_TOL) -> VerifyFlags:
    """Per-*slot* verification of a segment-packed topk result, ``(b, S)``.

    Same checks as :func:`verify_topk`, scoped to each segment of each
    packed row so the PR-7 guarantee holds per request, not per stack:

    * residuals are scaled by the *segment's* Frobenius norm (a small
      request must not hide behind a large neighbor's scale);
    * only the ``min(seg_len, k)`` *valid* lanes are checked — the packer
      guarantees every rider's window lies inside them (sentinel lanes sit
      at the front for ``largest``, at the back for smallest);
    * ``norm_ok`` additionally requires in-segment mass ``>= 1 - norm_tol``
      — the check that catches eigh rotating a cross-segment (near-)
      degenerate eigenspace into vectors that straddle two requests;
    * empty slots (``seg_len == 0``) pass vacuously.
    """
    b, s, k = lam_seg.shape
    n = a.shape[-1]
    dtype = a.dtype
    seg_off = seg_off.astype(jnp.int32)
    seg_len = seg_len.astype(jnp.int32)

    col = jnp.arange(n, dtype=jnp.int32)
    in_seg = ((seg_off[:, :, None] <= col[None, None, :])
              & (col[None, None, :]
                 < (seg_off + seg_len)[:, :, None]))  # (b, S, N)
    m = in_seg.astype(dtype)
    empty = seg_len == 0  # (b, S)

    # Valid lanes per slot: the min(len, k) real window positions.
    clen = jnp.minimum(seg_len, k)  # (b, S)
    t = jnp.arange(k, dtype=jnp.int32)[None, None, :]
    if largest:
        valid = t >= (k - clen)[:, :, None]  # (b, S, k)
    else:
        valid = t < clen[:, :, None]

    finite_lane = (jnp.isfinite(lam_seg)
                   & jnp.all(jnp.isfinite(vecs_seg), axis=-1))  # (b, S, k)
    finite = jnp.all(finite_lane | ~valid, axis=-1)

    # Segment-scoped scale: ||A[seg, seg]||_F via the mask quadratic form.
    seg_fro2 = jnp.einsum("bsp,bpq,bsq->bs", m, a * a, m)
    scale = jnp.maximum(jnp.sqrt(seg_fro2),
                        jnp.asarray(1e-30, dtype))  # (b, S)

    # Residual of the *served* slice: retire hands each caller only its
    # segment's columns, so the vector is masked to the segment first.  The
    # tridiag chain's rows carry ~1e-4 stray amplitude outside the segment
    # (minor-det mass is ~0 there, not exactly 0) that the caller never
    # sees; unmasked, the guard diagonals amplify it into a false reject.
    # What the mask drops is bounded by the in-segment mass check below.
    vm = vecs_seg * m[:, :, None, :]  # (b, S, k, N)
    av = jnp.einsum("bij,bskj->bski", a, vm)
    res = av - lam_seg[..., None] * vm
    res_norm = jnp.sqrt(jnp.sum(res * res, axis=-1))  # (b, S, k)
    worst = jnp.max(jnp.where(valid, res_norm, 0.0), axis=-1) / scale
    residual_ok = worst <= tol

    norms2 = jnp.sum(vecs_seg * vecs_seg, axis=-1)  # (b, S, k)
    mass = jnp.einsum("bsp,bskp->bsk", m, vecs_seg * vecs_seg)
    lane_norm_ok = (jnp.abs(jnp.sqrt(norms2) - 1.0) <= norm_tol) \
        & (mass >= 1.0 - norm_tol)
    norm_ok = jnp.all(lane_norm_ok | ~valid, axis=-1)

    # Ascending across adjacent *valid* lanes only (sentinels are contiguous
    # at one end, so valid lanes are contiguous and adjacency is enough).
    dif = lam_seg[..., 1:] - lam_seg[..., :-1]
    pair_valid = valid[..., 1:] & valid[..., :-1]
    ordered = jnp.all(
        (dif >= -tol * scale[..., None]) | ~pair_valid, axis=-1)
    if k < 2:
        ordered = jnp.ones_like(finite)

    ok = (finite & residual_ok & norm_ok & ordered) | empty
    return VerifyFlags(ok=ok, finite=finite | empty,
                       residual_ok=residual_ok | empty,
                       norm_ok=norm_ok | empty, ordered=ordered | empty,
                       residual=jnp.where(empty, 0.0, worst))


def verify_topk_host(a: np.ndarray, lam_sel: np.ndarray, vecs: np.ndarray,
                     tol: float = DEFAULT_TOL,
                     norm_tol: float = DEFAULT_NORM_TOL) -> VerifyFlags:
    """Host (numpy) twin of :func:`verify_topk` for checking fallback
    solves without a device round-trip.  Same checks, same tolerances;
    returns :class:`VerifyFlags` of numpy arrays."""
    a = np.asarray(a)
    lam_sel = np.asarray(lam_sel)
    vecs = np.asarray(vecs)
    squeeze = a.ndim == 2
    if squeeze:
        a, lam_sel, vecs = a[None], lam_sel[None], vecs[None]

    fro = np.sqrt(np.sum(a * a, axis=(-2, -1)))
    scale = np.maximum(fro, 1e-30)

    finite = (np.all(np.isfinite(lam_sel), axis=-1)
              & np.all(np.isfinite(vecs), axis=(-2, -1)))

    av = np.einsum("...ij,...kj->...ki", a, vecs)
    res = av - lam_sel[..., :, None] * vecs
    with np.errstate(invalid="ignore", over="ignore"):
        res_norm = np.sqrt(np.sum(res * res, axis=-1))
        worst = np.max(res_norm, axis=-1) / scale
        residual_ok = worst <= tol

        norms = np.sqrt(np.sum(vecs * vecs, axis=-1))
        norm_ok = np.all(np.abs(norms - 1.0) <= norm_tol, axis=-1)

        dif = lam_sel[..., 1:] - lam_sel[..., :-1]
        ordered = np.all(dif >= -tol * scale[..., None], axis=-1)
    if lam_sel.shape[-1] < 2:
        ordered = np.ones_like(finite)

    ok = finite & residual_ok & norm_ok & ordered
    flags = VerifyFlags(ok=ok, finite=finite, residual_ok=residual_ok,
                        norm_ok=norm_ok, ordered=ordered, residual=worst)
    if squeeze:
        flags = VerifyFlags(*(f[0] for f in flags))
    return flags
