"""Post-solve result verification — the ``verify`` stage role.

The EEI identity degrades exactly where traffic is nastiest: (near-)
degenerate spectra collapse the product-difference denominators.  The
kernels clamp those denominators at ``eps * spectral scale`` so nothing
overflows, but clamping only guarantees *finite* garbage — nothing checked
that the emitted vectors are eigenvectors.  This module is that check: a
cheap batched stage appended after ``recover`` that scores every row of a
topk result and emits per-matrix boolean flags, so the serving layer can
route failing requests down the fallback chain instead of returning
garbage to a caller.

Checks (per matrix in the stack):

* **finite** — every selected eigenvalue and vector entry is finite.
* **residual** — ``max_i ||A v_i - lam_i v_i||_2 <= tol * scale(A)`` where
  ``scale(A) = max(||A||_F, tiny)`` — the Frobenius norm never vanishes on
  the guard-padded server stacks, and measured float32 EEI residuals track
  it with an n-independent constant (~3e-4 of ``||A||_F`` from n=16 to
  n=128), so one tolerance covers every bucket size.
* **unit norm** — ``| ||v_i||_2 - 1 | <= norm_tol`` for every row.
* **bracket order** — selected eigenvalues ascend: ``lam[j+1] >= lam[j] -
  tol * scale``.  A collapsed or crossed Sturm bracket shows up here.

``verify_topk`` is pure jnp on purpose: it runs inside the jitted program
on every backend (under GSPMD the post-recover arrays are already global,
so no shard_map wrapper is needed), and ``verify_topk_host`` is the same
math on numpy for checking host-side fallback solves.

Tolerances default to ``DEFAULT_TOL`` — >= 3x above the worst measured
healthy float32 EEI residual, while a clamped-denominator garbage vector
(residual ``O(|lam|_max)``, i.e. >= ``||A||_F / sqrt(n)``) sits >= 50x
*above* it at serving sizes — and an exactly-degenerate collapse shows up
as NaN, which the finiteness check catches outright.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

#: Default residual tolerance, in units of ``||A||_F``.  Measured healthy
#: float32 EEI residuals sit at ~1e-4..4e-4 of ``||A||_F`` across n=16..128
#: (the bisection tolerance dominates and tracks the Frobenius norm);
#: garbage from a clamped denominator is O(||A||_F / sqrt(n)) or NaN.
DEFAULT_TOL = 2e-3

#: Default unit-norm tolerance.  Recover stages renormalize explicitly, so
#: a healthy row is 1 +/- a few ulp; a NaN-poisoned or zero row is not.
DEFAULT_NORM_TOL = 1e-3


class VerifyFlags(NamedTuple):
    """Per-matrix verification verdict for a batched topk result.

    All fields carry the stack's leading batch axis ``(b,)``.  ``ok`` is
    the conjunction of the individual checks; ``residual`` is the worst
    relative residual (units of ``||A||_F``) for observability / debugging.
    """

    ok: jax.Array          # (b,) bool — all checks passed
    finite: jax.Array      # (b,) bool — no NaN/Inf in lam or vecs
    residual_ok: jax.Array # (b,) bool — max_i ||A v - lam v|| <= tol*scale
    norm_ok: jax.Array     # (b,) bool — rows unit-norm within norm_tol
    ordered: jax.Array     # (b,) bool — selected eigenvalues ascend
    residual: jax.Array    # (b,) float — worst relative residual


def _spectral_scale(a: jnp.ndarray) -> jnp.ndarray:
    """Per-matrix scale ``max(||A||_F, tiny)`` — never vanishes, so
    relative tolerances stay meaningful for near-zero matrices."""
    fro = jnp.sqrt(jnp.sum(a * a, axis=(-2, -1)))
    return jnp.maximum(fro, jnp.asarray(1e-30, a.dtype))


def verify_topk(a: jnp.ndarray, lam_sel: jnp.ndarray, vecs: jnp.ndarray,
                tol: float = DEFAULT_TOL,
                norm_tol: float = DEFAULT_NORM_TOL) -> VerifyFlags:
    """Batched verification of a topk result.

    ``a`` is the input stack ``(b, n, n)``, ``lam_sel`` the selected
    eigenvalues ``(b, k)`` ascending, ``vecs`` the selected eigenvectors
    ``(b, k, n)`` (rows).  Pure jnp — safe inside jit on every backend.
    """
    scale = _spectral_scale(a)  # (b,)

    finite = (jnp.all(jnp.isfinite(lam_sel), axis=-1)
              & jnp.all(jnp.isfinite(vecs), axis=(-2, -1)))

    # Residual ||A v_i - lam_i v_i|| per selected row; rows of `vecs` are
    # eigenvectors, so A acts on the last axis.
    av = jnp.einsum("...ij,...kj->...ki", a, vecs)
    res = av - lam_sel[..., :, None] * vecs
    res_norm = jnp.sqrt(jnp.sum(res * res, axis=-1))  # (b, k)
    worst = jnp.max(res_norm, axis=-1) / scale         # (b,)
    # NaN comparisons are False, so a poisoned row fails residual_ok too —
    # but report the raw worst for observability.
    residual_ok = worst <= tol

    norms = jnp.sqrt(jnp.sum(vecs * vecs, axis=-1))    # (b, k)
    norm_ok = jnp.all(jnp.abs(norms - 1.0) <= norm_tol, axis=-1)

    # Ascending within tol*scale: a collapsed bracket (repeated lam is
    # fine) passes, a crossed one fails.
    dif = lam_sel[..., 1:] - lam_sel[..., :-1]
    ordered = jnp.all(dif >= -tol * scale[..., None], axis=-1)
    if lam_sel.shape[-1] < 2:
        ordered = jnp.ones_like(finite)

    ok = finite & residual_ok & norm_ok & ordered
    return VerifyFlags(ok=ok, finite=finite, residual_ok=residual_ok,
                       norm_ok=norm_ok, ordered=ordered, residual=worst)


def verify_topk_host(a: np.ndarray, lam_sel: np.ndarray, vecs: np.ndarray,
                     tol: float = DEFAULT_TOL,
                     norm_tol: float = DEFAULT_NORM_TOL) -> VerifyFlags:
    """Host (numpy) twin of :func:`verify_topk` for checking fallback
    solves without a device round-trip.  Same checks, same tolerances;
    returns :class:`VerifyFlags` of numpy arrays."""
    a = np.asarray(a)
    lam_sel = np.asarray(lam_sel)
    vecs = np.asarray(vecs)
    squeeze = a.ndim == 2
    if squeeze:
        a, lam_sel, vecs = a[None], lam_sel[None], vecs[None]

    fro = np.sqrt(np.sum(a * a, axis=(-2, -1)))
    scale = np.maximum(fro, 1e-30)

    finite = (np.all(np.isfinite(lam_sel), axis=-1)
              & np.all(np.isfinite(vecs), axis=(-2, -1)))

    av = np.einsum("...ij,...kj->...ki", a, vecs)
    res = av - lam_sel[..., :, None] * vecs
    with np.errstate(invalid="ignore", over="ignore"):
        res_norm = np.sqrt(np.sum(res * res, axis=-1))
        worst = np.max(res_norm, axis=-1) / scale
        residual_ok = worst <= tol

        norms = np.sqrt(np.sum(vecs * vecs, axis=-1))
        norm_ok = np.all(np.abs(norms - 1.0) <= norm_tol, axis=-1)

        dif = lam_sel[..., 1:] - lam_sel[..., :-1]
        ordered = np.all(dif >= -tol * scale[..., None], axis=-1)
    if lam_sel.shape[-1] < 2:
        ordered = np.ones_like(finite)

    ok = finite & residual_ok & norm_ok & ordered
    flags = VerifyFlags(ok=ok, finite=finite, residual_ok=residual_ok,
                        norm_ok=norm_ok, ordered=ordered, residual=worst)
    if squeeze:
        flags = VerifyFlags(*(f[0] for f in flags))
    return flags
