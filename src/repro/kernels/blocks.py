"""Shared block-size clamping for the Pallas kernel wrappers.

Both kernel families take requested block shapes (defaults tuned for real
TPU tiles, possibly overridden by the autotune calibration table) and clamp
them to the *padded problem shape* before padding: a requested 128 tile on an
``n = 8`` problem must shrink to 8, not pad the operand 16x.  Clamping rounds
up to the hardware sublane/lane granule (8 for f32) so blocks stay aligned,
which bounds padding overhead at ``align - 1`` elements per axis instead of
``block - 1``.
"""

from __future__ import annotations


def pow2_bucket(x: int) -> int:
    """Smallest power of two ``>= x`` (``x >= 1``).

    Shape-bucketing helper shared by the serving runtime (stack sizes round
    to powers of two so a request stream executes through a bounded set of
    compiled programs) and the batch-axis block clamp below.
    """
    if x < 1:
        raise ValueError(f"bucket size must be >= 1, got {x}")
    return 1 << (x - 1).bit_length()


def clamp_batch_block(requested: int, b: int) -> int:
    """Batch-axis (``bb``) block near ``requested`` for a ``b``-row stack.

    The batch axis has no hardware granule (``align=1``) but a block that
    does not divide the padded stack wastes whole phantom matrices, so the
    clamp snaps to a power of two: the padded stack is then at most
    ``pow2_bucket(b)`` rows and every grid step is full.
    """
    clamped = clamp_block(requested, b, align=1)
    return min(pow2_bucket(clamped), pow2_bucket(b))


def clamp_block(requested: int, dim: int, align: int = 8) -> int:
    """Aligned block near ``requested`` that does not overshoot ``dim``.

    Returns ``min(requested, round_up(dim, align))`` rounded up to a multiple
    of ``align`` — the padded axis length is then ``round_up(dim, block)``,
    so a small problem pads by at most ``align - 1`` entries, never to a full
    default tile.  ``align=1`` disables alignment (batch-style axes where
    padded rows are pure waste).
    """
    if requested < 1:
        raise ValueError(f"block size must be >= 1, got {requested}")
    dim = max(dim, 1)
    rounded = -(-dim // align) * align
    clamped = min(requested, rounded)
    return max(align, -(-clamped // align) * align)


def pack_segments(
    lengths: list[int] | tuple[int, ...],
    row_width: int,
    max_slots: int,
    align: int = 8,
) -> list[list[tuple[int, int, int]]]:
    """First-fit pack of segment lengths into rows of ``row_width``.

    The packed-dispatch layout helper: each input length is rounded up to
    ``align`` (its *footprint* — segments must start on the hardware granule
    so block-aligned kernels see aligned offsets) and placed into the first
    open row with enough remaining width and a free slot.  Returns a list of
    rows, each a list of ``(index, offset, length)`` triples where ``index``
    is the position in ``lengths``, ``offset`` the aligned start column, and
    ``length`` the *unpadded* segment length (the aligned slack between
    ``offset + length`` and the next offset is guard territory).

    First-fit (rather than strict FIFO append) trades a bounded amount of
    reordering inside one packed stack — where all segments retire together
    anyway — for measurably fuller rows on mixed-size streams.
    """
    if row_width < align:
        raise ValueError(f"row_width {row_width} < align {align}")
    if max_slots < 1:
        raise ValueError(f"max_slots must be >= 1, got {max_slots}")
    rows: list[dict] = []
    for idx, length in enumerate(lengths):
        if length < 1:
            raise ValueError(f"segment length must be >= 1, got {length}")
        footprint = -(-length // align) * align
        if footprint > row_width:
            raise ValueError(
                f"segment length {length} (footprint {footprint}) exceeds "
                f"row width {row_width}")
        for row in rows:
            if row["used"] + footprint <= row_width and \
                    len(row["slots"]) < max_slots:
                row["slots"].append((idx, row["used"], length))
                row["used"] += footprint
                break
        else:
            rows.append({"used": footprint, "slots": [(idx, 0, length)]})
    return [row["slots"] for row in rows]
