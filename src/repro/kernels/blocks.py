"""Shared block-size clamping for the Pallas kernel wrappers.

Both kernel families take requested block shapes (defaults tuned for real
TPU tiles, possibly overridden by the autotune calibration table) and clamp
them to the *padded problem shape* before padding: a requested 128 tile on an
``n = 8`` problem must shrink to 8, not pad the operand 16x.  Clamping rounds
up to the hardware sublane/lane granule (8 for f32) so blocks stay aligned,
which bounds padding overhead at ``align - 1`` elements per axis instead of
``block - 1``.
"""

from __future__ import annotations


def clamp_block(requested: int, dim: int, align: int = 8) -> int:
    """Aligned block near ``requested`` that does not overshoot ``dim``.

    Returns ``min(requested, round_up(dim, align))`` rounded up to a multiple
    of ``align`` — the padded axis length is then ``round_up(dim, block)``,
    so a small problem pads by at most ``align - 1`` entries, never to a full
    default tile.  ``align=1`` disables alignment (batch-style axes where
    padded rows are pure waste).
    """
    if requested < 1:
        raise ValueError(f"block size must be >= 1, got {requested}")
    dim = max(dim, 1)
    rounded = -(-dim // align) * align
    clamped = min(requested, rounded)
    return max(align, -(-clamped // align) * align)
