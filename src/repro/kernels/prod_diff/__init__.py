from repro.kernels.prod_diff.ops import eei_magnitudes, logabs_sum  # noqa: F401
