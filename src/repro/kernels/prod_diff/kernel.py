"""Pallas TPU kernel: tiled log-space eigenvalue-difference products.

Computes ``out[b, i, j] = sum_k mask[k, j] * log|lam[b, i] - mu_t[b, k, j]|``
— the EEI numerator hot loop (O(b n^3) log-diff terms for full component
tables over a stack of ``b`` matrices).

Design (TPU-native re-think of the paper's "batched products"):

* **batch is a first-class grid axis**: one ``pallas_call`` covers the whole
  ``(b, n, n)`` stack with a 4-D ``(B/bb, I/bi, J/bj, K/bk)`` grid — no
  ``jax.vmap`` lifting, no per-matrix program launches, and the validity
  mask is *shared* across the batch (every matrix in a stack has the same
  shape) so it is fetched once per tile instead of once per matrix;
* **the batch axis itself is tiled** (``bb >= 1`` matrices per grid step):
  at very small ``n`` a single matrix's ``(bi, bj)`` tile underfills the
  8x128 VPU registers, so a batch tile stacks ``bb`` matrices into one
  rank-4 ``(bb, bi, chunk, bj)`` VPU op and recovers sublane occupancy;
  ``bb = 1`` reproduces the PR-2 one-matrix-per-step grid exactly;
* the paper's batch = our VMEM tile; per-batch partial ratios = per-tile
  partial log-sums accumulated across the ``k`` grid axis;
* log-space replaces the paper's ratio-pairing as the overflow fix, so tile
  shape is chosen purely for VMEM/VPU efficiency, not numerics;
* layout: ``i`` on sublanes, ``j`` on lanes, ``k`` swept inside the tile in
  sublane-sized chunks (a ``fori_loop`` of rank-3 ``(bi, 8, bj)`` VPU ops —
  8 mu rows per step instead of one, working set = one ``(bk, bj)`` mu tile
  + one chunk + one ``(bi, bj)`` accumulator);
* ``mu`` is passed transposed ``(K, J)`` so the lane dimension of every load
  matches the lane dimension of the output tile (no in-kernel transposes).

Grid: ``(B/bb, I/bi, J/bj, K/bk)`` with ``k`` innermost; the output block is
revisited across ``k`` steps and accumulated in place (initialized at
``k == 0``).  The legacy single-matrix 3-D grid (the PR-1 kernel this
replaces on the engine path) is kept as ``logabs_sum_padded`` — it is the
vmapped baseline the batched grid is benchmarked against
(``benchmarks/throughput.py``) and parity-tested against
(``tests/test_kernels.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: ``k`` rows consumed per inner-loop step (one f32 sublane granule).
K_CHUNK = 8


def _logabs_sum_batched_kernel(
    lam_ref, mut_ref, mask_ref, floor_ref, out_ref, *, block_k
):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lam = lam_ref[...]  # (bb, bi, 1)
    mut = mut_ref[...]  # (bb, bk, bj)
    mask = mask_ref[...]  # (bk, bj), shared across the batch axis
    floor = floor_ref[...]  # (bb, 1, 1) per-matrix gap clamp

    def body(c, acc):
        mu_c = jax.lax.dynamic_slice_in_dim(mut, c * K_CHUNK, K_CHUNK, axis=1)
        m_c = jax.lax.dynamic_slice_in_dim(mask, c * K_CHUNK, K_CHUNK, axis=0)
        # (bb, bi, K_CHUNK, bj): bb matrices advance in one VPU op.
        ad = jnp.abs(lam[:, :, :, None] - mu_c[:, None, :, :])
        ad = jnp.where(
            m_c[None, None, :, :] > 0,
            jnp.maximum(ad, floor[:, :, :, None]), 1.0)
        return acc + jnp.sum(jnp.log(ad), axis=2)

    acc = jax.lax.fori_loop(
        0, block_k // K_CHUNK, body, jnp.zeros(out_ref.shape, out_ref.dtype)
    )
    out_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_i", "block_j", "block_k", "interpret"),
)
def logabs_sum_batched_padded(
    lam_col: jax.Array,  # (B, I, 1), B % block_b == 0, I % block_i == 0
    mu_t: jax.Array,  # (B, K, J), K % block_k == 0, J % block_j == 0
    mask_t: jax.Array,  # (K, J) 1.0 valid / 0.0 padded — shared across B
    floor: jax.Array,  # (B, 1, 1) per-matrix gap clamp (1.0 on padded rows)
    *,
    block_b: int = 1,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Natively batched pallas_call on pre-padded operands (see ops)."""
    if block_k % K_CHUNK:
        raise ValueError(f"block_k must be a multiple of {K_CHUNK}, got {block_k}")
    b_total, i_total, _ = lam_col.shape
    k_total, j_total = mask_t.shape
    if b_total % block_b:
        raise ValueError(
            f"batch {b_total} not a multiple of block_b={block_b}")
    grid = (b_total // block_b, i_total // block_i, j_total // block_j,
            k_total // block_k)
    return pl.pallas_call(
        functools.partial(_logabs_sum_batched_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_i, 1), lambda b, i, j, k: (b, i, 0)),
            pl.BlockSpec(
                (block_b, block_k, block_j), lambda b, i, j, k: (b, k, j)),
            pl.BlockSpec((block_k, block_j), lambda b, i, j, k: (k, j)),
            pl.BlockSpec((block_b, 1, 1), lambda b, i, j, k: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, block_i, block_j), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b_total, i_total, j_total), lam_col.dtype),
        interpret=interpret,
    )(lam_col, mu_t, mask_t, floor)


def _logabs_sum_batched_masked_kernel(
    lam_ref, mut_ref, mask_ref, floor_ref, out_ref, *, block_k
):
    """Per-batch-mask twin of :func:`_logabs_sum_batched_kernel`.

    The mask tile carries a leading batch axis — each matrix in the stack
    masks its *own* ``(k, j)`` validity pattern.  This is the packed-dispatch
    plumbing: a segment-packed stack is ragged per row (each row's valid
    ``(j, k)`` region is its own segment layout), so the shared-mask
    assumption of the uniform bucketed path no longer holds.
    """
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lam = lam_ref[...]  # (bb, bi, 1)
    mut = mut_ref[...]  # (bb, bk, bj)
    mask = mask_ref[...]  # (bb, bk, bj) — per-matrix validity
    floor = floor_ref[...]  # (bb, 1, 1)

    def body(c, acc):
        mu_c = jax.lax.dynamic_slice_in_dim(mut, c * K_CHUNK, K_CHUNK, axis=1)
        m_c = jax.lax.dynamic_slice_in_dim(mask, c * K_CHUNK, K_CHUNK, axis=1)
        ad = jnp.abs(lam[:, :, :, None] - mu_c[:, None, :, :])
        ad = jnp.where(
            m_c[:, None, :, :] > 0,
            jnp.maximum(ad, floor[:, :, :, None]), 1.0)
        return acc + jnp.sum(jnp.log(ad), axis=2)

    acc = jax.lax.fori_loop(
        0, block_k // K_CHUNK, body, jnp.zeros(out_ref.shape, out_ref.dtype)
    )
    out_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_i", "block_j", "block_k", "interpret"),
)
def logabs_sum_batched_masked_padded(
    lam_col: jax.Array,  # (B, I, 1)
    mu_t: jax.Array,  # (B, K, J)
    mask_t: jax.Array,  # (B, K, J) 1.0 valid / 0.0 masked — per matrix
    floor: jax.Array,  # (B, 1, 1)
    *,
    block_b: int = 1,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Batched pallas_call with a per-matrix validity mask (see ops)."""
    if block_k % K_CHUNK:
        raise ValueError(f"block_k must be a multiple of {K_CHUNK}, got {block_k}")
    b_total, i_total, _ = lam_col.shape
    _, k_total, j_total = mask_t.shape
    if b_total % block_b:
        raise ValueError(
            f"batch {b_total} not a multiple of block_b={block_b}")
    grid = (b_total // block_b, i_total // block_i, j_total // block_j,
            k_total // block_k)
    return pl.pallas_call(
        functools.partial(_logabs_sum_batched_masked_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_i, 1), lambda b, i, j, k: (b, i, 0)),
            pl.BlockSpec(
                (block_b, block_k, block_j), lambda b, i, j, k: (b, k, j)),
            pl.BlockSpec(
                (block_b, block_k, block_j), lambda b, i, j, k: (b, k, j)),
            pl.BlockSpec((block_b, 1, 1), lambda b, i, j, k: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, block_i, block_j), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((b_total, i_total, j_total), lam_col.dtype),
        interpret=interpret,
    )(lam_col, mu_t, mask_t, floor)


# ---------------------------------------------------------------------------
# Legacy single-matrix 3-D grid (PR-1) — kept as the vmapped baseline.
# ---------------------------------------------------------------------------


def _logabs_sum_kernel(lam_ref, mut_ref, mask_ref, floor_ref, out_ref, *, block_k):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lam = lam_ref[...]  # (bi, 1) sublane vector
    mut = mut_ref[...]  # (bk, bj)
    mask = mask_ref[...]  # (bk, bj)
    floor = floor_ref[0, 0]

    def body(kk, acc):
        mu_row = jax.lax.dynamic_slice_in_dim(mut, kk, 1, axis=0)  # (1, bj)
        m_row = jax.lax.dynamic_slice_in_dim(mask, kk, 1, axis=0)  # (1, bj)
        ad = jnp.abs(lam - mu_row)  # (bi, bj)
        ad = jnp.where(m_row > 0, jnp.maximum(ad, floor), 1.0)
        return acc + jnp.log(ad)

    acc = jax.lax.fori_loop(0, block_k, body, jnp.zeros_like(out_ref[...]))
    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_j", "block_k", "interpret")
)
def logabs_sum_padded(
    lam_col: jax.Array,  # (I, 1), I % block_i == 0
    mu_t: jax.Array,  # (K, J), K % block_k == 0, J % block_j == 0
    mask_t: jax.Array,  # (K, J) 1.0 valid / 0.0 padded
    floor: jax.Array,  # (1, 1) gap clamp
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Legacy per-matrix pallas_call on pre-padded operands (see ops)."""
    i_total, _ = lam_col.shape
    k_total, j_total = mu_t.shape
    grid = (i_total // block_i, j_total // block_j, k_total // block_k)
    return pl.pallas_call(
        functools.partial(_logabs_sum_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_k, block_j), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k, block_j), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((i_total, j_total), lam_col.dtype),
        interpret=interpret,
    )(lam_col, mu_t, mask_t, floor)
