"""Pallas TPU kernel: tiled log-space eigenvalue-difference products.

Computes ``out[i, j] = sum_k mask[k, j] * log|lam[i] - mu_t[k, j]|`` — the
EEI numerator hot loop (O(n^3) log-diff terms for a full component table).

Design (TPU-native re-think of the paper's "batched products"):

* the paper's batch = our VMEM tile; per-batch partial ratios = per-tile
  partial log-sums accumulated across the ``k`` grid axis;
* log-space replaces the paper's ratio-pairing as the overflow fix, so tile
  shape is chosen purely for VMEM/VPU efficiency, not numerics;
* layout: ``i`` on sublanes, ``j`` on lanes, ``k`` sequential inside the tile
  (a ``fori_loop`` of rank-2 VPU ops — no rank-3 intermediate, working set =
  one ``(bk, bj)`` mu tile + one ``(bi, bj)`` accumulator);
* ``mu`` is passed transposed ``(K, J)`` so the lane dimension of every load
  matches the lane dimension of the output tile (no in-kernel transposes).

Grid: ``(I/bi, J/bj, K/bk)`` with ``k`` innermost; the output block is
revisited across ``k`` steps and accumulated in place (initialized at
``k == 0``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logabs_sum_kernel(lam_ref, mut_ref, mask_ref, floor_ref, out_ref, *, block_k):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lam = lam_ref[...]  # (bi, 1) sublane vector
    mut = mut_ref[...]  # (bk, bj)
    mask = mask_ref[...]  # (bk, bj)
    floor = floor_ref[0, 0]

    def body(kk, acc):
        mu_row = jax.lax.dynamic_slice_in_dim(mut, kk, 1, axis=0)  # (1, bj)
        m_row = jax.lax.dynamic_slice_in_dim(mask, kk, 1, axis=0)  # (1, bj)
        ad = jnp.abs(lam - mu_row)  # (bi, bj)
        ad = jnp.where(m_row > 0, jnp.maximum(ad, floor), 1.0)
        return acc + jnp.log(ad)

    acc = jax.lax.fori_loop(0, block_k, body, jnp.zeros_like(out_ref[...]))
    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_j", "block_k", "interpret")
)
def logabs_sum_padded(
    lam_col: jax.Array,  # (I, 1), I % block_i == 0
    mu_t: jax.Array,  # (K, J), K % block_k == 0, J % block_j == 0
    mask_t: jax.Array,  # (K, J) 1.0 valid / 0.0 padded
    floor: jax.Array,  # (1, 1) gap clamp
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Core pallas_call on pre-padded operands (see ops.logabs_sum)."""
    i_total, _ = lam_col.shape
    k_total, j_total = mu_t.shape
    grid = (i_total // block_i, j_total // block_j, k_total // block_k)
    return pl.pallas_call(
        functools.partial(_logabs_sum_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_k, block_j), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k, block_j), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((i_total, j_total), lam_col.dtype),
        interpret=interpret,
    )(lam_col, mu_t, mask_t, floor)
