"""Jit'd public wrappers for the prod_diff kernel (padding, masking, EEI)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.prod_diff import kernel as _kernel


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode everywhere but real TPU."""
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_j", "block_k", "interpret")
)
def logabs_sum(
    lam: jax.Array,  # (I,)
    mu: jax.Array,  # (J, K)
    floor: jax.Array | float,
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """``out[i, j] = sum_k log(max(|lam[i] - mu[j, k]|, floor))`` via Pallas."""
    if interpret is None:
        interpret = default_interpret()
    i_n = lam.shape[0]
    j_n, k_n = mu.shape
    block_i = min(block_i, max(8, i_n))
    block_j = min(block_j, max(8, j_n))
    block_k = min(block_k, max(8, k_n))
    lam_col = _pad_to(lam[:, None], 0, block_i)
    mask = jnp.ones((j_n, k_n), lam.dtype)
    mu_p = _pad_to(_pad_to(mu, 0, block_j), 1, block_k)
    mask_p = _pad_to(_pad_to(mask, 0, block_j), 1, block_k)
    floor_arr = jnp.asarray(floor, lam.dtype).reshape(1, 1)
    out = _kernel.logabs_sum_padded(
        lam_col,
        jnp.swapaxes(mu_p, 0, 1),
        jnp.swapaxes(mask_p, 0, 1),
        floor_arr,
        block_i=block_i,
        block_j=block_j,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:i_n, :j_n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def eei_magnitudes(
    lam: jax.Array, mu: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """All ``|v[i, j]|^2`` from spectra; numerator table via the kernel.

    lam: (n,) matrix spectrum (ascending); mu: (n, n-1) minor spectra.
    The O(n^2) denominator stays in jnp — it is not a hot spot.
    """
    n = lam.shape[0]
    eps = jnp.finfo(lam.dtype).eps
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    floor = eps * scale
    log_num = logabs_sum(lam, mu, floor, interpret=interpret)
    diff = jnp.abs(lam[:, None] - lam[None, :])
    diff = jnp.where(jnp.eye(n, dtype=bool), 1.0, jnp.maximum(diff, floor))
    log_den = jnp.sum(jnp.log(diff), axis=-1)
    return jnp.exp(log_num - log_den[:, None])
