"""Jit'd public wrappers for the prod_diff kernel (padding, masking, EEI).

Two entry tiers:

* ``logabs_sum_batched`` / ``eei_magnitudes_batched`` — the engine path: one
  natively batched pallas_call over a ``(b, ...)`` stack (4-D kernel grid,
  batch leading).
* ``logabs_sum`` / ``eei_magnitudes`` — single-matrix convenience wrappers
  over the legacy 3-D grid, kept as the vmapped PR-1 baseline that the
  batched grid is benchmarked and parity-tested against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blocks
from repro.kernels.prod_diff import kernel as _kernel


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode everywhere but real TPU."""
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_i", "block_j", "block_k", "interpret"),
)
def logabs_sum_batched(
    lam: jax.Array,  # (B, I)
    mu: jax.Array,  # (B, J, K)
    floor: jax.Array | float,  # scalar or (B,)
    *,
    mask: jax.Array | None = None,  # (B, J, K) 1.0 valid / 0.0 masked
    block_b: int = 1,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """``out[b, i, j] = sum_k log(max(|lam[b, i] - mu[b, j, k]|, floor[b]))``.

    One pallas_call for the whole stack: batch rides the leading grid axis
    (``block_b`` matrices per grid step — b-tiling recovers sublane occupancy
    at very small ``n``), the padding mask is shared across matrices, and
    blocks are clamped to the padded problem shape (no 128x padding for small
    ``n``).  Batch-padded rows get ``lam = mu = 0`` and ``floor = 1`` so they
    contribute exact zeros instead of ``log(0)``; they are sliced off before
    returning.

    ``mask`` switches to the per-matrix-mask kernel variant: each matrix
    masks its own ``(j, k)`` validity region (masked cells contribute exact
    zeros).  This is the segment plumbing for packed ragged stacks, where
    every row's valid region is its own segment layout rather than the
    uniform bucket shape; ``mask=None`` keeps the shared-mask grid (and its
    once-per-tile mask fetch) bitwise-unchanged.
    """
    if interpret is None:
        interpret = default_interpret()
    b_n, i_n = lam.shape
    _, j_n, k_n = mu.shape
    block_b = blocks.clamp_batch_block(block_b, b_n)
    block_i = blocks.clamp_block(block_i, i_n)
    block_j = blocks.clamp_block(block_j, j_n)
    block_k = blocks.clamp_block(block_k, k_n, align=_kernel.K_CHUNK)
    lam_col = _pad_to(_pad_to(lam[:, :, None], 1, block_i), 0, block_b)
    mu_p = _pad_to(
        _pad_to(_pad_to(mu, 1, block_j), 2, block_k), 0, block_b)
    floor_arr = (jnp.zeros((b_n,), lam.dtype) + jnp.asarray(floor, lam.dtype))
    floor_arr = _pad_to(floor_arr, 0, block_b, value=1.0)
    if mask is not None:
        mask_p = _pad_to(
            _pad_to(_pad_to(mask.astype(lam.dtype), 1, block_j), 2, block_k),
            0, block_b)
        out = _kernel.logabs_sum_batched_masked_padded(
            lam_col,
            jnp.swapaxes(mu_p, 1, 2),
            jnp.swapaxes(mask_p, 1, 2),
            floor_arr.reshape(-1, 1, 1),
            block_b=block_b,
            block_i=block_i,
            block_j=block_j,
            block_k=block_k,
            interpret=interpret,
        )
        return out[:b_n, :i_n, :j_n]
    mask_p = _pad_to(
        _pad_to(jnp.ones((j_n, k_n), lam.dtype), 0, block_j), 1, block_k
    )
    out = _kernel.logabs_sum_batched_padded(
        lam_col,
        jnp.swapaxes(mu_p, 1, 2),
        jnp.swapaxes(mask_p, 0, 1),
        floor_arr.reshape(-1, 1, 1),
        block_b=block_b,
        block_i=block_i,
        block_j=block_j,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:b_n, :i_n, :j_n]


def _floor_from_spectra(lam: jax.Array) -> jax.Array:
    """Per-matrix gap clamp: ``eps * spectral scale`` (lam ascending)."""
    eps = jnp.finfo(lam.dtype).eps
    scale = jnp.maximum(jnp.abs(lam[..., -1]), jnp.abs(lam[..., 0])) + 1e-30
    return eps * scale


def _log_denominator(lam: jax.Array, floor: jax.Array,
                     idx: jax.Array | None = None) -> jax.Array:
    """Cauchy log-denominator rows ``sum_k log max(|lam_i - lam_k|, floor)``
    (diagonal excluded), ``(B, n)`` — or only the ``idx`` rows ``(B, k)``.

    Rows are elementwise independent, so the windowed form is bitwise-equal
    to slicing the full table; one implementation serves both entry points
    so the windowed-equals-full contract cannot drift.
    """
    n = lam.shape[-1]
    lam_rows = lam if idx is None else lam[:, idx]
    eye = jnp.eye(n, dtype=bool)
    if idx is not None:
        eye = eye[idx]
    diff = jnp.abs(lam_rows[:, :, None] - lam[:, None, :])
    diff = jnp.where(eye, 1.0, jnp.maximum(diff, floor[:, None, None]))
    return jnp.sum(jnp.log(diff), axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_i", "block_j", "block_k", "interpret"),
)
def eei_magnitudes_batched(
    lam: jax.Array,  # (B, n) matrix spectra (ascending)
    mu: jax.Array,  # (B, n, n-1) minor spectra
    *,
    block_b: int = 1,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """All ``|v[b, i, j]|^2`` for a stack, numerator table via one kernel.

    The O(b n^2) denominator stays in jnp — it is not a hot spot.
    """
    floor = _floor_from_spectra(lam)  # (B,)
    log_num = logabs_sum_batched(
        lam, mu, floor,
        block_b=block_b, block_i=block_i, block_j=block_j, block_k=block_k,
        interpret=interpret,
    )
    log_den = _log_denominator(lam, floor)
    return jnp.exp(log_num - log_den[:, :, None])


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_i", "block_j", "block_k", "interpret"),
)
def eei_magnitudes_windowed(
    lam: jax.Array,  # (B, n) matrix spectra (ascending)
    mu: jax.Array,  # (B, n, n-1) minor spectra
    idx: jax.Array,  # (k,) selected eigenvalue rows, shared across the stack
    *,
    block_b: int = 1,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Selected rows ``|v[b, idx, j]|^2`` only — the windowed kernel path.

    The batched grid's I axis shrinks from ``n`` to ``k``: the kernel sees
    a ``(B, k)`` selected-``lam`` gather, so the numerator stage costs
    O(n^2 k) log-diff terms per matrix instead of O(n^3).  The gap floor
    and the O(n^2) jnp denominator are computed from the *full* spectrum
    exactly as :func:`eei_magnitudes_batched` computes them and row-sliced,
    and the kernel's k-sweep accumulation order does not depend on the I
    extent — the ``(B, k, n)`` result is bitwise-equal to the matching
    rows of the full table.
    """
    floor = _floor_from_spectra(lam)  # (B,) — full-spectrum extremes
    lam_sel = lam[:, idx]  # (B, k)
    log_num = logabs_sum_batched(
        lam_sel, mu, floor,
        block_b=block_b, block_i=block_i, block_j=block_j, block_k=block_k,
        interpret=interpret,
    )
    log_den = _log_denominator(lam, floor, idx)  # only the k window's rows
    return jnp.exp(log_num - log_den[:, :, None])


# ---------------------------------------------------------------------------
# Single-matrix wrappers over the legacy 3-D grid (vmapped PR-1 baseline).
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("block_i", "block_j", "block_k", "interpret")
)
def logabs_sum(
    lam: jax.Array,  # (I,)
    mu: jax.Array,  # (J, K)
    floor: jax.Array | float,
    *,
    block_i: int = 128,
    block_j: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """``out[i, j] = sum_k log(max(|lam[i] - mu[j, k]|, floor))`` via Pallas."""
    if interpret is None:
        interpret = default_interpret()
    i_n = lam.shape[0]
    j_n, k_n = mu.shape
    block_i = blocks.clamp_block(block_i, i_n)
    block_j = blocks.clamp_block(block_j, j_n)
    block_k = blocks.clamp_block(block_k, k_n)
    lam_col = _pad_to(lam[:, None], 0, block_i)
    mask = jnp.ones((j_n, k_n), lam.dtype)
    mu_p = _pad_to(_pad_to(mu, 0, block_j), 1, block_k)
    mask_p = _pad_to(_pad_to(mask, 0, block_j), 1, block_k)
    floor_arr = jnp.asarray(floor, lam.dtype).reshape(1, 1)
    out = _kernel.logabs_sum_padded(
        lam_col,
        jnp.swapaxes(mu_p, 0, 1),
        jnp.swapaxes(mask_p, 0, 1),
        floor_arr,
        block_i=block_i,
        block_j=block_j,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:i_n, :j_n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def eei_magnitudes(
    lam: jax.Array, mu: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """All ``|v[i, j]|^2`` from one matrix's spectra (legacy 3-D grid).

    lam: (n,) matrix spectrum (ascending); mu: (n, n-1) minor spectra.
    ``jax.vmap`` of this function is the PR-1 baseline the natively batched
    ``eei_magnitudes_batched`` replaces on the engine path.
    """
    n = lam.shape[0]
    floor = _floor_from_spectra(lam)
    log_num = logabs_sum(lam, mu, floor, interpret=interpret)
    diff = jnp.abs(lam[:, None] - lam[None, :])
    diff = jnp.where(jnp.eye(n, dtype=bool), 1.0, jnp.maximum(diff, floor))
    log_den = jnp.sum(jnp.log(diff), axis=-1)
    return jnp.exp(log_num - log_den[:, None])
