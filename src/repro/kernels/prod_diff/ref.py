"""Pure-jnp oracle for the prod_diff kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def logabs_sum(lam: jax.Array, mu: jax.Array, floor: float | jax.Array) -> jax.Array:
    """``out[i, j] = sum_k log(max(|lam[i] - mu[j, k]|, floor))``.

    lam: (I,), mu: (J, K) -> (I, J).
    """
    ad = jnp.abs(lam[:, None, None] - mu[None, :, :])
    return jnp.sum(jnp.log(jnp.maximum(ad, floor)), axis=-1)


def eei_magnitudes(lam: jax.Array, mu: jax.Array, floor_eps: float | None = None):
    """All ``|v[i, j]|^2`` from spectra (logspace); lam (n,), mu (n, n-1)."""
    n = lam.shape[0]
    if floor_eps is None:
        floor_eps = float(jnp.finfo(lam.dtype).eps)
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    floor = floor_eps * scale
    log_num = logabs_sum(lam, mu, floor)
    diff = jnp.abs(lam[:, None] - lam[None, :])
    diff = jnp.where(jnp.eye(n, dtype=bool), 1.0, jnp.maximum(diff, floor))
    log_den = jnp.sum(jnp.log(diff), axis=-1)
    return jnp.exp(log_num - log_den[:, None])
