from repro.kernels.sturm.ops import sturm_eigenvalues  # noqa: F401
