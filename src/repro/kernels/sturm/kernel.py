"""Pallas TPU kernel: batched Sturm-sequence bisection eigenvalues.

One program instance computes a ``(bb, bm)`` tile of eigenvalues — ``bb``
tridiagonal matrices on sublanes, ``bm`` eigenvalue indices on lanes.  Every
bisection iteration runs the Sturm recurrence sequentially over the matrix
dimension ``N`` (a ``fori_loop`` of rank-2 VPU ops) with all ``bb * bm``
bisection brackets advancing in lockstep — bisection is branch-free, so the
"divide" of divide-&-conquer becomes pure lane parallelism, which is the TPU
adaptation of LAPACK's recursion (see DESIGN.md §2).

Inputs are pre-padded by ``ops.py``:
  d      (B, N)   diagonals (padded rows = 0)
  e      (B, N)   off-diagonals, entry N-1 (and padding) = 0
  bounds (B, 4)   [lo, hi, pivmin, n_valid] per matrix; padded eigenvalue
                  indices (>= n_valid) converge onto ``hi`` and are sliced
                  off by the wrapper.

The full ``(bb, N)`` band rows live in VMEM (N f32 pairs: N=8192 -> 64 KiB
per row-block at bb=8), well inside the ~16 MiB budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sturm_kernel(d_ref, e_ref, bounds_ref, out_ref, *, n_iter, block_m, n_total,
                  target_base):
    d = d_ref[...]  # (bb, N)
    e = e_ref[...]  # (bb, N)
    e2 = e * e
    lo0 = bounds_ref[:, 0:1]  # (bb, 1)
    hi0 = bounds_ref[:, 1:2]
    pivmin = bounds_ref[:, 2:3]

    # ``target_base`` windows the eigenvalue-index axis: lane ``m`` of grid
    # step ``g`` brackets index ``target_base + g * block_m + m``.  The full
    # spectrum is ``target_base = 0`` with the grid spanning all n indices; a
    # top-k window starts the grid at ``n - k`` — bisection lanes are
    # independent, so a windowed lane is bitwise-equal to the same lane of a
    # full-spectrum run.
    m0 = target_base + pl.program_id(1) * block_m
    targets = m0 + jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)  # (1, bm)

    bb = d.shape[0]
    lo = jnp.broadcast_to(lo0, (bb, block_m))
    hi = jnp.broadcast_to(hi0, (bb, block_m))

    def count_below(x):
        """#eigenvalues < x per (matrix, lane); x: (bb, bm)."""
        q0 = jax.lax.dynamic_slice_in_dim(d, 0, 1, axis=1) - x  # (bb, bm)
        q0 = jnp.where(jnp.abs(q0) < pivmin, -pivmin, q0)
        c0 = (q0 < 0).astype(jnp.int32)

        def body(k, carry):
            q, c = carry
            dk = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (bb, 1)
            e2k = jax.lax.dynamic_slice_in_dim(e2, k - 1, 1, axis=1)  # (bb, 1)
            q = dk - x - e2k / q
            q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
            return q, c + (q < 0).astype(jnp.int32)

        _, c = jax.lax.fori_loop(1, n_total, body, (q0, c0))
        return c

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        c = count_below(mid)
        go_right = c <= targets
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iter, bisect, (lo, hi))
    out_ref[...] = 0.5 * (lo + hi)


def _sturm_segmented_kernel(d_ref, e_ref, lo_ref, hi_ref, piv_ref,
                            start_ref, end_ref, targ_ref, out_ref, *,
                            n_iter, n_total):
    """Per-segment windowed bisection over packed block-diagonal bands.

    Lane arrays replace the per-matrix bounds row: every lane carries its own
    bracket ``[lo, hi]``, ``pivmin``, segment window ``[start, end)`` and
    eigenvalue-index target.  The Sturm recurrence still runs over the whole
    packed band — junction off-diagonals are exactly zero in the packed
    layout, so ``q`` restarts by itself (``e2/q = 0``) — but the *count* is
    masked to the lane's segment, making each lane bracket eigenvalue
    ``target`` of its own diagonal block and nothing else.
    """
    d = d_ref[...]  # (bb, N)
    e = e_ref[...]  # (bb, N)
    e2 = e * e
    lo = lo_ref[...]  # (bb, bm)
    hi = hi_ref[...]
    pivmin = piv_ref[...]
    start = start_ref[...]  # (bb, bm) int32
    end = end_ref[...]
    targets = targ_ref[...]

    def count_below(x):
        """#eigenvalues of the lane's segment < x; x: (bb, bm)."""
        q0 = jax.lax.dynamic_slice_in_dim(d, 0, 1, axis=1) - x  # (bb, bm)
        q0 = jnp.where(jnp.abs(q0) < pivmin, -pivmin, q0)
        c0 = ((q0 < 0) & (start <= 0) & (end > 0)).astype(jnp.int32)

        def body(k, carry):
            q, c = carry
            dk = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (bb, 1)
            e2k = jax.lax.dynamic_slice_in_dim(e2, k - 1, 1, axis=1)
            q = dk - x - e2k / q
            q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
            in_seg = (start <= k) & (k < end)
            return q, c + ((q < 0) & in_seg).astype(jnp.int32)

        _, c = jax.lax.fori_loop(1, n_total, body, (q0, c0))
        return c

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        c = count_below(mid)
        go_right = c <= targets
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iter, bisect, (lo, hi))
    out_ref[...] = 0.5 * (lo + hi)


@functools.partial(
    jax.jit,
    static_argnames=("n_iter", "block_b", "block_m", "interpret"),
)
def sturm_segmented_padded(
    d: jax.Array,  # (B, N)
    e: jax.Array,  # (B, N)
    lo: jax.Array,  # (B, M) f32 lane brackets
    hi: jax.Array,  # (B, M)
    pivmin: jax.Array,  # (B, M)
    start: jax.Array,  # (B, M) int32 segment start (inclusive)
    end: jax.Array,  # (B, M) int32 segment end (exclusive)
    targets: jax.Array,  # (B, M) int32 per-segment eigenvalue index
    *,
    n_iter: int,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool = False,
):
    """Tiled segment-masked bisection: lane ``(b, m)`` brackets eigenvalue
    ``targets[b, m]`` of the diagonal block ``[start, end)`` of band row
    ``b``.  All operands pre-padded to block multiples by ``ops.py``."""
    b_total, n_total = d.shape
    m_total = targets.shape[1]
    grid = (b_total // block_b, m_total // block_m)
    lane = pl.BlockSpec((block_b, block_m), lambda b, m: (b, m))
    return pl.pallas_call(
        functools.partial(
            _sturm_segmented_kernel, n_iter=n_iter, n_total=n_total),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_total), lambda b, m: (b, 0)),
            pl.BlockSpec((block_b, n_total), lambda b, m: (b, 0)),
            lane, lane, lane, lane, lane, lane,
        ],
        out_specs=lane,
        out_shape=jax.ShapeDtypeStruct((b_total, m_total), d.dtype),
        interpret=interpret,
    )(d, e, lo, hi, pivmin, start, end, targets)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_iter", "block_b", "block_m", "interpret", "m_total", "target_base"),
)
def sturm_padded(
    d: jax.Array,  # (B, N)
    e: jax.Array,  # (B, N)
    bounds: jax.Array,  # (B, 4)
    *,
    n_iter: int,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool = False,
    m_total: int | None = None,
    target_base: int = 0,
):
    """Tiled bisection over ``m_total`` eigenvalue indices starting at
    ``target_base`` (defaults: the full spectrum — every band index)."""
    b_total, n_total = d.shape
    if m_total is None:
        m_total = n_total
    grid = (b_total // block_b, m_total // block_m)
    return pl.pallas_call(
        functools.partial(
            _sturm_kernel, n_iter=n_iter, block_m=block_m, n_total=n_total,
            target_base=target_base,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_total), lambda b, m: (b, 0)),
            pl.BlockSpec((block_b, n_total), lambda b, m: (b, 0)),
            pl.BlockSpec((block_b, 4), lambda b, m: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda b, m: (b, m)),
        out_shape=jax.ShapeDtypeStruct((b_total, m_total), d.dtype),
        interpret=interpret,
    )(d, e, bounds)
