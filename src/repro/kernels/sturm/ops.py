"""Jit'd public wrappers for the sturm kernel (bounds, padding, slicing).

``sturm_eigenvalues`` runs one tiled program over a ``(B, n)`` stack of
tridiagonal bands; ``sturm_minor_spectra`` is the stacked-minor-band layout —
all ``b * n`` minor bisection problems of a ``(b, n)`` batch flattened onto
the kernel's row axis so the whole stack is one pallas_call, not ``b``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blocks
from repro.kernels.sturm import kernel as _kernel


def _default_iters(dtype) -> int:
    return 64 if dtype == jnp.float64 else 32


@functools.partial(
    jax.jit,
    static_argnames=("n_iter", "block_b", "block_m", "interpret", "window"),
)
def sturm_eigenvalues(
    d: jax.Array,  # (B, n)
    e: jax.Array,  # (B, n-1)
    *,
    n_iter: int = 0,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool | None = None,
    window: tuple | None = None,
) -> jax.Array:
    """All eigenvalues of ``B`` symmetric tridiagonal matrices, ``(B, n)``.

    Decoupled systems (zero off-diagonal entries, e.g. EEI minors of a
    tridiagonal matrix) need no special handling — the Sturm count is exact
    across decoupling points.

    ``window=(k, largest)`` restricts the eigenvalue-index grid to the ``k``
    extremal indices (the counting function brackets eigenvalues *by
    index*, so a partial-spectrum query runs ``k`` bisection lanes instead
    of ``n``); the returned ``(B, k)`` window is ascending and
    bitwise-equal to the matching slice of the full-spectrum result
    (bisection lanes are independent).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b_n, n = d.shape
    dtype = d.dtype
    if n_iter == 0:
        n_iter = _default_iters(dtype)
    m_targets = n
    target_base = 0
    if window is not None:
        k_w, largest = int(window[0]), bool(window[1])
        if not 1 <= k_w <= n:
            raise ValueError(f"window k={k_w} out of range for n={n}")
        m_targets = k_w
        target_base = n - k_w if largest else 0

    # Per-matrix Gershgorin bounds + pivmin (computed on unpadded bands).
    abs_e = jnp.abs(e)
    r = jnp.zeros_like(d)
    if n > 1:
        r = r.at[:, :-1].add(abs_e)
        r = r.at[:, 1:].add(abs_e)
    lo = jnp.min(d - r, axis=1)
    hi = jnp.max(d + r, axis=1)
    span = jnp.maximum(hi - lo, 1.0)
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    lo = lo - eps * span
    hi = hi + eps * span
    scale = jnp.maximum(
        jnp.max(jnp.abs(d), axis=1),
        jnp.max(abs_e, axis=1) if n > 1 else jnp.zeros((b_n,), dtype),
    )
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    pivmin = jnp.maximum(eps * eps * scale * scale, tiny)
    bounds = jnp.stack([lo, hi, pivmin, jnp.full((b_n,), n, dtype)], axis=1)

    # Clamp blocks to the padded problem shape: a 128-lane tile on an n=8
    # problem must shrink to 8, not pad the band 16x (align 8 keeps lanes
    # aligned; the batch axis clamps unaligned — padded rows are pure waste).
    # The eigenvalue-index (target) axis and the band axis coincide only for
    # full-spectrum runs: a window tiles k target lanes over the full band.
    block_m = blocks.clamp_block(block_m, m_targets)
    block_b = blocks.clamp_block(block_b, b_n, align=1)
    pad_m = (-m_targets) % block_m
    pad_n = (-n) % block_m if window is None else (-n) % 8
    pad_b = (-b_n) % block_b
    # Padded diagonal entries sit above hi (decoupled via zero e), so padded
    # eigenvalue indices converge onto hi and are sliced off below.
    d_p = jnp.pad(d, ((0, pad_b), (0, pad_n)), constant_values=0.0)
    if pad_n or n >= 1:
        big = (jnp.abs(hi) + span)[:, None]
        col = jnp.arange(n + pad_n)[None, :]
        d_p = jnp.where(
            col >= n, jnp.pad(big, ((0, pad_b), (0, 0)), constant_values=1.0), d_p
        )
    e_p = jnp.zeros_like(d_p)
    if n > 1:
        e_p = e_p.at[:b_n, : n - 1].set(e)
    bounds_p = jnp.pad(bounds, ((0, pad_b), (0, 0)), constant_values=1.0)

    out = _kernel.sturm_padded(
        d_p,
        e_p,
        bounds_p,
        n_iter=n_iter,
        block_b=block_b,
        block_m=block_m,
        interpret=interpret,
        m_total=m_targets + pad_m,
        target_base=target_base,
    )
    return out[:b_n, :m_targets]


@functools.partial(
    jax.jit, static_argnames=("n_iter", "block_b", "block_m", "interpret")
)
def sturm_minor_spectra(
    dm: jax.Array,  # (b, n, m) stacked minor diagonals
    em: jax.Array,  # (b, n, m-1) stacked minor off-diagonals
    *,
    n_iter: int = 0,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Spectra of all ``b * n`` stacked minor bands as one tiled program.

    The stacked-minor-band layout: the ``(b, n)`` leading axes flatten onto
    the kernel's row (sublane) axis, so every bisection problem in the whole
    batch advances in lockstep inside a single pallas_call — per-program
    launch overhead is amortized across the stack instead of paid ``b``
    times.  Returns ``(b, n, m)``.
    """
    b_n, n, m = dm.shape
    mu = sturm_eigenvalues(
        dm.reshape(b_n * n, m),
        em.reshape(b_n * n, m - 1),
        n_iter=n_iter,
        block_b=block_b,
        block_m=block_m,
        interpret=interpret,
    )
    return mu.reshape(b_n, n, m)
