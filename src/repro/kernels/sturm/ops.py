"""Jit'd public wrappers for the sturm kernel (bounds, padding, slicing).

``sturm_eigenvalues`` runs one tiled program over a ``(B, n)`` stack of
tridiagonal bands; ``sturm_minor_spectra`` is the stacked-minor-band layout —
all ``b * n`` minor bisection problems of a ``(b, n)`` batch flattened onto
the kernel's row axis so the whole stack is one pallas_call, not ``b``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blocks
from repro.kernels.sturm import kernel as _kernel


def _default_iters(dtype) -> int:
    return 64 if dtype == jnp.float64 else 32


@functools.partial(
    jax.jit,
    static_argnames=("n_iter", "block_b", "block_m", "interpret", "window"),
)
def sturm_eigenvalues(
    d: jax.Array,  # (B, n)
    e: jax.Array,  # (B, n-1)
    *,
    n_iter: int = 0,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool | None = None,
    window: tuple | None = None,
) -> jax.Array:
    """All eigenvalues of ``B`` symmetric tridiagonal matrices, ``(B, n)``.

    Decoupled systems (zero off-diagonal entries, e.g. EEI minors of a
    tridiagonal matrix) need no special handling — the Sturm count is exact
    across decoupling points.

    ``window=(k, largest)`` restricts the eigenvalue-index grid to the ``k``
    extremal indices (the counting function brackets eigenvalues *by
    index*, so a partial-spectrum query runs ``k`` bisection lanes instead
    of ``n``); the returned ``(B, k)`` window is ascending and
    bitwise-equal to the matching slice of the full-spectrum result
    (bisection lanes are independent).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b_n, n = d.shape
    dtype = d.dtype
    if n_iter == 0:
        n_iter = _default_iters(dtype)
    m_targets = n
    target_base = 0
    if window is not None:
        k_w, largest = int(window[0]), bool(window[1])
        if not 1 <= k_w <= n:
            raise ValueError(f"window k={k_w} out of range for n={n}")
        m_targets = k_w
        target_base = n - k_w if largest else 0

    # Per-matrix Gershgorin bounds + pivmin (computed on unpadded bands).
    abs_e = jnp.abs(e)
    r = jnp.zeros_like(d)
    if n > 1:
        r = r.at[:, :-1].add(abs_e)
        r = r.at[:, 1:].add(abs_e)
    lo = jnp.min(d - r, axis=1)
    hi = jnp.max(d + r, axis=1)
    span = jnp.maximum(hi - lo, 1.0)
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    lo = lo - eps * span
    hi = hi + eps * span
    scale = jnp.maximum(
        jnp.max(jnp.abs(d), axis=1),
        jnp.max(abs_e, axis=1) if n > 1 else jnp.zeros((b_n,), dtype),
    )
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    pivmin = jnp.maximum(eps * eps * scale * scale, tiny)
    bounds = jnp.stack([lo, hi, pivmin, jnp.full((b_n,), n, dtype)], axis=1)

    # Clamp blocks to the padded problem shape: a 128-lane tile on an n=8
    # problem must shrink to 8, not pad the band 16x (align 8 keeps lanes
    # aligned; the batch axis clamps unaligned — padded rows are pure waste).
    # The eigenvalue-index (target) axis and the band axis coincide only for
    # full-spectrum runs: a window tiles k target lanes over the full band.
    block_m = blocks.clamp_block(block_m, m_targets)
    block_b = blocks.clamp_block(block_b, b_n, align=1)
    pad_m = (-m_targets) % block_m
    pad_n = (-n) % block_m if window is None else (-n) % 8
    pad_b = (-b_n) % block_b
    # Padded diagonal entries sit above hi (decoupled via zero e), so padded
    # eigenvalue indices converge onto hi and are sliced off below.
    d_p = jnp.pad(d, ((0, pad_b), (0, pad_n)), constant_values=0.0)
    if pad_n or n >= 1:
        big = (jnp.abs(hi) + span)[:, None]
        col = jnp.arange(n + pad_n)[None, :]
        d_p = jnp.where(
            col >= n, jnp.pad(big, ((0, pad_b), (0, 0)), constant_values=1.0), d_p
        )
    e_p = jnp.zeros_like(d_p)
    if n > 1:
        e_p = e_p.at[:b_n, : n - 1].set(e)
    bounds_p = jnp.pad(bounds, ((0, pad_b), (0, 0)), constant_values=1.0)

    out = _kernel.sturm_padded(
        d_p,
        e_p,
        bounds_p,
        n_iter=n_iter,
        block_b=block_b,
        block_m=block_m,
        interpret=interpret,
        m_total=m_targets + pad_m,
        target_base=target_base,
    )
    return out[:b_n, :m_targets]


@functools.partial(
    jax.jit,
    static_argnames=("k", "largest", "n_iter", "block_b", "block_m",
                     "interpret"),
)
def sturm_eigenvalues_segmented(
    d: jax.Array,  # (B, N) packed block-diagonal bands
    e: jax.Array,  # (B, N-1) off-diagonals (zero at segment junctions)
    seg_off: jax.Array,  # (B, S) int32 segment start columns
    seg_len: jax.Array,  # (B, S) int32 segment lengths (0 = empty slot)
    *,
    k: int,
    largest: bool,
    n_iter: int = 0,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """The ``k`` extremal eigenvalues of every *segment* of packed bands.

    Each band row carries up to ``S`` independent tridiagonal blocks
    (segments) laid out by the serving packer: block ``s`` of row ``b``
    occupies columns ``[seg_off[b, s], seg_off[b, s] + seg_len[b, s])`` and
    junction off-diagonals are exactly zero, so the Sturm count restricted
    to a segment window is the exact count for that block (decoupling is a
    property of the recurrence, not an approximation).  Lane ``(s, t)``
    brackets per-segment index ``len - k + t`` (largest, clamped at 0) or
    ``t`` (smallest, clamped at ``len - 1``) — clamped lanes duplicate the
    boundary eigenvalue and sit *outside* the slice a ``k' <= len`` request
    reads, mirroring the guard convention of the bucketed path.

    Returns ``(B, S, k)``, ascending per segment; empty slots return zeros.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b_n, n = d.shape
    s_slots = seg_off.shape[1]
    dtype = d.dtype
    if n_iter == 0:
        n_iter = _default_iters(dtype)
    if k < 1:
        raise ValueError(f"window k={k} must be >= 1")

    seg_off = seg_off.astype(jnp.int32)
    seg_len = seg_len.astype(jnp.int32)
    seg_end = seg_off + seg_len

    # Per-segment Gershgorin bounds + pivmin via masked reductions over the
    # band (the segment layout is traced data, so everything stays jittable).
    e_full = jnp.zeros_like(d)
    if n > 1:
        e_full = e_full.at[:, : n - 1].set(jnp.abs(e))
    r = jnp.zeros_like(d)
    if n > 1:
        r = r.at[:, :-1].add(e_full[:, : n - 1])
        r = r.at[:, 1:].add(e_full[:, : n - 1])
    col = jnp.arange(n, dtype=jnp.int32)[None, None, :]  # (1, 1, N)
    in_seg = (seg_off[:, :, None] <= col) & (col < seg_end[:, :, None])
    big = jnp.asarray(jnp.finfo(dtype).max, dtype)
    lo_s = jnp.min(
        jnp.where(in_seg, (d - r)[:, None, :], big), axis=2)  # (B, S)
    hi_s = jnp.max(jnp.where(in_seg, (d + r)[:, None, :], -big), axis=2)
    empty = seg_len == 0
    lo_s = jnp.where(empty, 0.0, lo_s)
    hi_s = jnp.where(empty, 0.0, hi_s)
    span = jnp.maximum(hi_s - lo_s, 1.0)
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    lo_s = lo_s - eps * span
    hi_s = hi_s + eps * span
    scale = jnp.max(
        jnp.where(in_seg, jnp.abs(d)[:, None, :], 0.0), axis=2)
    scale = jnp.maximum(
        scale, jnp.max(jnp.where(in_seg, e_full[:, None, :], 0.0), axis=2))
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    piv_s = jnp.maximum(eps * eps * scale * scale, tiny)

    # Lane layout: m = s * k + t.  Targets are per-segment indices.
    t = jnp.arange(k, dtype=jnp.int32)[None, None, :]  # (1, 1, k)
    if largest:
        targ = jnp.maximum(seg_len[:, :, None] - k + t, 0)
    else:
        targ = jnp.minimum(t, jnp.maximum(seg_len[:, :, None] - 1, 0))

    m_total = s_slots * k
    block_m = blocks.clamp_block(block_m, m_total)
    block_b = blocks.clamp_block(block_b, b_n, align=1)
    pad_m = (-m_total) % block_m
    pad_b = (-b_n) % block_b
    pad_n = (-n) % 8

    def pad_lane(x, value):
        """Broadcast (B, S[, k]) to lanes (B, S*k) and pad to blocks."""
        x = jnp.broadcast_to(x[:, :, None] if x.ndim == 2 else x,
                             (b_n, s_slots, k)).reshape(b_n, m_total)
        return jnp.pad(x, ((0, pad_b), (0, pad_m)), constant_values=value)

    lo_l = pad_lane(lo_s, 0.0)
    hi_l = pad_lane(hi_s, 0.0)
    piv_l = pad_lane(piv_s, 1.0)
    start_l = pad_lane(seg_off, 0)
    end_l = pad_lane(seg_end, 0)  # padded lanes: empty window, count 0
    targ_l = pad_lane(targ, 0)

    d_p = jnp.pad(d, ((0, pad_b), (0, pad_n)), constant_values=1.0)
    e_p = jnp.zeros_like(d_p)
    if n > 1:
        e_p = e_p.at[:b_n, : n - 1].set(e)

    out = _kernel.sturm_segmented_padded(
        d_p, e_p, lo_l, hi_l, piv_l, start_l, end_l, targ_l,
        n_iter=n_iter, block_b=block_b, block_m=block_m,
        interpret=interpret)
    return out[:b_n, :m_total].reshape(b_n, s_slots, k)


@functools.partial(
    jax.jit,
    static_argnames=("k", "largest", "n_iter", "block_b", "block_m",
                     "interpret"),
)
def sturm_eigenvalues_bracketed(
    d: jax.Array,  # (B, n)
    e: jax.Array,  # (B, n-1)
    lo: jax.Array,  # (B, k) per-lane warm lower brackets
    hi: jax.Array,  # (B, k) per-lane warm upper brackets
    *,
    k: int,
    largest: bool,
    n_iter: int = 0,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """The ``k`` extremal eigenvalues from caller-supplied per-lane brackets.

    The warm-started twin of the ``window=`` entry of
    :func:`sturm_eigenvalues`: each bisection lane starts from its own
    ``(lo, hi)`` (interlacing-tightened brackets from a previous spectrum —
    see ``repro.linalg.interlace.rank1_update_brackets``) instead of the
    matrix-wide Gershgorin interval.  Implemented on the *segmented* kernel
    with one full-band segment per row: the segmented program already
    threads per-lane bounds and per-lane target indices, so the warm path
    reuses the packed-dispatch machinery rather than growing a third kernel.

    Warm brackets are validated, never trusted: one pair of host-side Sturm
    sweeps checks ``count(lo[t]) <= target_t < count(hi[t])`` and any lane
    whose bracket cannot prove containment of its index restarts from the
    Gershgorin interval — stale sessions cost iterations, not correctness.
    Returns ``(B, k)``, ascending.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b_n, n = d.shape
    dtype = d.dtype
    if n_iter == 0:
        n_iter = _default_iters(dtype)
    if not 1 <= k <= n:
        raise ValueError(f"window k={k} out of range for n={n}")

    # Per-matrix Gershgorin bounds + pivmin (the validation fallback).
    abs_e = jnp.abs(e)
    r = jnp.zeros_like(d)
    if n > 1:
        r = r.at[:, :-1].add(abs_e)
        r = r.at[:, 1:].add(abs_e)
    lo_g = jnp.min(d - r, axis=1)
    hi_g = jnp.max(d + r, axis=1)
    span = jnp.maximum(hi_g - lo_g, 1.0)
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    lo_g = lo_g - eps * span
    hi_g = hi_g + eps * span
    scale = jnp.maximum(
        jnp.max(jnp.abs(d), axis=1),
        jnp.max(abs_e, axis=1) if n > 1 else jnp.zeros((b_n,), dtype),
    )
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    piv = jnp.maximum(eps * eps * scale * scale, tiny)

    targ = (jnp.arange(n - k, n) if largest else jnp.arange(k))
    targ = jnp.broadcast_to(targ.astype(jnp.int32)[None, :], (b_n, k))
    lo = jnp.asarray(lo, dtype)
    hi = jnp.asarray(hi, dtype)
    from repro.linalg.sturm import sturm_count  # pure-jnp count, vmappable

    counts = jax.vmap(sturm_count)(d, e, jnp.concatenate([lo, hi], axis=1))
    ok = (counts[:, :k] <= targ) & (counts[:, k:] > targ) & (lo <= hi)
    lo = jnp.where(ok, lo, lo_g[:, None])
    hi = jnp.where(ok, hi, hi_g[:, None])

    block_m = blocks.clamp_block(block_m, k)
    block_b = blocks.clamp_block(block_b, b_n, align=1)
    pad_m = (-k) % block_m
    pad_b = (-b_n) % block_b
    pad_n = (-n) % 8

    def pad_lane(x, value):
        return jnp.pad(x, ((0, pad_b), (0, pad_m)), constant_values=value)

    lo_l = pad_lane(lo, 0.0)
    hi_l = pad_lane(hi, 0.0)
    piv_l = pad_lane(jnp.broadcast_to(piv[:, None], (b_n, k)), 1.0)
    start_l = pad_lane(jnp.zeros((b_n, k), jnp.int32), 0)
    end_l = pad_lane(jnp.full((b_n, k), n, jnp.int32), 0)
    targ_l = pad_lane(targ, 0)

    d_p = jnp.pad(d, ((0, pad_b), (0, pad_n)), constant_values=1.0)
    e_p = jnp.zeros_like(d_p)
    if n > 1:
        e_p = e_p.at[:b_n, : n - 1].set(e)

    out = _kernel.sturm_segmented_padded(
        d_p, e_p, lo_l, hi_l, piv_l, start_l, end_l, targ_l,
        n_iter=n_iter, block_b=block_b, block_m=block_m,
        interpret=interpret)
    return out[:b_n, :k]


@functools.partial(
    jax.jit, static_argnames=("n_iter", "block_b", "block_m", "interpret")
)
def sturm_minor_spectra(
    dm: jax.Array,  # (b, n, m) stacked minor diagonals
    em: jax.Array,  # (b, n, m-1) stacked minor off-diagonals
    *,
    n_iter: int = 0,
    block_b: int = 8,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Spectra of all ``b * n`` stacked minor bands as one tiled program.

    The stacked-minor-band layout: the ``(b, n)`` leading axes flatten onto
    the kernel's row (sublane) axis, so every bisection problem in the whole
    batch advances in lockstep inside a single pallas_call — per-program
    launch overhead is amortized across the stack instead of paid ``b``
    times.  Returns ``(b, n, m)``.
    """
    b_n, n, m = dm.shape
    mu = sturm_eigenvalues(
        dm.reshape(b_n * n, m),
        em.reshape(b_n * n, m - 1),
        n_iter=n_iter,
        block_b=block_b,
        block_m=block_m,
        interpret=interpret,
    )
    return mu.reshape(b_n, n, m)
