"""Pure-jnp oracle for the sturm kernel: repro.linalg.sturm re-exported with
the kernel's batched calling convention."""

from __future__ import annotations

import jax

from repro.linalg import sturm as _sturm


def sturm_eigenvalues(d: jax.Array, e: jax.Array, n_iter: int = 0) -> jax.Array:
    """Eigenvalues of a batch of tridiagonals; d (B, n), e (B, n-1) -> (B, n)."""
    return _sturm.bisect_eigenvalues_batched(d, e, n_iter=n_iter)
