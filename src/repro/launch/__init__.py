from repro.launch.mesh import chips, make_local_mesh, make_production_mesh  # noqa: F401
