import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST precede every other import: jax locks the device
# count at first initialization, and the dry-run needs 512 placeholder host
# devices to build the production meshes.  (Everything else in the repo sees
# the real device count — this flag is set only in this entrypoint.)

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import ARCHS  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import dryrun_lib  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower + compile every "
                    "(arch x shape x mesh) cell.")
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multipod", action="store_true",
                    help="use the (pod=2, data=16, model=16) mesh")
    ap.add_argument("--roofline", action="store_true",
                    help="also run L-extrapolation lowerings for roofline terms")
    ap.add_argument("--no-full", action="store_true",
                    help="skip the full-depth compile (roofline lowerings only)")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--paper-eei", action="store_true",
                    help="lower the paper's own EEI workload on the mesh")
    ap.add_argument("--eei-n", type=int, default=4096)
    ap.add_argument("--eei-reduce", default="sum", choices=["sum", "dot", "dot_bf16"],
                    help="numerator reduction form (dot = fused contraction)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    mesh = mesh_lib.make_production_mesh(multi_pod=args.multipod)

    if args.paper_eei:
        result = dryrun_lib.dryrun_paper_eei(mesh, n=args.eei_n,
                                             reduce=args.eei_reduce)
        result["shape"] = f"eei_n{args.eei_n}_{args.eei_reduce}"
        path = dryrun_lib.save_artifact(result, args.out)
        rl = result["roofline"]
        print(f"[OK     ] paper-eei n={args.eei_n} chips={result['chips']}"
              f" dominant={rl['dominant']}"
              f" tC={rl['t_compute_s']:.4f}s tM={rl['t_memory_s']:.4f}s"
              f" tX={rl['t_collective_s']:.4f}s -> {path}")
        return 0
    cells = (dryrun_lib.all_cells() if args.all
             else [(args.arch, args.shape)])
    if not args.all and (args.arch is None or args.shape is None):
        ap.error("--arch and --shape are required unless --all")

    failures = 0
    for arch, shape in cells:
        t0 = time.monotonic()
        tag = f"{arch} x {shape} x {'multipod' if args.multipod else 'pod'}"
        try:
            result = dryrun_lib.dryrun_cell(
                arch, shape, mesh,
                roofline=args.roofline, full_compile=not args.no_full)
        except Exception:
            failures += 1
            print(f"[FAIL] {tag}")
            traceback.print_exc()
            result = {"arch": arch, "shape": shape, "status": "failed",
                      "chips": int(mesh.devices.size),
                      "error": traceback.format_exc(limit=3)}
            dryrun_lib.save_artifact(result, args.out)
            continue
        path = dryrun_lib.save_artifact(result, args.out)
        dt = time.monotonic() - t0
        status = result["status"]
        line = f"[{status.upper():7s}] {tag}  ({dt:.1f}s) -> {path}"
        if status == "ok" and "full" in result:
            mem = result["full"].get("memory", {})
            cost = result["full"].get("cost", {})
            coll = result["full"].get("collectives", {})
            line += (f"\n  flops={cost.get('flops', 0):.3e}"
                     f" bytes={cost.get('bytes accessed', 0):.3e}"
                     f" coll={coll.get('total', 0):.3e}"
                     f" args={mem.get('argument_size_in_bytes', 0):.3e}B"
                     f" temp={mem.get('temp_size_in_bytes', 0):.3e}B")
            print(line)
            print("  memory_analysis:", json.dumps(mem))
            print("  cost_analysis(flops)=", cost.get("flops"))
        else:
            print(line)
            if status == "skipped":
                print("  reason:", result.get("reason"))
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
