"""Dry-run core: lower + compile every (arch x shape x mesh) cell, extract
memory analysis, cost analysis, collective bytes, and roofline terms.

Importable without touching jax device state; the ``dryrun`` entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* importing
this module.  Tests call ``dryrun_cell`` on a small local mesh.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.registry import ARCHS, get_config
from repro.models.lm import LanguageModel
from repro.roofline import (
    CostVector,
    Roofline,
    collective_bytes,
    cost_vector,
    extrapolate,
    model_flops,
    slstm_extra_flops,
)
from repro.sharding import rules as rules_lib
from repro.train import steps as steps_lib


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, fsdp: bool | None = None):
    """Lower the cell's step on ``mesh``; returns the jax Lowered object."""
    from repro.sharding import hints

    model = LanguageModel(cfg)
    if fsdp is None:
        fsdp = rules_lib.fsdp_recommended(model.n_params(), mesh)
    rules = rules_lib.make_rules(mesh, fsdp=fsdp)
    param_specs = steps_lib.param_pspecs(model, rules)
    param_sh = _named(mesh, param_specs)
    repl = NamedSharding(mesh, P())

    with mesh, hints.axis_hints(data=rules_lib.data_axes(mesh), model="model",
                                model_size=rules_lib.mesh_axis_size(mesh, "model")):
        if shape.kind == "train":
            state_sh = _named(mesh, steps_lib.state_pspecs(model, rules))
            batch_sh = _named(mesh, steps_lib.batch_pspecs(cfg, mesh))
            step = steps_lib.make_train_step(model)
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
            state = steps_lib.abstract_state(model)
            batch = steps_lib.input_specs(cfg, shape)
            return fn.lower(state, batch)
        if shape.kind == "prefill":
            batch_sh = _named(mesh, steps_lib.batch_pspecs(cfg, mesh))
            cache_shapes = model.cache_spec(shape.global_batch, shape.seq_len,
                                            jnp.bfloat16)
            cache_sh = _named(mesh, steps_lib.prune_specs(
                steps_lib.cache_pspecs(model, mesh), cache_shapes, mesh))

            def prefill_step(params, batch):
                params = steps_lib.cast_tree(params, jnp.bfloat16)
                return model.prefill(params, batch, shape.seq_len,
                                     cache_dtype=jnp.bfloat16)

            fn = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh),
                         out_shardings=(repl, cache_sh))
            batch = steps_lib.input_specs(cfg, shape)
            return fn.lower(model.abstract(jnp.float32), batch)
        # decode
        spec = steps_lib.input_specs(cfg, shape)
        cache_specs = steps_lib.prune_specs(
            steps_lib.cache_pspecs(model, mesh), spec["caches"], mesh)
        cache_sh = _named(mesh, cache_specs)
        serve = steps_lib.make_serve_step(model)
        fn = jax.jit(serve, in_shardings=(param_sh, cache_sh, repl, repl),
                     out_shardings=(repl, cache_sh))
        return fn.lower(model.abstract(jnp.float32), spec["caches"],
                        spec["token"], spec["pos"])


# ---------------------------------------------------------------------------
# Cost extraction
# ---------------------------------------------------------------------------


def compile_and_extract(lowered) -> dict:
    t0 = time.monotonic()
    compiled = lowered.compile()
    compile_s = time.monotonic() - t0
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    coll = collective_bytes(text)
    mem: dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # CPU backend may not implement everything
        mem["error"] = str(e)
    return {
        "compile_s": compile_s,
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
        "memory": mem,
    }


def _scaled_pattern(cfg: ModelConfig, repeats: list[int]) -> ModelConfig:
    pattern = tuple(
        (r, kinds) for r, (_, kinds) in zip(repeats, cfg.pattern)
    )
    n_layers = sum(r * len(k) for r, k in pattern)
    # unroll_groups: the extrapolation lowerings must not hide per-layer cost
    # inside a while body (cost_analysis visits it once regardless of trip
    # count) — unrolled scans make cost(L) exactly linear in L.
    return dataclasses.replace(cfg, pattern=pattern, n_layers=n_layers,
                               unroll_groups=True)


def _with_enc(cfg: ModelConfig, n_enc: int) -> ModelConfig:
    return dataclasses.replace(cfg, n_enc_layers=n_enc)


def roofline_for_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      fsdp: bool | None = None) -> dict:
    """L-extrapolated cost vector + roofline terms (see analysis module)."""
    repeats = [r for r, _ in cfg.pattern]
    base_cfg = _scaled_pattern(cfg, [1] * len(repeats))
    if cfg.n_enc_layers:
        base_cfg = _with_enc(base_cfg, 1)
    ex_base = compile_and_extract(lower_cell(base_cfg, shape, mesh, fsdp))
    c_base = cost_vector(ex_base["cost"], ex_base["collectives"])

    slopes: list[CostVector] = []
    lowerings = {"base": ex_base}
    for g in range(len(repeats)):
        reps = [1] * len(repeats)
        reps[g] = 2
        cfg_g = _scaled_pattern(cfg, reps)
        if cfg.n_enc_layers:
            cfg_g = _with_enc(cfg_g, 1)
        ex_g = compile_and_extract(lower_cell(cfg_g, shape, mesh, fsdp))
        lowerings[f"group{g}x2"] = ex_g
        slopes.append(cost_vector(ex_g["cost"], ex_g["collectives"]))
    total = extrapolate(c_base, slopes, repeats)

    if cfg.n_enc_layers:
        cfg_e = _with_enc(_scaled_pattern(cfg, [1] * len(repeats)), 2)
        ex_e = compile_and_extract(lower_cell(cfg_e, shape, mesh, fsdp))
        lowerings["encx2"] = ex_e
        enc_slope = cost_vector(ex_e["cost"], ex_e["collectives"]) - c_base
        total = total + enc_slope.scale(cfg.n_enc_layers - 1)

    # cost_analysis reports the per-device SPMD program (verified in
    # EXPERIMENTS.md §Methodology) — globalize by chip count so the roofline
    # formula's global/(chips*peak) convention holds.
    total = total.scale(float(mesh.devices.size))

    rl = Roofline(
        flops=total.flops,
        bytes_accessed=total.bytes_accessed,
        collective_bytes=total.collective.get("total", 0.0),
        chips=int(mesh.devices.size),
        model_flops=model_flops(cfg, shape),
        extra_flops=slstm_extra_flops(cfg, shape),
    )
    return {
        "roofline": rl.as_dict(),
        "collective_breakdown": total.collective,
        "lowerings": {
            k: {kk: v[kk] for kk in ("compile_s", "cost", "collectives")}
            for k, v in lowerings.items()
        },
    }


# ---------------------------------------------------------------------------
# Cell driver
# ---------------------------------------------------------------------------


def dryrun_cell(arch: str, shape_name: str, mesh, *, roofline: bool = False,
                full_compile: bool = True, fsdp: bool | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    result: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "chips": int(mesh.devices.size),
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result
    try:
        if full_compile:
            lowered = lower_cell(cfg, shape, mesh, fsdp)
            result["full"] = compile_and_extract(lowered)
        if roofline:
            result.update(roofline_for_cell(cfg, shape, mesh, fsdp))
        result["status"] = "ok"
    except Exception as e:
        result["status"] = "failed"
        result["error"] = f"{type(e).__name__}: {e}"
        raise
    return result


def save_artifact(result: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multipod" if result.get("chips", 0) > 256 else "pod"
    path = os.path.join(
        out_dir, f"{result['arch']}__{result['shape']}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]


# ---------------------------------------------------------------------------
# The paper's own workload as a dry-run citizen: distributed EEI at scale
# ---------------------------------------------------------------------------


def lower_paper_eei(mesh, n: int = 4096, logspace: bool = True,
                    reduce: str = "sum"):
    """Lower the EEI component-table computation (Algorithm 2's hot loop) on
    the production mesh: spectra replicated, minors sharded on ``model``,
    batch of matrices on ``data``.

    The O(n^3) difference-product stage dominates (the bisection/minor-spectra
    stages are O(n^2 * iters), ~500x smaller at n=4096 — counted analytically
    in EXPERIMENTS.md).  Pure-jnp lowering so cost_analysis sees every flop
    (no LAPACK custom-calls, no while loops).
    """
    from repro.core import identity

    repl = NamedSharding(mesh, P())
    d_axes = rules_lib.data_axes(mesh)
    lam_sh = NamedSharding(mesh, P(d_axes, None))
    mu_sh = NamedSharding(mesh, P(d_axes, "model", None))
    out_sh = NamedSharding(mesh, P(d_axes, None, "model"))
    batch = rules_lib.mesh_axis_size(mesh, "data") * rules_lib.mesh_axis_size(
        mesh, "pod")

    # "dot_bf16": minor spectra stored/shipped in bf16 (eigenvalue *gaps*
    # carry the signal; bf16's 8-bit mantissa costs ~0.4% per log term,
    # averaged over n-1 terms), upcast fused into the contraction.
    mu_dtype = jnp.bfloat16 if reduce == "dot_bf16" else jnp.float32
    reduce_kind = "dot" if reduce.startswith("dot") else reduce

    def eei_table(lam, mu):
        mu = mu.astype(jnp.float32)
        if reduce_kind == "dot":
            # §Perf iteration 6: the (n,) denominator is replicated work —
            # shard its producer chain over `model` (16 KB all-gather joins).
            log_num = jax.vmap(identity.logabs_numerator_dot)(lam, mu)
            log_den = jax.vmap(identity.logabs_denominator_dot)(lam)
            log_den = jax.lax.with_sharding_constraint(
                log_den, NamedSharding(mesh, P(d_axes, "model")))
            return jnp.exp(log_num - log_den[:, :, None])
        return jax.vmap(
            lambda l, m: identity.magnitudes_from_spectra(
                l, m, logspace=logspace, reduce=reduce_kind)
        )(lam, mu)

    with mesh:
        fn = jax.jit(eei_table, in_shardings=(lam_sh, mu_sh),
                     out_shardings=out_sh)
        lam = jax.ShapeDtypeStruct((batch, n), jnp.float32)
        mu = jax.ShapeDtypeStruct((batch, n, n - 1), mu_dtype)
        return fn.lower(lam, mu)


def dryrun_paper_eei(mesh, n: int = 4096, reduce: str = "sum") -> dict:
    ex = compile_and_extract(lower_paper_eei(mesh, n, reduce=reduce))
    chips = int(mesh.devices.size)
    total = cost_vector(ex["cost"], ex["collectives"]).scale(float(chips))
    batch = rules_lib.mesh_axis_size(mesh, "data") * rules_lib.mesh_axis_size(
        mesh, "pod")
    # useful flops: 3 ops (sub, log-abs, add) per (i, j, k) numerator term
    # + n^2 denominator, per matrix in the batch.
    useful = 3.0 * batch * (float(n) ** 3)
    rl = Roofline(
        flops=total.flops,
        bytes_accessed=total.bytes_accessed,
        collective_bytes=total.collective.get("total", 0.0),
        chips=chips,
        model_flops=useful,
    )
    return {
        "arch": "paper-eei", "shape": f"n{n}",
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "chips": chips, "status": "ok",
        "full": ex,
        "roofline": rl.as_dict(),
        "collective_breakdown": total.collective,
    }
