"""Production meshes.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the ``pod`` axis is pure DCN data parallelism.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before the first jax call).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over available devices (tests, CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))


def chips(mesh) -> int:
    return mesh.devices.size
