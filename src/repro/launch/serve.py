"""Serving launcher: batched LM decode, or batched EEI top-k queries.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the production decode path: prefill fills sharded KV caches,
then ``serve_step`` (one token, cache update in place via donated buffers)
iterates.  Request batching is static (continuous batching is an orthogonal
scheduler concern; the cache layout supports it — position is per-batch
scalar here for the dry-run shapes).

The EEI mode serves the paper's workload — streams of top-k eigenpair
queries over stacks of symmetric matrices — through the plan-driven
``repro.engine.SolverEngine`` (one batched program per stack):

    PYTHONPATH=src python -m repro.launch.serve --eei --batch 8 --n 64 \
        --k 4 --requests 16
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.launch import mesh as mesh_lib
from repro.launch.train import parse_mesh
from repro.models.lm import LanguageModel
from repro.train import build_programs
from repro.train.steps import cast_tree

log = logging.getLogger("repro.serve")


def serve_eei(args):
    """Serve a stream of batched top-k spectral queries via the engine."""
    from repro.engine import SolverEngine, autotune, plan_for, \
        resolved_crossovers

    if args.calibration:
        autotune.set_table(autotune.load_table(args.calibration))
    table = autotune.get_table()
    eigh_x, dense_x = resolved_crossovers()
    log.info("plan calibration: %s (eigh_crossover_n=%d dense_crossover_n=%d)",
             table.source if table else "static fallback constants",
             eigh_x, dense_x)

    mesh = parse_mesh(args.mesh)
    rng = np.random.default_rng(args.seed)
    shape = (args.batch, args.n, args.n)
    plan = plan_for(shape, k=args.k,
                    mesh=mesh if mesh.devices.size > 1 else None)
    engine = SolverEngine(plan)
    log.info("eei serve plan: method=%s backend=%s batch=%d n=%d k=%d",
             plan.method, plan.backend, args.batch, args.n, args.k)

    def stack():
        a = rng.standard_normal(shape).astype(np.float32)
        return jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)

    # Warmup compiles the batched program once per (plan, n, k).
    out = engine.topk(stack(), args.k)
    jax.block_until_ready(out)

    t0 = time.monotonic()
    solved = 0
    for _ in range(args.requests):
        out = engine.topk(stack(), args.k)
        jax.block_until_ready(out)
        solved += args.batch
    dt = time.monotonic() - t0
    log.info("served %d top-%d solves in %.3fs (%.1f solves/s, "
             "%.1f requests/s)", solved, args.k, dt,
             solved / max(dt, 1e-9), args.requests / max(dt, 1e-9))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--eei", action="store_true",
                    help="serve batched EEI top-k queries instead of an LM")
    ap.add_argument("--n", type=int, default=64, help="EEI matrix size")
    ap.add_argument("--k", type=int, default=4, help="EEI top-k per query")
    ap.add_argument("--requests", type=int, default=8,
                    help="EEI request batches to serve")
    ap.add_argument("--calibration", default=None,
                    help="path to an autotune calibration table (JSON); "
                    "default: env/cache/repo-default resolution chain")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    if args.eei:
        return serve_eei(args)
    if args.arch is None:
        ap.error("--arch is required unless --eei is given")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = parse_mesh(args.mesh)
    model = LanguageModel(cfg)
    compute_dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    smax = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(args.seed)
    with mesh:
        programs = build_programs(model, mesh, compute_dtype=compute_dtype)
        params = jax.jit(
            model.init, out_shardings=programs.state_shardings.params
        )(rng)
        batch = {"tokens": jax.random.randint(
            rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        batch["labels"] = batch["tokens"]
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                rng, (args.batch, cfg.enc_seq, cfg.d_model)) * 0.02
        if cfg.family == "vlm":
            batch["images"] = jax.random.normal(
                rng, (args.batch, cfg.img_seq, cfg.d_model)) * 0.02

        t0 = time.monotonic()
        logits, caches = jax.jit(
            lambda p, b: model.prefill(cast_tree(p, compute_dtype), b, smax),
            out_shardings=(None, programs.cache_shardings),
        )(params, batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        log.info("prefill %.3fs (B=%d, S=%d)", time.monotonic() - t0,
                 args.batch, args.prompt_len)

        out_tokens = [np.asarray(tok)]
        t0 = time.monotonic()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            tok, caches = programs.serve_step(params, caches, tok, pos)
            out_tokens.append(np.asarray(tok))
        dt = time.monotonic() - t0
        gen = np.stack(out_tokens, axis=1)
        log.info("decode %d tokens x %d seqs in %.3fs (%.1f tok/s)",
                 gen.shape[1], gen.shape[0], dt,
                 gen.size / max(dt, 1e-9))
        print(gen)
    return gen


if __name__ == "__main__":
    main()
