"""Serving launcher: batched LM decode, or batched EEI top-k queries.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the production decode path: prefill fills sharded KV caches,
then ``serve_step`` (one token, cache update in place via donated buffers)
iterates.  Request batching is static (continuous batching is an orthogonal
scheduler concern; the cache layout supports it — position is per-batch
scalar here for the dry-run shapes).

The EEI mode serves the paper's workload — streams of top-k eigenpair
queries over many small symmetric matrices — through the continuous-batching
``repro.engine.EeiServer`` (queue -> coalesce -> shape buckets -> program
cache -> async double-buffered dispatch):

    PYTHONPATH=src python -m repro.launch.serve --eei --batch 8 --n 64 \
        --k 4 --requests 64 [--mixed] [--sync] [--linger-ms 2] \
        [--gap-ms 1] [--sharded] [--spectrum auto|full|windowed] \
        [--chaos SEED] [--chaos-rate 0.05] \
        [--replicas 3 [--replica-mode subprocess] [--chaos-replicas]]

``--mixed`` samples ``n`` and ``k`` per request (the heterogeneous stream
the server exists for); ``--sync`` runs the PR-2-style synchronous
per-request loop instead (the baseline the server is benchmarked against).
``--linger-ms`` turns on the threaded serving runtime: a background
admission thread dispatches partial stacks once their oldest request has
lingered that long, so the stream completes with *no* ``flush()`` — pair it
with ``--gap-ms`` (mean inter-arrival sleep) to emulate the sparse stream
the linger thread exists for.  ``--sharded`` serves through the multi-device
mesh from ``--mesh`` (the server rounds pow2 stack buckets up to the mesh
batch axis); force host devices off-TPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
``--chaos SEED`` runs a soak: deterministic fault injection (compile and
launch failures, NaN-poisoned results, slow retires, thread crashes) at
``--chaos-rate`` per injection point — the stream must still complete, with
the robustness counters (verify failures, retries, stack splits, degraded
resolutions, per-plan fallbacks, injections) logged at the end.
``--replicas N`` serves through an ``EeiFleet`` of N replica servers
(rendezvous-hashed routing, health probes, failover redispatch, restart);
``--replica-mode subprocess`` isolates each replica in its own process,
and ``--chaos-replicas`` arms the replica-level kill/hang/slow points so
replicas die mid-stream while every request must still resolve.
The request stream is generated *before* the timed region either way.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.launch import mesh as mesh_lib
from repro.launch.train import parse_mesh
from repro.models.lm import LanguageModel
from repro.train import build_programs
from repro.train.steps import cast_tree

log = logging.getLogger("repro.serve")


def serve_eei(args):
    """Serve a pre-generated stream of top-k spectral queries.

    Default: continuous batching through ``EeiServer``.  ``--sync``: the
    synchronous per-request loop (one engine.topk + block_until_ready per
    matrix) — the PR-2 baseline the server's ≥2x requests/s claim is
    measured against.
    """
    from repro.engine import EeiServer, SolverEngine, autotune, plan_for, \
        resolved_crossovers
    from repro.engine.server import make_eei_stream

    if args.calibration:
        autotune.set_table(autotune.load_table(args.calibration))
    table = autotune.get_table()

    mesh = parse_mesh(args.mesh)
    if args.sharded and mesh.shape["data"] < 2:
        raise SystemExit(
            "--sharded needs a multi-device data axis; pass --mesh DxM and "
            "(off-TPU) XLA_FLAGS=--xla_force_host_platform_device_count=N")
    serve_mesh = mesh if mesh.devices.size > 1 else None
    plan = plan_for((args.batch, args.n, args.n), k=args.k, mesh=serve_mesh,
                    backend="sharded" if args.sharded else None,
                    spectrum=None if args.spectrum == "auto" else
                    args.spectrum)
    # Crossovers are backend-specific since schema v2 — log the pair the
    # resolved plan's backend actually dispatches on.
    eigh_x, dense_x = resolved_crossovers(plan.backend)
    log.info("plan calibration: %s (backend=%s eigh_crossover_n=%d "
             "dense_crossover_n=%d)",
             table.source if table else "static fallback constants",
             plan.backend, eigh_x, dense_x)
    mode = "sync-loop" if args.sync else (
        f"continuous-batching linger={args.linger_ms}ms"
        if args.linger_ms is not None else "continuous-batching")
    if args.mixed and not args.sync:
        # The server re-plans per shape bucket; the fixed plan above is
        # only the log's reference point for the nominal (batch, n, k).
        log.info("eei serve: per-bucket planning, max_batch=%d nominal "
                 "n=%d k=%d mode=%s mixed-shapes", args.batch, args.n,
                 args.k, mode)
    else:
        log.info("eei serve plan: method=%s backend=%s spectrum=%s "
                 "max_batch=%d n=%d k=%d mode=%s", plan.method, plan.backend,
                 plan.spectrum, args.batch, args.n, args.k, mode)

    # The stream is generated before t0 — only serving is timed.
    stream = make_eei_stream(args.requests, args.n, args.k,
                             seed=args.seed, mixed=args.mixed)

    gap_s = (args.gap_ms or 0.0) / 1e3
    rng = np.random.default_rng(args.seed)
    if args.sync:
        engine = SolverEngine(plan)
        # Warmup compiles outside the timed region, like the server path.
        for n_i in sorted({a.shape[0] for a, _ in stream}):
            for k_i in sorted({k for a, k in stream if a.shape[0] == n_i}):
                jax.block_until_ready(
                    engine.topk(jnp.zeros((n_i, n_i), jnp.float32), k_i))
        t0 = time.monotonic()
        out = None
        for a, k_i in stream:
            if gap_s:
                # The sync baseline pays the same arrival gaps as the
                # server path, so --sync vs --linger-ms comparisons at
                # equal flags stay apples-to-apples.
                time.sleep(rng.exponential(gap_s))
            out = engine.topk(jnp.asarray(a), k_i)
            jax.block_until_ready(out)
        dt = time.monotonic() - t0
        log.info("sync loop served %d requests in %.3fs (%.1f solves/s, "
                 "%.1f requests/s)", len(stream), dt,
                 len(stream) / max(dt, 1e-9), len(stream) / max(dt, 1e-9))
        return out

    if args.replicas > 1:
        return _serve_eei_fleet(args, stream, gap_s, rng)

    chaos = None
    if args.chaos is not None:
        from repro.runtime import ChaosConfig, ChaosMonkey

        chaos = ChaosMonkey(ChaosConfig(seed=args.chaos,
                                        rate=args.chaos_rate))
        log.info("chaos soak: seed=%d rate=%.3f (deterministic injection "
                 "at compile/launch/result/retire/thread points)",
                 args.chaos, args.chaos_rate)
    # --mixed uses per-bucket planning (plan=None + the serve mesh); a
    # fixed nominal shape pins the one plan computed above.
    server = EeiServer(plan if args.mixed is False else None,
                       max_batch=args.batch, max_inflight=args.inflight,
                       linger_ms=args.linger_ms,
                       mesh=serve_mesh if args.mixed else None,
                       pack=args.pack, chaos=chaos)
    t0 = time.monotonic()
    futures = []
    for a, k_i in stream:
        if gap_s:
            time.sleep(rng.exponential(gap_s))  # sparse Poisson-ish arrivals
        futures.append(server.submit(a, k_i))
    if args.linger_ms is not None:
        # The whole point of the linger thread: the stream drains with no
        # explicit flush — just wait on the completion futures.
        for f in futures:
            f.result(timeout=600)
    else:
        server.flush()
    dt = time.monotonic() - t0
    server.close()
    stats = server.stats()
    log.info("served %d requests in %.3fs (%.1f solves/s, %.1f requests/s)",
             len(stream), dt, len(stream) / max(dt, 1e-9),
             len(stream) / max(dt, 1e-9))
    log.info("latency p50=%.1fms p99=%.1fms | %d stacks, %d program "
             "compiles over %d distinct buckets, %d cache hits",
             stats["p50_latency_ms"], stats["p99_latency_ms"],
             stats["stacks_dispatched"], stats["program_compiles"],
             stats["distinct_buckets"], stats["program_hits"])
    per_bucket = ", ".join(
        f"{name}={frac:.3f}"
        for name, frac in sorted(stats["pad_waste_by_bucket"].items()))
    log.info("pad waste %.3f (%d of %d grid cells padding) | per bucket: %s",
             stats["pad_waste_frac"],
             stats["grid_cells_total"] - stats["grid_cells_real"],
             stats["grid_cells_total"], per_bucket or "none")
    if stats["packed_stacks_dispatched"]:
        log.info("packed dispatch (--pack=%s): %d of %d stacks packed, "
                 "%d requests packed | pad waste packed=%.3f bucketed=%.3f",
                 args.pack, stats["packed_stacks_dispatched"],
                 stats["stacks_dispatched"],
                 stats["packed_requests_completed"],
                 stats["pad_waste_packed_frac"],
                 stats["pad_waste_bucketed_frac"])
    by_plan = ", ".join(f"{name}={count}" for name, count in
                        sorted(stats["fallbacks_by_plan"].items()))
    log.info("robustness: %d verify failures, %d retries, %d stack splits, "
             "%d degraded | fallbacks: %s",
             stats["verify_failed"], stats["retries"], stats["stack_splits"],
             stats["requests_degraded"], by_plan or "none")
    if chaos is not None:
        injected = ", ".join(f"{point}={count}" for point, count in
                             sorted(stats["chaos_injected"].items()))
        log.info("chaos injected: %s | requests_failed=%d",
                 injected or "none", stats["requests_failed"])
    # A zero-request stream (--requests 0: config smoke, drained replay)
    # has no futures — the rollups above already guard division by zero /
    # empty percentiles; returning None instead of futures[-1] keeps the
    # degenerate run from dying with IndexError after serving nothing.
    return futures[-1].result() if futures else None


def _serve_eei_fleet(args, stream, gap_s, rng):
    """Serve the stream through an ``EeiFleet`` of ``--replicas`` servers.

    ``--chaos-replicas`` arms the replica-level injection points
    (kill / hang / slow, each at ``--chaos-rate``, seeded by ``--chaos``)
    — replicas die *while serving* and the stream must still complete,
    with the failover counters logged at the end.
    """
    from repro.engine import EeiFleet

    chaos = None
    if args.chaos_replicas:
        from repro.runtime import ChaosConfig, ChaosMonkey

        seed = args.chaos if args.chaos is not None else 0
        chaos = ChaosMonkey(ChaosConfig(
            seed=seed, rate=0.0, replica_kill_rate=args.chaos_rate,
            replica_hang_rate=args.chaos_rate / 2,
            replica_slow_rate=args.chaos_rate))
        log.info("replica chaos soak: seed=%d kill/slow rate=%.3f "
                 "hang rate=%.3f", seed, args.chaos_rate,
                 args.chaos_rate / 2)
    fleet = EeiFleet(
        args.replicas,
        replica_mode=args.replica_mode,
        server_kwargs=dict(
            max_batch=args.batch, max_inflight=args.inflight,
            linger_ms=args.linger_ms if args.linger_ms is not None else 2.0,
            pack=args.pack),
        chaos=chaos,
        restart_policy_kwargs=dict(max_restarts=1000),
    )
    log.info("eei fleet: %d %s replicas, max_batch=%d", args.replicas,
             args.replica_mode, args.batch)
    t0 = time.monotonic()
    futures = []
    for a, k_i in stream:
        if gap_s:
            time.sleep(rng.exponential(gap_s))
        futures.append(fleet.submit(a, k_i))
    for f in futures:
        f.result(timeout=600)
    dt = time.monotonic() - t0
    stranded = fleet.close(timeout=120)
    stats = fleet.stats()
    log.info("fleet served %d requests in %.3fs (%.1f requests/s) | "
             "%d unresolved at close", len(stream), dt,
             len(stream) / max(dt, 1e-9), len(stranded))
    log.info("fleet latency p50=%.1fms p99=%.1fms | states=%s",
             stats["p50_latency_ms"], stats["p99_latency_ms"],
             stats["replica_states"])
    log.info("failover: %d redispatches, %d hedges (%d wasted), "
             "%d kills, %d restarts, %d deadline deaths",
             stats["redispatches"], stats["hedges"], stats["hedge_wasted"],
             stats["replicas_killed"], stats["replicas_restarted"],
             stats["deadline_deaths"])
    if chaos is not None:
        injected = ", ".join(f"{point}={count}" for point, count in
                             sorted(stats["chaos_injected"].items())
                             if count)
        log.info("chaos injected: %s | requests_failed=%d",
                 injected or "none", stats["requests_failed"])
    return futures[-1].result() if futures else None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--eei", action="store_true",
                    help="serve batched EEI top-k queries instead of an LM")
    ap.add_argument("--n", type=int, default=64, help="EEI matrix size")
    ap.add_argument("--k", type=int, default=4, help="EEI top-k per query")
    ap.add_argument("--requests", type=int, default=64,
                    help="EEI requests (single-matrix queries) to serve")
    ap.add_argument("--mixed", action="store_true",
                    help="EEI: sample n and k per request (heterogeneous "
                    "stream through the shape-bucketed server)")
    ap.add_argument("--sync", action="store_true",
                    help="EEI: synchronous per-request loop instead of the "
                    "continuous-batching server (baseline)")
    ap.add_argument("--pack", choices=["auto", "never", "always"],
                    default="never",
                    help="EEI: segment-packed dispatch — coalesce small-n "
                    "requests into block-diagonal packed rows ('auto': "
                    "pack below the calibrated crossover; 'always': pack "
                    "anything that fits a row; default 'never' keeps the "
                    "pure shape-bucketed path)")
    ap.add_argument("--spectrum", choices=["auto", "full", "windowed"],
                    default="auto",
                    help="EEI: pin the stage composition — 'windowed' "
                    "computes only the k requested extremal rows (the "
                    "k-windowed Sturm + minor-determinant path), 'full' "
                    "the whole table; 'auto' lets the calibrated planner "
                    "pick per bucket (windowed_k_frac crossover)")
    ap.add_argument("--inflight", type=int, default=2,
                    help="EEI server: max in-flight stacks (double "
                    "buffering = 2)")
    ap.add_argument("--linger-ms", type=float, default=None,
                    help="EEI: run the threaded serving runtime — a "
                    "background admission thread dispatches partial stacks "
                    "after this linger timeout (no explicit flush)")
    ap.add_argument("--gap-ms", type=float, default=0.0,
                    help="EEI: mean inter-arrival sleep between submits "
                    "(emulates the sparse stream the linger thread serves)")
    ap.add_argument("--sharded", action="store_true",
                    help="EEI: serve through the sharded backend on the "
                    "--mesh data axis (stack buckets round up to it)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="EEI: soak mode — deterministically inject faults "
                    "(compile/launch failures, NaN-poisoned results, slow "
                    "retires, thread crashes) from this seed and log the "
                    "robustness counters; the stream must still complete")
    ap.add_argument("--chaos-rate", type=float, default=0.05,
                    help="EEI: per-injection-point chaos probability "
                    "(default 0.05; only with --chaos)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="EEI: serve through an EeiFleet of this many "
                    "replica servers (health-probed routing, failover "
                    "redispatch, restart); 1 = single server")
    ap.add_argument("--replica-mode", choices=["inprocess", "subprocess"],
                    default="inprocess",
                    help="EEI fleet: replica driver — in-process servers "
                    "sharing one program cache, or one worker process per "
                    "replica (true process isolation and parallelism)")
    ap.add_argument("--chaos-replicas", action="store_true",
                    help="EEI fleet: arm replica-level chaos (kill/hang/"
                    "slow at --chaos-rate, seeded by --chaos) — replicas "
                    "die mid-stream and the fleet must still answer "
                    "every request")
    ap.add_argument("--calibration", default=None,
                    help="path to an autotune calibration table (JSON); "
                    "default: env/cache/repo-default resolution chain")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    if args.eei:
        return serve_eei(args)
    if args.arch is None:
        ap.error("--arch is required unless --eei is given")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = parse_mesh(args.mesh)
    model = LanguageModel(cfg)
    compute_dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    smax = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(args.seed)
    with mesh:
        programs = build_programs(model, mesh, compute_dtype=compute_dtype)
        params = jax.jit(
            model.init, out_shardings=programs.state_shardings.params
        )(rng)
        batch = {"tokens": jax.random.randint(
            rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        batch["labels"] = batch["tokens"]
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                rng, (args.batch, cfg.enc_seq, cfg.d_model)) * 0.02
        if cfg.family == "vlm":
            batch["images"] = jax.random.normal(
                rng, (args.batch, cfg.img_seq, cfg.d_model)) * 0.02

        t0 = time.monotonic()
        logits, caches = jax.jit(
            lambda p, b: model.prefill(cast_tree(p, compute_dtype), b, smax),
            out_shardings=(None, programs.cache_shardings),
        )(params, batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        log.info("prefill %.3fs (B=%d, S=%d)", time.monotonic() - t0,
                 args.batch, args.prompt_len)

        out_tokens = [np.asarray(tok)]
        t0 = time.monotonic()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            tok, caches = programs.serve_step(params, caches, tok, pos)
            out_tokens.append(np.asarray(tok))
        dt = time.monotonic() - t0
        gen = np.stack(out_tokens, axis=1)
        log.info("decode %d tokens x %d seqs in %.3fs (%.1f tok/s)",
                 gen.shape[1], gen.shape[0], dt,
                 gen.size / max(dt, 1e-9))
        print(gen)
    return gen


if __name__ == "__main__":
    main()
