"""Serving launcher: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the production decode path: prefill fills sharded KV caches,
then ``serve_step`` (one token, cache update in place via donated buffers)
iterates.  Request batching is static (continuous batching is an orthogonal
scheduler concern; the cache layout supports it — position is per-batch
scalar here for the dry-run shapes).
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.launch import mesh as mesh_lib
from repro.launch.train import parse_mesh
from repro.models.lm import LanguageModel
from repro.train import build_programs
from repro.train.steps import cast_tree

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = parse_mesh(args.mesh)
    model = LanguageModel(cfg)
    compute_dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    smax = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(args.seed)
    with mesh:
        programs = build_programs(model, mesh, compute_dtype=compute_dtype)
        params = jax.jit(
            model.init, out_shardings=programs.state_shardings.params
        )(rng)
        batch = {"tokens": jax.random.randint(
            rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
        batch["labels"] = batch["tokens"]
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                rng, (args.batch, cfg.enc_seq, cfg.d_model)) * 0.02
        if cfg.family == "vlm":
            batch["images"] = jax.random.normal(
                rng, (args.batch, cfg.img_seq, cfg.d_model)) * 0.02

        t0 = time.monotonic()
        logits, caches = jax.jit(
            lambda p, b: model.prefill(cast_tree(p, compute_dtype), b, smax),
            out_shardings=(None, programs.cache_shardings),
        )(params, batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        log.info("prefill %.3fs (B=%d, S=%d)", time.monotonic() - t0,
                 args.batch, args.prompt_len)

        out_tokens = [np.asarray(tok)]
        t0 = time.monotonic()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            tok, caches = programs.serve_step(params, caches, tok, pos)
            out_tokens.append(np.asarray(tok))
        dt = time.monotonic() - t0
        gen = np.stack(out_tokens, axis=1)
        log.info("decode %d tokens x %d seqs in %.3fs (%.1f tok/s)",
                 gen.shape[1], gen.shape[0], dt,
                 gen.size / max(dt, 1e-9))
        print(gen)
    return gen


if __name__ == "__main__":
    main()
