"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --shape train_4k --steps 100 --mesh 1x1 [--reduced] [--eigenpre]

Wires together: config registry -> model -> sharded programs -> synthetic
data pipeline (prefetched) -> Supervisor (checkpoint/restart, straggler
watchdog) -> training loop.  ``--reduced`` runs the smoke-size config (CPU
container); full-size runs are the same code path on a real mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.data import PrefetchIterator, make_synthetic
from repro.launch import mesh as mesh_lib
from repro.models.lm import LanguageModel
from repro.optim import AdamW, EigenPre
from repro.runtime import Supervisor, SupervisorConfig, StragglerWatchdog
from repro.train import TrainState, build_programs

log = logging.getLogger("repro.train")


def parse_mesh(spec: str):
    parts = [int(p) for p in spec.split("x")]
    if len(parts) == 2:
        return mesh_lib.make_local_mesh(*parts)
    if len(parts) == 3:
        return jax.make_mesh(tuple(parts), ("pod", "data", "model"))
    raise ValueError(f"bad mesh spec {spec!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--shape", choices=sorted(SHAPES), default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="1x1", help="DxM or PxDxM")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size config (CPU)")
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--eigenpre", action="store_true",
                    help="EEI spectral preconditioner (the paper in the loop)")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = SHAPES[args.shape]
    if args.batch or args.seq:
        shape = dataclasses.replace(
            shape,
            global_batch=args.batch or shape.global_batch,
            seq_len=args.seq or shape.seq_len,
        )
    if args.reduced and not (args.batch and args.seq):
        shape = ShapeConfig(shape.name, args.seq or 64, args.batch or 4,
                            shape.kind)

    mesh = parse_mesh(args.mesh)
    model = LanguageModel(cfg)
    optimizer = EigenPre() if args.eigenpre else AdamW()
    compute_dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    log.info("arch=%s params=%.3fM mesh=%s", cfg.name, model.n_params() / 1e6,
             mesh.devices.shape)

    with mesh:
        programs = build_programs(model, mesh, optimizer=optimizer,
                                  compute_dtype=compute_dtype,
                                  microbatch=args.microbatch or None)
        params = jax.jit(
            model.init, out_shardings=programs.state_shardings.params
        )(jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(
            optimizer.init, out_shardings=programs.state_shardings.opt_state
        )(params)
        state = TrainState(params, opt_state, jnp.zeros((), jnp.int32))

        manager = CheckpointManager(
            f"{args.ckpt_dir}/{cfg.name}", keep=3)
        supervisor = Supervisor(
            manager, SupervisorConfig(checkpoint_every=args.ckpt_every))
        supervisor.install_signal_handlers()
        start_step = 0
        if args.resume and manager.latest_step() is not None:
            state, extra = manager.restore(
                state, shardings=programs.state_shardings)
            start_step = extra.get("data_step", manager.latest_step())
            log.info("resumed at step %s", start_step)

        source = make_synthetic(cfg, shape, seed=args.seed)
        data = PrefetchIterator(source, start_step=start_step)
        watchdog = StragglerWatchdog()

        def put(batch):
            return {
                k: jax.device_put(v, programs.batch_shardings[k])
                for k, v in batch.items()
            }

        def step_fn(state, batch):
            return programs.train_step(state, put(batch))

        t_start = time.monotonic()

        def on_metrics(step, metrics, dt):
            watchdog.observe(step, dt)
            if step % args.log_every == 0:
                loss = float(np.asarray(metrics["loss"]))
                gn = float(np.asarray(metrics.get("grad_norm", 0.0)))
                log.info("step %5d loss %.4f |g| %.3f %.2fs/step",
                         step, loss, gn, dt)

        state = supervisor.run(state, data, step_fn, args.steps,
                               state_shardings=programs.state_shardings,
                               on_metrics=on_metrics)
        data.close()
        log.info("done: %d steps in %.1fs (stragglers flagged: %d)",
                 args.steps, time.monotonic() - t_start, watchdog.events)
    return state


if __name__ == "__main__":
    main()
