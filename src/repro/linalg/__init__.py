"""Dense symmetric eigensolver substrate: Householder tridiagonalization,
Sturm-sequence bisection, and interlacing utilities.

These are the pure-JAX reference implementations; the performance-critical
paths have Pallas kernels under ``repro.kernels``.
"""

from repro.linalg.householder import tridiagonalize, tridiagonal_matrix
from repro.linalg.sturm import (
    gershgorin_bounds,
    sturm_count,
    bisect_eigenvalues,
    bisect_eigenvalues_batched,
    bisect_eigenvalues_windowed,
    bisect_eigenvalues_windowed_batched,
)
from repro.linalg.interlace import interlacing_holds, ritz_interlacing_holds
from repro.linalg.lanczos import (
    LanczosResult,
    default_m,
    default_si_m,
    lanczos_partial,
    krylov_reduce,
    krylov_reduce_batched,
    krylov_shift_invert_reduce,
    krylov_shift_invert_reduce_batched,
    shift_invert_sigma,
)

__all__ = [
    "tridiagonalize",
    "tridiagonal_matrix",
    "gershgorin_bounds",
    "sturm_count",
    "bisect_eigenvalues",
    "bisect_eigenvalues_batched",
    "bisect_eigenvalues_windowed",
    "bisect_eigenvalues_windowed_batched",
    "interlacing_holds",
    "ritz_interlacing_holds",
    "LanczosResult",
    "default_m",
    "default_si_m",
    "lanczos_partial",
    "krylov_reduce",
    "krylov_reduce_batched",
    "krylov_shift_invert_reduce",
    "krylov_shift_invert_reduce_batched",
    "shift_invert_sigma",
]
