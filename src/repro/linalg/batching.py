"""Leading-axis vmap lifting shared by the batched linalg entry points."""

from __future__ import annotations

from typing import Callable

import jax


def vmap_leading(fn: Callable, extra_ndim: int) -> Callable:
    """Lift ``fn`` over ``extra_ndim`` leading batch axes of its arguments."""
    for _ in range(extra_ndim):
        fn = jax.vmap(fn)
    return fn
