"""Householder tridiagonalization of a symmetric matrix, pure JAX.

``Q^T A Q = T`` with ``T`` tridiagonal and ``Q`` orthogonal.  This is the
matmul-rich (MXU-friendly) stage of the TPU-native EEI pipeline: once ``A`` is
tridiagonal, every minor spectrum is a decoupled tridiagonal problem
(``repro.core.minors.tridiagonal_minor_bands``) solvable by the Sturm kernel.

Static shapes throughout (masked full-size updates inside ``lax.fori_loop``),
so the function jits once per ``n``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _householder_vector(x: jax.Array, k: jax.Array):
    """Householder vector annihilating ``x[k+2:]`` (entries <= k are masked).

    ``x`` is a full-length column; only indices ``>= k+1`` participate.
    Returns ``(v, beta)`` with ``H = I - beta v v^T`` and ``v`` zero outside
    the active range.  ``beta = 0`` (identity) when the tail is already zero.
    """
    n = x.shape[0]
    active = jnp.arange(n) > k  # rows k+1 .. n-1
    xa = jnp.where(active, x, 0.0)
    head_idx = k + 1
    x0 = xa[head_idx]
    sigma = jnp.sum(jnp.where(jnp.arange(n) > head_idx, xa * xa, 0.0))
    norm = jnp.sqrt(x0 * x0 + sigma)
    # alpha = -sign(x0) * ||x_active|| avoids cancellation.
    sign = jnp.where(x0 >= 0, 1.0, -1.0)
    alpha = -sign * norm
    v0 = x0 - alpha
    v = jnp.where(jnp.arange(n) == head_idx, v0, xa)
    v = jnp.where(active, v, 0.0)
    vnorm2 = jnp.sum(v * v)
    beta = jnp.where(vnorm2 > 0, 2.0 / jnp.maximum(vnorm2, 1e-300), 0.0)
    # Degenerate tail (sigma == 0 and x0 == 0): identity reflection.
    beta = jnp.where(norm > 0, beta, 0.0)
    return v, beta


def tridiagonalize(a: jax.Array, with_q: bool = True):
    """Reduce symmetric ``a`` to tridiagonal form.

    Returns ``(d, e, q)``: diagonal ``(n,)``, off-diagonal ``(n-1,)``, and the
    accumulated orthogonal ``q`` (``q.T @ a @ q`` is tridiagonal); ``q`` is
    ``None`` when ``with_q=False``.
    """
    n = a.shape[0]
    dtype = a.dtype

    def body(k, carry):
        a_k, q_k = carry
        v, beta = _householder_vector(a_k[:, k], k)
        # Symmetric two-sided update: A <- H A H with H = I - beta v v^T.
        p = beta * (a_k @ v)  # (n,)
        kv = 0.5 * beta * jnp.dot(p, v)
        w = p - kv * v
        a_next = a_k - jnp.outer(v, w) - jnp.outer(w, v)
        if with_q:
            # Q <- Q H
            qv = beta * (q_k @ v)
            q_next = q_k - jnp.outer(qv, v)
        else:
            q_next = q_k
        return a_next, q_next

    q0 = jnp.eye(n, dtype=dtype) if with_q else jnp.zeros((1, 1), dtype)
    a_fin, q_fin = jax.lax.fori_loop(0, max(n - 2, 0), body, (a, q0))
    d = jnp.diagonal(a_fin)
    e = jnp.diagonal(a_fin, offset=1)
    return d, e, (q_fin if with_q else None)


def tridiagonalize_batched(a: jax.Array, with_q: bool = True):
    """``tridiagonalize`` mapped over leading batch axes of ``a (..., n, n)``.

    Returns ``(d, e, q)`` with shapes ``(..., n)``, ``(..., n-1)`` and
    ``(..., n, n)`` (``q`` is ``None`` when ``with_q=False``).  Each matrix in
    the stack jits as one fused program — this is the batched entry point the
    SolverEngine's tridiagonal stage uses.
    """
    from repro.linalg.batching import vmap_leading

    return vmap_leading(lambda m: tridiagonalize(m, with_q=with_q),
                        a.ndim - 2)(a)


def tridiagonal_matrix(d: jax.Array, e: jax.Array) -> jax.Array:
    """Dense ``tridiag(e, d, e)`` for testing."""
    n = d.shape[0]
    t = jnp.diag(d)
    if n > 1:
        t = t + jnp.diag(e, 1) + jnp.diag(e, -1)
    return t
