"""Cauchy interlacing utilities.

If ``mu`` is the (sorted) spectrum of any principal minor of ``A`` with
(sorted) spectrum ``lam``, then ``lam[k] <= mu[k] <= lam[k+1]``.  The EEI
products inherit their non-negativity from this, and bisection brackets for
minor spectra can be tightened with it.  Asserted as a hypothesis property in
the test suite.
"""

from __future__ import annotations

import jax.numpy as jnp


def interlacing_holds(lam, mu, rtol: float = 1e-6) -> jnp.ndarray:
    """Boolean scalar: does ``mu`` interlace ``lam`` (up to tolerance)?"""
    lam = jnp.sort(lam)
    mu = jnp.sort(mu)
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    tol = rtol * scale
    lower_ok = jnp.all(mu >= lam[:-1] - tol)
    upper_ok = jnp.all(mu <= lam[1:] + tol)
    return jnp.logical_and(lower_ok, upper_ok)


def interlacing_brackets(lam):
    """Per-index bisection brackets ``(lo, hi)`` for a minor's spectrum."""
    return lam[:-1], lam[1:]
