"""Cauchy interlacing utilities.

If ``mu`` is the (sorted) spectrum of any principal minor of ``A`` with
(sorted) spectrum ``lam``, then ``lam[k] <= mu[k] <= lam[k+1]``.  The EEI
products inherit their non-negativity from this, and bisection brackets for
minor spectra can be tightened with it.  Asserted as a hypothesis property in
the test suite.
"""

from __future__ import annotations

import jax.numpy as jnp


def interlacing_holds(lam, mu, rtol: float = 1e-6) -> jnp.ndarray:
    """Boolean scalar: does ``mu`` interlace ``lam`` (up to tolerance)?"""
    lam = jnp.sort(lam)
    mu = jnp.sort(mu)
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    tol = rtol * scale
    lower_ok = jnp.all(mu >= lam[:-1] - tol)
    upper_ok = jnp.all(mu <= lam[1:] + tol)
    return jnp.logical_and(lower_ok, upper_ok)


def interlacing_brackets(lam):
    """Per-index bisection brackets ``(lo, hi)`` for a minor's spectrum."""
    return lam[:-1], lam[1:]


def ritz_interlacing_holds(lam, theta, rtol: float = 1e-6) -> jnp.ndarray:
    """Boolean scalar: do the Ritz values ``theta`` (size m) satisfy the
    Poincare separation bounds against the full spectrum ``lam`` (size n)?

    For any orthonormal ``Q (n, m)`` the eigenvalues of ``Q^T A Q`` obey
    ``lam[i] <= theta[i] <= lam[i + n - m]`` — the rank-(n-m) generalization
    of the principal-minor Cauchy interlacing above (which is the m = n-1
    case).  Lanczos bands must satisfy this exactly (up to roundoff) when
    the basis stays orthonormal; ghost Ritz values from lost orthogonality
    violate it.
    """
    lam = jnp.sort(lam)
    theta = jnp.sort(theta)
    n = lam.shape[-1]
    m = theta.shape[-1]
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    tol = rtol * scale
    lower_ok = jnp.all(theta >= lam[:m] - tol)
    upper_ok = jnp.all(theta <= lam[n - m:] + tol)
    return jnp.logical_and(lower_ok, upper_ok)
