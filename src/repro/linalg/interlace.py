"""Cauchy interlacing utilities.

If ``mu`` is the (sorted) spectrum of any principal minor of ``A`` with
(sorted) spectrum ``lam``, then ``lam[k] <= mu[k] <= lam[k+1]``.  The EEI
products inherit their non-negativity from this, and bisection brackets for
minor spectra can be tightened with it.  Asserted as a hypothesis property in
the test suite.
"""

from __future__ import annotations

import jax.numpy as jnp


def interlacing_holds(lam, mu, rtol: float = 1e-6) -> jnp.ndarray:
    """Boolean scalar: does ``mu`` interlace ``lam`` (up to tolerance)?"""
    lam = jnp.sort(lam)
    mu = jnp.sort(mu)
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    tol = rtol * scale
    lower_ok = jnp.all(mu >= lam[:-1] - tol)
    upper_ok = jnp.all(mu <= lam[1:] + tol)
    return jnp.logical_and(lower_ok, upper_ok)


def _bracket_scale(lam):
    """Spectral scale used for bracket-width floors: ``max |lam|`` over the
    trailing axis, with a tiny absolute floor so an all-zero spectrum still
    yields usable (non-degenerate) brackets."""
    return jnp.max(jnp.abs(lam), axis=-1, keepdims=True) + 1e-30


def interlacing_brackets(lam, rtol: float = 1e-7):
    """Per-index bisection brackets ``(lo, hi)`` for a minor's spectrum.

    By Cauchy interlacing the minor's ``mu[i]`` lives in
    ``[lam[i], lam[i+1]]``.  When ``lam`` carries exactly-repeated
    eigenvalues that interval is *degenerate* (zero width), which is
    mathematically fine (``mu[i]`` equals the repeated value) but useless
    as a bisection bracket: ``0.5 * (lo + hi)`` never moves and downstream
    interval arithmetic divides by the width.  Clamp every bracket to a
    floor of ``rtol * scale`` width, widened symmetrically about its
    midpoint, so the containment ``lo <= mu <= hi`` is preserved (widening
    only) and every bracket is bisectable.  ``rtol=0`` recovers the raw
    interlacing intervals.
    """
    lam = jnp.asarray(lam)
    lo, hi = lam[..., :-1], lam[..., 1:]
    if rtol <= 0:
        return lo, hi
    floor = rtol * _bracket_scale(lam)
    pad = jnp.maximum(floor - (hi - lo), 0.0) * 0.5
    return lo - pad, hi + pad


def rank1_update_brackets(lam, rho, drift_bound=0.0, rtol: float = 1e-7):
    """Per-index bisection brackets for the spectrum of ``A + rho * u u^T``
    (``u`` unit-norm, ``rho`` the *signed* squared update norm), warm-started
    from the previous spectrum ``lam``.

    Weyl plus rank-1 interlacing pin each updated eigenvalue ``lam'[i]``:

    * ``rho >= 0``:  ``lam[i] <= lam'[i] <= min(lam[i+1], lam[i] + rho)``
      (top index: ``lam[-1] <= lam'[-1] <= lam[-1] + rho``);
    * ``rho <  0``:  ``max(lam[i-1], lam[i] + rho) <= lam'[i] <= lam[i]``
      (bottom index: ``lam[0] + rho <= lam'[0] <= lam[0]``).

    These intervals have width at most ``min(gap, |rho|)`` — for a small
    update that is 2-4 bisection steps instead of the ~50 a Gershgorin
    bracket needs.  ``drift_bound`` widens both ends by an absolute slack
    (accumulated drift allowance + residual fuzz of the cached spectrum),
    and the same ``rtol * scale`` width floor as
    :func:`interlacing_brackets` keeps repeated eigenvalues bisectable.

    ``lam`` is ``(..., m)`` ascending; ``rho`` is scalar or ``(...,)`` and
    broadcasts.  Returns ``(lo, hi)`` of shape ``(..., m)``.
    """
    lam = jnp.asarray(lam)
    rho = jnp.asarray(rho)[..., None]
    up_lo = lam
    up_hi = jnp.minimum(
        jnp.concatenate([lam[..., 1:], lam[..., -1:] + rho], axis=-1),
        lam + rho)
    dn_lo = jnp.maximum(
        jnp.concatenate([lam[..., :1] + rho, lam[..., :-1]], axis=-1),
        lam + rho)
    dn_hi = lam
    pos = rho >= 0
    lo = jnp.where(pos, up_lo, dn_lo) - drift_bound
    hi = jnp.where(pos, up_hi, dn_hi) + drift_bound
    floor = rtol * _bracket_scale(lam)
    pad = jnp.maximum(floor - (hi - lo), 0.0) * 0.5
    return lo - pad, hi + pad


def secular_bracket_refine(lam, z2, rho, lo, hi, n_iter: int = 12):
    """Tighten rank-1 update brackets by bisecting the *secular equation*.

    In the frame of the previous eigenbasis the updated matrix compresses
    to ``diag(lam) + rho * z z^T`` with ``z`` the coefficients of the unit
    update vector (``z2 = z**2``, ``sum(z2) <= 1``); its eigenvalues are
    the roots of the secular function

        ``f(x) = 1 + rho * sum_j z2[j] / (lam[j] - x)``.

    Each updated eigenvalue of the compression lives strictly inside its
    interlacing interval, and ``f`` is monotone there, so a few bisection
    steps on ``f`` shrink ``(lo, hi)`` toward the exact compressed root —
    a cheap ``O(m^2)`` refinement that typically leaves only 1-2 Sturm
    steps for the band solve.  The refined interval never escapes the
    input one, so outer bounds (Weyl + slack) supplied by the caller are
    preserved.  ``lam, z2, lo, hi`` are ``(..., m)``; ``rho`` broadcasts.
    """
    lam = jnp.asarray(lam)[..., None, :]  # (..., 1, m): poles
    z2 = jnp.asarray(z2)[..., None, :]
    # Normalize direction so "g(mid) < 0 => root is to the right" holds for
    # both signs: f' has the sign of rho on each interlacing interval.
    sgn = jnp.where(jnp.asarray(rho) >= 0, 1.0, -1.0)[..., None]
    rho = jnp.asarray(rho)[..., None]

    def f(x):
        # x: (..., m) evaluation points, one per bracket lane.
        d = x[..., :, None] - lam  # (..., m, m)
        # Guard the pole: where x coincides with a pole the sign of the
        # blow-up decides the bisection direction; keep it finite.
        d = jnp.where(jnp.abs(d) < 1e-30, jnp.where(d >= 0, 1e-30, -1e-30),
                      d)
        return 1.0 - rho * jnp.sum(z2 / d, axis=-1)

    for _ in range(n_iter):
        mid = 0.5 * (lo + hi)
        go_right = sgn * f(mid) < 0
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo, hi


def ritz_interlacing_holds(lam, theta, rtol: float = 1e-6) -> jnp.ndarray:
    """Boolean scalar: do the Ritz values ``theta`` (size m) satisfy the
    Poincare separation bounds against the full spectrum ``lam`` (size n)?

    For any orthonormal ``Q (n, m)`` the eigenvalues of ``Q^T A Q`` obey
    ``lam[i] <= theta[i] <= lam[i + n - m]`` — the rank-(n-m) generalization
    of the principal-minor Cauchy interlacing above (which is the m = n-1
    case).  Lanczos bands must satisfy this exactly (up to roundoff) when
    the basis stays orthonormal; ghost Ritz values from lost orthogonality
    violate it.
    """
    lam = jnp.sort(lam)
    theta = jnp.sort(theta)
    n = lam.shape[-1]
    m = theta.shape[-1]
    scale = jnp.maximum(jnp.abs(lam[-1]), jnp.abs(lam[0])) + 1e-30
    tol = rtol * scale
    lower_ok = jnp.all(theta >= lam[:m] - tol)
    upper_ok = jnp.all(theta <= lam[n - m:] + tol)
    return jnp.logical_and(lower_ok, upper_ok)
