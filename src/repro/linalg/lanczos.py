"""Lanczos partial tridiagonalization — the Krylov ``reduce`` stage.

Dense Householder reduction costs O(n^3) with a sequential outer loop — the
wall the EEI pipeline hits at n >= 4096 even though everything downstream of
the reduce stage is O(n k) on the windowed path.  For a top-k window a
Krylov subspace of dimension m << n suffices: m Lanczos steps build an
orthonormal basis ``Q (n, m)`` and a tridiagonal band ``T = Q^T A Q`` whose
extremal Ritz pairs converge to A's extremal eigenpairs long before m
reaches n.  The stage graph makes this *just another reduce stage*: the
``(d, e, q)`` it emits feed the existing windowed Sturm spectrum stage, the
minor-determinant components stage and the sign-recurrence recover stage
unchanged — all of them are band-size agnostic, and the back-transform with
``Q`` lifts band eigenvectors to the dense basis exactly as it does for
Householder's square ``Q``.

Robustness follows the classical playbook:

* **Full reorthogonalization** (CGS2 — "twice is enough") against every
  retained basis vector keeps ``max |Q^T Q - I|`` at machine-epsilon level
  so no ghost Ritz values appear (property-tested across SPD / clustered /
  rank-deficient matrices in ``tests/test_lanczos.py``).
* **Residual-based stopping**: every ``check_every`` steps the windowed
  Ritz values of the current band are bisected and the Ritz residual bound
  ``|A y - theta y| = beta_j |s_j[last]|`` evaluated; the loop exits when
  every windowed pair meets ``rtol`` (relative to the band's spectral
  scale) — or at the ``m`` cap.
* **Breakdown restart**: ``beta_j ~ 0`` means an exact invariant subspace
  was captured.  The iteration restarts with a fresh pseudo-random
  direction orthogonalized against the basis; the band decouples through an
  exactly-zero junction — the same decoupling the serving runtime's
  guard-diagonal embedding relies on — so matrices whose first Krylov space
  is deficient (rank-deficient / high-multiplicity spectra) still fill the
  band.

Unused band slots (early convergence) are filled with a guard value
strictly outside the active band's spectrum on the side *away* from the
requested extreme — the EeiServer guard-embedding convention — so the
downstream windowed stages can never select them.

Shift-and-invert mode runs the same iteration on ``B = (A - sigma I)^{-1}``
via one LU factorization (the same batched ``lu_factor``/``lu_solve``
program shape sign recovery uses): clustered extremal spectra that direct
Lanczos separates slowly spread out as ``theta = 1/(lambda - sigma)``, and
the recover chain maps Ritz values back with ``lambda = sigma + 1/theta``
(see the ``shift_invert_map`` stage in ``engine/engine.py``).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import identity
from repro.linalg import sturm

#: Krylov band sizing for a k-window: ``m = min(n, max(FACTOR * k, MIN))``.
#: Measured on the reference container (GOE f32): the top-k=16 window at
#: n = 4096 needs m ~ 16k for a ~1e-3-relative spectrum (m = 128 leaves
#: ~2e-2); k = 4 converges by m = 128.  ``SolverPlan.krylov_m`` overrides.
KRYLOV_M_FACTOR = 16
KRYLOV_M_MIN = 128

#: Shift-and-invert band sizing: the inverted operator separates the target
#: cluster, so far fewer steps are needed per converged pair.
KRYLOV_SI_M_FACTOR = 8
KRYLOV_SI_M_MIN = 64

#: Shift margin for shift-and-invert, as a fraction of the Gershgorin span:
#: sigma sits this far outside the spectrum on the requested side (small, so
#: ``theta = 1/(lambda - sigma)`` strongly amplifies the extremal cluster).
SI_MARGIN_FRAC = 1e-3


def default_m(n: int, k: int) -> int:
    """Default Krylov band size for a direct top-k window at size ``n``."""
    return min(n, max(KRYLOV_M_FACTOR * k, KRYLOV_M_MIN))


def default_si_m(n: int, k: int) -> int:
    """Default band size for the shift-and-invert mode."""
    return min(n, max(KRYLOV_SI_M_FACTOR * k, KRYLOV_SI_M_MIN))


def _resolve_m(n: int, k: int, m: int, si: bool = False) -> int:
    if m:
        return min(n, max(int(m), k))
    return default_si_m(n, k) if si else default_m(n, k)


def _default_rtol(dtype) -> float:
    return 1e-12 if jnp.dtype(dtype) == jnp.float64 else 1e-5


class LanczosResult(NamedTuple):
    """One partial tridiagonalization, guard-masked and engine-oriented."""

    d: jax.Array  # (m,) band diagonal; guard value beyond `steps`
    e: jax.Array  # (m-1,) band off-diagonal; 0 beyond the active block
    q: jax.Array  # (n, m) columns are the Lanczos basis; 0 beyond `steps`
    steps: jax.Array  # () int32 — Lanczos steps actually taken
    resid: jax.Array  # (k,) last windowed Ritz residual bound (relative)


def _band_bounds(d: jax.Array, e_band: jax.Array, active: jax.Array):
    """Gershgorin ``(lo, hi)`` of the *active* rows of a masked band."""
    m = d.shape[0]
    rad = jnp.zeros((m,), d.dtype)
    if m > 1:
        rad = rad.at[:-1].add(jnp.abs(e_band))
        rad = rad.at[1:].add(jnp.abs(e_band))
    lo = jnp.min(jnp.where(active, d - rad, jnp.inf))
    hi = jnp.max(jnp.where(active, d + rad, -jnp.inf))
    return lo, hi


def _guard_value(d, e_band, active, largest: bool):
    """Guard for inactive band slots: strictly outside the active block's
    spectrum, on the side away from the requested extreme (the serving
    runtime's guard-diagonal convention)."""
    lo, hi = _band_bounds(d, e_band, active)
    floor = jnp.asarray(jnp.finfo(d.dtype).tiny, d.dtype) ** 0.5
    margin = 0.01 * (hi - lo) + 1e-3 * (jnp.abs(hi) + jnp.abs(lo)) + floor
    return lo - margin if largest else hi + margin


def _mask_band(d, e, j, m, largest: bool):
    """Guard-fill band entries beyond ``j`` active steps; returns the
    ``(m,)`` diagonal and ``(m-1,)`` off-diagonal the spectrum stage sees."""
    idx = jnp.arange(m)
    e_band = (jnp.where(idx[: m - 1] < j - 1, e[: m - 1], 0.0)
              if m > 1 else e[:0])
    active = idx < j
    guard = _guard_value(d, e_band, active, largest)
    return jnp.where(active, d, guard), e_band


def lanczos_iterate(
    a: jax.Array,
    m: int,
    *,
    window: Optional[Tuple[int, bool]] = None,
    matvec=None,
    rtol: float = 0.0,
    check_every: int = 32,
    seed: int = 0,
):
    """Raw m-step Lanczos loop on one matrix (or abstract ``matvec``).

    Returns ``(d (m,), e (m,), Q (m+1, n) rows, steps, resid)`` — the
    unmasked internals; :func:`lanczos_partial` is the masked public form.
    ``window=(k, largest)`` enables the windowed Ritz residual stop.
    """
    n = a.shape[-1]
    dtype = a.dtype
    mv = matvec if matvec is not None else (lambda v: a @ v)
    if not 1 <= m <= n:
        raise ValueError(f"Krylov band m={m} out of range for n={n}")
    if window is not None and not 1 <= window[0] <= m:
        raise ValueError(f"window k={window[0]} out of range for m={m}")
    rtol = float(rtol) if rtol else _default_rtol(dtype)
    eps = jnp.asarray(jnp.finfo(dtype).eps, dtype)
    floor = jnp.asarray(jnp.finfo(dtype).tiny, dtype) ** 0.5

    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (n,), dtype)
    v0 = v0 / jnp.linalg.norm(v0)
    k_win = window[0] if window is not None else 1

    def ritz_resid(d, e, j1, beta):
        """Relative Ritz residual bound for the k windowed pairs of the
        current masked band: ``beta_j |s_i[j-1]| / scale``."""
        k, largest = window
        d_m, e_m = _mask_band(d, e, j1, m, largest)
        theta = sturm.bisect_eigenvalues_windowed(d_m, e_m, k, largest)
        mags = identity.tridiag_windowed_magnitudes(d_m, e_m, theta)
        s_last = jnp.sqrt(jnp.maximum(mags[:, j1 - 1], 0.0))
        lo, hi = _band_bounds(d_m, e_m, jnp.arange(m) < j1)
        scale = jnp.maximum(jnp.maximum(jnp.abs(lo), jnp.abs(hi)), floor)
        return beta * s_last / scale

    def body(carry):
        Q, d, e, j, resid, done = carry
        qj = Q[j]
        w = mv(qj)
        alpha = jnp.dot(qj, w)
        w = w - alpha * qj
        # Full reorthogonalization, CGS2: rows of Q beyond the basis are
        # exactly zero, so no masking is needed in the projections.
        w = w - Q.T @ (Q @ w)
        w = w - Q.T @ (Q @ w)
        beta = jnp.linalg.norm(w)
        d = d.at[j].set(alpha)
        scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.max(jnp.abs(e)))
        breakdown = beta <= jnp.maximum(100.0 * eps * scale, floor)

        def restart(_):
            # Invariant subspace captured: continue in a fresh direction
            # orthogonal to the basis (one projection pass suffices for a
            # random vector), through an exactly-zero band junction.
            r = jax.random.normal(jax.random.fold_in(key, j + 1), (n,), dtype)
            r = r - Q.T @ (Q @ r)
            rn = jnp.linalg.norm(r)
            return jnp.where(rn > floor, r / jnp.maximum(rn, floor), 0.0)

        qn = jax.lax.cond(
            breakdown, restart,
            lambda _: w / jnp.maximum(beta, floor), None)
        e = e.at[j].set(jnp.where(breakdown, 0.0, beta))
        Q = Q.at[j + 1].set(qn)
        j1 = j + 1
        if window is not None:
            do_check = (j1 % check_every == 0) & (j1 >= k_win + 1)
            resid = jax.lax.cond(
                do_check,
                lambda _: ritz_resid(d, e, j1, beta),
                lambda _: resid, None)
            done = jnp.all(resid <= rtol)
        return Q, d, e, j1, resid, done

    def cond(carry):
        _, _, _, j, _, done = carry
        return (j < m) & (~done)

    carry0 = (
        jnp.zeros((m + 1, n), dtype).at[0].set(v0),
        jnp.zeros((m,), dtype),
        jnp.zeros((m,), dtype),
        jnp.asarray(0, jnp.int32),
        jnp.full((k_win,), jnp.inf, dtype),
        jnp.asarray(False),
    )
    Q, d, e, j, resid, _ = jax.lax.while_loop(cond, body, carry0)
    return d, e, Q, j, resid


def lanczos_partial(
    a: jax.Array,
    m: int,
    k: int,
    largest: bool = True,
    *,
    matvec=None,
    rtol: float = 0.0,
    check_every: int = 32,
    seed: int = 0,
) -> LanczosResult:
    """Guard-masked m-step Lanczos band + basis for a ``(k, largest)`` window.

    ``d (m,)`` / ``e (m-1,)`` carry the active block with inactive slots
    guard-filled away from the window; ``q (n, m)`` columns are the basis
    (zero beyond ``steps``).  The triple plugs directly into the windowed
    spectrum/components/recover stages.
    """
    d, e, Q, j, resid = lanczos_iterate(
        a, m, window=(k, largest), matvec=matvec, rtol=rtol,
        check_every=check_every, seed=seed)
    d_m, e_m = _mask_band(d, e, j, m, largest)
    # Row `steps` of Q was written by the last body step but is outside the
    # retained basis — zero everything beyond the active block.
    q = jnp.where(jnp.arange(m)[:, None] < j, Q[:m], 0.0)
    return LanczosResult(d_m, e_m, jnp.swapaxes(q, -1, -2), j, resid)


# ---------------------------------------------------------------------------
# Engine stage entry points (batched)
# ---------------------------------------------------------------------------


def krylov_reduce(a: jax.Array, k: int, largest: bool = True, m: int = 0,
                  rtol: float = 0.0):
    """Single-matrix krylov reduce stage: ``(d, e, q)`` for a top-k window."""
    n = a.shape[-1]
    mm = _resolve_m(n, k, m)
    res = lanczos_partial(a, mm, min(k, mm), largest, rtol=rtol)
    return res.d, res.e, res.q


@functools.partial(jax.jit, static_argnames=("k", "largest", "m", "rtol"))
def krylov_reduce_batched(a: jax.Array, k: int, largest: bool = True,
                          m: int = 0, rtol: float = 0.0):
    """Leading-axis batched :func:`krylov_reduce`."""
    from repro.linalg.batching import vmap_leading

    fn = lambda aa: krylov_reduce(aa, k, largest, m, rtol)
    return vmap_leading(fn, a.ndim - 2)(a)


def shift_invert_sigma(a: jax.Array, largest: bool = True):
    """Gershgorin shift strictly outside the spectrum on the target side."""
    radius = jnp.sum(jnp.abs(a), axis=-1) - jnp.abs(jnp.diagonal(a))
    diag = jnp.diagonal(a)
    lo = jnp.min(diag - radius)
    hi = jnp.max(diag + radius)
    floor = jnp.asarray(jnp.finfo(a.dtype).tiny, a.dtype) ** 0.5
    margin = SI_MARGIN_FRAC * (hi - lo) + 1e-6 * (
        jnp.abs(hi) + jnp.abs(lo)) + floor
    return hi + margin if largest else lo - margin


def krylov_shift_invert_reduce(a: jax.Array, k: int, largest: bool = True,
                               m: int = 0, rtol: float = 0.0):
    """Shift-and-invert krylov reduce: ``(d, e, q, sigma)`` in theta-space.

    Lanczos runs on ``B = (A - sigma I)^{-1}`` through one LU
    factorization; the band's Ritz values are ``theta = 1/(lambda - sigma)``
    and the *opposite* extreme of theta corresponds to the requested extreme
    of lambda (the ``shift_invert_map`` recover stage undoes both).
    """
    n = a.shape[-1]
    mm = _resolve_m(n, k, m, si=True)
    sigma = shift_invert_sigma(a, largest)
    lu, piv = jax.scipy.linalg.lu_factor(
        a - sigma * jnp.eye(n, dtype=a.dtype))
    mv = lambda v: jax.scipy.linalg.lu_solve((lu, piv), v)
    res = lanczos_partial(a, mm, min(k, mm), not largest, matvec=mv,
                          rtol=rtol)
    return res.d, res.e, res.q, sigma


@functools.partial(jax.jit, static_argnames=("k", "largest", "m", "rtol"))
def krylov_shift_invert_reduce_batched(a: jax.Array, k: int,
                                       largest: bool = True, m: int = 0,
                                       rtol: float = 0.0):
    """Leading-axis batched :func:`krylov_shift_invert_reduce`."""
    from repro.linalg.batching import vmap_leading

    fn = lambda aa: krylov_shift_invert_reduce(aa, k, largest, m, rtol)
    return vmap_leading(fn, a.ndim - 2)(a)
