"""Sturm-sequence bisection eigenvalues for symmetric tridiagonal matrices.

Pure-JAX reference for the ``repro.kernels.sturm`` Pallas kernel.  Bisection
is branch-free, fixed-iteration, and embarrassingly parallel across eigenvalue
indices — the TPU-native replacement for LAPACK's divide & conquer, and the
engine behind minor spectra in the EEI pipeline (every minor of a tridiagonal
matrix is tridiagonal; see ``repro.core.minors``).

The Sturm count uses the LAPACK ``dstebz``-style recurrence

    q_0 = d_0 - x ;  q_k = d_k - x - e_{k-1}^2 / q_{k-1}

where ``count(x) = #{k : q_k < 0}`` equals the number of eigenvalues < x.
A ``pivmin`` floor keeps the recurrence finite when ``q`` crosses zero.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def gershgorin_bounds(d: jax.Array, e: jax.Array):
    """(lo, hi) scalars bounding the whole spectrum."""
    n = d.shape[0]
    r = jnp.zeros((n,), d.dtype)
    if n > 1:
        r = r.at[:-1].add(jnp.abs(e))
        r = r.at[1:].add(jnp.abs(e))
    lo = jnp.min(d - r)
    hi = jnp.max(d + r)
    span = jnp.maximum(hi - lo, 1.0)
    eps = jnp.asarray(jnp.finfo(d.dtype).eps, d.dtype)
    return lo - eps * span, hi + eps * span


def _pivmin(d, e):
    eps = jnp.finfo(d.dtype).eps
    scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.max(jnp.abs(e)) if e.shape[0] else 0.0)
    tiny = jnp.asarray(jnp.finfo(d.dtype).tiny, d.dtype)
    return jnp.maximum(eps * eps * scale * scale, tiny)


def sturm_count(d: jax.Array, e: jax.Array, x: jax.Array) -> jax.Array:
    """Number of eigenvalues strictly below ``x``.

    ``x`` may be a vector of shift points — the recurrence is vectorized over
    it (this is the lane-parallel structure the Pallas kernel exploits).
    """
    x = jnp.asarray(x)
    e2 = e * e if e.shape[0] else jnp.zeros((0,), d.dtype)
    pivmin = _pivmin(d, e)

    def step(carry, dk_e2k):
        q_prev, count = carry
        dk, e2k = dk_e2k
        q = dk - x - e2k / q_prev
        q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
        return (q, count + (q < 0).astype(jnp.int32)), None

    q0 = d[0] - x
    q0 = jnp.where(jnp.abs(q0) < pivmin, -pivmin, q0)
    count0 = (q0 < 0).astype(jnp.int32)
    (q_fin, count), _ = jax.lax.scan(step, (q0, count0), (d[1:], e2))
    del q_fin
    return count


@functools.partial(jax.jit, static_argnames=("n_iter",))
def bisect_eigenvalues(d: jax.Array, e: jax.Array, n_iter: int = 0) -> jax.Array:
    """All eigenvalues of ``tridiag(e, d, e)`` by index-targeted bisection.

    Fixed-iteration bisection: eigenvalue ``m`` is bracketed by maintaining
    ``count(lo_m) <= m < count(hi_m)``; every iteration halves every bracket
    simultaneously (one vectorized Sturm sweep per iteration).  The full
    spectrum is the ``k = n`` window — one bisection body serves both, so
    the windowed path's bitwise-equality contract cannot drift.
    """
    return bisect_eigenvalues_windowed(d, e, d.shape[0], n_iter=n_iter)


@functools.partial(jax.jit, static_argnames=("n_iter",))
def bisect_eigenvalues_batched(d: jax.Array, e: jax.Array, n_iter: int = 0):
    """Batched over leading axes: ``d (..., n)``, ``e (..., n-1)``."""
    from repro.linalg.batching import vmap_leading

    fn = lambda dd, ee: bisect_eigenvalues(dd, ee, n_iter=n_iter)
    return vmap_leading(fn, d.ndim - 1)(d, e)


@functools.partial(jax.jit, static_argnames=("k", "largest", "n_iter"))
def bisect_eigenvalues_windowed(
    d: jax.Array, e: jax.Array, k: int, largest: bool = True, n_iter: int = 0
) -> jax.Array:
    """The ``k`` extremal eigenvalues by index-targeted bisection, ascending.

    The Sturm counting function brackets eigenvalues *by index*, so a
    partial-spectrum query needs only ``k`` bisection lanes instead of ``n``
    — this is the windowed spectrum stage of the stage graph.  Every lane
    runs exactly the iterations the full bisection would run for its index
    (same Gershgorin start bracket, same count function), so the window is
    **bitwise-equal** to the matching slice of :func:`bisect_eigenvalues`.

    Returns the ``k`` *largest* (indices ``n-k .. n-1``) or *smallest*
    (indices ``0 .. k-1``) eigenvalues, ascending either way.
    """
    n = d.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"window k={k} out of range for n={n}")
    if n_iter == 0:
        n_iter = 64 if d.dtype == jnp.float64 else 32
    lo0, hi0 = gershgorin_bounds(d, e)
    targets = jnp.arange(n - k, n) if largest else jnp.arange(k)
    lo = jnp.full((k,), lo0, d.dtype)
    hi = jnp.full((k,), hi0, d.dtype)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        c = sturm_count(d, e, mid)
        go_right = c <= targets
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    return 0.5 * (lo + hi)


@functools.partial(jax.jit, static_argnames=("k", "largest", "n_iter"))
def bisect_eigenvalues_bracketed(
    d: jax.Array, e: jax.Array, lo: jax.Array, hi: jax.Array,
    k: int, largest: bool = True, n_iter: int = 0,
) -> jax.Array:
    """The ``k`` extremal eigenvalues from *caller-supplied* per-index
    brackets — the warm-started entry behind the rank-1 update path.

    Identical bisection body to :func:`bisect_eigenvalues_windowed`, but
    each lane starts from ``(lo[t], hi[t])`` (e.g. interlacing-tightened
    brackets from ``repro.linalg.interlace.rank1_update_brackets``) instead
    of the global Gershgorin interval — for a small rank-1 drift the start
    bracket is already within a few ulps of the answer, so a handful of
    iterations suffice where the cold start needs ~50.

    Warm brackets are a *hint*, never trusted: one pair of Sturm sweeps
    validates ``count(lo[t]) <= t < count(hi[t])`` per lane, and any lane
    whose bracket does not provably contain its target index falls back to
    the Gershgorin interval.  The result is therefore index-correct for the
    band regardless of how stale the caller's spectrum was.
    """
    n = d.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"window k={k} out of range for n={n}")
    if n_iter == 0:
        n_iter = 64 if d.dtype == jnp.float64 else 32
    lo0, hi0 = gershgorin_bounds(d, e)
    targets = jnp.arange(n - k, n) if largest else jnp.arange(k)
    lo = jnp.asarray(lo, d.dtype)
    hi = jnp.asarray(hi, d.dtype)
    ok = (sturm_count(d, e, lo) <= targets) & \
        (sturm_count(d, e, hi) > targets) & (lo <= hi)
    lo = jnp.where(ok, lo, lo0)
    hi = jnp.where(ok, hi, hi0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        c = sturm_count(d, e, mid)
        go_right = c <= targets
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    return 0.5 * (lo + hi)


@functools.partial(jax.jit, static_argnames=("k", "largest", "n_iter"))
def bisect_eigenvalues_bracketed_batched(
    d: jax.Array, e: jax.Array, lo: jax.Array, hi: jax.Array,
    k: int, largest: bool = True, n_iter: int = 0,
):
    """Batched :func:`bisect_eigenvalues_bracketed` over leading axes."""
    from repro.linalg.batching import vmap_leading

    fn = lambda dd, ee, ll, hh: bisect_eigenvalues_bracketed(
        dd, ee, ll, hh, k, largest=largest, n_iter=n_iter)
    return vmap_leading(fn, d.ndim - 1)(d, e, lo, hi)


@functools.partial(jax.jit, static_argnames=("k", "largest", "n_iter"))
def bisect_eigenvalues_windowed_batched(
    d: jax.Array, e: jax.Array, k: int, largest: bool = True, n_iter: int = 0
):
    """Batched :func:`bisect_eigenvalues_windowed` over leading axes."""
    from repro.linalg.batching import vmap_leading

    fn = lambda dd, ee: bisect_eigenvalues_windowed(
        dd, ee, k, largest=largest, n_iter=n_iter)
    return vmap_leading(fn, d.ndim - 1)(d, e)
