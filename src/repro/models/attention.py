"""Attention mixers: GQA/MQA (RoPE, sliding window, softcap), MLA, cross-attn.

All attention is *chunked* (online-softmax over KV chunks, statically
unrolled) so no ``S x S`` score matrix is ever materialized — the lowered HLO
contains no while-loops (a hard requirement of the roofline methodology, see
EXPERIMENTS.md §Methodology) and fully-masked chunk pairs are skipped at
trace time (real FLOP savings for sliding-window layers).

Conventions:
  x          (B, S, d)
  q          (B, S, H, Dh);  k/v (B, S, Hkv, Dh)
  cache      {"k": (B, Smax, Hkv, Dh), "v": ...} + scalar position carried by
             the caller; decode is a single unchunked einsum over Smax.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.params import ParamDecl, ParamTable
from repro.sharding import hints


def _sequence_parallel_qkv(cfg, q, k, v):
    """Context-parallel fallback for head counts indivisible by the model
    axis (whisper 20H, starcoder2 36H, gemma2 8H on a 16-way axis).

    Without this, head-sharding fails divisibility and GSPMD replicates the
    whole attention computation per model shard (measured 5-12x useful-flops
    inflation; EXPERIMENTS.md §Perf whisper iteration 1).  Sharding the query
    sequence dimension instead divides score/output flops by the model size;
    K/V stay sequence-sharded until the chunked loop gathers them (KV tensors
    are the small GQA side).
    """
    if cfg.n_heads % hints.model_axis_size() == 0:
        # Head sharding divides — but pin it explicitly: prefill writes the
        # KV cache with *sequence*-sharded layout, and GSPMD propagates that
        # backward into the attention K/V, turning every AV/score einsum
        # into a partial-sum all-reduce (measured: ~1.1e11 B/device/layer on
        # deepseek prefill — the whole collective-bound verdict; §Perf D3).
        if cfg.n_kv_heads % hints.model_axis_size() == 0:
            k = hints.constrain(k, "data", None, "model", None)
            v = hints.constrain(v, "data", None, "model", None)
        q = hints.constrain(q, "data", None, "model", None)
        return q, k, v
    q = hints.constrain(q, "data", "model", None, None)
    k = hints.constrain(k, "data", "model", None, None)
    v = hints.constrain(v, "data", "model", None, None)
    return q, k, v


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    causal: bool = True
    window: int | None = None  # sliding window (gemma2 local layers)
    softcap: float | None = None  # attn logit softcap (gemma2)
    use_rope: bool = True
    chunk_q: int = 1024
    chunk_k: int = 1024

    @property
    def rep(self) -> int:
        return self.n_heads // self.n_kv_heads


def attn_param_table(cfg: AttnConfig) -> ParamTable:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamDecl((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamDecl((d, hk, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDecl((d, hk, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDecl((h, dh, d), ("heads", "head_dim", "embed"), init="output",
                        fan_in=h * dh),
    }


# ---------------------------------------------------------------------------
# Chunked online-softmax attention core
# ---------------------------------------------------------------------------


def _chunk_skippable(cfg, q_lo, q_hi, k_lo, k_hi) -> bool:
    """Static: is the (q-chunk, k-chunk) pair fully masked?"""
    if cfg.causal and k_lo > q_hi:
        return True
    if cfg.window is not None and k_hi <= q_lo - cfg.window:
        return True
    return False


def _fit_chunk(s: int, c: int) -> int:
    """Largest divisor of ``s`` that is <= c (chunking odd sequence lengths
    like whisper's 1500 encoder frames)."""
    c = min(c, s)
    while s % c:
        c -= 1
    return c


def chunked_attention(
    cfg: AttnConfig,
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Sk, Hkv, Dh)
    v: jax.Array,  # (B, Sk, Hkv, Dh)
    q_start: int = 0,
) -> jax.Array:
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    hkv, rep = cfg.n_kv_heads, cfg.rep
    scale = 1.0 / math.sqrt(dh)
    cq = _fit_chunk(sq, cfg.chunk_q)
    ck = _fit_chunk(sk, cfg.chunk_k)

    qr = q.reshape(b, sq, hkv, rep, dh)
    out_chunks = []
    for qi in range(sq // cq):
        q_lo, q_hi = q_start + qi * cq, q_start + (qi + 1) * cq - 1
        qc = qr[:, qi * cq : (qi + 1) * cq]  # (B,Cq,hkv,rep,Dh)
        m = jnp.full((b, hkv, rep, cq), common.NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, rep, cq), jnp.float32)
        acc = jnp.zeros((b, hkv, rep, cq, dh), jnp.float32)
        q_pos = q_start + qi * cq + jnp.arange(cq)
        for ki in range(sk // ck):
            k_lo, k_hi = ki * ck, (ki + 1) * ck - 1
            if _chunk_skippable(cfg, q_lo, q_hi, k_lo, k_hi):
                continue
            kc = k[:, ki * ck : (ki + 1) * ck]
            vc = v[:, ki * ck : (ki + 1) * ck]
            s = jnp.einsum(
                "bqgrd,bkgd->bgrqk", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            if cfg.softcap is not None:
                s = common.softcap(s, cfg.softcap)
            k_pos = ki * ck + jnp.arange(ck)
            if cfg.causal:
                mask = common.causal_window_mask(q_pos, k_pos, cfg.window)
            elif cfg.window is not None:
                mask = jnp.abs(k_pos[None, :] - q_pos[:, None]) < cfg.window
            else:
                mask = None
            if mask is not None:
                s = jnp.where(mask[None, None, None], s, common.NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            m = m_new
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out_chunks.append(
            out.transpose(0, 3, 1, 2, 4).reshape(b, cq, h, dh).astype(q.dtype)
        )
    return jnp.concatenate(out_chunks, axis=1) if len(out_chunks) > 1 else out_chunks[0]


def decode_attention(
    cfg: AttnConfig,
    q: jax.Array,  # (B, 1, H, Dh)
    k_cache: jax.Array,  # (B, Smax, Hkv, Dh)
    v_cache: jax.Array,
    pos: jax.Array,  # scalar int32 — current position (same for the batch)
) -> jax.Array:
    b, _, h, dh = q.shape
    smax = k_cache.shape[1]
    hkv, rep = cfg.n_kv_heads, cfg.rep
    scale = 1.0 / math.sqrt(dh)
    qr = q.reshape(b, hkv, rep, dh)
    s = jnp.einsum(
        "bgrd,bkgd->bgrk", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if cfg.softcap is not None:
        s = common.softcap(s, cfg.softcap)
    k_pos = jnp.arange(smax)
    valid = k_pos <= pos
    if cfg.window is not None:
        valid = jnp.logical_and(valid, k_pos > pos - cfg.window)
    s = jnp.where(valid[None, None, None], s, common.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA self-attention block mixer
# ---------------------------------------------------------------------------


def self_attention(cfg: AttnConfig, p: dict, x: jax.Array, positions: jax.Array):
    """Training / prefill. Returns (out, kv) so callers may fill a cache."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.use_rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    q, k, v = _sequence_parallel_qkv(cfg, q, k, v)
    out = chunked_attention(cfg, q, k, v)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), (k, v)


def self_attention_decode(
    cfg: AttnConfig, p: dict, x: jax.Array, cache: dict, pos: jax.Array
):
    """Single-token decode; cache entries updated at ``pos``."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.use_rope:
        posb = jnp.full((x.shape[0], 1), pos)
        q = common.apply_rope(q, posb, cfg.rope_theta)
        k = common.apply_rope(k, posb, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    out = decode_attention(cfg, q, k_cache, v_cache, pos)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), {"k": k_cache, "v": v_cache}


def attn_cache_spec(cfg: AttnConfig, batch: int, smax: int, dtype):
    shp = (batch, smax, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dtype), "v": jax.ShapeDtypeStruct(shp, dtype)}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder, vision-LM image layers)
# ---------------------------------------------------------------------------


def cross_attention(cfg: AttnConfig, p: dict, x: jax.Array, kv_src: jax.Array):
    """kv_src: (B, Se, d) encoder/vision states. Bidirectional over kv_src."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", kv_src, p["wv"])
    xcfg = dataclasses.replace(cfg, causal=False, window=None, use_rope=False)
    se = k.shape[1]
    ck = se if se < xcfg.chunk_k else xcfg.chunk_k
    while se % ck:
        ck -= 1
    xcfg = dataclasses.replace(xcfg, chunk_k=ck, chunk_q=min(xcfg.chunk_q, x.shape[1]))
    out = chunked_attention(xcfg, q, k, v)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), (k, v)


def cross_attention_cached(cfg: AttnConfig, p: dict, x: jax.Array, cache: dict):
    """Decode-side cross-attn against precomputed (k, v)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    b, _, h, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    qr = q.reshape(b, cfg.n_kv_heads, cfg.rep, dh)
    s = jnp.einsum("bgrd,bkgd->bgrk", qr, cache["k"],
                   preferred_element_type=jnp.float32) * scale
    pbs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", pbs.astype(cache["v"].dtype), cache["v"],
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h, dh).astype(x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 10000.0
    chunk_q: int = 1024
    chunk_k: int = 1024

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_param_table(cfg: MLAConfig) -> ParamTable:
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wdq": ParamDecl((d, cfg.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ParamDecl((cfg.q_lora_rank,), ("q_lora",), init="zeros"),
        "wuq": ParamDecl((cfg.q_lora_rank, h, cfg.qk_dim),
                         ("q_lora", "heads", "head_dim")),
        "wdkv": ParamDecl((d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                          ("embed", "kv_lora")),
        "kv_norm": ParamDecl((cfg.kv_lora_rank,), ("kv_lora",), init="zeros"),
        "wuk": ParamDecl((cfg.kv_lora_rank, h, cfg.qk_nope_dim),
                         ("kv_lora", "heads", "head_dim")),
        "wuv": ParamDecl((cfg.kv_lora_rank, h, cfg.v_dim),
                         ("kv_lora", "heads", "head_dim")),
        "wo": ParamDecl((h, cfg.v_dim, d), ("heads", "head_dim", "embed"),
                        init="output", fan_in=h * cfg.v_dim),
    }


def _mla_q(cfg: MLAConfig, p: dict, x: jax.Array, positions: jax.Array):
    ql = common.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", ql, p["wuq"])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = common.apply_rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg: MLAConfig, p: dict, x: jax.Array, positions: jax.Array):
    dkv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    latent = common.rms_norm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = common.apply_rope(
        dkv[..., cfg.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]  # (B, S, dr) — single shared rope key
    return latent, k_rope


def mla_attention(cfg: MLAConfig, p: dict, x: jax.Array, positions: jax.Array):
    """Prefill/train: materialize per-head K/V from the latent, chunked."""
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", latent, p["wuk"])
    v = jnp.einsum("bsr,rhe->bshe", latent, p["wuv"])
    h = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_rope.shape[:2], h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    acfg = AttnConfig(
        d_model=cfg.d_model, n_heads=h, n_kv_heads=h, head_dim=cfg.qk_dim,
        use_rope=False, chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
    )
    # Pin attention-time sharding to heads: the latent cache output is
    # sequence-sharded and would otherwise propagate into these K/V (§Perf D3).
    q, k, v = _sequence_parallel_qkv(acfg, q, k, v)
    # v_dim != qk_dim: pad V to qk_dim for the shared core, then slice.
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, cfg.qk_dim - cfg.v_dim)))
    out = chunked_attention(acfg, q, k, v_p)[..., : cfg.v_dim]
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return y, (latent, k_rope)


def mla_attention_decode(cfg: MLAConfig, p: dict, x: jax.Array, cache: dict,
                         pos: jax.Array):
    """Absorbed decode: scores and values live in the latent space — the KV
    cache is ``(latent, k_rope)`` only (MLA's memory win)."""
    b = x.shape[0]
    posb = jnp.full((b, 1), pos)
    q_nope, q_rope = _mla_q(cfg, p, x, posb)  # (B,1,H,*)
    latent_new, k_rope_new = _mla_latent(cfg, p, x, posb)
    latent = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent_new.astype(cache["latent"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1)
    # Absorb W_uk into the query: q_abs (B,H,r)
    q_abs = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], p["wuk"])
    s = jnp.einsum("bhr,bkr->bhk", q_abs, latent, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhe,bke->bhk", q_rope[:, 0], k_rope,
                       preferred_element_type=jnp.float32)
    s = s / math.sqrt(cfg.qk_dim)
    k_posn = jnp.arange(latent.shape[1])
    s = jnp.where((k_posn <= pos)[None, None], s, common.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", pr.astype(latent.dtype), latent,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bhr,rhe->bhe", o_lat, p["wuv"])
    y = jnp.einsum("bhe,hed->bd", out, p["wo"])[:, None]
    return y, {"latent": latent, "k_rope": k_rope}


def mla_cache_spec(cfg: MLAConfig, batch: int, smax: int, dtype):
    return {
        "latent": jax.ShapeDtypeStruct((batch, smax, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, smax, cfg.qk_rope_dim), dtype),
    }
