"""Block assembly: ModelConfig block-kind -> params, apply, decode, cache.

A "block" is one residual unit.  Attention-family blocks are
(pre-norm mixer + pre-norm FFN); recurrent blocks (mamba/mlstm/slstm) are
self-contained.  All functions are pure; parameters are flat
``{path: array}`` dicts scoped by the caller.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common, mlp, moe, ssm, xlstm
from repro.models.params import ParamDecl, ParamTable, merge_tables, prefix_table


# ---------------------------------------------------------------------------
# Sub-config builders
# ---------------------------------------------------------------------------


def attn_config(cfg: ModelConfig, kind: str) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        causal=kind != "attn_bidir",
        window=cfg.window if kind == "attn_local" else None,
        softcap=cfg.attn_softcap,
        use_rope=kind != "attn_bidir" or cfg.family != "audio",
        chunk_q=cfg.attn_chunk,
        chunk_k=cfg.attn_chunk,
    )


def mla_config(cfg: ModelConfig) -> attn.MLAConfig:
    return attn.MLAConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim,
        v_dim=cfg.v_head_dim,
        rope_theta=cfg.rope_theta,
        chunk_q=cfg.attn_chunk,
        chunk_k=cfg.attn_chunk,
    )


def mlp_config(cfg: ModelConfig) -> mlp.MLPConfig:
    return mlp.MLPConfig(cfg.d_model, cfg.d_ff, cfg.activation)


def moe_config(cfg: ModelConfig) -> moe.MoEConfig:
    return moe.MoEConfig(
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff or cfg.d_ff,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        n_shared=cfg.n_shared_experts,
    )


def mamba_config(cfg: ModelConfig) -> ssm.Mamba2Config:
    return ssm.Mamba2Config(
        d_model=cfg.d_model, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
    )


def mlstm_config(cfg: ModelConfig) -> xlstm.MLSTMConfig:
    return xlstm.MLSTMConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, chunk=cfg.ssm_chunk
    )


def slstm_config(cfg: ModelConfig) -> xlstm.SLSTMConfig:
    return xlstm.SLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_kv_heads)


def _norm(name: str, d: int) -> ParamTable:
    return {name: ParamDecl((d,), ("embed",), init="zeros")}


# ---------------------------------------------------------------------------
# Param tables per block kind
# ---------------------------------------------------------------------------

_ATTN_KINDS = ("attn", "attn_local", "attn_bidir", "attn_moe", "attn_shared")


def block_param_table(cfg: ModelConfig, kind: str) -> ParamTable:
    d = cfg.d_model
    if kind in _ATTN_KINDS:
        t = merge_tables(
            _norm("ln1", d),
            prefix_table("attn", attn.attn_param_table(attn_config(cfg, kind))),
            _norm("ln2", d),
        )
        if kind == "attn_moe":
            return merge_tables(t, prefix_table("moe", moe.moe_param_table(moe_config(cfg))))
        return merge_tables(t, prefix_table("mlp", mlp.mlp_param_table(mlp_config(cfg))))
    if kind in ("mla", "mla_moe"):
        t = merge_tables(
            _norm("ln1", d),
            prefix_table("attn", attn.mla_param_table(mla_config(cfg))),
            _norm("ln2", d),
        )
        if kind == "mla_moe":
            return merge_tables(t, prefix_table("moe", moe.moe_param_table(moe_config(cfg))))
        return merge_tables(t, prefix_table("mlp", mlp.mlp_param_table(mlp_config(cfg))))
    if kind == "cross":
        return merge_tables(
            _norm("ln1", d),
            prefix_table("xattn", attn.attn_param_table(attn_config(cfg, kind))),
            _norm("ln2", d),
            prefix_table("mlp", mlp.mlp_param_table(mlp_config(cfg))),
            {"xgate": ParamDecl((1,), (None,), init="zeros")},  # llama-vision gate
        )
    if kind == "dec_cross":
        return merge_tables(
            _norm("ln1", d),
            prefix_table("attn", attn.attn_param_table(attn_config(cfg, kind))),
            _norm("lnx", d),
            prefix_table("xattn", attn.attn_param_table(attn_config(cfg, kind))),
            _norm("ln2", d),
            prefix_table("mlp", mlp.mlp_param_table(mlp_config(cfg))),
        )
    if kind == "mamba":
        return merge_tables(
            _norm("ln1", d),
            prefix_table("mamba", ssm.mamba2_param_table(mamba_config(cfg))),
        )
    if kind == "mlstm":
        return merge_tables(
            _norm("ln1", d),
            prefix_table("mlstm", xlstm.mlstm_param_table(mlstm_config(cfg))),
        )
    if kind == "slstm":
        return prefix_table("slstm", xlstm.slstm_param_table(slstm_config(cfg)))
    raise ValueError(f"unknown block kind {kind!r}")


def _sub(p: dict, prefix: str) -> dict:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in p.items() if k.startswith(pre)}


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def apply_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array, ctx: dict):
    """Returns (x, aux_loss, kv) — kv is the prefill cache payload or None."""
    eps = cfg.norm_eps
    zero = jnp.zeros((), jnp.float32)
    if kind in _ATTN_KINDS:
        acfg = attn_config(cfg, kind)
        h, kv = attn.self_attention(acfg, _sub(p, "attn"),
                                    common.rms_norm(x, p["ln1"], eps),
                                    ctx["positions"])
        x = x + h
        if kind == "attn_moe":
            h, aux = moe.moe(moe_config(cfg), _sub(p, "moe"),
                             common.rms_norm(x, p["ln2"], eps))
            return x + h, aux, kv
        h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                    common.rms_norm(x, p["ln2"], eps))
        return x + h, zero, kv
    if kind in ("mla", "mla_moe"):
        mcfg = mla_config(cfg)
        h, kv = attn.mla_attention(mcfg, _sub(p, "attn"),
                                   common.rms_norm(x, p["ln1"], eps),
                                   ctx["positions"])
        x = x + h
        if kind == "mla_moe":
            h, aux = moe.moe(moe_config(cfg), _sub(p, "moe"),
                             common.rms_norm(x, p["ln2"], eps))
            return x + h, aux, kv
        h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                    common.rms_norm(x, p["ln2"], eps))
        return x + h, zero, kv
    if kind == "cross":
        acfg = attn_config(cfg, kind)
        h, kv = attn.cross_attention(acfg, _sub(p, "xattn"),
                                     common.rms_norm(x, p["ln1"], eps),
                                     ctx["kv_src"])
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * h
        h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                    common.rms_norm(x, p["ln2"], eps))
        return x + h, zero, kv
    if kind == "dec_cross":
        acfg = attn_config(cfg, kind)
        h, kv_self = attn.self_attention(acfg, _sub(p, "attn"),
                                         common.rms_norm(x, p["ln1"], eps),
                                         ctx["positions"])
        x = x + h
        h, kv_cross = attn.cross_attention(acfg, _sub(p, "xattn"),
                                           common.rms_norm(x, p["lnx"], eps),
                                           ctx["kv_src"])
        x = x + h
        h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                    common.rms_norm(x, p["ln2"], eps))
        return x + h, zero, (kv_self, kv_cross)
    if kind == "mamba":
        h, state = ssm.mamba2(mamba_config(cfg), _sub(p, "mamba"),
                              common.rms_norm(x, p["ln1"], eps))
        return x + h, zero, state
    if kind == "mlstm":
        h, state = xlstm.mlstm(mlstm_config(cfg), _sub(p, "mlstm"),
                               common.rms_norm(x, p["ln1"], eps))
        return x + h, zero, state
    if kind == "slstm":
        y, carry = xlstm.slstm(slstm_config(cfg), _sub(p, "slstm"), x)
        return y, zero, carry
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Decode (one token, cache update)
# ---------------------------------------------------------------------------


def decode_block(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                 cache, ctx: dict):
    eps = cfg.norm_eps
    pos = ctx["pos"]
    if kind in _ATTN_KINDS:
        acfg = attn_config(cfg, kind)
        h, cache_new = attn.self_attention_decode(
            acfg, _sub(p, "attn"), common.rms_norm(x, p["ln1"], eps), cache, pos)
        x = x + h
        if kind == "attn_moe":
            h, _ = moe.moe(moe_config(cfg), _sub(p, "moe"),
                           common.rms_norm(x, p["ln2"], eps))
        else:
            h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                        common.rms_norm(x, p["ln2"], eps))
        return x + h, cache_new
    if kind in ("mla", "mla_moe"):
        mcfg = mla_config(cfg)
        h, cache_new = attn.mla_attention_decode(
            mcfg, _sub(p, "attn"), common.rms_norm(x, p["ln1"], eps), cache, pos)
        x = x + h
        if kind == "mla_moe":
            h, _ = moe.moe(moe_config(cfg), _sub(p, "moe"),
                           common.rms_norm(x, p["ln2"], eps))
        else:
            h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                        common.rms_norm(x, p["ln2"], eps))
        return x + h, cache_new
    if kind == "cross":
        acfg = attn_config(cfg, kind)
        h, cache_new = attn.cross_attention_cached(
            acfg, _sub(p, "xattn"), common.rms_norm(x, p["ln1"], eps), cache)
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * h
        h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                    common.rms_norm(x, p["ln2"], eps))
        return x + h, cache_new
    if kind == "dec_cross":
        acfg = attn_config(cfg, kind)
        h, self_new = attn.self_attention_decode(
            acfg, _sub(p, "attn"), common.rms_norm(x, p["ln1"], eps),
            cache["self"], pos)
        x = x + h
        h, cross_new = attn.cross_attention_cached(
            acfg, _sub(p, "xattn"), common.rms_norm(x, p["lnx"], eps),
            cache["cross"])
        x = x + h
        h = mlp.mlp(mlp_config(cfg), _sub(p, "mlp"),
                    common.rms_norm(x, p["ln2"], eps))
        return x + h, {"self": self_new, "cross": cross_new}
    if kind == "mamba":
        h, cache_new = ssm.mamba2_decode(
            mamba_config(cfg), _sub(p, "mamba"),
            common.rms_norm(x, p["ln1"], eps), cache)
        return x + h, cache_new
    if kind == "mlstm":
        h, cache_new = xlstm.mlstm_decode(
            mlstm_config(cfg), _sub(p, "mlstm"),
            common.rms_norm(x, p["ln1"], eps), cache)
        return x + h, cache_new
    if kind == "slstm":
        y, cache_new = xlstm.slstm_decode(slstm_config(cfg), _sub(p, "slstm"),
                                          x, cache)
        return y, cache_new
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, smax: int, dtype):
    if kind in _ATTN_KINDS:
        return attn.attn_cache_spec(attn_config(cfg, kind), batch, smax, dtype)
    if kind in ("mla", "mla_moe"):
        return attn.mla_cache_spec(mla_config(cfg), batch, smax, dtype)
    if kind == "cross":
        acfg = attn_config(cfg, kind)
        shp = (batch, cfg.img_seq, acfg.n_kv_heads, acfg.head_dim)
        return {"k": jax.ShapeDtypeStruct(shp, dtype),
                "v": jax.ShapeDtypeStruct(shp, dtype)}
    if kind == "dec_cross":
        acfg = attn_config(cfg, kind)
        xshp = (batch, cfg.enc_seq, acfg.n_kv_heads, acfg.head_dim)
        return {
            "self": attn.attn_cache_spec(acfg, batch, smax, dtype),
            "cross": {"k": jax.ShapeDtypeStruct(xshp, dtype),
                      "v": jax.ShapeDtypeStruct(xshp, dtype)},
        }
    if kind == "mamba":
        return ssm.mamba2_cache_spec(mamba_config(cfg), batch, dtype)
    if kind == "mlstm":
        return xlstm.mlstm_cache_spec(mlstm_config(cfg), batch, dtype)
    if kind == "slstm":
        return xlstm.slstm_cache_spec(slstm_config(cfg), batch, dtype)
    raise ValueError(f"unknown block kind {kind!r}")
