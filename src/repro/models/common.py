"""Shared model building blocks: norms, RoPE, activations, masking."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


# -- rotary position embeddings ------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,d/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- masking -------------------------------------------------------------------


def causal_window_mask(
    q_pos: jax.Array, k_pos: jax.Array, window: int | None = None
) -> jax.Array:
    """(Sq, Sk) boolean mask: k may attend iff k_pos <= q_pos (& window)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m = jnp.logical_and(m, k_pos[None, :] > q_pos[:, None] - window)
    return m


NEG_INF = -1e30
