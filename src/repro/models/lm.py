"""Model assembly: embedding -> scanned block groups -> head.

One assembly covers all ten assigned architectures; family differences enter
through the block pattern (ModelConfig.pattern), the optional encoder stack
(audio), and the cross-attention context (audio/vlm stubs).

Layer groups are ``lax.scan``-ned over stacked parameters (compile time and
HLO size are O(1) in depth); the roofline pipeline recovers true per-layer
costs by L-extrapolation (EXPERIMENTS.md §Methodology).  Remat wraps each
group body (activation checkpointing).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, common
from repro.models.params import (
    ParamDecl,
    ParamTable,
    abstract_params,
    init_params,
    logical_axes,
    merge_tables,
    num_params,
    prefix_table,
    stack_table,
)


def _enc_pattern(cfg: ModelConfig):
    return ((cfg.n_enc_layers, ("attn_bidir",)),) if cfg.n_enc_layers else ()


class LanguageModel:
    """Functional model bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameter table -----------------------------------------------------

    def param_table(self) -> ParamTable:
        cfg = self.cfg
        t: ParamTable = {
            "embed/tokens": ParamDecl((cfg.vocab_size, cfg.d_model),
                                      ("vocab", "embed"), init="embed"),
            "final_norm": ParamDecl((cfg.d_model,), ("embed",), init="zeros"),
        }
        if not cfg.tie_embeddings:
            t["unembed"] = ParamDecl((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), init="output")
        for gi, (repeat, kinds) in enumerate(cfg.pattern):
            group: ParamTable = {}
            for bi, kind in enumerate(kinds):
                if kind in cfg.shared_blocks:
                    continue
                group = merge_tables(
                    group,
                    prefix_table(f"b{bi}:{kind}",
                                 blocks.block_param_table(cfg, kind)),
                )
            t.update(prefix_table(f"dec/g{gi}", stack_table(group, repeat)))
        for kind in cfg.shared_blocks:
            t.update(prefix_table(f"shared/{kind}",
                                  blocks.block_param_table(cfg, kind)))
        for gi, (repeat, kinds) in enumerate(_enc_pattern(cfg)):
            group = prefix_table("b0:attn_bidir",
                                 blocks.block_param_table(cfg, "attn_bidir"))
            t.update(prefix_table(f"enc/g{gi}", stack_table(group, repeat)))
        if cfg.n_enc_layers:
            t["enc_pos"] = ParamDecl((cfg.enc_seq, cfg.d_model),
                                     (None, "embed"), init="embed")
            t["enc_final_norm"] = ParamDecl((cfg.d_model,), ("embed",),
                                            init="zeros")
        return t

    def init(self, rng, dtype=jnp.float32):
        return init_params(self.param_table(), rng, dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.param_table(), dtype)

    def axes(self):
        return logical_axes(self.param_table())

    def n_params(self) -> int:
        return num_params(self.param_table())

    # -- group plumbing --------------------------------------------------------

    def _group_params(self, params: dict, scope: str, gi: int, kinds) -> dict:
        """Nested {bkey: {path: stacked array}} for scan."""
        out: dict[str, dict[str, Any]] = {}
        pre = f"{scope}/g{gi}/"
        for k, v in params.items():
            if not k.startswith(pre):
                continue
            rest = k[len(pre):]
            bkey, ppath = rest.split("/", 1)
            out.setdefault(bkey, {})[ppath] = v
        return out

    def _shared_params(self, params: dict, kind: str) -> dict:
        pre = f"shared/{kind}/"
        return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}

    def _run_groups(self, params, x, ctx, pattern, scope, collect_kv=False):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        kv_all: list[Any] = []
        for gi, (repeat, kinds) in enumerate(pattern):
            gparams = self._group_params(params, scope, gi, kinds)
            shared = {k: self._shared_params(params, k)
                      for k in cfg.shared_blocks}

            def body(carry, layer_params, _kinds=kinds, _shared=shared):
                xx, aux = carry
                kvs = {}
                for bi, kind in enumerate(_kinds):
                    p = (_shared[kind] if kind in cfg.shared_blocks
                         else layer_params[f"b{bi}:{kind}"])
                    xx, aux_b, kv = blocks.apply_block(cfg, kind, p, xx, ctx)
                    aux = aux + aux_b
                    if collect_kv:
                        kvs[f"b{bi}:{kind}"] = kv
                return (xx, aux), (kvs if collect_kv else None)

            if cfg.remat:
                policy = (jax.checkpoint_policies.checkpoint_dots
                          if cfg.remat_policy == "dots" else None)
                body = jax.checkpoint(body, policy=policy)
            (x, aux_total), kvs = jax.lax.scan(
                body, (x, aux_total), gparams,
                unroll=repeat if cfg.unroll_groups else 1)
            kv_all.append(kvs)
        return x, aux_total, kv_all

    # -- embedding / head -------------------------------------------------------

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed/tokens"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        return x

    def _head(self, params, x):
        cfg = self.cfg
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = (params["embed/tokens"].T if cfg.tie_embeddings
             else params["unembed"])
        logits = jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
        if cfg.final_softcap is not None:
            logits = common.softcap(logits, cfg.final_softcap)
        return logits

    def _encode(self, params, frames):
        """Audio encoder over precomputed frame embeddings (frontend stub)."""
        cfg = self.cfg
        x = frames + params["enc_pos"][None, : frames.shape[1]].astype(frames.dtype)
        pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                               frames.shape[:2])
        ectx = {"positions": pos, "kv_src": None}
        x, _, _ = self._run_groups(params, x, ectx, _enc_pattern(cfg), "enc")
        return common.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    def _context(self, params, batch, seq_len):
        cfg = self.cfg
        pos = jnp.broadcast_to(jnp.arange(seq_len)[None],
                               (batch["tokens"].shape[0], seq_len))
        kv_src = None
        if cfg.family == "audio":
            kv_src = self._encode(params, batch["frames"])
        elif cfg.family == "vlm":
            kv_src = batch["images"]
        return {"positions": pos, "kv_src": kv_src}

    # -- training loss -----------------------------------------------------------

    def loss(self, params, batch):
        """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = masked)."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed(params, tokens)
        ctx = self._context(params, batch, tokens.shape[1])
        x, aux, _ = self._run_groups(params, x, ctx, cfg.pattern, "dec")
        logits = self._head(params, x)
        mask = (labels >= 0).astype(jnp.float32)
        labels_safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
        ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + aux, {"ce": ce, "aux": aux}

    # -- serving -----------------------------------------------------------------

    def cache_spec(self, batch: int, smax: int, dtype):
        cfg = self.cfg
        spec = []
        for repeat, kinds in cfg.pattern:
            group = {}
            for bi, kind in enumerate(kinds):
                one = blocks.block_cache_spec(cfg, kind, batch, smax, dtype)
                group[f"b{bi}:{kind}"] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((repeat, *s.shape), s.dtype),
                    one,
                )
            spec.append(group)
        return spec

    def init_cache(self, batch: int, smax: int, dtype):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_spec(batch, smax, dtype))

    def decode_step(self, params, caches, token, pos, kv_ctx=None):
        """token: (B,) int32; pos: scalar int32. Returns (logits (B,V), caches).

        ``caches`` layout == ``cache_spec``; cross-attention caches inside it
        are static (written by prefill).
        """
        cfg = self.cfg
        x = self._embed(params, token[:, None])
        ctx = {"pos": pos, "kv_src": kv_ctx}
        new_caches = []
        for gi, (repeat, kinds) in enumerate(cfg.pattern):
            gparams = self._group_params(params, "dec", gi, kinds)
            shared = {k: self._shared_params(params, k)
                      for k in cfg.shared_blocks}

            def body(xx, scanned, _kinds=kinds, _shared=shared):
                layer_params, layer_cache = scanned
                new_cache = {}
                for bi, kind in enumerate(_kinds):
                    bkey = f"b{bi}:{kind}"
                    p = (_shared[kind] if kind in cfg.shared_blocks
                         else layer_params[bkey])
                    xx, c = blocks.decode_block(cfg, kind, p, xx,
                                                layer_cache[bkey], ctx)
                    new_cache[bkey] = c
                return xx, new_cache

            x, nc = jax.lax.scan(body, x, (gparams, caches[gi]),
                                 unroll=repeat if cfg.unroll_groups else 1)
            new_caches.append(nc)
        logits = self._head(params, x[:, 0])
        return logits, new_caches

    def prefill(self, params, batch, smax, cache_dtype=None):
        """Run the full prompt, return (last-token logits, filled caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        dtype = cache_dtype or params["embed/tokens"].dtype
        x = self._embed(params, tokens)
        ctx = self._context(params, batch, s)
        x, _, kv_all = self._run_groups(params, x, ctx, cfg.pattern, "dec",
                                        collect_kv=True)
        logits = self._head(params, x[:, -1])
        caches = self.init_cache(b, smax, dtype)
        for gi, (repeat, kinds) in enumerate(cfg.pattern):
            for bi, kind in enumerate(kinds):
                bkey = f"b{bi}:{kind}"
                payload = kv_all[gi][bkey]
                caches[gi][bkey] = _payload_to_cache(
                    cfg, kind, payload, caches[gi][bkey], s)
        return logits, caches


def _scatter_seq(cache_arr, kv, s):
    """kv: (L, B, S, ...) -> write into cache (L, B, Smax, ...)."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache_arr, kv.astype(cache_arr.dtype), 0, axis=2)


def _payload_to_cache(cfg, kind, payload, cache, s):
    if kind in blocks._ATTN_KINDS:
        k, v = payload
        return {"k": _scatter_seq(cache["k"], k, s),
                "v": _scatter_seq(cache["v"], v, s)}
    if kind in ("mla", "mla_moe"):
        latent, k_rope = payload
        return {"latent": _scatter_seq(cache["latent"], latent, s),
                "k_rope": _scatter_seq(cache["k_rope"], k_rope, s)}
    if kind == "cross":
        k, v = payload
        return {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    if kind == "dec_cross":
        (k, v), (kx, vx) = payload
        return {
            "self": {"k": _scatter_seq(cache["self"]["k"], k, s),
                     "v": _scatter_seq(cache["self"]["v"], v, s)},
            "cross": {"k": kx.astype(cache["cross"]["k"].dtype),
                      "v": vx.astype(cache["cross"]["v"].dtype)},
        }
    if kind in ("mamba", "mlstm"):
        return jax.tree.map(lambda c, p: p.astype(c.dtype), cache, payload)
    if kind == "slstm":
        return {"carry": [p.astype(c.dtype) for c, p in
                          zip(cache["carry"], list(payload))]}
    raise ValueError(kind)
