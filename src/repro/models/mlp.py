"""Dense MLP blocks (SwiGLU / GeLU)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.params import ParamDecl, ParamTable


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "swiglu"  # swiglu | gelu | gelu_tanh


def mlp_param_table(cfg: MLPConfig) -> ParamTable:
    d, f = cfg.d_model, cfg.d_ff
    t: ParamTable = {
        "w_up": ParamDecl((d, f), ("embed", "mlp")),
        "w_down": ParamDecl((f, d), ("mlp", "embed"), init="output"),
    }
    if cfg.activation == "swiglu":
        t["w_gate"] = ParamDecl((d, f), ("embed", "mlp"))
    return t


def mlp(cfg: MLPConfig, p: dict, x: jax.Array) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.activation == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = common.swiglu(gate, up)
    else:
        h = common.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
