"""Mixture-of-Experts layer — capacity-based top-k routing, TPU idiom.

GShard/Switch-style einsum dispatch: tokens are grouped, each token picks
top-k experts, a position-in-expert is assigned by cumulative sum, and
dispatch/combine are dense one-hot einsums.  Under the production mesh the
expert axis is sharded on ``model`` (expert parallelism): dispatch/expert
matmuls run on local experts and one all-reduce over ``model`` joins the
combine — the canonical TPU EP pattern (no all-to-all emulation of NCCL).

Faithfulness notes (DESIGN.md §Arch-applicability):
* DeepSeek-V3 routes with sigmoid+bias-correction and a shared expert; we
  implement softmax top-k + shared expert and note the deviation.
* Capacity dropping replaces DeepSeek's dropless routing — the TPU-shaped
  trade (static shapes) used by GLaM/Switch.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.params import ParamDecl, ParamTable
from repro.sharding import hints


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0  # shared-expert multiplier (d_ff * n_shared dense path)
    capacity_factor: float = 1.25
    group_size: int = 1024  # tokens per routing group
    router_z_weight: float = 1e-3
    load_balance_weight: float = 1e-2


def moe_param_table(cfg: MoEConfig) -> ParamTable:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    t: ParamTable = {
        "router": ParamDecl((d, e), ("embed", "experts")),
        "w_gate": ParamDecl((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": ParamDecl((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": ParamDecl((e, f, d), ("experts", "expert_mlp", "embed"),
                            init="output", fan_in=f),
    }
    if cfg.n_shared:
        fs = cfg.d_ff * cfg.n_shared
        t["shared/w_gate"] = ParamDecl((d, fs), ("embed", "mlp"))
        t["shared/w_up"] = ParamDecl((d, fs), ("embed", "mlp"))
        t["shared/w_down"] = ParamDecl((fs, d), ("mlp", "embed"), init="output")
    return t


def _capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(4, (c + 3) // 4 * 4)


def moe(cfg: MoEConfig, p: dict, x: jax.Array):
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    tokens = b * s
    g_size = min(cfg.group_size, tokens)
    while tokens % g_size:
        g_size //= 2
    n_groups = tokens // g_size
    cap = _capacity(cfg, g_size)
    e = cfg.n_experts

    xg = x.reshape(n_groups, g_size, d)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)  # (g,t,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Position-in-expert by arrival order; tokens beyond capacity are dropped.
    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (g,t,k,e)
    # priority: expert choice rank first, then token order (GShard ordering)
    sel_flat = sel.transpose(0, 2, 1, 3).reshape(n_groups, cfg.top_k * g_size, e)
    pos_flat = jnp.cumsum(sel_flat, axis=1) - sel_flat  # (g, k*t, e)
    pos = pos_flat.reshape(n_groups, cfg.top_k, g_size, e).transpose(0, 2, 1, 3)
    within_cap = pos < cap
    sel = sel * within_cap
    pos = jnp.sum(pos * sel, axis=-1).astype(jnp.int32)  # (g,t,k) slot index

    dispatch = jnp.einsum(
        "gtke,gtkc->gtec", sel, jax.nn.one_hot(pos, cap, dtype=jnp.float32)
    )  # (g, t, e, c) one-hot
    combine = dispatch * jnp.sum(gate_vals[..., None] * sel, axis=2)[..., None]

    # Pin intermediate shardings: tokens (g) on data, experts (e) on model.
    # Without these, GSPMD's propagation reshards the rank-4 dispatch tensor
    # between einsums (measured ~58x collective overhead; §Perf deepseek).
    dispatch = hints.constrain(dispatch.astype(x.dtype), "data", None, "model",
                               None)
    combine = hints.constrain(combine, "data", None, "model", None)
    x_e = jnp.einsum("gtec,gtd->gecd", dispatch, xg)  # (g,e,c,d)
    x_e = hints.constrain(x_e, "data", "model", None, None)
    h = common.swiglu(
        jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"]),
        jnp.einsum("gecd,edf->gecf", x_e, p["w_up"]),
    )
    h = hints.constrain(h, "data", "model", None, None)
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y_e = hints.constrain(y_e, "data", "model", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), y_e)
    y = hints.constrain(y, "data", None, None)
    y = y.reshape(b, s, d)

    if cfg.n_shared:
        y = y + _shared_mlp(cfg, p, x)

    # Aux losses: load balance (Switch) + router z-loss.
    density = jnp.mean(sel.sum(axis=2), axis=1)  # (g, e) fraction routed
    density_prob = jnp.mean(probs, axis=1)  # (g, e)
    lb = jnp.mean(density * density_prob) * (e**2) * cfg.load_balance_weight
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_weight
    return y, lb + z


def _shared_mlp(cfg: MoEConfig, p: dict, x: jax.Array) -> jax.Array:
    h = common.swiglu(
        jnp.einsum("bsd,df->bsf", x, p["shared/w_gate"]),
        jnp.einsum("bsd,df->bsf", x, p["shared/w_up"]),
    )
    return jnp.einsum("bsf,fd->bsd", h, p["shared/w_down"])
