"""Declarative parameter tables with logical sharding axes.

Every model declares its parameters once as a flat ``{path: ParamDecl}``
table; the same table drives

* ``init_params``      — RNG materialization (training),
* ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (dry-run, no alloc),
* ``logical_axes``     — per-param logical axis names, mapped to mesh axes by
  ``repro.sharding.rules`` (T5X-style logical axis rules).

Stacked (scanned) layer groups prepend a ``layers`` axis to the declared
shape; the ``layers`` logical axis always maps to ``None`` (it is the scan
dimension, never sharded).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | embed | output
    fan_in: int | None = None  # overrides shape-derived fan-in for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTable = dict[str, ParamDecl]


def stack_table(table: ParamTable, count: int) -> ParamTable:
    """Prepend a scanned ``layers`` axis of size ``count`` to every decl."""
    return {
        path: ParamDecl(
            shape=(count, *decl.shape),
            axes=("layers", *decl.axes),
            init=decl.init,
            fan_in=decl.fan_in,
        )
        for path, decl in table.items()
    }


def prefix_table(prefix: str, table: ParamTable) -> ParamTable:
    return {f"{prefix}/{path}": decl for path, decl in table.items()}


def merge_tables(*tables: ParamTable) -> ParamTable:
    out: ParamTable = {}
    for t in tables:
        for k, v in t.items():
            if k in out:
                raise ValueError(f"duplicate param path {k!r}")
            out[k] = v
    return out


def _init_one(decl: ParamDecl, key: jax.Array, dtype) -> jax.Array:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    if decl.init == "embed":
        return jax.random.normal(key, decl.shape, dtype)
    # fan-in scaled normal (truncated variance scaling is overkill here)
    if decl.fan_in is not None:
        fan_in = decl.fan_in
    else:
        # contracting dim: last-but-one for matrices, last for vectors
        fan_in = decl.shape[-2] if len(decl.shape) >= 2 else decl.shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    if decl.init == "output":
        std = std * 0.5
    return (jax.random.normal(key, decl.shape, jnp.float32) * std).astype(dtype)


def init_params(table: ParamTable, rng: jax.Array, dtype=jnp.float32):
    paths = sorted(table)
    keys = jax.random.split(rng, len(paths))
    return {p: _init_one(table[p], k, dtype) for p, k in zip(paths, keys)}


def abstract_params(table: ParamTable, dtype=jnp.float32):
    return {
        p: jax.ShapeDtypeStruct(d.shape, dtype) for p, d in sorted(table.items())
    }


def logical_axes(table: ParamTable) -> dict[str, tuple[str | None, ...]]:
    return {p: d.axes for p, d in table.items()}


def num_params(table: ParamTable) -> int:
    return sum(math.prod(d.shape) for d in table.values())
