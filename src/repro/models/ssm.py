"""State-space / gated-linear-attention mixers: shared chunked core + Mamba2.

The core computes, per head, the gated linear-attention recurrence

    S_t = exp(log_decay_t) * S_{t-1} + exp(gate_t) * v_t k_t^T
    n_t = exp(log_decay_t) * n_{t-1} + exp(gate_t) * k_t          (optional)
    y_t = q_t @ S_t   [ / max(|q_t . n_t|, 1) ]

in *chunked* form: quadratic (matmul-rich, MXU-friendly) within chunks of
length ``Lc``, and a **log-depth ``associative_scan``** across chunks — the
lowered HLO contains no while-loops (roofline methodology requirement) and
wall-clock depth is O(log(S/Lc)), the TPU-native substitute for sequential
recurrence.

Mamba2 (SSD) maps onto the core with q=C, k=B, v=dt*x, log_decay=dt*A and no
normalizer; mLSTM (repro.models.xlstm) adds sigmoid-forget decays, clamped
exponential input gates and the normalizer state.  Numerical boundedness:
log_decay <= 0 always (decays), gates are clamped, so no cross-chunk
stabilizer is needed (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.params import ParamDecl, ParamTable

# ---------------------------------------------------------------------------
# Shared chunked core
# ---------------------------------------------------------------------------


def _assoc_combine(a, b):
    """(decay, S, n) segments: b follows a."""
    da, sa, na = a
    db, sb, nb = b
    return (da * db, db[..., None, None] * sa + sb, db[..., None] * na + nb)


def chunked_gla(
    q: jax.Array,  # (B, S, H, Dk)
    k: jax.Array,  # (B, S, H, Dk)
    v: jax.Array,  # (B, S, H, Dv)
    log_decay: jax.Array,  # (B, S, H), <= 0
    gate: jax.Array,  # (B, S, H), log input weights
    chunk: int = 128,
    normalize: bool = False,
    state: tuple | None = None,  # (S0 (B,H,Dk,Dv), n0 (B,H,Dk))
):
    """Returns (y (B,S,H,Dv), (S_final, n_final))."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    lc = min(chunk, s)
    while s % lc:
        lc //= 2
    nc = s // lc

    def cshape(x):
        return x.reshape(b, nc, lc, *x.shape[2:])

    qc, kc, vc = cshape(q), cshape(k), cshape(v)
    ldc, gc = cshape(log_decay), cshape(gate)
    lcum = jnp.cumsum(ldc.astype(jnp.float32), axis=2)  # (B,nc,Lc,H) inclusive
    l_last = lcum[:, :, -1]  # (B,nc,H)

    # ---- within-chunk quadratic part -------------------------------------
    # W[t, t'] = exp(L_t - L_{t'} + g_{t'}) for t' <= t
    wexp = lcum[:, :, :, None, :] - lcum[:, :, None, :, :] + gc[:, :, None, :, :]
    t_idx = jnp.arange(lc)
    causal = (t_idx[:, None] >= t_idx[None, :])[None, None, :, :, None]
    w = jnp.where(causal, jnp.exp(wexp), 0.0)  # (B,nc,Lc,Lc',H)
    sqk = jnp.einsum("bclhd,bcmhd->bclmh", qc, kc,
                     preferred_element_type=jnp.float32)
    ws = w * sqk
    y_intra = jnp.einsum("bclmh,bcmhv->bclhv", ws.astype(v.dtype), vc,
                         preferred_element_type=jnp.float32)
    if normalize:
        n_intra = jnp.einsum("bclmh,bcmhd->bclhd", w.astype(k.dtype), kc,
                             preferred_element_type=jnp.float32)

    # ---- chunk summaries ---------------------------------------------------
    w_end = jnp.exp(l_last[:, :, None] - lcum + gc)  # (B,nc,Lc,H)
    s_chunk = jnp.einsum("bclh,bclhd,bclhv->bchdv", w_end.astype(k.dtype), kc, vc,
                         preferred_element_type=jnp.float32)
    n_chunk = jnp.einsum("bclh,bclhd->bchd", w_end.astype(k.dtype), kc,
                         preferred_element_type=jnp.float32)
    decay_chunk = jnp.exp(l_last)  # (B,nc,H)

    # ---- inter-chunk associative scan (log-depth, no while loop) ----------
    dec_i, s_i, n_i = jax.lax.associative_scan(
        _assoc_combine, (decay_chunk, s_chunk, n_chunk), axis=1
    )
    if state is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
    else:
        s0, n0 = state
        s0 = s0.astype(jnp.float32)
        n0 = n0.astype(jnp.float32)
    # exclusive prefix: state seen by chunk c = dec_i[c-1]*s0 + s_i[c-1]
    dec_prev = jnp.concatenate([jnp.ones((b, 1, h), jnp.float32), dec_i[:, :-1]],
                               axis=1)
    s_prev = jnp.concatenate([jnp.zeros_like(s_i[:, :1]), s_i[:, :-1]], axis=1)
    s_prev = s_prev + dec_prev[..., None, None] * s0[:, None]
    n_prev = jnp.concatenate([jnp.zeros_like(n_i[:, :1]), n_i[:, :-1]], axis=1)
    n_prev = n_prev + dec_prev[..., None] * n0[:, None]

    # ---- inter-chunk contribution ------------------------------------------
    elc = jnp.exp(lcum)  # (B,nc,Lc,H)
    y_inter = jnp.einsum("bclh,bclhd,bchdv->bclhv", elc.astype(q.dtype), qc,
                         s_prev.astype(q.dtype), preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).astype(jnp.float32)

    if normalize:
        n_t = n_intra + elc[..., None] * n_prev[:, :, None].astype(jnp.float32)
        denom = jnp.abs(jnp.einsum("bclhd,bclhd->bclh", qc.astype(jnp.float32),
                                   n_t))
        y = y / jnp.maximum(denom, 1.0)[..., None]

    s_fin = dec_i[:, -1][..., None, None] * s0 + s_i[:, -1]
    n_fin = dec_i[:, -1][..., None] * n0 + n_i[:, -1]
    return y.reshape(b, s, h, dv).astype(v.dtype), (s_fin, n_fin)


def gla_decode_step(q, k, v, log_decay, gate, state, normalize: bool = False):
    """One-token recurrent update. q/k/v: (B,H,D*); state (S, n)."""
    s_st, n_st = state
    d = jnp.exp(log_decay.astype(jnp.float32))  # (B,H)
    g = jnp.exp(gate.astype(jnp.float32))
    s_new = d[..., None, None] * s_st + g[..., None, None] * jnp.einsum(
        "bhd,bhv->bhdv", k, v
    ).astype(jnp.float32)
    n_new = d[..., None] * n_st + g[..., None] * k.astype(jnp.float32)
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), s_new)
    if normalize:
        denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n_new))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y.astype(v.dtype), (s_new, n_new)


# ---------------------------------------------------------------------------
# Causal depthwise conv (width 4), static shifts — no while loops
# ---------------------------------------------------------------------------


def causal_conv4(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (C, 4); returns silu(conv(x))."""
    acc = x * w[None, None, :, 3]
    for i in range(1, 4):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        acc = acc + shifted * w[None, None, :, 3 - i]
    return jax.nn.silu(acc + b[None, None])


def causal_conv4_step(x_t: jax.Array, conv_state: jax.Array, w, b):
    """x_t: (B, C); conv_state: (B, 3, C) last 3 inputs. Returns (y, state)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,4,C)
    y = jnp.einsum("bkc,ck->bc", window, w) + b[None]
    return jax.nn.silu(y), window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_param_table(cfg: Mamba2Config) -> ParamTable:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        "w_z": ParamDecl((d, di), ("embed", "inner")),
        "w_x": ParamDecl((d, di), ("embed", "inner")),
        "w_b": ParamDecl((d, n), ("embed", "state")),
        "w_c": ParamDecl((d, n), ("embed", "state")),
        "w_dt": ParamDecl((d, h), ("embed", "heads")),
        "dt_bias": ParamDecl((h,), ("heads",), init="zeros"),
        "a_log": ParamDecl((h,), ("heads",), init="zeros"),
        "d_skip": ParamDecl((h,), ("heads",), init="ones"),
        "conv_x_w": ParamDecl((di, 4), ("inner", None)),
        "conv_x_b": ParamDecl((di,), ("inner",), init="zeros"),
        "conv_b_w": ParamDecl((n, 4), ("state", None)),
        "conv_b_b": ParamDecl((n,), ("state",), init="zeros"),
        "conv_c_w": ParamDecl((n, 4), ("state", None)),
        "conv_c_b": ParamDecl((n,), ("state",), init="zeros"),
        "norm": ParamDecl((di,), ("inner",), init="zeros"),
        "w_out": ParamDecl((di, d), ("inner", "embed"), init="output"),
    }


def _mamba2_inputs(cfg: Mamba2Config, p: dict, x: jax.Array):
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bb = jnp.einsum("bsd,dn->bsn", x, p["w_b"])
    cc = jnp.einsum("bsd,dn->bsn", x, p["w_c"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    return z, xi, bb, cc, dt


def mamba2(cfg: Mamba2Config, p: dict, x: jax.Array):
    """Training/prefill. Returns (y, decode-ready cache payload)."""
    b, s, _ = x.shape
    h, pd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xi, bb, cc, dt = _mamba2_inputs(cfg, p, x)
    xi_raw, bb_raw, cc_raw = xi, bb, cc  # pre-conv inputs (decode conv windows)
    xi = causal_conv4(xi, p["conv_x_w"], p["conv_x_b"])
    bb = causal_conv4(bb, p["conv_b_w"], p["conv_b_b"])
    cc = causal_conv4(cc, p["conv_c_w"], p["conv_c_b"])
    xh = xi.reshape(b, s, h, pd)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (h,) < 0
    log_decay = dt * a[None, None, :]  # (B,S,H) <= 0
    qh = jnp.broadcast_to(cc[:, :, None], (b, s, h, n)).astype(x.dtype)
    kh = jnp.broadcast_to(bb[:, :, None], (b, s, h, n)).astype(x.dtype)
    vh = (xh * dt[..., None]).astype(x.dtype)
    y, (s_fin, _) = chunked_gla(qh, kh, vh, log_decay, jnp.zeros_like(log_decay),
                                chunk=cfg.chunk)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm"])
    cache = {"ssm": s_fin, "conv_x": xi_raw[:, -3:], "conv_b": bb_raw[:, -3:],
             "conv_c": cc_raw[:, -3:]}
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), cache


def mamba2_decode(cfg: Mamba2Config, p: dict, x: jax.Array, cache: dict):
    """x: (B, 1, d). cache: {"ssm": (B,H,N,P), "conv_x": (B,3,di), ...}."""
    b = x.shape[0]
    h, pd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xi, bb, cc, dt = _mamba2_inputs(cfg, p, x)
    xi1, conv_x = causal_conv4_step(xi[:, 0], cache["conv_x"], p["conv_x_w"],
                                    p["conv_x_b"])
    bb1, conv_b = causal_conv4_step(bb[:, 0], cache["conv_b"], p["conv_b_w"],
                                    p["conv_b_b"])
    cc1, conv_c = causal_conv4_step(cc[:, 0], cache["conv_c"], p["conv_c_w"],
                                    p["conv_c_b"])
    xh = xi1.reshape(b, h, pd)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt1 = dt[:, 0]  # (B,H)
    qh = jnp.broadcast_to(cc1[:, None], (b, h, n)).astype(x.dtype)
    kh = jnp.broadcast_to(bb1[:, None], (b, h, n)).astype(x.dtype)
    vh = (xh * dt1[..., None]).astype(x.dtype)
    y, (s_new, _) = gla_decode_step(
        qh, kh, vh, dt1 * a[None], jnp.zeros_like(dt1),
        (cache["ssm"], jnp.zeros((b, h, n), jnp.float32)),
    )
    y = y + xh * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, cfg.d_inner)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"ssm": s_new, "conv_x": conv_x, "conv_b": conv_b,
                 "conv_c": conv_c}


def mamba2_cache_spec(cfg: Mamba2Config, batch: int, dtype):
    h, pd, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    return {
        "ssm": jax.ShapeDtypeStruct((batch, h, n, pd), jnp.float32),
        "conv_x": jax.ShapeDtypeStruct((batch, 3, cfg.d_inner), dtype),
        "conv_b": jax.ShapeDtypeStruct((batch, 3, n), dtype),
        "conv_c": jax.ShapeDtypeStruct((batch, 3, n), dtype),
    }
