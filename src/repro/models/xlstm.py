"""xLSTM blocks: mLSTM (matrix memory, chunked) and sLSTM (scalar memory).

mLSTM rides the shared ``chunked_gla`` core (repro.models.ssm): sigmoid
forget gates give log-decays <= 0, input gates are exponential with a softcap
clamp (boundedness replaces the running-max stabilizer; DESIGN.md §7), and
the normalizer state ``n`` implements ``h = C q / max(|n . q|, 1)``.

sLSTM has true recurrence (gates read h_{t-1} through block-diagonal R), so it
scans over time — the one deliberate while-loop in the model zoo; its FLOPs
are corrected analytically in the roofline (EXPERIMENTS.md §Methodology).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.params import ParamDecl, ParamTable
from repro.models.ssm import (
    causal_conv4,
    causal_conv4_step,
    chunked_gla,
    gla_decode_step,
)

GATE_CLAMP = 15.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int
    expand: int = 2
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def mlstm_param_table(cfg: MLSTMConfig) -> ParamTable:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "w_up": ParamDecl((d, di), ("embed", "inner")),
        "w_z": ParamDecl((d, di), ("embed", "inner")),
        "conv_w": ParamDecl((di, 4), ("inner", None)),
        "conv_b": ParamDecl((di,), ("inner",), init="zeros"),
        "w_q": ParamDecl((di, di), ("inner", "inner2")),
        "w_k": ParamDecl((di, di), ("inner", "inner2")),
        "w_v": ParamDecl((di, di), ("inner", "inner2")),
        "w_i": ParamDecl((di, h), ("inner", "heads")),
        "w_f": ParamDecl((di, h), ("inner", "heads")),
        "b_i": ParamDecl((h,), ("heads",), init="zeros"),
        "b_f": ParamDecl((h,), ("heads",), init="ones"),
        "norm": ParamDecl((di,), ("inner",), init="zeros"),
        "w_down": ParamDecl((di, d), ("inner", "embed"), init="output"),
    }


def _mlstm_qkv_gates(cfg: MLSTMConfig, p: dict, x: jax.Array):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    c = causal_conv4(up, p["conv_w"], p["conv_b"])
    q = jnp.einsum("bse,ef->bsf", c, p["w_q"]).reshape(b, s, h, dh)
    k = jnp.einsum("bse,ef->bsf", c, p["w_k"]).reshape(b, s, h, dh)
    v = jnp.einsum("bse,ef->bsf", up, p["w_v"]).reshape(b, s, h, dh)
    i_raw = jnp.einsum("bse,eh->bsh", up, p["w_i"]) + p["b_i"]
    f_raw = jnp.einsum("bse,eh->bsh", up, p["w_f"]) + p["b_f"]
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    log_i = common.softcap(i_raw.astype(jnp.float32), GATE_CLAMP)
    k = k / jnp.sqrt(jnp.asarray(dh, jnp.float32)).astype(k.dtype)
    return q, k, v, log_f, log_i, z, up


def mlstm(cfg: MLSTMConfig, p: dict, x: jax.Array):
    b, s, _ = x.shape
    q, k, v, log_f, log_i, z, up = _mlstm_qkv_gates(cfg, p, x)
    y, state = chunked_gla(q, k, v, log_f, log_i, chunk=cfg.chunk,
                           normalize=True)
    y = y.reshape(b, s, cfg.d_inner)
    y = common.rms_norm(y, p["norm"]) * jax.nn.silu(z)
    cache = {"s": state[0], "n": state[1], "conv": up[:, -3:]}
    return jnp.einsum("bse,ed->bsd", y, p["w_down"]), cache


def mlstm_decode(cfg: MLSTMConfig, p: dict, x: jax.Array, cache: dict):
    b = x.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])[:, 0]
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])[:, 0]
    c, conv_state = causal_conv4_step(up, cache["conv"], p["conv_w"], p["conv_b"])
    q = (c @ p["w_q"]).reshape(b, h, dh)
    k = (c @ p["w_k"]).reshape(b, h, dh) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32)
    ).astype(x.dtype)
    v = (up @ p["w_v"]).reshape(b, h, dh)
    log_f = jax.nn.log_sigmoid((up @ p["w_f"] + p["b_f"]).astype(jnp.float32))
    log_i = common.softcap((up @ p["w_i"] + p["b_i"]).astype(jnp.float32),
                           GATE_CLAMP)
    y, state = gla_decode_step(q, k, v, log_f, log_i,
                               (cache["s"], cache["n"]), normalize=True)
    y = y.reshape(b, 1, cfg.d_inner)
    y = common.rms_norm(y, p["norm"]) * jax.nn.silu(z)[:, None]
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    return out, {"s": state[0], "n": state[1], "conv": conv_state}


def mlstm_cache_spec(cfg: MLSTMConfig, batch: int, dtype):
    h, dh = cfg.n_heads, cfg.head_dim
    return {
        "s": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, 3, cfg.d_inner), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int
    ff_factor: float = 4.0 / 3.0


def slstm_param_table(cfg: SLSTMConfig) -> ParamTable:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    dff = int(cfg.ff_factor * d)
    t: ParamTable = {
        "norm": ParamDecl((d,), ("embed",), init="zeros"),
        "w_gates": ParamDecl((d, 4 * d), ("embed", "inner")),  # i,f,z,o
        "r_gates": ParamDecl((h, dh, 4 * dh), ("heads", None, None)),  # blockdiag
        "b_gates": ParamDecl((4 * d,), ("inner",), init="zeros"),
        "gnorm": ParamDecl((d,), ("embed",), init="zeros"),
        "ffn/w_gate": ParamDecl((d, dff), ("embed", "mlp")),
        "ffn/w_up": ParamDecl((d, dff), ("embed", "mlp")),
        "ffn/w_down": ParamDecl((dff, d), ("mlp", "embed"), init="output"),
        "ffn_norm": ParamDecl((d,), ("embed",), init="zeros"),
    }
    return t


def _slstm_cell(cfg: SLSTMConfig, p: dict, wx_t, carry):
    """One step. wx_t: (B, 4d) precomputed input contributions."""
    h_prev, c_prev, n_prev, m_prev = carry
    b = h_prev.shape[0]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    hh = h_prev.reshape(b, nh, dh)
    rx = jnp.einsum("bhd,hde->bhe", hh, p["r_gates"]).reshape(b, 4 * cfg.d_model)
    gates = (wx_t + rx + p["b_gates"]).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(gates, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m_prev, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m_prev - m_new)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    c_new = f_g * c_prev + i_g * z
    n_new = f_g * n_prev + i_g
    h_new = o * (c_new / jnp.maximum(n_new, 1.0))
    return (h_new, c_new, n_new, m_new), h_new.astype(wx_t.dtype)


def slstm(cfg: SLSTMConfig, p: dict, x: jax.Array, carry=None):
    """x: (B, S, d). The deliberate sequential scan (see module docstring)."""
    b, s, d = x.shape
    xn = common.rms_norm(x, p["norm"])
    wx = jnp.einsum("bsd,de->bse", xn, p["w_gates"])  # (B,S,4d)
    if carry is None:
        carry = slstm_init_carry(cfg, b)
    carry, hs = jax.lax.scan(
        lambda c, w: _slstm_cell(cfg, p, w, c), carry, wx.transpose(1, 0, 2)
    )
    y = hs.transpose(1, 0, 2)  # (B,S,d)
    y = x + common.rms_norm(y, p["gnorm"])
    # post-FFN (xLSTM block structure)
    yn = common.rms_norm(y, p["ffn_norm"])
    ff = common.swiglu(
        jnp.einsum("bsd,df->bsf", yn, p["ffn/w_gate"]),
        jnp.einsum("bsd,df->bsf", yn, p["ffn/w_up"]),
    )
    y = y + jnp.einsum("bsf,fd->bsd", ff, p["ffn/w_down"])
    return y, carry


def slstm_init_carry(cfg: SLSTMConfig, batch: int):
    d = cfg.d_model
    return (
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.full((batch, d), -1e30, jnp.float32),
    )


def slstm_decode(cfg: SLSTMConfig, p: dict, x: jax.Array, cache: dict):
    y, carry = slstm(cfg, p, x, carry=tuple(cache["carry"]))
    return y, {"carry": list(carry)}


def slstm_cache_spec(cfg: SLSTMConfig, batch: int, dtype):
    d = cfg.d_model
    f32 = jnp.float32
    return {"carry": [
        jax.ShapeDtypeStruct((batch, d), f32),
        jax.ShapeDtypeStruct((batch, d), f32),
        jax.ShapeDtypeStruct((batch, d), f32),
        jax.ShapeDtypeStruct((batch, d), f32),
    ]}
