from repro.optim.adamw import AdamW, AdamWState, global_norm  # noqa: F401
from repro.optim.eigenpre import EigenPre, EigenPreState  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
