"""AdamW with bf16-compressed first moment — pure JAX, optax-style API.

Distributed-optimization tricks baked in:

* optimizer state inherits parameter sharding (with FSDP rules this is
  ZeRO-3: params, m, v all fully sharded);
* the first moment is stored in bf16 (state compression — halves optimizer
  HBM for free at these scales; v stays fp32 for rsqrt stability);
* updates are computed in fp32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any  # bf16 pytree
    v: Any  # fp32 pytree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_m: bool = True

    def init(self, params) -> AdamWState:
        m = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16 if self.compress_m else jnp.float32),
            params,
        )
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), m, v)

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        count = state.count + 1
        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * clip
            m32 = m.astype(jnp.float32)
            m_new = self.b1 * m32 + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * g * g
            mhat = m_new / (1 - self.b1 ** count.astype(jnp.float32))
            vhat = v_new / (1 - self.b2 ** count.astype(jnp.float32))
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (
                (-self.lr * lr_scale * step).astype(p.dtype),
                m_new.astype(m.dtype),
                v_new,
            )

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        return new_params, AdamWState(count, m, v), {"grad_norm": gnorm}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )
