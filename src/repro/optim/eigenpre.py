"""EigenPre — EEI-powered spectral preconditioning (the paper in the loop).

Shampoo-style Kronecker-factor preconditioning needs, per 2-D parameter, the
top eigenpairs of gram factors ``L = G G^T / n`` accumulated over steps —
exactly the *partial-spectrum* query regime where the paper's identity beats
full eigendecomposition (its Fig. 1(a)/Table 1 use case).  The preconditioner
applies a low-rank spectral transform

    P(g) = g + sum_i (f(lam_i) - 1) u_i (u_i^T g)      f(lam) = rsqrt(lam+eps)

using only the top-k eigenpairs from ``repro.engine.SolverEngine`` — i.e. the
EEI pipeline (tridiagonalize -> Sturm -> EEI -> signed back-transform), and
falls back to identity for non-matrix params.

This is intentionally a *grafted* preconditioner (applied on top of AdamW's
update direction) so it composes with the production optimizer; refresh
cadence amortizes the spectral solve, as Shampoo implementations do.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.engine import SolverEngine, SolverPlan
from repro.optim.adamw import AdamW, AdamWState


class EigenPreState(NamedTuple):
    adamw: AdamWState
    gram: Any  # per-param left gram factor (d, d) or None placeholder
    eigvals: Any  # (k,) top eigenvalues per param
    eigvecs: Any  # (k, d) top eigenvectors per param


@dataclasses.dataclass(frozen=True)
class EigenPre:
    """AdamW + EEI low-rank spectral graft on 2-D params."""

    adamw: AdamW = AdamW()
    rank: int = 4
    refresh_every: int = 10
    beta_gram: float = 0.95
    eps: float = 1e-6
    max_dim: int = 1024  # precondition only dims <= this (monitoring regime)
    engine: SolverEngine = SolverEngine(SolverPlan(method="eei_tridiag"))

    def _eligible(self, p) -> bool:
        return p.ndim == 2 and p.shape[0] <= self.max_dim

    def init(self, params) -> EigenPreState:
        def gram0(p):
            if self._eligible(p):
                return jnp.zeros((p.shape[0], p.shape[0]), jnp.float32)
            return jnp.zeros((1, 1), jnp.float32)

        def val0(p):
            return jnp.ones((self.rank,), jnp.float32)

        def vec0(p):
            d = p.shape[0] if self._eligible(p) else 1
            return jnp.zeros((self.rank, d), jnp.float32)

        return EigenPreState(
            self.adamw.init(params),
            jax.tree.map(gram0, params),
            jax.tree.map(val0, params),
            jax.tree.map(vec0, params),
        )

    def update(self, grads, state: EigenPreState, params, lr_scale=1.0):
        step = state.adamw.count + 1

        # 1. accumulate gram factors
        def acc(g, gram):
            if gram.shape[0] == 1:
                return gram
            g32 = g.astype(jnp.float32)
            return self.beta_gram * gram + (1 - self.beta_gram) * (
                g32 @ g32.T / g32.shape[1]
            )

        gram = jax.tree.map(acc, grads, state.gram)

        # 2. refresh top-k eigenpairs via the EEI engine (amortized)
        do_refresh = (step % self.refresh_every) == 1

        def refresh(gr, val, vec):
            if gr.shape[0] == 1:
                return val, vec

            def compute(_):
                lam, v = self.engine.topk(
                    gr + self.eps * jnp.eye(gr.shape[0], dtype=gr.dtype),
                    min(self.rank, gr.shape[0]),
                )
                k = lam.shape[0]
                lam_p = jnp.concatenate([jnp.ones((self.rank - k,)), lam]) \
                    if k < self.rank else lam
                v_p = jnp.concatenate(
                    [jnp.zeros((self.rank - k, gr.shape[0])), v]
                ) if k < self.rank else v
                return lam_p.astype(jnp.float32), v_p.astype(jnp.float32)

            return jax.lax.cond(do_refresh, compute,
                                lambda _: (val, vec), operand=None)

        out = jax.tree.map(refresh, gram, state.eigvals, state.eigvecs)
        eigvals = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        eigvecs = jax.tree.map(lambda o: o[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))

        # 3. precondition gradients in the top-k eigenspace
        def precond(g, val, vec):
            if vec.shape[1] == 1:
                return g
            g32 = g.astype(jnp.float32)
            proj = vec @ g32  # (k, cols)
            scale = jax.lax.rsqrt(jnp.maximum(val, 0.0) + self.eps)
            scale = scale / jnp.maximum(jnp.max(scale), 1e-12)  # graft norm
            corrected = (scale - 1.0)[:, None] * proj
            return (g32 + vec.T @ corrected).astype(g.dtype)

        grads_p = jax.tree.map(precond, grads, eigvals, eigvecs)

        # 4. AdamW on preconditioned gradients
        new_params, adamw_state, metrics = self.adamw.update(
            grads_p, state.adamw, params, lr_scale
        )
        return new_params, EigenPreState(adamw_state, gram, eigvals, eigvecs), metrics
