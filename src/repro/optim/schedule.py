"""LR schedules (pure functions of step)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1.0) / max(warmup, 1))  # step 0 trains
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
