from repro.roofline.analysis import (  # noqa: F401
    CostVector,
    Roofline,
    active_params,
    cost_vector,
    extrapolate,
    model_flops,
    slstm_extra_flops,
)
from repro.roofline.hlo_collectives import collective_bytes  # noqa: F401
