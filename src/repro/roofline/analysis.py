"""Roofline terms from compiled dry-run artifacts.

    compute    = HLO_FLOPs            / (chips * PEAK_FLOPS_BF16)
    memory     = HLO_bytes_accessed   / (chips * HBM_BW)
    collective = collective_bytes     / (chips * ICI_LINK_BW)

Because ``cost_analysis`` visits ``while`` bodies once (verified empirically,
see EXPERIMENTS.md §Methodology), layer-scanned models are measured by
L-extrapolation: lower the step with every group repeat = 1 (``cost_1``) and
with group g's repeat = 2 (``cost_g2``); the slope ``cost_g2 - cost_1`` is
group g's exact per-layer cost (layers within a group are identical), so

    cost(full) = cost_1 + sum_g (repeat_g - 1) * slope_g .

MODEL_FLOPS uses the 6*N*D convention (N = params, N_active for MoE,
D = tokens per step); decode steps use D = global_batch (one token each).
"""

from __future__ import annotations

import dataclasses
import math

from repro.roofline import constants as C


@dataclasses.dataclass
class CostVector:
    flops: float
    bytes_accessed: float
    collective: dict[str, float]

    def __add__(self, o):
        keys = set(self.collective) | set(o.collective)
        return CostVector(
            self.flops + o.flops,
            self.bytes_accessed + o.bytes_accessed,
            {k: self.collective.get(k, 0) + o.collective.get(k, 0) for k in keys},
        )

    def scale(self, a: float):
        return CostVector(
            self.flops * a,
            self.bytes_accessed * a,
            {k: v * a for k, v in self.collective.items()},
        )

    def __sub__(self, o):
        return self + o.scale(-1.0)


def cost_vector(cost_analysis: dict, coll: dict) -> CostVector:
    return CostVector(
        flops=max(float(cost_analysis.get("flops", 0.0)), 0.0),
        bytes_accessed=max(float(cost_analysis.get("bytes accessed", 0.0)), 0.0),
        collective=dict(coll),
    )


def extrapolate(cost_1: CostVector, group_costs_2: list[CostVector],
                repeats: list[int]) -> CostVector:
    """cost_1: all repeats=1. group_costs_2[g]: repeat_g=2, others 1."""
    total = cost_1
    for c2, r in zip(group_costs_2, repeats):
        slope = c2 - cost_1
        total = total + slope.scale(max(r - 1, 0))
    return total


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    model_flops: float
    extra_flops: float = 0.0  # analytic correction (e.g. sLSTM time-scan)

    @property
    def t_compute(self) -> float:
        return (self.flops + self.extra_flops) / (self.chips * C.PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * C.HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * C.ICI_LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo = self.flops + self.extra_flops
        return self.model_flops / hlo if hlo > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline bound that is useful model compute."""
        if self.bound_time <= 0:
            return 0.0
        t_model = self.model_flops / (self.chips * C.PEAK_FLOPS_BF16)
        return t_model / self.bound_time

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "extra_flops": self.extra_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D convention)
# ---------------------------------------------------------------------------


def active_params(cfg) -> int:
    """Parameters touched per token: total minus inactive experts."""
    from repro.models.lm import LanguageModel

    total = LanguageModel(cfg).n_params()
    if not cfg.n_experts:
        return total
    # count MoE layers
    moe_layers = sum(
        r for r, kinds in cfg.pattern for k in kinds if k.endswith("_moe")
    )
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    inactive = moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def model_flops(cfg, shape) -> float:
    n_active = active_params(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def slstm_extra_flops(cfg, shape) -> float:
    """Analytic correction for the sLSTM time scan (counted once by HLO).

    Per step per token: recurrent matmul 2*(d/h)*(4d/h)*h = 8 d^2 / h plus
    O(d) gate math (negligible).  Multiplied by sLSTM layer count and by the
    (trip_count - 1) steps the HLO misses.
    """
    n_slstm = sum(
        r for r, kinds in cfg.pattern for k in kinds if k == "slstm"
    )
    if not n_slstm:
        return 0.0
    d, h = cfg.d_model, cfg.n_kv_heads
    per_tok = 8.0 * d * d / h
    seq = shape.seq_len if shape.kind != "decode" else 1
    missed = max(seq - 1, 0) * shape.global_batch
    factor = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd
    return factor * n_slstm * per_tok * missed
