"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_LINK_BW = 50e9  # B/s per link (the roofline formula uses one link/chip)
HBM_PER_CHIP = 16e9  # bytes
