"""Collective-byte accounting from optimized HLO text.

``compiled.cost_analysis()`` does not expose collective traffic, so we parse
the post-optimization HLO module.  Modern HLO printing omits operand types,
so byte counts derive from the *output* shape on the LHS plus the op's
semantics and the replica-group size G:

    all-reduce / all-to-all / collective-permute : operand = output
    all-gather                                   : operand = output / G
    reduce-scatter                               : operand = output * G

(the reported number is the spec's "operand size" per op).  Collectives
inside ``while`` bodies are counted once — same convention as cost_analysis —
and extrapolated over scan trip counts by the caller (EXPERIMENTS.md
§Methodology).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <types> opcode(" — capture everything between '=' and the opcode.
_LINE_RE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\("
)
_DONE_RE = re.compile(r"-done\(")
# iota-style groups: replica_groups=[2,4]<=[8] -> 2 groups of 4
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# explicit groups: replica_groups={{0,1},{2,3}}
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        n = math.prod(int(d) for d in dims.split(",") if d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """{op_kind: operand_bytes_total} + {"total": sum} over the module."""
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or _DONE_RE.search(line):
            continue
        lhs_types, op = m.group(1), m.group(2)
        out_bytes = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(lhs_types))
        g = _group_size(line)
        if op == "all-gather":
            nbytes = out_bytes // g
        elif op == "reduce-scatter":
            nbytes = out_bytes * g
        else:
            nbytes = out_bytes
        out[op] += nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)
