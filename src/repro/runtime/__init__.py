from repro.runtime.fault_tolerance import (  # noqa: F401
    Preempted, RestartPolicy, Supervisor, SupervisorConfig,
    decorrelated_jitter,
)
from repro.runtime.straggler import StragglerWatchdog  # noqa: F401
from repro.runtime.elastic import (  # noqa: F401
    best_grid, make_elastic_mesh, reshard_state, route_key,
)
from repro.runtime.chaos import ChaosConfig, ChaosError, ChaosFailure, ChaosMonkey  # noqa: F401
