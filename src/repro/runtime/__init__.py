from repro.runtime.fault_tolerance import Preempted, Supervisor, SupervisorConfig  # noqa: F401
from repro.runtime.straggler import StragglerWatchdog  # noqa: F401
from repro.runtime.elastic import best_grid, make_elastic_mesh, reshard_state  # noqa: F401
from repro.runtime.chaos import ChaosConfig, ChaosError, ChaosFailure, ChaosMonkey  # noqa: F401
