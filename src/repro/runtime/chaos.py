"""Deterministic, seedable fault injection for the serving path.

``EeiServer(chaos=ChaosMonkey(ChaosConfig(seed=..., rate=...)))`` arms a
set of named injection points inside the server's dispatch/retire machinery.
Each point draws from one seeded ``numpy`` Generator under a lock, so a
given ``(seed, request stream)`` pair replays the *same* fault schedule —
the property the chaos conformance tests lean on: the every-future-
resolves-exactly-once invariant must hold under faults, and a failure must
be reproducible from its seed.

Injection points (all off unless the config enables them):

    compile       raise ``ChaosFailure`` where the server fetches a program
                  from the ``ProgramCache`` (models a transient compile /
                  allocation failure).
    launch        raise ``ChaosFailure`` after program fetch, at dispatch
                  (models a device launch failure).
    nan           poison the retired eigenvector block of one stack row
                  with NaN (models the clamped-denominator garbage the
                  verify stage exists to catch).
    slow_retire   sleep ``slow_s`` inside retire (models a straggler
                  device; exercises latency accounting, not correctness).
    thread        raise ``ChaosError`` in the admission / retire loop body
                  (models a crashed service thread; exercises the server's
                  bounded restart machinery).

Replica-level points (fired by ``EeiFleet`` per routed dispatch, not by
the server — a fleet is required for them to mean anything):

    replica_kill  kill the replica the request just routed to (process
                  death / OOM-kill); the fleet must redispatch its
                  unresolved work and restart it.
    replica_hang  wedge the replica (accepts work, never answers) — only
                  the deadline probe can catch this one.
    replica_slow  slow the replica by ``replica_slow_s`` per request —
                  the health watchdog should classify it slow and hedge.

``ChaosFailure`` subclasses ``RuntimeError`` and is marked *transient* —
the server's retry/backoff path treats it like a recoverable device error.
``ChaosError`` is *not* retried as transient: it models a genuine thread
crash.  Both are distinguishable from real faults by type, so tests can
assert nothing chaos-injected ever escapes to a caller unresolved.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np


class ChaosFailure(RuntimeError):
    """Injected *transient* fault (compile / launch).  The server's retry
    machinery treats it like any other transient dispatch error."""


class ChaosError(RuntimeError):
    """Injected *thread* fault — models a crashed admission/retire loop."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """What to inject, how often, from which seed.

    ``rate`` is the default per-point firing probability; a per-point
    override (``compile_rate`` etc.) of ``None`` inherits it.  Points fire
    independently.  All rates in [0, 1].
    """

    seed: int = 0
    rate: float = 0.05
    compile_rate: Optional[float] = None
    launch_rate: Optional[float] = None
    nan_rate: Optional[float] = None
    slow_retire_rate: Optional[float] = None
    thread_rate: Optional[float] = None
    #: Replica-level points default OFF even when ``rate`` is set: a chaos
    #: monkey armed on a single server must not try to kill replicas that
    #: do not exist.  ``EeiFleet`` chaos configs set them explicitly.
    replica_kill_rate: float = 0.0
    replica_hang_rate: float = 0.0
    replica_slow_rate: float = 0.0
    #: Sleep injected by a ``slow_retire`` firing, seconds.
    slow_s: float = 0.05
    #: Per-request delay while a ``replica_slow`` action is active, seconds.
    replica_slow_s: float = 0.05
    #: How long a ``replica_hang`` wedges the replica, seconds (bounded so
    #: soaks terminate; the deadline probe should fire well before this).
    replica_hang_s: float = 2.0

    def rate_for(self, point: str) -> float:
        override = getattr(self, f"{point}_rate", None)
        return self.rate if override is None else override


class ChaosMonkey:
    """Armed injection points over one seeded generator.

    Thread-safe: the generator draw sits under a lock (the server calls in
    from its admission, dispatch, and retire threads).  Counters record
    every firing per point — exposed through ``EeiServer.stats()`` as
    ``chaos_injected`` so soak runs can report the realized fault rate.
    """

    def __init__(self, config: Optional[ChaosConfig] = None, **kwargs):
        if config is None:
            config = ChaosConfig(**kwargs)
        elif kwargs:
            raise TypeError("pass either a ChaosConfig or kwargs, not both")
        self.config = config
        self._rng = np.random.default_rng(config.seed)
        self._lock = threading.Lock()
        self.injected = {
            "compile": 0, "launch": 0, "nan": 0, "slow_retire": 0,
            "thread": 0, "replica_kill": 0, "replica_hang": 0,
            "replica_slow": 0,
        }

    def _fire(self, point: str) -> bool:
        rate = self.config.rate_for(point)
        if rate <= 0.0:
            return False
        with self._lock:
            hit = self._rng.random() < rate
            if hit:
                self.injected[point] += 1
            return hit

    def counts(self) -> dict:
        with self._lock:
            return dict(self.injected)

    # -- injection points ---------------------------------------------------

    def on_compile(self) -> None:
        """Before the ProgramCache fetch.  Raises ChaosFailure on a hit."""
        if self._fire("compile"):
            raise ChaosFailure("chaos: injected compile failure")

    def on_launch(self) -> None:
        """At dispatch, after program fetch.  Raises ChaosFailure."""
        if self._fire("launch"):
            raise ChaosFailure("chaos: injected launch failure")

    def on_result(self, vecs: np.ndarray) -> np.ndarray:
        """At retire, on the host copy of the eigenvector block.  On a hit,
        poisons one row of the stack with NaN (in a copy) — the verify /
        fallback path must catch it before any caller sees it."""
        if not self._fire("nan"):
            return vecs
        vecs = np.array(vecs, copy=True)
        with self._lock:
            row = int(self._rng.integers(vecs.shape[0]))
        vecs[row] = np.nan
        return vecs

    def on_retire_sleep(self) -> None:
        """Inside retire.  Sleeps ``slow_s`` on a hit (straggler device)."""
        if self._fire("slow_retire"):
            time.sleep(self.config.slow_s)

    def on_thread(self, which: str) -> None:
        """In the admission / retire loop body.  Raises ChaosError on a hit
        — the loop's bounded-restart machinery must absorb it."""
        if self._fire("thread"):
            raise ChaosError(f"chaos: injected {which} thread crash")

    def on_replica(self, rid) -> Optional[str]:
        """Per fleet-routed dispatch, for replica ``rid``.  Draws the three
        replica points independently and returns the most severe hit
        (``"kill"`` > ``"hang"`` > ``"slow"``) or ``None``.  The *fleet*
        executes the action (outside its lock) — the monkey only decides,
        so the schedule stays a pure function of the seed and the dispatch
        sequence regardless of which replica driver is in use."""
        action = None
        if self._fire("replica_slow"):
            action = "slow"
        if self._fire("replica_hang"):
            action = "hang"
        if self._fire("replica_kill"):
            action = "kill"
        return action
