"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

Checkpoints are mesh-independent (unsharded leaves), so elasticity is:
pick the best (data, model) grid for the surviving device count, rebuild
shardings from the same logical rules, and ``CheckpointManager.restore`` with
the new shardings.  ``best_grid`` keeps the model axis no larger than
required (TP degree is a *model* property; losing nodes shrinks data
parallelism first).
"""

from __future__ import annotations

import zlib

import jax

from repro.sharding import rules as rules_lib


def route_key(key, candidates, salt: int = 0):
    """Rendezvous (highest-random-weight) hash: pick one of ``candidates``
    for ``key``.

    Each ``(key, candidate)`` pair gets an independent deterministic score;
    the winner is the max.  Properties the fleet router leans on:

    * removing a candidate remaps only the keys it owned (minimal churn on
      replica death), and restoring it returns exactly those keys (routing
      self-heals after restart, no table to rebuild);
    * pure function of ``(key, candidate, salt)`` — identical across
      processes and runs, so tests can predict placement.

    ``candidates`` must be non-empty; candidates and ``key`` need stable
    ``repr``s (ints / strings / tuples thereof).
    """
    if not candidates:
        raise ValueError("route_key: no candidates")

    def score(c) -> int:
        # crc32 alone is GF(2)-linear: a salt change would XOR every
        # candidate's score by the *same* constant (same-length reprs) and
        # barely reshuffle ownership.  Fold the salt in through an
        # avalanche mix (finalizer-style) so distinct salts give
        # independent placements.
        h = zlib.crc32(repr((key, c)).encode())
        h = (h + 0x9E3779B9 * (salt + 1)) & 0xFFFFFFFF
        h ^= h >> 16
        h = (h * 0x45D9F3B) & 0xFFFFFFFF
        h ^= h >> 16
        return h

    return max(candidates, key=score)


def best_grid(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid with model axis == requested TP degree.

    Falls back to shrinking TP by powers of two if the device count cannot
    sustain it (e.g. 12 survivors of a 16-TP job -> (3, 4) ... -> (12, 1)).
    """
    tp = model_parallel
    while tp > 1 and n_devices % tp:
        tp //= 2
    return max(n_devices // tp, 1), tp


def make_elastic_mesh(model_parallel: int, devices=None):
    devices = devices if devices is not None else jax.devices()
    data, model = best_grid(len(devices), model_parallel)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[: data * model])


def reshard_state(manager, abstract_state, mesh, model, fsdp=None, step=None):
    """Restore the latest checkpoint onto ``mesh`` (any size)."""
    from repro.train import steps as steps_lib

    if fsdp is None:
        fsdp = rules_lib.fsdp_recommended(model.n_params(), mesh)
    rules = rules_lib.make_rules(mesh, fsdp=fsdp)
    specs = steps_lib.state_pspecs(model, rules)
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    return manager.restore(abstract_state, step=step, shardings=shardings)
