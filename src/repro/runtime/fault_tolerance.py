"""Fault tolerance: supervised training with checkpoint/restart semantics.

``Supervisor`` owns the failure policy a 1000-node fleet needs:

* periodic async checkpoints (params + optimizer + data-iterator step);
* SIGTERM/SIGINT = preemption notice -> synchronous checkpoint, clean exit
  (maps to TPU maintenance events / GKE node drains);
* step-level retry: transient failures (preempted host, flaky interconnect
  surfacing as RuntimeError) restore the latest checkpoint and replay — the
  deterministic data pipeline makes the replay exact;
* NaN/overflow quarantine: a non-finite loss triggers rollback to the last
  checkpoint and skips the offending data window (documented escape hatch
  rather than silent divergence).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable

import numpy as np

from repro.checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.runtime")


def decorrelated_jitter(rng: np.random.Generator, base: float, prev: float,
                        cap: float = 30.0) -> float:
    """One step of AWS-style decorrelated-jitter backoff.

    ``delay = min(cap, uniform(base, prev * 3))`` — grows roughly
    geometrically like plain exponential backoff but with a full-width
    random spread, so two clients that failed *together* do not retry
    together (deterministic ``base * 2**attempt`` schedules re-collide
    every attempt).  Pass the previous delay back in as ``prev``; seed the
    first call with ``prev=base``.
    """
    if prev < base:
        prev = base
    return min(cap, float(rng.uniform(base, max(prev * 3.0, base))))


@dataclasses.dataclass
class RestartPolicy:
    """Bounded, jittered restart schedule for a dead replica.

    The fleet asks ``next_delay()`` before each rebuild; ``give_up`` turns
    True once ``max_restarts`` is exhausted (the replica stays dead and its
    keys remain remapped).  ``reset()`` forgives history after a replica
    survives ``forgive_after_s`` of healthy service.
    """

    max_restarts: int = 5
    base_delay_s: float = 0.05
    cap_s: float = 5.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._prev = self.base_delay_s
        self.restarts = 0

    @property
    def give_up(self) -> bool:
        return self.restarts >= self.max_restarts

    def next_delay(self) -> float:
        self.restarts += 1
        self._prev = decorrelated_jitter(
            self._rng, self.base_delay_s, self._prev, self.cap_s)
        return self._prev

    def reset(self) -> None:
        self._prev = self.base_delay_s
        self.restarts = 0


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_every: int = 50
    max_retries: int = 3
    nan_skip_window: int = 1  # steps to skip after a NaN rollback


class Preempted(Exception):
    pass


class Supervisor:
    def __init__(self, manager: CheckpointManager,
                 cfg: SupervisorConfig = SupervisorConfig()):
        self.manager = manager
        self.cfg = cfg
        self._preempt = False
        self._orig_handlers = {}

    def install_signal_handlers(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._orig_handlers[sig] = signal.signal(sig, self._on_signal)

    def _on_signal(self, signum, frame):
        log.warning("preemption signal %s received", signum)
        self._preempt = True

    def run(
        self,
        state: Any,
        data_iter,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        n_steps: int,
        state_shardings=None,
        on_metrics: Callable | None = None,
    ):
        """Run to ``n_steps`` with retry/rollback. Returns final state."""
        retries = 0
        step = int(np.asarray(_get_step(state)))
        if self.manager.latest_step() is None:
            # Seed a step-0 checkpoint before the loop: without one, a
            # failure before the first periodic checkpoint found nothing to
            # restore and replayed the same failing step against the
            # *unmodified* state until max_retries — no rollback, and a
            # NaN quarantine that never skipped the offending window.
            # Blocking: it must be restorable before the first step runs.
            self.manager.save(step, state,
                              extra={"data_step": data_iter.state()["step"]},
                              blocking=True)
        while step < n_steps:
            if self._preempt:
                self.manager.save(step, state,
                                  extra={"data_step": data_iter.state()["step"]},
                                  blocking=True)
                raise Preempted(f"checkpointed at step {step}")
            try:
                batch = next(data_iter)
                t0 = time.monotonic()
                state, metrics = step_fn(state, batch)
                loss = float(np.asarray(metrics["loss"]))
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                dt = time.monotonic() - t0
                if on_metrics:
                    on_metrics(step, metrics, dt)
                retries = 0
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.manager.save(
                        step, state,
                        extra={"data_step": data_iter.state()["step"]})
            except (RuntimeError, FloatingPointError) as e:
                retries += 1
                log.error("step %d failed (%s); retry %d/%d", step, e,
                          retries, self.cfg.max_retries)
                if retries > self.cfg.max_retries:
                    raise
                latest = self.manager.latest_step()
                if latest is not None:
                    state, extra = self.manager.restore(
                        state, shardings=state_shardings)
                    step = latest
                    skip = extra.get("data_step", step)
                    if isinstance(e, FloatingPointError):
                        skip += self.cfg.nan_skip_window
                    data_iter = _reset_iter(data_iter, skip)
        self.manager.wait()
        return state


def _get_step(state):
    return state.step if hasattr(state, "step") else state["step"]


def _reset_iter(data_iter, step: int):
    from repro.data.pipeline import PrefetchIterator

    data_iter.close()
    return PrefetchIterator(data_iter.source, start_step=step,
                            host=data_iter.host, n_hosts=data_iter.n_hosts)
