"""Straggler detection & mitigation hooks.

On a real fleet the SPMD step is a barrier: one slow host drags the world.
The watchdog keeps a rolling step-time distribution; a step slower than
``threshold x median`` flags a straggler event.  Mitigations wired here:

* log + counter (always) — feeds the fleet scheduler's drain decision;
* ``on_straggler`` callback — production deployments attach host-swap /
  re-mesh logic (see repro.runtime.elastic);
* optional deadline — step exceeding a hard deadline raises, which the
  Supervisor converts into checkpoint-restore on a healthy mesh.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Callable


@dataclasses.dataclass
class StragglerWatchdog:
    window: int = 50
    threshold: float = 2.0
    deadline_s: float | None = None
    on_straggler: Callable | None = None
    #: Observations required before the median is trusted; below this the
    #: watchdog never flags (a cold replica must not look like a straggler).
    min_samples: int = 10

    def __post_init__(self):
        self._times = collections.deque(maxlen=self.window)
        self.events = 0

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        flagged = False
        if len(self._times) >= self.min_samples:
            med = statistics.median(self._times)
            if dt > self.threshold * med:
                self.events += 1
                flagged = True
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        if self.deadline_s is not None and dt > self.deadline_s:
            raise RuntimeError(
                f"step {step} exceeded deadline {self.deadline_s}s ({dt:.1f}s)")
        self._times.append(dt)
        return flagged

    def classify(self, dt: float) -> str:
        """Non-mutating probe: would ``dt`` flag against the current
        distribution?  Returns ``"slow"`` / ``"healthy"`` (``"healthy"``
        while under ``min_samples`` — no baseline yet)."""
        if len(self._times) >= self.min_samples:
            if dt > self.threshold * statistics.median(self._times):
                return "slow"
        return "healthy"

    def reset(self) -> None:
        """Drop history (e.g. after a replica restart: the post-warm-up
        latency distribution is a different population)."""
        self._times.clear()

    @property
    def samples(self) -> int:
        return len(self._times)

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
