"""Straggler detection & mitigation hooks.

On a real fleet the SPMD step is a barrier: one slow host drags the world.
The watchdog keeps a rolling step-time distribution; a step slower than
``threshold x median`` flags a straggler event.  Mitigations wired here:

* log + counter (always) — feeds the fleet scheduler's drain decision;
* ``on_straggler`` callback — production deployments attach host-swap /
  re-mesh logic (see repro.runtime.elastic);
* optional deadline — step exceeding a hard deadline raises, which the
  Supervisor converts into checkpoint-restore on a healthy mesh.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Callable


@dataclasses.dataclass
class StragglerWatchdog:
    window: int = 50
    threshold: float = 2.0
    deadline_s: float | None = None
    on_straggler: Callable | None = None

    def __post_init__(self):
        self._times = collections.deque(maxlen=self.window)
        self.events = 0

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        flagged = False
        if len(self._times) >= 10:
            med = statistics.median(self._times)
            if dt > self.threshold * med:
                self.events += 1
                flagged = True
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        if self.deadline_s is not None and dt > self.deadline_s:
            raise RuntimeError(
                f"step {step} exceeded deadline {self.deadline_s}s ({dt:.1f}s)")
        self._times.append(dt)
        return flagged

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
