from repro.sharding.rules import (  # noqa: F401
    FSDP_RULES,
    TP_RULES,
    ShardingRules,
    batch_spec,
    cache_spec,
    data_axes,
    fsdp_recommended,
    make_rules,
    mesh_axis_size,
)
