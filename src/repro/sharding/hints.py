"""Activation-sharding hints for model internals.

Model code (e.g. the MoE dispatch path) sometimes needs explicit
``with_sharding_constraint`` annotations — GSPMD's propagation otherwise
picks pathological shardings for high-rank intermediates (measured: the
(groups, tokens, experts, capacity) dispatch tensor drew ~58x the expected
collective traffic on deepseek-v3 prefill; EXPERIMENTS.md §Perf).

Hints are process-local context (set by the launcher/dry-run around
lowering); when unset, constraints are no-ops so unit tests and single-device
runs never see mesh machinery.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_HINTS: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "sharding_hints", default=None)


@contextlib.contextmanager
def axis_hints(data=None, model=None, model_size: int = 1):
    """Declare mesh axis names (+ model-axis size) for activation constraints."""
    token = _HINTS.set({"data": data, "model": model,
                        "model_size": model_size})
    try:
        yield
    finally:
        _HINTS.reset(token)


def current() -> dict | None:
    return _HINTS.get()


def model_axis_size() -> int:
    hints = _HINTS.get()
    return hints.get("model_size", 1) if hints else 1


def constrain(x: jax.Array, *axes) -> jax.Array:
    """``with_sharding_constraint(x, P(*mapped_axes))`` under active hints.

    ``axes`` entries are "data" / "model" / None, mapped through the hint
    table; no-op when hints are absent.
    """
    hints = _HINTS.get()
    if hints is None:
        return x
    mapped = tuple(hints.get(a) if isinstance(a, str) else a for a in axes)
    if all(m is None for m in mapped):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*mapped))
    except (ValueError, RuntimeError):
        return x  # no mesh context — leave unconstrained
