"""Logical-axis -> mesh-axis rules (T5X-style), with divisibility fallback.

Two rule sets:

* ``tp``       — tensor/expert parallelism only: weights sharded on ``model``,
                 replicated across ``data`` (small models; cheapest comms).
* ``tp_fsdp``  — additionally shards the ``embed`` (d_model) dimension of every
                 weight over ``data`` (ZeRO-3/FSDP): required for the >=90B
                 configs, where data-replicated parameters cannot fit HBM.
                 FSDP stays *within* a pod — the ``pod`` axis carries pure data
                 parallelism (one DCN gradient all-reduce per step), the
                 standard multi-pod posture.

A logical axis maps to its mesh axis only when the dimension is divisible by
the mesh axis size (e.g. granite's kv_heads=1 falls back to replicated; the
KV *cache* then shards on sequence instead — see ``activation_rules``).
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TP_RULES: dict[str, str | None] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "inner": "model",
    "inner2": None,
    "q_lora": None,
    "kv_lora": None,
    "state": None,
    "embed": None,
    "layers": None,
}

FSDP_RULES = dict(TP_RULES, embed="data", q_lora="data")


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict
    mesh: Mesh

    def spec_for(self, shape, axes) -> P:
        assert len(shape) == len(axes), (shape, axes)
        mesh_shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        used: set[str] = set()
        out = []
        for dim, ax in zip(shape, axes):
            mapped = self.rules.get(ax) if ax is not None else None
            if (
                mapped is not None
                and mapped not in used
                and mapped in mesh_shape
                and dim % mesh_shape[mapped] == 0
            ):
                out.append(mapped)
                used.add(mapped)
            else:
                out.append(None)
        return P(*out)

    def sharding_for(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))

    def param_specs(self, table_axes: dict, table_shapes: dict) -> dict:
        return {
            path: self.spec_for(table_shapes[path], axes)
            for path, axes in table_axes.items()
        }


def make_rules(mesh: Mesh, fsdp: bool = False) -> ShardingRules:
    return ShardingRules(FSDP_RULES if fsdp else TP_RULES, mesh)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes carrying the global batch ('pod' + 'data' when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh))


def cache_spec(mesh: Mesh, n_kv_heads: int, kind: str = "attn") -> P:
    """KV-cache sharding: (layers, batch, seq, heads, dim).

    Heads shard on ``model`` when divisible; otherwise the sequence dimension
    takes ``model`` (flash-decoding style — GSPMD inserts the LSE-combine
    all-reduce in the softmax).  MLA latent caches always shard on sequence
    (the latent dim is contracted every step).
    """
    model = mesh.devices.shape[mesh.axis_names.index("model")] if "model" in mesh.axis_names else 1
    d = data_axes(mesh)
    if kind == "mla":
        return P(None, d, "model", None)
    if n_kv_heads % model == 0:
        return P(None, d, None, "model", None)
    return P(None, d, "model", None, None)


def fsdp_recommended(n_params: int, mesh: Mesh, hbm_per_chip: float = 16e9) -> bool:
    """FSDP when fp32 params + Adam(m, v) replicated over data would overflow.

    12 bytes/param (fp32 master + m + v) divided by the model axis only.
    """
    model = mesh.devices.shape[mesh.axis_names.index("model")] if "model" in mesh.axis_names else 1
    bytes_per_chip = 12.0 * n_params / model
    return bytes_per_chip > 0.5 * hbm_per_chip


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[mesh.axis_names.index(name)]
