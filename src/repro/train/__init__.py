from repro.train.steps import (  # noqa: F401
    CompiledPrograms,
    TrainState,
    abstract_state,
    build_programs,
    input_specs,
    make_serve_step,
    make_train_step,
)
