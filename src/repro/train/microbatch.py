"""Gradient accumulation over microbatches.

Splits the global batch into ``n`` microbatches and accumulates gradients
with a scan.  Used to (a) fit activation memory and (b) overlap the DCN
gradient all-reduce with compute: XLA hoists the cross-pod reduction of the
accumulated gradient out of the scan, so only the final accumulation step
pays DCN latency while earlier microbatches stream.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulated_grads(grad_fn, params, batch, n_micro: int):
    """grad_fn(params, microbatch) -> (loss, metrics, grads)."""

    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        loss, metrics, grads = grad_fn(params, mb)
        grads_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), grads_acc,
                                 grads)
        return (loss_acc + loss, grads_acc), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), micro
    )
    grads = jax.tree.map(lambda g: g / n_micro, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / n_micro, metrics, grads
