"""train_step / serve_step factories with full sharding annotations.

These are the functions the dry-run lowers and the launcher runs:

    train_step(state, batch)          -> (state, metrics)
    serve_step(params, caches, token, pos) -> (next_token, caches)

Sharding: parameters via logical-axis rules (TP or TP+FSDP), batch on the
data axes, KV caches per block kind (heads when divisible, else sequence).
Mixed precision: fp32 master params, bf16 compute cast at step entry, fp32
softmax/loss; gradient all-reduces happen in bf16 (compression) because the
cast tree is what autodiff differentiates.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import blocks
from repro.models.lm import LanguageModel
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedule import warmup_cosine
from repro.sharding import rules as rules_lib


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


# ---------------------------------------------------------------------------
# Spec trees
# ---------------------------------------------------------------------------


def param_pspecs(model: LanguageModel, rules: rules_lib.ShardingRules) -> dict:
    table = model.param_table()
    return {p: rules.spec_for(d.shape, d.axes) for p, d in table.items()}


def state_pspecs(model: LanguageModel, rules: rules_lib.ShardingRules):
    ps = param_pspecs(model, rules)
    return TrainState(
        params=ps,
        opt_state=AdamWState(count=P(), m=dict(ps), v=dict(ps)),
        step=P(),
    )


def batch_pspecs(cfg: ModelConfig, mesh) -> dict:
    d = rules_lib.data_axes(mesh)
    specs = {"tokens": P(d, None), "labels": P(d, None)}
    if cfg.family == "audio":
        specs["frames"] = P(d, None, None)
    if cfg.family == "vlm":
        specs["images"] = P(d, None, None)
    return specs


def _kv_heads_spec(mesh, n_kv_heads: int) -> P:
    """(L, B, S, Hkv, Dh): heads on model if divisible, else sequence."""
    d = rules_lib.data_axes(mesh)
    if n_kv_heads % rules_lib.mesh_axis_size(mesh, "model") == 0:
        return P(None, d, None, "model", None)
    return P(None, d, "model", None, None)


def cache_pspecs(model: LanguageModel, mesh):
    """Spec tree matching ``model.cache_spec`` exactly."""
    cfg = model.cfg
    d = rules_lib.data_axes(mesh)
    msz = rules_lib.mesh_axis_size(mesh, "model")

    def attn_spec():
        return {"k": _kv_heads_spec(mesh, cfg.n_kv_heads),
                "v": _kv_heads_spec(mesh, cfg.n_kv_heads)}

    def cross_spec():
        return {"k": _kv_heads_spec(mesh, cfg.n_kv_heads),
                "v": _kv_heads_spec(mesh, cfg.n_kv_heads)}

    def kind_spec(kind: str):
        if kind in blocks._ATTN_KINDS:
            return attn_spec()
        if kind in ("mla", "mla_moe"):
            return {"latent": P(None, d, "model", None),
                    "k_rope": P(None, d, "model", None)}
        if kind == "cross":
            return cross_spec()
        if kind == "dec_cross":
            return {"self": attn_spec(), "cross": cross_spec()}
        if kind == "mamba":
            mcfg = blocks.mamba_config(cfg)
            h_ax = "model" if mcfg.n_heads % msz == 0 else None
            di_ax = "model" if mcfg.d_inner % msz == 0 else None
            return {
                "ssm": P(None, d, h_ax, None, None),
                "conv_x": P(None, d, None, di_ax),
                "conv_b": P(None, d, None, None),
                "conv_c": P(None, d, None, None),
            }
        if kind == "mlstm":
            mcfg = blocks.mlstm_config(cfg)
            h_ax = "model" if mcfg.n_heads % msz == 0 else None
            di_ax = "model" if mcfg.d_inner % msz == 0 else None
            return {
                "s": P(None, d, h_ax, None, None),
                "n": P(None, d, h_ax, None),
                "conv": P(None, d, None, di_ax),
            }
        if kind == "slstm":
            return {"carry": [P(None, d, None)] * 4}
        raise ValueError(kind)

    return [
        {f"b{bi}:{kind}": kind_spec(kind) for bi, kind in enumerate(kinds)}
        for _, kinds in cfg.pattern
    ]


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def prune_specs(spec_tree, shape_tree, mesh):
    """Drop spec axes that do not divide the actual dimension (e.g. batch=1
    long-context caches on a 16-way data axis)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            out = 1
            for a in ax:
                out *= mesh_shape.get(a, 1)
            return out
        return mesh_shape.get(ax, 1)

    def prune(spec: P, shaped) -> P:
        dims = shaped.shape
        out = list(spec) + [None] * (len(dims) - len(spec))
        for i, ax in enumerate(out):
            if dims[i] % size(ax):
                out[i] = None
        return P(*out)

    return jax.tree.map(prune, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def make_train_step(
    model: LanguageModel,
    optimizer: AdamW | Any = None,
    compute_dtype=jnp.bfloat16,
    schedule: Callable = warmup_cosine,
    microbatch: int | None = None,
):
    optimizer = optimizer or AdamW()

    def grad_fn(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(cast_tree(p, compute_dtype), batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if microbatch and microbatch > 1:
            from repro.train.microbatch import accumulated_grads

            loss, metrics, grads = accumulated_grads(grad_fn, state.params,
                                                     batch, microbatch)
        else:
            loss, metrics, grads = grad_fn(state.params, batch)
        lr_scale = schedule(state.step)
        new_params, opt_state, opt_metrics = optimizer.update(
            grads, state.opt_state, state.params, lr_scale
        )
        metrics = dict(metrics, loss=loss, lr_scale=lr_scale, **opt_metrics)
        return TrainState(new_params, opt_state, state.step + 1), metrics

    return train_step


def make_serve_step(model: LanguageModel, compute_dtype=jnp.bfloat16):
    def serve_step(params, caches, token, pos):
        logits, caches = model.decode_step(
            cast_tree(params, compute_dtype), caches, token, pos
        )
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, caches

    return serve_step


# ---------------------------------------------------------------------------
# Jitted, mesh-aware wrappers (used by launcher and dry-run)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledPrograms:
    train_step: Any = None
    serve_step: Any = None
    state_shardings: Any = None
    batch_shardings: Any = None
    cache_shardings: Any = None


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_programs(
    model: LanguageModel,
    mesh,
    fsdp: bool | None = None,
    optimizer=None,
    compute_dtype=jnp.bfloat16,
    microbatch: int | None = None,
    cache_shapes=None,  # pass model.cache_spec(...) to prune indivisible axes
) -> CompiledPrograms:
    cfg = model.cfg
    if fsdp is None:
        fsdp = rules_lib.fsdp_recommended(model.n_params(), mesh)
    rules = rules_lib.make_rules(mesh, fsdp=fsdp)

    state_specs = state_pspecs(model, rules)
    batch_specs = batch_pspecs(cfg, mesh)
    cache_specs = cache_pspecs(model, mesh)
    if cache_shapes is not None:
        cache_specs = prune_specs(cache_specs, cache_shapes, mesh)

    state_sh = _named(mesh, state_specs)
    batch_sh = _named(mesh, batch_specs)
    cache_sh = _named(mesh, cache_specs)
    param_sh = state_sh.params
    repl = NamedSharding(mesh, P())

    train_step = make_train_step(model, optimizer, compute_dtype,
                                 microbatch=microbatch)
    serve_step = make_serve_step(model, compute_dtype)

    # Bind activation-sharding hints at trace time (MoE dispatch pinning,
    # sequence-parallel attention fallback — see repro.sharding.hints).
    from repro.sharding import hints as hints_lib

    def _hinted(fn):
        def wrapped(*a, **k):
            with hints_lib.axis_hints(
                data=rules_lib.data_axes(mesh), model="model",
                model_size=rules_lib.mesh_axis_size(mesh, "model"),
            ):
                return fn(*a, **k)
        return wrapped

    train_step = _hinted(train_step)
    serve_step = _hinted(serve_step)

    train_jit = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    serve_jit = jax.jit(
        serve_step,
        in_shardings=(param_sh, cache_sh, repl, repl),
        out_shardings=(repl, cache_sh),
        donate_argnums=(1,),
    )
    return CompiledPrograms(
        train_step=train_jit,
        serve_step=serve_jit,
        state_shardings=state_sh,
        batch_shardings=batch_sh,
        cache_shardings=cache_sh,
    )


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct) — shared by dry-run and tests
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, compute_dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of a workload cell."""
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), compute_dtype)
        if cfg.family == "vlm":
            specs["images"] = jax.ShapeDtypeStruct(
                (b, cfg.img_seq, cfg.d_model), compute_dtype)
        return specs
    if shape.kind == "prefill":
        s = shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), compute_dtype)
        if cfg.family == "vlm":
            specs["images"] = jax.ShapeDtypeStruct(
                (b, cfg.img_seq, cfg.d_model), compute_dtype)
        return specs
    # decode: one new token against a cache of seq_len
    model = LanguageModel(cfg)
    return {
        "caches": model.cache_spec(b, shape.seq_len, compute_dtype),
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def abstract_state(model: LanguageModel, optimizer=None) -> TrainState:
    optimizer = optimizer or AdamW()
    params = model.abstract(jnp.float32)
    opt = jax.eval_shape(optimizer.init, params)
    return TrainState(params=params, opt_state=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))
