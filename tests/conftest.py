import os

# Tests see the real device count (the dry-run entrypoint sets its own flag);
# a handful of mesh tests request a small host-device mesh via this env var
# being absent — they use whatever is available and skip if too few.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def random_symmetric(rng, n, dtype=np.float64):
    a = rng.standard_normal((n, n)).astype(dtype)
    return (a + a.T) / 2
