"""Optional-``hypothesis`` shim for the property-based test suites.

``hypothesis`` is a dev-only dependency (see ``pyproject.toml``); CI images
without it must still collect and run the rest of the suite.  When the real
package is importable this module re-exports it untouched; otherwise it
provides just enough of the ``given``/``settings``/``strategies`` surface for
the property tests to *define* themselves and then skip at call time.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg wrapper: pytest must not mistake the wrapped test's
            # hypothesis parameters for fixtures.
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Inert stand-in for a hypothesis strategy object."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()
