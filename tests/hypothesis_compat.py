"""Optional-``hypothesis`` shim for the property-based test suites.

``hypothesis`` is a dev-only dependency (see ``pyproject.toml``); CI images
without it must still collect and run the rest of the suite.  When the real
package is importable this module re-exports it untouched.  Otherwise it
provides a **degraded sampling fallback**: enough of the
``given``/``settings``/``strategies``/``assume`` surface that the property
tests still *run* — a bounded number of seeded random examples per test
(boundary values first), no shrinking, no example database — instead of
silently skipping.  The seed derives from the test's qualified name, so a
failure reproduces deterministically and the falsifying example is printed.

Environments that must run the real engine (CI does) set
``REPRO_REQUIRE_HYPOTHESIS=1``: the import then fails loudly rather than
letting property coverage degrade without anyone noticing.
``REPRO_FALLBACK_EXAMPLES`` bounds examples per test in fallback mode
(default 10; real hypothesis honors each test's own ``max_examples``).
"""

from __future__ import annotations

import os

_REQUIRED = os.environ.get("REPRO_REQUIRE_HYPOTHESIS", "").lower() in (
    "1", "true", "yes")

try:
    from hypothesis import assume, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError as _exc:
    if _REQUIRED:
        raise ModuleNotFoundError(
            "REPRO_REQUIRE_HYPOTHESIS is set but the real `hypothesis` "
            "package is not installed — property tests would run in the "
            "degraded sampling fallback. Install it (pip install "
            "hypothesis) or unset the variable.") from _exc

    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    #: Examples per property test in fallback mode (the real engine runs
    #: each test's own ``max_examples``; the fallback bounds local runtime).
    FALLBACK_EXAMPLES = int(os.environ.get("REPRO_FALLBACK_EXAMPLES", "10"))

    class _Unsatisfied(Exception):
        """Raised by ``assume(False)``: discard the example, draw another."""

    def assume(condition):
        if not condition:
            raise _Unsatisfied
        return True

    class _Strategy:
        """Seeded sampler: boundary examples first, then random draws."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self._edges = tuple(edges)

        def example(self, rng, index: int):
            if index < len(self._edges):
                return self._edges[index]
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)),
                             tuple(fn(e) for e in self._edges))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    value = self._draw(rng)
                    if pred(value):
                        return value
                raise _Unsatisfied

            return _Strategy(draw, tuple(e for e in self._edges if pred(e)))

    class _Strategies:
        """The subset of ``hypothesis.strategies`` this repo's tests use."""

        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2 ** 15) if min_value is None else int(min_value)
            hi = 2 ** 15 if max_value is None else int(max_value)
            return _Strategy(lambda rng: rng.randint(lo, hi), (lo, hi))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5, (False, True))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            if not seq:
                raise ValueError("sampled_from() on an empty collection")
            return _Strategy(lambda rng: rng.choice(seq), (seq[0], seq[-1]))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kwargs):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: rng.uniform(lo, hi), (lo, hi))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value, (value,))

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = min_size + 8 if max_size is None else max_size

            def draw(rng):
                size = rng.randint(min_size, hi)
                return [elements.example(rng, len(elements._edges) + 1)
                        for _ in range(size)]

            edges = ([],) if min_size == 0 else ()
            return _Strategy(draw, edges)

        @staticmethod
        def tuples(*elements):
            def draw(rng):
                return tuple(e.example(rng, len(e._edges) + 1)
                             for e in elements)

            return _Strategy(draw)

    st = _Strategies()

    def given(*_args, **kw_strategies):
        if _args:
            raise TypeError(
                "the hypothesis fallback shim supports keyword-form "
                "@given only (given(x=st.integers(...), ...))")

        def deco(fn):
            # Zero-arg wrapper: pytest must not mistake the wrapped test's
            # hypothesis parameters for fixtures.
            @functools.wraps(fn)
            def runner():
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                budget = runner._repro_max_examples
                ran = attempt = 0
                while ran < budget and attempt < budget * 50:
                    attempt += 1
                    try:
                        # A .filter() strategy that cannot satisfy its
                        # predicate raises _Unsatisfied and counts as a
                        # discard, same as assume() inside the test body.
                        kwargs = {name: strat.example(rng, attempt - 1)
                                  for name, strat in kw_strategies.items()}
                    except _Unsatisfied:
                        continue
                    except BaseException:
                        # Separate from the call below so a broken draw
                        # never prints a stale example as "falsifying".
                        print(f"Strategy draw failed (fallback sampler, "
                              f"seed={seed}, attempt={attempt}) for "
                              f"{fn.__name__}")
                        raise
                    try:
                        fn(**kwargs)
                    except _Unsatisfied:
                        continue
                    except BaseException:
                        print(f"Falsifying example (fallback sampler, "
                              f"seed={seed}): {fn.__name__}(**{kwargs!r})")
                        raise
                    ran += 1
                if ran == 0:
                    # Mirror real hypothesis's Unsatisfiable error: a
                    # property that never executes must not pass silently.
                    raise AssertionError(
                        f"fallback sampler could not satisfy the "
                        f"assumptions of {fn.__name__} in {attempt} "
                        f"attempts — zero examples executed")

            # functools.wraps sets __wrapped__ = fn, which would make
            # pytest unwrap to fn's signature and treat the strategy
            # parameters as fixtures — drop it.
            del runner.__wrapped__
            runner._repro_max_examples = FALLBACK_EXAMPLES
            return runner

        return deco

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None and \
                    hasattr(fn, "_repro_max_examples"):
                fn._repro_max_examples = min(
                    int(max_examples), FALLBACK_EXAMPLES)
            return fn

        return deco
