"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode path consistency with the parallel
forward pass (cache correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.models.lm import LanguageModel
from repro.optim import AdamW
from repro.train import TrainState, make_train_step

RNG = jax.random.PRNGKey(0)
B, S, SMAX = 2, 12, 24


def make_batch(cfg, toks):
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(RNG, (B, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        b["images"] = jax.random.normal(RNG, (B, cfg.img_seq, cfg.d_model)) * 0.1
    return b


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = reduced_config(get_config(request.param))
    model = LanguageModel(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)
    return cfg, model, params, toks


def test_forward_loss_finite(arch_setup):
    cfg, model, params, toks = arch_setup
    loss, metrics = jax.jit(model.loss)(params, make_batch(cfg, toks))
    assert np.isfinite(float(loss)), cfg.name
    assert float(metrics["ce"]) > 0


def test_train_step_updates_params(arch_setup):
    cfg, model, params, toks = arch_setup
    opt = AdamW(lr=1e-3)
    step = make_train_step(model, opt, compute_dtype=jnp.float32)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    state2, metrics = jax.jit(step)(state, make_batch(cfg, toks))
    assert np.isfinite(float(metrics["loss"]))
    # at least one param changed, none went NaN
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, state2.params)
    assert any(jax.tree.leaves(changed)), cfg.name
    finite = jax.tree.map(lambda x: bool(jnp.all(jnp.isfinite(x))),
                          state2.params)
    assert all(jax.tree.leaves(finite)), cfg.name


def test_prefill_decode_shapes_and_consistency(arch_setup):
    cfg, model, params, toks = arch_setup
    batch = make_batch(cfg, toks)
    logits_full, _ = model.prefill(params, batch, SMAX)
    assert logits_full.shape == (B, cfg.vocab_size)

    batch_m1 = dict(batch, tokens=toks[:, : S - 1], labels=toks[:, : S - 1])
    _, caches = model.prefill(params, batch_m1, SMAX)
    logits_dec, caches = model.decode_step(
        params, caches, toks[:, S - 1], jnp.asarray(S - 1, jnp.int32))
    assert logits_dec.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits_dec)).all()

    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    err = float(jnp.max(jnp.abs(logits_full - logits_dec))) / scale
    # capacity-based MoE routes prefill groups differently from single-token
    # decode (token drops differ per group), so exact logit agreement is not
    # expected there — bound the drift loosely and tightly elsewhere.
    tol = 0.5 if cfg.n_experts else 2e-4
    assert err < tol, (cfg.name, err)


def test_multistep_decode_finite(arch_setup):
    cfg, model, params, toks = arch_setup
    batch = make_batch(cfg, toks)
    logits, caches = model.prefill(params, batch, SMAX)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(3):
        logits, caches = model.decode_step(
            params, caches, tok, jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits)).all(), cfg.name
