"""Calibration-table schema: v4 round-trip, v1/v2/v3 warn-once-and-fallback,
the backend-mismatch skip warning, and the backend-specific crossover +
windowed-k-frac + krylov-n-min resolution the planner dispatches on."""

import json
import logging
from pathlib import Path

import pytest

from repro.engine import autotune
from repro.engine.autotune import CalibrationTable, load_table
from repro.engine.plan import WINDOWED_K_FRAC

PR2_DEFAULT = Path(__file__).parent / "data" / "calibration_default_pr2.json"
PR3_DEFAULT = Path(__file__).parent / "data" / "calibration_default_pr3.json"
PR5_DEFAULT = Path(__file__).parent / "data" / "calibration_default_pr5.json"


def _full_table() -> CalibrationTable:
    return CalibrationTable(
        eigh_crossover_n=24, dense_crossover_n=48,
        prod_diff_blocks=(64, 128, 128), sturm_blocks=(8, 128),
        prod_diff_block_b=4,
        pallas_eigh_crossover_n=16, pallas_dense_crossover_n=32,
        windowed_k_frac=0.25,
        host="test", backend="cpu")


def test_current_schema_round_trip(tmp_path):
    table = _full_table()
    path = table.save(tmp_path / "cal.json")
    loaded = load_table(path)
    d = json.loads(path.read_text())
    assert d["schema_version"] == autotune._SCHEMA_VERSION
    assert loaded.prod_diff_block_b == 4
    assert loaded.pallas_eigh_crossover_n == 16
    assert loaded.windowed_k_frac == 0.25
    assert loaded.crossovers_for("pallas") == (16, 32)
    assert loaded.crossovers_for("jnp") == (24, 48)
    assert loaded.crossovers_for(None) == (24, 48)


def test_v1_table_loads_with_warning_and_defaults(tmp_path, caplog):
    """Older (PR-2, schema v1) tables must warn and fall back, not crash."""
    v1 = {
        "schema_version": 1,
        "eigh_crossover_n": 128, "dense_crossover_n": 8,
        "prod_diff_blocks": [64, 128, 128], "sturm_blocks": [16, 128],
        "host": "x86_64-cpu-cpu", "backend": "cpu",
    }
    path = tmp_path / "v1.json"
    path.write_text(json.dumps(v1))
    with caplog.at_level(logging.WARNING, logger="repro.autotune"):
        table = load_table(path)
    assert "schema_version 1" in caplog.text
    assert table.eigh_crossover_n == 128
    assert table.prod_diff_block_b == 1  # bb default: PR-2 grid
    assert table.pallas_eigh_crossover_n is None
    assert table.windowed_k_frac == WINDOWED_K_FRAC
    # pallas falls back to the jnp-measured pair on v1 tables
    assert table.crossovers_for("pallas") == (128, 8)


def test_v2_table_loads_with_defaults_and_warns_exactly_once(tmp_path,
                                                             caplog):
    """Regression pinning the v2 default behavior: the missing
    ``windowed_k_frac`` loads as the static planner fallback, and the
    old-schema warning fires exactly once per (source, version) per
    process, however many times the table is re-loaded."""
    v2 = json.loads(PR3_DEFAULT.read_text())
    assert v2["schema_version"] == 2
    path = tmp_path / "v2.json"
    path.write_text(json.dumps(v2))
    autotune._WARNED.discard((f"file:{path}", 2))
    with caplog.at_level(logging.WARNING, logger="repro.autotune"):
        table = load_table(path)
        reloaded = load_table(path)  # second load: deduped
        load_table(path)
    warnings = [r for r in caplog.records
                if "schema_version 2" in r.getMessage()]
    assert len(warnings) == 1, [r.getMessage() for r in warnings]
    for t in (table, reloaded):
        assert t.windowed_k_frac == WINDOWED_K_FRAC
        # v2 fields survive untouched
        assert t.eigh_crossover_n == v2["eigh_crossover_n"]
        assert t.prod_diff_block_b == v2["prod_diff_block_b"]
        assert t.crossovers_for("pallas") == (
            v2["pallas_eigh_crossover_n"], v2["pallas_dense_crossover_n"])


def test_pr2_checked_in_default_still_loads():
    """Regression: the exact calibration_default.json shipped by PR 2."""
    table = load_table(PR2_DEFAULT)
    assert table.eigh_crossover_n == 128
    assert table.dense_crossover_n == 8
    assert table.prod_diff_blocks == (64, 128, 128)
    assert table.prod_diff_block_b == 1
    assert table.crossovers_for("pallas") == table.crossovers_for("jnp")
    assert table.windowed_k_frac == WINDOWED_K_FRAC


def test_round_trip_carries_krylov_n_min(tmp_path):
    table = CalibrationTable(
        eigh_crossover_n=24, dense_crossover_n=48,
        prod_diff_blocks=(64, 128, 128), sturm_blocks=(8, 128),
        windowed_k_frac=0.5, krylov_n_min=512,
        host="test", backend="cpu")
    path = table.save(tmp_path / "cal.json")
    d = json.loads(path.read_text())
    assert d["schema_version"] == autotune._SCHEMA_VERSION
    assert d["krylov_n_min"] == 512
    assert load_table(path).krylov_n_min == 512


def test_v4_table_loads_without_pack_fields_and_warns(tmp_path, caplog):
    """A v4 (PR-6) table predates the packed-dispatch crossovers: it must
    load with both fields None (the planner then uses the static
    ``plan.PACK_N_MAX`` / ``plan.PACKED_EIGH_N_MAX`` fallbacks) and warn
    once about the stale schema."""
    v4 = {
        "schema_version": 4,
        "eigh_crossover_n": 128, "dense_crossover_n": 8,
        "prod_diff_blocks": [64, 64, 64], "sturm_blocks": [16, 128],
        "prod_diff_block_b": 4, "windowed_k_frac": 1.0,
        "krylov_n_min": 256,
        "host": "x86_64-cpu-cpu", "backend": "cpu",
    }
    path = tmp_path / "v4.json"
    path.write_text(json.dumps(v4))
    autotune._WARNED.discard((f"file:{path}", 4))
    with caplog.at_level(logging.WARNING, logger="repro.autotune"):
        table = load_table(path)
    assert "schema_version 4" in caplog.text
    assert table.pack_n_max is None
    assert table.packed_eigh_n_max is None
    assert table.krylov_n_min == 256


def test_v5_round_trip_carries_pack_crossovers(tmp_path):
    table = CalibrationTable(
        eigh_crossover_n=24, dense_crossover_n=48,
        prod_diff_blocks=(64, 128, 128), sturm_blocks=(8, 128),
        windowed_k_frac=0.5, pack_n_max=16, packed_eigh_n_max=64,
        host="test", backend="cpu")
    path = table.save(tmp_path / "cal.json")
    d = json.loads(path.read_text())
    assert d["schema_version"] == 5
    assert d["pack_n_max"] == 16
    assert d["packed_eigh_n_max"] == 64
    loaded = load_table(path)
    assert loaded.pack_n_max == 16
    assert loaded.packed_eigh_n_max == 64


def test_v3_table_loads_without_krylov_n_min_and_warns(tmp_path, caplog):
    """A v3 (PR-5) table predates ``krylov_n_min``: it must load with the
    field as None (planner then uses the static ``plan.KRYLOV_N_MIN``
    fallback) and warn once about the stale schema."""
    v3 = json.loads(PR5_DEFAULT.read_text())
    assert v3["schema_version"] == 3
    assert "krylov_n_min" not in v3
    path = tmp_path / "v3.json"
    path.write_text(json.dumps(v3))
    autotune._WARNED.discard((f"file:{path}", 3))
    with caplog.at_level(logging.WARNING, logger="repro.autotune"):
        table = load_table(path)
    assert "schema_version 3" in caplog.text
    assert table.krylov_n_min is None
    assert table.windowed_k_frac == v3["windowed_k_frac"]


def test_chain_candidate_with_mismatched_backend_warns_not_silent(
        tmp_path, caplog, monkeypatch):
    """A user-cache/repo-default table measured on another backend is
    (correctly) skipped — but the skip must announce itself once, not leave
    the planner silently running on static fallbacks."""
    other = "tpu" if autotune.jax.default_backend() != "tpu" else "cpu"
    stale = CalibrationTable(
        eigh_crossover_n=24, dense_crossover_n=48,
        prod_diff_blocks=(64, 128, 128), sturm_blocks=(8, 128),
        host="elsewhere", backend=other)
    cache = tmp_path / "calibration.json"
    stale.save(cache)
    monkeypatch.delenv(autotune.CALIBRATION_ENV, raising=False)
    monkeypatch.setattr(autotune, "CACHE_PATH", cache)
    monkeypatch.setattr(autotune, "REPO_DEFAULT_PATH",
                        tmp_path / "missing.json")
    with caplog.at_level(logging.WARNING, logger="repro.autotune"):
        assert load_table() is None  # skipped, nothing else resolves
        load_table()  # second resolution: warning already emitted
    skips = [r for r in caplog.records
             if "measured on backend" in r.getMessage()]
    assert len(skips) == 1, [r.getMessage() for r in skips]
    assert f"backend {other!r}" in skips[0].getMessage()
    # An *explicit* path is trusted verbatim — no skip, no warning.
    assert load_table(cache).backend == other


def test_newer_schema_still_rejected(tmp_path):
    path = tmp_path / "future.json"
    d = _full_table().to_dict()
    d["schema_version"] = 99
    path.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="newer"):
        load_table(path)


def test_repo_default_is_current_schema():
    """The committed repo default is regenerated at the current schema (the
    v1/v2 copies live in tests/data/ purely as back-compat fixtures)."""
    d = json.loads(autotune.REPO_DEFAULT_PATH.read_text())
    assert d["schema_version"] == autotune._SCHEMA_VERSION
    table = load_table(autotune.REPO_DEFAULT_PATH)
    assert table.pallas_eigh_crossover_n is not None
    assert "windowed_k_frac" in d
    assert 0.0 <= table.windowed_k_frac <= 1.0
    assert table.krylov_n_min is not None and table.krylov_n_min >= 1


def test_planner_uses_backend_specific_crossovers():
    from repro.engine import plan, plan_for, set_table

    try:
        set_table(CalibrationTable(
            eigh_crossover_n=8, dense_crossover_n=12,
            prod_diff_blocks=(32, 32, 32), sturm_blocks=(8, 64),
            prod_diff_block_b=2,
            pallas_eigh_crossover_n=16, pallas_dense_crossover_n=24))
        assert plan.resolved_crossovers("jnp") == (8, 12)
        assert plan.resolved_crossovers("pallas") == (16, 24)
        # n=14: above the jnp eigh crossover but below the pallas one
        assert plan_for((14, 14), backend="jnp").method == "eei_tridiag"
        assert plan_for((14, 14), backend="pallas").method == "eigh"
    finally:
        set_table(None)


def test_planner_reads_windowed_k_frac():
    from repro.engine import plan, set_table

    try:
        set_table(CalibrationTable(
            eigh_crossover_n=8, dense_crossover_n=12,
            prod_diff_blocks=(32, 32, 32), sturm_blocks=(8, 64),
            windowed_k_frac=0.125))
        assert plan.resolved_windowed_k_frac() == 0.125
    finally:
        set_table(None)
    assert 0.0 <= plan.resolved_windowed_k_frac() <= 1.0
