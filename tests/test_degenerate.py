"""Degenerate-spectrum fixtures and conformance.

Clustered eigenvalues are the EEI identity's known failure mode: the
product-difference denominators collapse and the clamped kernels emit NaN
or finite garbage.  These tests pin the guarded-serving contract around
that regime, on every backend:

* the verifier never *passes* garbage (a row flagged ok is a genuine
  eigenpair, checked against the eigh oracle);
* the verifier never *rejects* a correct degenerate solution (repeated
  eigenvalues are legal — only wrong answers fail);
* the serving fallback chain resolves a degenerate request at oracle
  quality, marked ``degraded``, without perturbing co-batched neighbors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import (
    DegradedResult,
    EeiServer,
    SolverPlan,
    verify_topk_host,
)
from repro.engine import engine as engine_mod
from repro.engine.verify import DEFAULT_TOL

K = 4


def _host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _plan(backend: str) -> SolverPlan:
    mesh = _host_mesh() if backend == "sharded" else None
    return SolverPlan(method="eei_tridiag", backend=backend, mesh=mesh)


BACKENDS = ["reference", "jnp", "pallas", "sharded"]


# -- fixtures -----------------------------------------------------------------


def degenerate_matrix(seed: int, n: int, eps: float = 0.0,
                      dtype=np.float32) -> np.ndarray:
    """Symmetric ``(n, n)`` whose top ``K`` eigenvalues are exactly
    repeated (``eps=0``) or ``eps``-separated, embedded in an otherwise
    well-separated spectrum via ``Q diag(lam) Q^T`` with orthogonal Q."""
    rng = np.random.default_rng(seed)
    lam = np.linspace(-1.0, 0.5, n)
    lam[-K:] = 1.5 + np.arange(K) * eps
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return np.asarray((q * lam) @ q.T, dtype)


def two_cluster_matrix(seed: int, n: int, dtype=np.float32) -> np.ndarray:
    """Symmetric ``(n, n)`` with the whole spectrum in two exactly-repeated
    clusters (``n//2`` eigenvalues at 1.0, the rest at 2.0) — maximal
    multiplicity, so the product-difference denominators collapse across
    the entire table.  float32 rounding of ``Q diag(lam) Q^T`` splits the
    clusters by ~1e-7 * ||A||, right at the kernel's clamp boundary, so
    whether a given draw emits NaN, large-residual garbage, or a passable
    basis is seed- and shape-dependent — deterministic per compiled
    program, but not predictable a priori (see the probing in the serving
    test below)."""
    rng = np.random.default_rng(seed)
    lam = np.where(np.arange(n) < n // 2, 1.0, 2.0)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return np.asarray((q * lam) @ q.T, dtype)


def healthy_matrix(seed: int, n: int, dtype=np.float32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(dtype)
    return (a + a.T) / 2


def _oracle_topk(a: np.ndarray, k: int):
    lam, v = np.linalg.eigh(np.asarray(a, np.float64))
    return lam[-k:], v[:, -k:].T


# -- verifier conformance -----------------------------------------------------


@pytest.mark.parametrize("eps", [0.0, 1e-7])
def test_verifier_accepts_oracle_on_degenerate(eps):
    """Repeated eigenvalues are legal: the verifier must pass a *correct*
    degenerate solution (no false rejection that would send every
    clustered request down the fallback chain twice)."""
    for seed in (0, 1):
        a = degenerate_matrix(seed, 24, eps=eps)
        lam, vecs = _oracle_topk(a, K)
        flags = verify_topk_host(a, lam.astype(np.float32),
                                 vecs.astype(np.float32))
        assert bool(flags.ok), (
            f"verifier rejected an oracle solution (eps={eps}): {flags}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("fixture", ["repeated", "eps", "clusters"])
def test_no_unflagged_garbage_on_degenerate(backend, fixture):
    """The safety contract on every backend: a stack mixing healthy and
    degenerate matrices through the verify-enabled program never yields a
    row that is flagged ok but numerically wrong — and the healthy
    co-batched row always passes (no false positive)."""
    n = 24
    mk = {
        "repeated": lambda s: degenerate_matrix(s, n, eps=0.0),
        "eps": lambda s: degenerate_matrix(s, n, eps=1e-7),
        "clusters": lambda s: two_cluster_matrix(s, n),
    }[fixture]
    a = np.stack([healthy_matrix(0, n), mk(1), mk(2)])
    plan = _plan(backend)
    program = engine_mod.topk_program(plan, K, True, True)
    (lam, vecs), flags = program(jnp.asarray(a))
    lam, vecs = np.asarray(lam), np.asarray(vecs)
    ok = np.asarray(flags.ok)

    assert bool(ok[0]), "healthy co-batched row failed verification"
    # Device verdict agrees with the host twin (same math, same tolerance;
    # measured margins are ~10x either side of DEFAULT_TOL).
    host = verify_topk_host(a, lam, vecs)
    np.testing.assert_array_equal(ok, np.asarray(host.ok))

    for row in range(a.shape[0]):
        if not ok[row]:
            continue
        # Flagged ok => genuine eigenpairs: residual within tolerance and
        # eigenvalues matching the float64 oracle.
        lam_o, _ = _oracle_topk(a[row], K)
        np.testing.assert_allclose(lam[row], lam_o, atol=1e-3)
        r = verify_topk_host(a[row], lam[row], vecs[row])
        assert float(r.residual) <= DEFAULT_TOL


# -- serving-path conformance -------------------------------------------------


def test_degenerate_request_degrades_without_failing_neighbors():
    """A degenerate request co-batched with healthy neighbors resolves at
    eigh-oracle quality through the fallback chain, marked ``degraded``;
    the neighbors resolve normally, unmarked and unperturbed.

    Whether a given clustered draw fails the primary path is deterministic
    per compiled program but not predictable a priori (float32 rounding
    lands the cluster splits at the clamp boundary), so probe the *exact*
    bucket program the server will run for a seed the verifier rejects —
    the server must then escalate exactly that row.  Two-cluster draws
    fail at a measured ~2/3 rate, so 12 seeds never come up empty."""
    n = 24
    good = [healthy_matrix(s, n) for s in (10, 11, 12)]
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    program = engine_mod.topk_program(plan, K, True, True)
    bad = None
    for seed in range(12):
        cand = two_cluster_matrix(seed, n)
        stack = np.stack([good[0], cand, good[1], good[2]])
        _, flags = program(jnp.asarray(stack))
        if not bool(np.asarray(flags.ok)[1]):
            bad = cand
            break
    assert bad is not None, "no probed draw failed the primary path"
    srv = EeiServer(plan, max_batch=4, linger_ms=0.0)
    try:
        futs = [srv.submit(a, K) for a in (good[0], bad, good[1], good[2])]
        srv.flush()
        results = [f.result(timeout=120) for f in futs]
    finally:
        srv.close()

    rb = results[1]
    assert isinstance(rb, DegradedResult) and rb.degraded
    assert rb.fallback  # which chain link resolved it, for observability
    lam_o, _ = _oracle_topk(bad, K)
    np.testing.assert_allclose(np.asarray(rb.eigenvalues), lam_o, atol=1e-4)
    assert bool(verify_topk_host(bad, np.asarray(rb.eigenvalues),
                                 np.asarray(rb.vectors)).ok)

    for res, a in zip((results[0], results[2], results[3]),
                      (good[0], good[1], good[2])):
        assert not res.degraded
        lam_o, _ = _oracle_topk(a, K)
        np.testing.assert_allclose(np.asarray(res.eigenvalues), lam_o,
                                   atol=1e-4)

    stats = srv.stats()
    assert stats["requests_failed"] == 0
    assert stats["requests_degraded"] == 1
    assert stats["verify_failed"] == 1
    assert sum(stats["fallbacks_by_plan"].values()) == 1
