"""Distribution substrate: sharding rules, checkpoint/restore (incl. elastic
resharding), fault-tolerant supervisor, microbatching, distributed EEI."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config, reduced_config
from repro.data import PrefetchIterator, make_synthetic
from repro.data.synthetic import SyntheticLM
from repro.models.lm import LanguageModel
from repro.optim import AdamW
from repro.runtime import Supervisor, SupervisorConfig, StragglerWatchdog, best_grid
from repro.sharding import make_rules, mesh_axis_size
from repro.train import TrainState, make_train_step
from repro.train.steps import prune_specs
from repro.configs.base import ShapeConfig


def test_rules_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh)
    # everything divides a size-1 axis
    assert rules.spec_for((8, 16), ("embed", "mlp")) == P(None, "model")


def test_rules_no_duplicate_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, fsdp=True)
    spec = rules.spec_for((16, 16), ("vocab", "heads"))
    axes = [s for s in spec if s is not None]
    assert len(axes) == len(set(axes))


def test_prune_specs_drops_indivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = {"a": P(None, ("data",), None)}
    shapes = {"a": jax.ShapeDtypeStruct((3, 1, 5), jnp.float32)}
    out = prune_specs(specs, shapes, mesh)
    assert out["a"] == P(None, None, None) or out["a"] == P(None, ("data",), None)


def test_checkpoint_roundtrip_and_gc():
    cfg = reduced_config(get_config("gemma2-2b"))
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW()
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in (1, 2, 3):
            mgr.save(step, state, extra={"data_step": step}, blocking=True)
        assert mgr.steps() == [2, 3]  # keep-2 GC
        restored, extra = mgr.restore(state)
        assert extra["data_step"] == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_elastic_reshard():
    """Restore with explicit shardings (the elastic path)."""
    cfg = reduced_config(get_config("codeqwen1.5-7b"))
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh)
    table = model.param_table()
    shardings = {
        k: jax.sharding.NamedSharding(mesh, rules.spec_for(d.shape, d.axes))
        for k, d in table.items()
    }
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, params, blocking=True)
        restored, _ = mgr.restore(params, shardings=shardings)
        for k in params:
            np.testing.assert_array_equal(np.asarray(params[k]),
                                          np.asarray(restored[k]))
            assert restored[k].sharding == shardings[k]


def test_supervisor_recovers_from_transient_failure():
    cfg = reduced_config(get_config("xlstm-125m"))
    model = LanguageModel(cfg)
    opt = AdamW(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step_fn_inner = jax.jit(make_train_step(model, opt,
                                            compute_dtype=jnp.float32))
    shape = ShapeConfig("t", 16, 2, "train")
    source = make_synthetic(cfg, shape)
    data = PrefetchIterator(source)
    boom = {"armed": True}

    def step_fn(state, batch):
        if int(np.asarray(state.step)) == 3 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return step_fn_inner(state, batch)

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(CheckpointManager(d),
                         SupervisorConfig(checkpoint_every=2))
        final = sup.run(state, data, step_fn, n_steps=6)
    data.close()
    assert int(np.asarray(final.step)) == 6
    assert not boom["armed"], "failure was injected and survived"


def test_supervisor_rolls_back_before_first_periodic_checkpoint():
    """Regression: a failure *before* the first periodic checkpoint used to
    find ``latest_step() is None`` and retry without rolling anything back —
    the failing step replayed against unmodified state while the data
    iterator silently advanced, skewing the step<->batch correspondence.
    The step-0 seed checkpoint makes the retry an exact replay."""
    cfg = reduced_config(get_config("xlstm-125m"))
    model = LanguageModel(cfg)
    opt = AdamW(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step_fn_inner = jax.jit(make_train_step(model, opt,
                                            compute_dtype=jnp.float32))
    shape = ShapeConfig("t", 16, 2, "train")
    source = make_synthetic(cfg, shape)
    data = PrefetchIterator(source)
    boom = {"armed": True}
    seen = []  # (step, batch fingerprint) for every *successful* step

    def step_fn(state, batch):
        s = int(np.asarray(state.step))
        # Fail at step 1: checkpoint_every=2 means no periodic checkpoint
        # exists yet — only the seeded step-0 one can roll this back.
        if s == 1 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected failure before first checkpoint")
        seen.append((s, int(np.asarray(batch["tokens"]).sum())))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return step_fn_inner(state, batch)

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(CheckpointManager(d),
                         SupervisorConfig(checkpoint_every=2))
        final = sup.run(state, data, step_fn, n_steps=4)
    data.close()
    assert int(np.asarray(final.step)) == 4
    assert not boom["armed"], "failure was injected and survived"
    # Exact replay: the last execution of step s consumed batch s — the
    # deterministic pipeline's batch for that step, not a skewed one.
    expected = [int(source.shard_at(s, 0, 1)["tokens"].sum())
                for s in range(4)]
    last_by_step = dict(seen)  # later entries overwrite earlier replays
    assert last_by_step == {s: expected[s] for s in range(4)}


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(window=20, threshold=2.0)
    for i in range(15):
        wd.observe(i, 0.1)
    assert wd.observe(15, 0.5) is True
    assert wd.events == 1
    wd2 = StragglerWatchdog(deadline_s=0.2)
    with pytest.raises(RuntimeError):
        for i in range(20):
            wd2.observe(i, 0.1 if i < 12 else 0.3)


def test_best_grid_elastic():
    assert best_grid(256, 16) == (16, 16)
    assert best_grid(12, 16) == (3, 4)
    assert best_grid(7, 4) == (7, 1)


def test_data_pipeline_determinism_and_sharding():
    src = SyntheticLM(vocab_size=100, seq_len=8, global_batch=4, seed=1)
    b0 = src.global_batch_at(3)
    b1 = src.global_batch_at(3)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    # host shards partition the global batch rows
    s0 = src.shard_at(3, 0, 2)
    s1 = src.shard_at(3, 1, 2)
    np.testing.assert_array_equal(
        np.sort(np.concatenate([s0["tokens"], s1["tokens"]]), axis=0),
        np.sort(b0["tokens"], axis=0))
    # labels are next-token shifted
    full = np.concatenate([b0["tokens"][:, :1], b0["labels"]], axis=1)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], full[:, 1:-1])


def test_prefetch_iterator_resume():
    src = SyntheticLM(vocab_size=50, seq_len=4, global_batch=2, seed=0)
    it = PrefetchIterator(src, start_step=0)
    a = next(it)
    b = next(it)
    it.close()
    it2 = PrefetchIterator(src, start_step=1)
    b2 = next(it2)
    it2.close()
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_microbatch_grads_match_full_batch():
    cfg = reduced_config(get_config("codeqwen1.5-7b"))
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def loss_fn(p, b):
        loss, m = model.loss(p, b)
        return loss, m

    def grad_fn(p, b):
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        return l, m, g

    from repro.train.microbatch import accumulated_grads

    l_full, _, g_full = grad_fn(params, batch)
    l_mb, _, g_mb = accumulated_grads(grad_fn, params, batch, 2)
    np.testing.assert_allclose(float(l_full), float(l_mb), rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_mb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-5)


def test_sharding_hints_noop_without_context():
    from repro.sharding import hints

    x = jnp.ones((4, 4))
    assert hints.current() is None
    y = hints.constrain(x, "data", "model")  # must be identity
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with hints.axis_hints(data=("data",), model="model", model_size=4):
        assert hints.model_axis_size() == 4
        # no mesh context -> still a graceful no-op outside jit
        z = hints.constrain(x, None, "model")
        assert z.shape == x.shape


def test_eei_dot_reduction_matches_sum():
    """§Perf paper-eei optimization preserves the identity numerically."""
    from repro.core import identity

    rng2 = np.random.default_rng(3)
    a = rng2.standard_normal((40, 40))
    a = jnp.asarray((a + a.T) / 2, jnp.float32)
    lam, v = jnp.linalg.eigh(a)
    mu = identity.minor_spectra(a)
    m_sum = identity.magnitudes_from_spectra(lam, mu, reduce="sum")
    m_dot = identity.magnitudes_from_spectra(lam, mu, reduce="dot")
    np.testing.assert_allclose(np.asarray(m_sum), np.asarray(m_dot),
                               rtol=2e-3, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_dot), np.asarray((v * v).T),
                               rtol=2e-3, atol=2e-4)
    # chunked paths (n > chunk) agree with the reference forms
    lam_big = jnp.sort(jax.random.normal(jax.random.PRNGKey(0), (2100,)))
    d_ref = identity.logabs_denominator(lam_big)
    d_dot = identity.logabs_denominator_dot(lam_big, chunk_i=1024)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_dot),
                               rtol=1e-5, atol=1e-3)
