"""SolverEngine: batched solve/topk vs a vmapped eigh oracle on every
backend, planner heuristics, registry dispatch, and the batched
Cauchy-interlacing property on stacked minor spectra."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import (
    SolverEngine,
    SolverPlan,
    available_backends,
    get_backend,
    plan_for,
)
from repro.linalg import interlace

B, N = 3, 18


def _stack(seed: int, b: int = B, n: int = N) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((b, n, n))
    return jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)


def _oracle(a):
    lam, v = jax.vmap(jnp.linalg.eigh)(a)
    return lam, jnp.swapaxes(v * v, -1, -2)


def _host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _plan(backend: str, method: str = "eei_tridiag") -> SolverPlan:
    mesh = _host_mesh() if backend == "sharded" else None
    return SolverPlan(method=method, backend=backend, mesh=mesh)


BACKENDS = ["reference", "jnp", "pallas", "sharded"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["eigh", "eei_dense", "eei_tridiag"])
def test_batched_solve_matches_vmapped_eigh(backend, method):
    a = _stack(0)
    lam_ref, mags_ref = _oracle(a)
    lam, mags = SolverEngine(_plan(backend, method)).solve(a)
    assert lam.shape == (B, N) and mags.shape == (B, N, N)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(mags), np.asarray(mags_ref),
                               rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_topk_matches_vmapped_eigh(backend):
    a = _stack(1)
    lam_ref, v_ref = jax.vmap(jnp.linalg.eigh)(a)
    k = 4
    lam, vecs = SolverEngine(_plan(backend)).topk(a, k)
    assert lam.shape == (B, k) and vecs.shape == (B, k, N)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref[:, -k:]),
                               rtol=1e-6, atol=1e-8)
    ref = np.asarray(jnp.swapaxes(v_ref[..., :, -k:], -1, -2))
    got = np.asarray(vecs)
    err = np.minimum(np.abs(got - ref), np.abs(got + ref)).max()
    assert err < 1e-5, err
    # residual check: A v = lam v per pair
    res = jnp.einsum("bij,bkj->bki", a, vecs) - lam[..., None] * vecs
    assert float(jnp.abs(res).max()) < 1e-5


def test_single_matrix_round_trip():
    a = _stack(2, b=1)[0]
    lam_ref, v_ref = jnp.linalg.eigh(a)
    engine = SolverEngine(SolverPlan(method="eei_tridiag"))
    lam, mags = engine.solve(a)
    assert lam.shape == (N,) and mags.shape == (N, N)
    np.testing.assert_allclose(np.asarray(mags),
                               np.asarray((v_ref * v_ref).T),
                               rtol=1e-4, atol=1e-7)
    ev, vecs = engine.topk(a, 2)
    assert ev.shape == (2,) and vecs.shape == (2, N)


def test_eigenvalues_only_and_microbatching():
    a = _stack(3, b=5)
    lam_ref, _ = _oracle(a)
    engine = SolverEngine(SolverPlan(method="eei_tridiag", max_batch=2))
    lam = engine.eigenvalues(a)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)
    lam2, _ = engine.solve(a)  # 5 -> chunks of 2, 2, 1
    np.testing.assert_allclose(np.asarray(lam2), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)


def test_sharded_backend_pads_indivisible_stack():
    mesh = _host_mesh()
    a = _stack(4, b=3)
    lam_ref, mags_ref = _oracle(a)
    plan = SolverPlan(method="eei_tridiag", backend="sharded", mesh=mesh)
    lam, mags = SolverEngine(plan).solve(a)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(mags), np.asarray(mags_ref),
                               rtol=1e-4, atol=1e-7)


def test_batched_minor_spectra_interlace():
    """Cauchy interlacing holds for every matrix and minor in the stack."""
    a = _stack(5)
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    stages = get_backend(plan)
    d, e, _ = stages.tridiagonalize(a, False)
    lam = stages.tridiag_eigenvalues(d, e)
    mu = stages.tridiag_minor_spectra(d, e)  # (b, n, n-1)
    assert mu.shape == (B, N, N - 1)
    for bi in range(B):
        for j in range(N):
            assert bool(interlace.interlacing_holds(lam[bi], mu[bi, j])), \
                (bi, j)


# ---------------------------------------------------------------------------
# Planner + registry
# ---------------------------------------------------------------------------


def test_planner_heuristics():
    assert plan_for((8, 8)).method == "eigh"
    assert plan_for((40, 40)).method == "eei_dense"
    assert plan_for((4, 100, 100)).method == "eei_tridiag"
    assert plan_for((100, 100), k=100).method == "eigh"
    # off-TPU hosts get the portable fused-jnp backend
    assert plan_for((100, 100)).backend in ("jnp", "pallas")
    mesh = _host_mesh()
    # 1-device data axis -> not worth sharding
    assert plan_for((4, 100, 100), mesh=mesh).backend != "sharded"


def test_plan_validation():
    with pytest.raises(ValueError):
        SolverPlan(method="nope")
    with pytest.raises(ValueError):
        SolverPlan(backend="nope")
    with pytest.raises(ValueError):
        SolverPlan(backend="sharded")  # mesh required
    with pytest.raises(ValueError):
        SolverEngine(SolverPlan()).topk(_stack(0), 0)


def test_registry_lists_all_backends():
    assert set(available_backends()) >= {"reference", "jnp", "pallas",
                                         "sharded"}
    for name in ["reference", "jnp", "pallas"]:
        stages = get_backend(SolverPlan(backend=name))
        assert stages.name == name


def test_spectral_engine_shim_delegates():
    """The deprecated SpectralEngine façade routes through the engine."""
    from repro.core.spectral import SpectralEngine

    a = _stack(6, b=1)[0]
    lam_ref, v_ref = jnp.linalg.eigh(a)
    shim = SpectralEngine(method="eei_tridiag", use_kernels=True)
    ev, vecs = shim.topk_eigenpairs(a, 3)
    np.testing.assert_allclose(np.asarray(ev), np.asarray(lam_ref[-3:]),
                               rtol=1e-8, atol=1e-8)
    mags = shim.component_magnitudes(a)
    np.testing.assert_allclose(np.asarray(mags),
                               np.asarray((v_ref * v_ref).T),
                               rtol=1e-4, atol=1e-7)
