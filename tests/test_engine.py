"""SolverEngine: batched solve/topk vs a vmapped eigh oracle on every
backend, planner heuristics, registry dispatch, and the batched
Cauchy-interlacing property on stacked minor spectra."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import (
    SolveResult,
    SolverEngine,
    SolverPlan,
    available_backends,
    get_backend,
    plan_for,
)
from repro.linalg import interlace

B, N = 3, 18


def _stack(seed: int, b: int = B, n: int = N) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((b, n, n))
    return jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)


def _oracle(a):
    lam, v = jax.vmap(jnp.linalg.eigh)(a)
    return lam, jnp.swapaxes(v * v, -1, -2)


def _host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _plan(backend: str, method: str = "eei_tridiag") -> SolverPlan:
    mesh = _host_mesh() if backend == "sharded" else None
    return SolverPlan(method=method, backend=backend, mesh=mesh)


BACKENDS = ["reference", "jnp", "pallas", "sharded"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["eigh", "eei_dense", "eei_tridiag"])
def test_batched_solve_matches_vmapped_eigh(backend, method):
    a = _stack(0)
    lam_ref, mags_ref = _oracle(a)
    lam, mags = SolverEngine(_plan(backend, method)).solve(a)
    assert lam.shape == (B, N) and mags.shape == (B, N, N)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(mags), np.asarray(mags_ref),
                               rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_topk_matches_vmapped_eigh(backend):
    a = _stack(1)
    lam_ref, v_ref = jax.vmap(jnp.linalg.eigh)(a)
    k = 4
    lam, vecs = SolverEngine(_plan(backend)).topk(a, k)
    assert lam.shape == (B, k) and vecs.shape == (B, k, N)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref[:, -k:]),
                               rtol=1e-6, atol=1e-8)
    ref = np.asarray(jnp.swapaxes(v_ref[..., :, -k:], -1, -2))
    got = np.asarray(vecs)
    err = np.minimum(np.abs(got - ref), np.abs(got + ref)).max()
    assert err < 1e-5, err
    # residual check: A v = lam v per pair
    res = jnp.einsum("bij,bkj->bki", a, vecs) - lam[..., None] * vecs
    assert float(jnp.abs(res).max()) < 1e-5


def test_single_matrix_round_trip():
    a = _stack(2, b=1)[0]
    lam_ref, v_ref = jnp.linalg.eigh(a)
    engine = SolverEngine(SolverPlan(method="eei_tridiag"))
    lam, mags = engine.solve(a)
    assert lam.shape == (N,) and mags.shape == (N, N)
    np.testing.assert_allclose(np.asarray(mags),
                               np.asarray((v_ref * v_ref).T),
                               rtol=1e-4, atol=1e-7)
    ev, vecs = engine.topk(a, 2)
    assert ev.shape == (2,) and vecs.shape == (2, N)


def test_eigenvalues_only_and_microbatching():
    a = _stack(3, b=5)
    lam_ref, _ = _oracle(a)
    engine = SolverEngine(SolverPlan(method="eei_tridiag", max_batch=2))
    lam = engine.eigenvalues(a)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)
    lam2, _ = engine.solve(a)  # 5 -> chunks of 2, 2, 1
    np.testing.assert_allclose(np.asarray(lam2), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)


def test_microbatch_ragged_tail_reuses_one_program_shape():
    """b=5 with max_batch=2 must run every chunk at the (2, n, n) step shape
    — the ragged 1-row tail is padded up and sliced, not recompiled."""
    engine = SolverEngine(SolverPlan(method="eei_tridiag", max_batch=2))
    seen = []

    def fake_program(a):
        seen.append(a.shape)
        return SolveResult(jnp.zeros(a.shape[:2]), jnp.zeros(a.shape))

    engine._run(fake_program, _stack(6, b=5))
    assert seen == [(2, N, N)] * 3  # uniform shapes: one executable
    # and the padded-tail path is numerically invisible
    a = _stack(6, b=5)
    lam_ref, _ = _oracle(a)
    lam, _ = engine.solve(a)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)


def test_sharded_backend_pads_indivisible_stack():
    mesh = _host_mesh()
    a = _stack(4, b=3)
    lam_ref, mags_ref = _oracle(a)
    plan = SolverPlan(method="eei_tridiag", backend="sharded", mesh=mesh)
    lam, mags = SolverEngine(plan).solve(a)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(mags), np.asarray(mags_ref),
                               rtol=1e-4, atol=1e-7)


def test_batched_minor_spectra_interlace():
    """Cauchy interlacing holds for every matrix and minor in the stack."""
    a = _stack(5)
    plan = SolverPlan(method="eei_tridiag", backend="jnp")
    stages = get_backend(plan)
    d, e, _ = stages.tridiagonalize(a, False)
    lam = stages.tridiag_eigenvalues(d, e)
    mu = stages.tridiag_minor_spectra(d, e)  # (b, n, n-1)
    assert mu.shape == (B, N, N - 1)
    for bi in range(B):
        for j in range(N):
            assert bool(interlace.interlacing_holds(lam[bi], mu[bi, j])), \
                (bi, j)


# ---------------------------------------------------------------------------
# Planner + registry
# ---------------------------------------------------------------------------


def test_planner_heuristics():
    # Whatever the crossovers resolve to (calibrated or fallback), the
    # method choice must respect them.
    from repro.engine import resolved_crossovers

    eigh_x, dense_x = resolved_crossovers()
    assert plan_for((eigh_x, eigh_x)).method == "eigh"
    if dense_x > eigh_x:
        assert plan_for((dense_x, dense_x)).method == "eei_dense"
    big = max(eigh_x, dense_x) + 1
    assert plan_for((4, big, big)).method == "eei_tridiag"
    assert plan_for((big, big), k=big).method == "eigh"
    # off-TPU hosts get the portable fused-jnp backend
    assert plan_for((big, big)).backend in ("jnp", "pallas")
    mesh = _host_mesh()
    # 1-device data axis -> not worth sharding
    assert plan_for((4, big, big), mesh=mesh).backend != "sharded"


def test_planner_reads_calibration_table():
    """SolverPlan resolution consults the calibration table when set, and
    falls back to the static constants when none is available."""
    from repro.engine import CalibrationTable, plan, set_table

    try:
        set_table(CalibrationTable(
            eigh_crossover_n=4, dense_crossover_n=10,
            prod_diff_blocks=(32, 32, 32), sturm_blocks=(8, 64)))
        assert plan.resolved_crossovers() == (4, 10)
        assert plan_for((8, 8)).method == "eei_dense"  # 4 < 8 <= 10
        assert plan_for((12, 12)).method == "eei_tridiag"
        # Pallas backend picks its tile shapes up from the same table.
        stages = get_backend(SolverPlan(backend="pallas"))
        assert stages.name == "pallas"
    finally:
        set_table(None)  # back to the resolution chain
    assert plan.resolved_crossovers()[0] >= 1


def test_plan_validation():
    with pytest.raises(ValueError):
        SolverPlan(method="nope")
    with pytest.raises(ValueError):
        SolverPlan(backend="nope")
    with pytest.raises(ValueError):
        SolverPlan(backend="sharded")  # mesh required
    with pytest.raises(ValueError):
        SolverEngine(SolverPlan()).topk(_stack(0), 0)


def test_registry_lists_all_backends():
    assert set(available_backends()) >= {"reference", "jnp", "pallas",
                                         "sharded"}
    for name in ["reference", "jnp", "pallas"]:
        stages = get_backend(SolverPlan(backend=name))
        assert stages.name == name


def test_spectral_engine_shim_removed():
    """The deprecated SpectralEngine façade is gone; the engine is the API."""
    import repro.core as core

    assert not hasattr(core, "SpectralEngine")
    with pytest.raises(ImportError):
        from repro.core import spectral  # noqa: F401


def test_dense_signs_one_lu_matches_per_pair_solves():
    """Batched one-LU sign recovery == the per-(matrix, pair) solve oracle."""
    from repro.core.directions import (
        inverse_iteration_signs,
        inverse_iteration_signs_batched,
    )

    a = _stack(7, b=4, n=20)
    lam, v = jax.vmap(jnp.linalg.eigh)(a)
    k = 5
    lam_sel = lam[:, -k:]
    mags_sel = jnp.swapaxes(v * v, -1, -2)[:, -k:, :]
    batched = inverse_iteration_signs_batched(a, lam_sel, mags_sel)
    per_pair = jax.vmap(
        jax.vmap(inverse_iteration_signs, in_axes=(None, 0, 0))
    )(a, lam_sel, mags_sel)
    assert batched.shape == (4, k, 20)
    np.testing.assert_allclose(np.asarray(batched), np.asarray(per_pair),
                               rtol=1e-10, atol=1e-12)
