"""EeiFleet: multi-replica routing, health, failover, restart, and the
replica-level chaos conformance suite.

The fleet's contract extends the single-server one across replica death:
every caller future resolves exactly once with a finite, non-garbage
result, no matter which replica attempts raced, died, hung, or slowed —
and the fleet survives to serve the whole stream.  Which single-server
invariants lift to the fleet (and which do not) is documented in
``docs/ARCHITECTURE.md``; the tests here lock the lifted ones down.

Satellite coverage rides along: decorrelated retry jitter (seedable,
divergent across stacks), ``close(timeout=...)`` returning unresolved
futures instead of hanging, and the cross-server shared ``ProgramCache``
single-compile guarantee.
"""

import os
import signal
import threading
import time

import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.engine import (
    EeiFleet,
    EeiServer,
    FleetClosed,
    ProgramCache,
    SolverPlan,
    verify_topk_host,
)
from repro.engine.fleet import HEALTHY, SLOW
from repro.runtime import ChaosConfig, ChaosMonkey, route_key
from repro.runtime.fault_tolerance import RestartPolicy, decorrelated_jitter

PLAN = SolverPlan(method="eei_tridiag", backend="jnp")

#: One cache across the whole module (mirrors test_server): every
#: in-process fleet shares it, so compiled programs amortize across tests
#: and restarted replicas come back warm.
SHARED_CACHE = ProgramCache()


def _sym(rng, n: int) -> np.ndarray:
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _fleet(n_replicas: int = 3, **kwargs) -> EeiFleet:
    # Fixed plan (not per-bucket auto-planning) so warm-up traffic and the
    # module-shared cache hit the same program keys across all tests.
    kwargs.setdefault("server_kwargs", dict(plan=PLAN))
    kwargs.setdefault("cache", SHARED_CACHE)
    kwargs.setdefault("probe_interval_s", 0.01)
    return EeiFleet(n_replicas, **kwargs)


def _warm(n: int = 8, k: int = 2, largest: bool = True) -> None:
    """Compile the ``(b=1, n, k, largest)`` bucket into SHARED_CACHE so
    tests with tight deadlines never mistake a cold XLA compile for a
    hang."""
    rng = np.random.default_rng(99)
    with EeiServer(PLAN, max_batch=1, cache=SHARED_CACHE) as s:
        fut = s.submit(_sym(rng, n), k, largest=largest)
        s.flush()
        fut.result(timeout=300)


def _assert_fleet_safe(reqs, stats) -> None:
    """``reqs`` is ``[(a, k, future), ...]``; the fleet-level safety
    contract: every caller future resolved exactly once with a finite,
    non-garbage result, and the counters account for the stream."""
    degraded = 0
    for a, k, fut in reqs:
        assert fut.done(), "a submitted future never resolved"
        res = fut.result(timeout=0)
        lam, vec = np.asarray(res.eigenvalues), np.asarray(res.vectors)
        assert lam.shape == (k,) and vec.shape == (k, a.shape[0])
        assert np.all(np.isfinite(lam)) and np.all(np.isfinite(vec))
        # Same garbage separator as the single-server chaos suite: healthy
        # float32 residuals are ~3e-4 of ||A||_F, garbage >= ~0.1.
        flags = verify_topk_host(a, lam, vec)
        assert float(flags.residual) <= 2e-2, (
            f"garbage reached a caller: residual={float(flags.residual)}")
        if getattr(res, "degraded", False):
            degraded += 1
    assert stats["requests_failed"] == 0
    assert stats["requests_completed"] == len(reqs)
    assert stats["requests_unresolved"] == 0


def _wait_for(predicate, timeout_s: float, what: str) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.01)
    pytest.fail(f"timed out after {timeout_s}s waiting for {what}")


# ---------------------------------------------------------------------------
# Building blocks: rendezvous routing, jitter, restart policy, watchdog
# ---------------------------------------------------------------------------


def test_route_key_deterministic_and_minimal_remap():
    """Rendezvous hashing: deterministic for a (key, candidates, salt)
    triple; removing a non-owner never remaps a key; restoring the dead
    candidate restores exactly the original assignment (self-healing)."""
    candidates = [0, 1, 2, 3]
    keys = [(n, largest) for n in range(4, 40) for largest in (True, False)]
    owner = {k: route_key(k, candidates, salt=7) for k in keys}
    assert owner == {k: route_key(k, candidates, salt=7) for k in keys}
    # different salts shuffle ownership (not all keys land identically)
    assert any(owner[k] != route_key(k, candidates, salt=8) for k in keys)
    dead = 2
    survivors = [c for c in candidates if c != dead]
    for k in keys:
        new = route_key(k, survivors, salt=7)
        if owner[k] != dead:
            assert new == owner[k], "removal of a non-owner remapped a key"
    assert {k: route_key(k, candidates, salt=7) for k in keys} == owner


def test_decorrelated_jitter_bounds_and_seed():
    rng = np.random.default_rng(3)
    base, cap, prev = 0.01, 0.5, 0.01
    seen = []
    for _ in range(50):
        prev = decorrelated_jitter(rng, base, prev, cap)
        assert base <= prev <= cap
        seen.append(prev)
    # seedable: the same seed replays the same schedule
    rng2 = np.random.default_rng(3)
    prev2 = 0.01
    replay = []
    for _ in range(50):
        prev2 = decorrelated_jitter(rng2, base, prev2, cap)
        replay.append(prev2)
    assert seen == replay
    assert len(set(seen)) > 10  # jitter, not a fixed ladder


def test_restart_policy_bounded_and_jittered():
    pol = RestartPolicy(max_restarts=3, base_delay_s=0.01, cap_s=1.0, seed=5)
    delays = []
    while not pol.give_up:
        delays.append(pol.next_delay())
    assert len(delays) == 3
    assert all(0.01 <= d <= 1.0 for d in delays)
    pol.reset()
    assert not pol.give_up
    # same seed is NOT re-seeded by reset: schedules keep diverging, which
    # is the point of decorrelated jitter (no thundering herd on flapping)
    assert pol.next_delay() > 0.0


# ---------------------------------------------------------------------------
# Fleet: routing + plain serving
# ---------------------------------------------------------------------------


def test_fleet_basic_serve_and_exactly_once():
    """N=3 in-process, mixed shapes, no chaos: every result matches the
    LAPACK oracle, every future resolves exactly once, nothing degraded."""
    rng = np.random.default_rng(0)
    reqs = []
    with _fleet(3) as fleet:
        for i in range(18):
            n = int(rng.integers(4, 13))
            k = 1 + int(rng.integers(0, n))
            a = _sym(rng, n)
            reqs.append((a, k, fleet.submit(a, k, largest=True)))
        assert fleet.flush(timeout=300)
        stats = fleet.stats()
    _assert_fleet_safe(reqs, stats)
    assert stats["requests_submitted"] == len(reqs)
    for a, k, fut in reqs:
        lam = np.sort(np.asarray(fut.result().eigenvalues))
        ref = np.linalg.eigvalsh(a.astype(np.float64))[-k:]
        np.testing.assert_allclose(lam, ref, rtol=5e-3, atol=5e-3)


def test_fleet_routes_one_key_to_one_replica():
    """All requests sharing a coalesce key land on the rendezvous owner:
    per-replica server stats show exactly one replica served them."""
    rng = np.random.default_rng(1)
    with _fleet(3, salt=4) as fleet:
        futs = [fleet.submit(_sym(rng, 8), 2) for _ in range(10)]
        for f in futs:
            f.result(timeout=300)
        per = fleet.stats()["per_replica"]
        served = [rid for rid, s in per.items()
                  if s.get("requests_submitted", 0) > 0]
    assert served == [route_key((8, True), [0, 1, 2], salt=4)]


def test_fleet_submit_validation_and_closed():
    with _fleet(1) as fleet:
        with pytest.raises(ValueError):
            fleet.submit(np.zeros((3, 4), dtype=np.float32), 1)
        with pytest.raises(ValueError):
            fleet.submit(np.eye(4, dtype=np.float32), 5)
    # after close: rejected with FleetClosed, never silently dropped
    fut = fleet.submit(np.eye(4, dtype=np.float32), 1)
    with pytest.raises(FleetClosed):
        fut.result(timeout=10)
    assert fleet.stats()["requests_rejected"] == 1


# ---------------------------------------------------------------------------
# Failover, restart, deadline, hedging
# ---------------------------------------------------------------------------


def test_fleet_failover_on_kill_and_restart():
    """Killing the replica that owns in-flight work must redispatch every
    unresolved request to a survivor (exactly-once, no caller ever sees
    ReplicaDied) and restart the dead replica within the timeout."""
    rng = np.random.default_rng(2)
    _warm()
    fleet = _fleet(3, salt=1,
                   restart_policy_kwargs=dict(base_delay_s=0.01, cap_s=0.1))
    try:
        # warm traffic before the kill, counted like everything else
        a0 = _sym(rng, 8)
        reqs = [(a0, 2, fleet.submit(a0, 2))]
        reqs[0][2].result(timeout=300)
        owner = route_key((8, True), [0, 1, 2], salt=1)
        reqs += [(a := _sym(rng, 8), 2, fleet.submit(a, 2))
                 for _ in range(6)]
        fleet._kill_replica(owner, reason="test kill")
        for _, _, f in reqs:
            f.result(timeout=300)
        stats = fleet.stats()
        _assert_fleet_safe(reqs, stats)
        assert stats["replicas_killed"] >= 1
        # the dead replica must come back and re-own its keys
        _wait_for(lambda: fleet.stats()["replica_states"][owner] == HEALTHY,
                  60, "killed replica to restart")
        assert fleet.stats()["replicas_restarted"] >= 1
        # rendezvous self-heals: traffic for the key flows to it again
        fleet.submit(_sym(rng, 8), 2).result(timeout=300)
        assert fleet._replicas[owner].driver.stats()[
            "requests_submitted"] >= 1
    finally:
        assert fleet.close(timeout=120) == []


def test_fleet_deadline_catches_hung_replica():
    """A hung replica (accepts work, never answers) is only visible to the
    deadline probe: the fleet must declare it dead, redispatch, and the
    caller future must still resolve with a good result."""
    rng = np.random.default_rng(3)
    _warm()  # a cold compile must never look like a hang to the deadline
    fleet = _fleet(2, salt=0, deadline_s=0.6,
                   restart_policy_kwargs=dict(base_delay_s=0.01, cap_s=0.1))
    try:
        for _ in range(2):
            fleet.submit(_sym(rng, 8), 2).result(timeout=300)
        owner = route_key((8, True), [0, 1], salt=0)
        fleet._replicas[owner].driver.hang(30.0)
        a = _sym(rng, 8)
        fut = fleet.submit(a, 2)
        res = fut.result(timeout=60)
        assert float(verify_topk_host(
            a, np.asarray(res.eigenvalues),
            np.asarray(res.vectors)).residual) <= 2e-2
        stats = fleet.stats()
        assert stats["deadline_deaths"] >= 1
        assert stats["replicas_killed"] >= 1
    finally:
        fleet.close(timeout=120)


def test_fleet_hedges_slow_replica_first_result_wins():
    """A SLOW-classified replica gets hedged: requests stuck past
    ``hedge_age_s`` are re-attempted on a healthy replica and the first
    result wins (the loser's internal future is cancelled, and a late
    duplicate success is counted, not double-resolved)."""
    rng = np.random.default_rng(4)
    _warm()
    fleet = _fleet(2, salt=0, hedge_age_s=0.05, slow_cooldown_s=30.0)
    try:
        for _ in range(2):
            fleet.submit(_sym(rng, 8), 2).result(timeout=300)
        owner = route_key((8, True), [0, 1], salt=0)
        replica = fleet._replicas[owner]
        # white-box: pin the classification (the organic watchdog path is
        # covered by the soak); delay every forward so hedges must win
        replica.driver.slow(1.0, duration_s=30.0)
        with fleet._cv:
            replica.state = SLOW
            replica.last_slow_flag = time.monotonic()
        a = _sym(rng, 8)
        fut = fleet.submit(a, 2)
        t0 = time.monotonic()
        res = fut.result(timeout=60)
        assert float(verify_topk_host(
            a, np.asarray(res.eigenvalues),
            np.asarray(res.vectors)).residual) <= 2e-2
        # the hedge (healthy replica, warm cache) beats the 1s-delayed
        # original by a wide margin
        assert time.monotonic() - t0 < 0.9
        assert fleet.stats()["hedges"] >= 1
    finally:
        fleet.close(timeout=120)


def test_fleet_parks_when_no_replica_routable_then_recovers():
    """With every replica dead, new work parks (never fails) and flows the
    moment a restart lands."""
    rng = np.random.default_rng(5)
    fleet = _fleet(2, restart_policy_kwargs=dict(base_delay_s=0.05,
                                                 cap_s=0.2))
    try:
        fleet.submit(_sym(rng, 8), 2).result(timeout=300)
        for rid in (0, 1):
            fleet._kill_replica(rid, reason="test: total outage")
        with fleet._cv:
            dead_now = all(r.state != HEALTHY
                           for r in fleet._replicas.values())
        assert dead_now
        a = _sym(rng, 8)
        fut = fleet.submit(a, 2)  # admitted during the outage
        res = fut.result(timeout=120)  # resolves after restart
        assert float(verify_topk_host(
            a, np.asarray(res.eigenvalues),
            np.asarray(res.vectors)).residual) <= 2e-2
        assert fleet.stats()["replicas_restarted"] >= 1
    finally:
        assert fleet.close(timeout=120) == []


# ---------------------------------------------------------------------------
# Chaos conformance (the fleet-level analogue of the server chaos fuzz)
# ---------------------------------------------------------------------------

_FREQ = st.tuples(st.integers(4, 12), st.integers(0, 1), st.booleans(),
                  st.integers(0, 2))


@settings(max_examples=3, deadline=None)
@given(ops=st.lists(_FREQ, min_size=4, max_size=14),
       rate=st.sampled_from([0.05, 0.1]),
       seed=st.integers(0, 999), chaos_seed=st.integers(0, 999))
def test_fleet_chaos_stream_conformance_fuzz(ops, rate, seed, chaos_seed):
    """Random streams under 5-10% replica-level chaos (kills, hangs,
    slowdowns): every caller future resolves exactly once with a finite,
    non-garbage result; infra failures never surface to callers; the
    fleet survives the whole stream."""
    chaos = ChaosMonkey(ChaosConfig(
        seed=chaos_seed, rate=0.0, replica_kill_rate=rate,
        replica_hang_rate=rate / 2, replica_slow_rate=rate,
        replica_slow_s=0.01, replica_hang_s=0.3))
    fleet = _fleet(3, chaos=chaos, deadline_s=30.0,
                   restart_policy_kwargs=dict(
                       max_restarts=10_000, base_delay_s=0.01, cap_s=0.1))
    rng = np.random.default_rng(seed)
    reqs = []
    try:
        for n, k_raw, largest, action in ops:
            a, k = _sym(rng, n), 1 + k_raw % n
            reqs.append((a, k, fleet.submit(a, k, largest=largest)))
            if action == 1:
                time.sleep(0.002)
        for _, _, f in reqs:
            f.result(timeout=300)
    finally:
        stranded = fleet.close(timeout=300)
    assert stranded == []
    stats = fleet.stats()
    _assert_fleet_safe(reqs, stats)
    assert stats["chaos_injected"] == chaos.counts()


# ---------------------------------------------------------------------------
# Satellites: retry jitter, close-timeout semantics, shared program cache
# ---------------------------------------------------------------------------


def _all_launches_fail_server(jitter_seed, chaos_seed=11):
    """A server whose every dispatch launch fails (transient): each stack
    burns the full retry ladder (recording its jittered delays) and then
    resolves through the fallback chain — callers still get answers."""
    return EeiServer(
        PLAN, max_batch=1, retry_backoff_s=0.001, retry_backoff_cap_s=0.01,
        retry_jitter_seed=jitter_seed, cache=ProgramCache(),
        chaos=ChaosMonkey(ChaosConfig(seed=chaos_seed, rate=0.0,
                                      launch_rate=1.0)))


def _burn_retries(server, n_stacks=3):
    rng = np.random.default_rng(9)
    reqs = [(a := _sym(rng, 8), server.submit(a, 2)) for _ in range(n_stacks)]
    server.flush()
    for a, f in reqs:
        res = f.result(timeout=300)
        assert res.degraded  # resolved via fallback, not the failing launch
    return list(server.retry_delays_s)


def test_retry_jitter_seedable_and_schedules_diverge():
    """Decorrelated retry jitter: two servers with different jitter seeds
    (same fault schedule) sleep different backoff ladders — retries from
    concurrently-failing stacks spread instead of marching in lockstep —
    while the same seed replays the exact schedule."""
    d1 = _burn_retries(_all_launches_fail_server(jitter_seed=1))
    d2 = _burn_retries(_all_launches_fail_server(jitter_seed=2))
    d1_again = _burn_retries(_all_launches_fail_server(jitter_seed=1))
    # max_retries=2 default: two backoff sleeps per failing stack
    assert len(d1) == len(d2) == len(d1_again) == 6
    assert all(0.001 <= d <= 0.01 for d in d1 + d2)
    assert d1 == d1_again, "same seed must replay the same schedule"
    assert d1 != d2, "different seeds must decorrelate the schedules"
    assert len(set(d1)) > 1, "delays within one schedule must vary"


def test_close_timeout_returns_unresolved_futures():
    """``close(drain=True, timeout=...)`` on a server wedged by chaos
    slow-retires must return the still-unresolved futures instead of
    hanging or raising — the caller decides what to do with the tail."""
    chaos = ChaosMonkey(ChaosConfig(seed=3, rate=0.0, slow_retire_rate=1.0,
                                    slow_s=1.0))
    server = EeiServer(PLAN, max_batch=1, linger_ms=1.0, cache=SHARED_CACHE,
                       chaos=chaos)
    rng = np.random.default_rng(6)
    futs = [server.submit(_sym(rng, 8), 2) for _ in range(4)]
    t0 = time.monotonic()
    stranded = server.close(drain=True, timeout=0.2)
    assert time.monotonic() - t0 < 5.0, "close() must respect its timeout"
    assert stranded, "slow-retired tail should still be unresolved"
    assert set(stranded) <= set(futs)
    assert not server.alive()
    # the retire thread is still draining (daemon): the stranded futures
    # eventually resolve — close() never double-resolved or leaked them
    for f in futs:
        f.result(timeout=300)
    assert server.stats()["requests_unresolved"] == 0


def test_clean_close_returns_empty_list():
    rng = np.random.default_rng(7)
    server = EeiServer(PLAN, max_batch=2, linger_ms=1.0, cache=SHARED_CACHE)
    futs = [server.submit(_sym(rng, 8), 2) for _ in range(3)]
    assert server.close(drain=True, timeout=300) == []
    assert all(f.done() for f in futs)


def test_cross_server_shared_cache_compiles_once_per_bucket():
    """Two servers sharing one injected ProgramCache: concurrent misses on
    the same bucket from *different* servers still compile exactly once
    (the fleet's warm-restart property depends on this)."""
    cache = ProgramCache()
    servers = [EeiServer(PLAN, max_batch=1, cache=cache) for _ in range(2)]
    rng = np.random.default_rng(8)
    mats = [_sym(rng, 8) for _ in range(6)]
    barrier = threading.Barrier(2)
    results = [None, None]

    def drive(i):
        barrier.wait()  # race the first-miss compile across servers
        futs = [servers[i].submit(a, 2) for a in mats]
        servers[i].flush()
        results[i] = [f.result(timeout=300) for f in futs]

    threads = [threading.Thread(target=drive, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive()
    assert cache.compiles == 1 and len(cache) == 1
    assert cache.hits + cache.misses == 12  # every dispatch accounted
    lam0 = [np.asarray(r.eigenvalues) for r in results[0]]
    lam1 = [np.asarray(r.eigenvalues) for r in results[1]]
    for x, y in zip(lam0, lam1):
        np.testing.assert_array_equal(x, y)  # same program, same bits
    for s in servers:
        s.close()


# ---------------------------------------------------------------------------
# Stress lane (-m slow): soak + subprocess replica kill lane
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fleet_chaos_soak_kill_restart_resume():
    """60-request soak at 8% kills / 4% hangs / 8% slowdowns: exactly-once
    and nothing-degraded-unflagged hold end-to-end, kills actually fired,
    and killed replicas restarted and resumed serving."""
    chaos = ChaosMonkey(ChaosConfig(
        seed=7, rate=0.0, replica_kill_rate=0.08, replica_hang_rate=0.04,
        replica_slow_rate=0.08, replica_slow_s=0.01, replica_hang_s=0.3))
    fleet = _fleet(3, chaos=chaos, deadline_s=30.0,
                   restart_policy_kwargs=dict(
                       max_restarts=10_000, base_delay_s=0.01, cap_s=0.1))
    rng = np.random.default_rng(12)
    reqs = []
    try:
        for i in range(60):
            n = int(rng.integers(4, 13))
            k = 1 + int(rng.integers(0, n))
            a = _sym(rng, n)
            reqs.append((a, k, fleet.submit(a, k, largest=bool(i % 2))))
            if i % 7 == 0:
                time.sleep(0.005)
        for _, _, f in reqs:
            f.result(timeout=300)
    finally:
        stranded = fleet.close(timeout=300)
    assert stranded == []
    stats = fleet.stats()
    _assert_fleet_safe(reqs, stats)
    assert stats["replicas_killed"] >= 1, "soak never exercised a kill"
    assert stats["replicas_restarted"] >= 1
    assert stats["redispatches"] >= 1
    assert stats["chaos_injected"]["replica_kill"] >= 1


@pytest.mark.slow
def test_fleet_subprocess_replica_sigkill_failover():
    """Real process isolation: N=2 subprocess replicas, SIGKILL one worker
    mid-stream — EOF on its pipe must fail over every outstanding request
    to the survivor, exactly-once, and close() leaves nothing stranded."""
    if os.cpu_count() is None or os.cpu_count() < 1:
        pytest.skip("no CPU count available")
    rng = np.random.default_rng(13)
    fleet = EeiFleet(
        2, replica_mode="subprocess", probe_interval_s=0.02,
        server_kwargs=dict(max_batch=4, linger_ms=2.0),
        restart_policy_kwargs=dict(max_restarts=100, base_delay_s=0.05,
                                   cap_s=0.5))
    reqs = []
    try:
        # warm both workers (each owns its own process-local cache)
        for _ in range(4):
            a = _sym(rng, 8)
            reqs.append((a, 2, fleet.submit(a, 2)))
        for _, _, f in reqs:
            f.result(timeout=300)
        victim = fleet._replicas[0]
        for _ in range(6):
            a = _sym(rng, 8)
            reqs.append((a, 2, fleet.submit(a, 2)))
        os.kill(victim.driver._proc.pid, signal.SIGKILL)
        for _, _, f in reqs:
            f.result(timeout=300)
        stats = fleet.stats()
        assert stats["replicas_killed"] >= 1
    finally:
        stranded = fleet.close(timeout=300)
    assert stranded == []
    _assert_fleet_safe(reqs, fleet.stats())
