"""The degraded-sampling fallback in ``hypothesis_compat``.

These only run where the real ``hypothesis`` is absent (the fallback is
active); with the real engine installed the shim is a pure re-export and
there is nothing of ours to test.
"""

import pytest

import hypothesis_compat as hc

pytestmark = pytest.mark.skipif(
    hc.HAVE_HYPOTHESIS, reason="real hypothesis installed; shim is inert")


def test_sampler_runs_boundaries_first_and_bounded_examples():
    seen = []

    @hc.settings(max_examples=50, deadline=None)
    @hc.given(x=hc.st.integers(3, 7))
    def prop(x):
        seen.append(x)

    prop()
    assert seen[0] == 3 and seen[1] == 7  # boundary examples first
    assert all(3 <= x <= 7 for x in seen)
    assert len(seen) == min(50, hc.FALLBACK_EXAMPLES)


def test_sampler_is_deterministic_per_test():
    runs = []
    for _ in range(2):
        seen = []

        @hc.given(x=hc.st.integers(0, 10_000))
        def prop(x):
            seen.append(x)

        prop()
        runs.append(seen)
    assert runs[0] == runs[1]  # seed derives from the test name


def test_assume_discards_and_unsatisfiable_fails():
    ran = []

    @hc.given(x=hc.st.integers(0, 100))
    def sometimes(x):
        hc.assume(x % 2 == 0)
        ran.append(x)

    sometimes()
    assert ran and all(x % 2 == 0 for x in ran)

    @hc.given(x=hc.st.integers(0, 100))
    def never(x):
        hc.assume(False)

    # Zero executed examples must fail loudly, not pass silently.
    with pytest.raises(AssertionError, match="zero examples"):
        never()


def test_filter_strategy_discards_not_errors():
    ran = []

    @hc.given(x=hc.st.integers(0, 100).filter(lambda v: v >= 99))
    def prop(x):
        ran.append(x)

    prop()  # sparse filter: discards must not surface as errors
    assert all(x >= 99 for x in ran)


def test_failing_example_propagates():
    @hc.given(x=hc.st.integers(0, 100))
    def bad(x):
        assert x < 0

    with pytest.raises(AssertionError):
        bad()
