"""EEI core correctness: every variant against the eigh oracle, plus the
hypothesis property suite on the system's invariants."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import identity, minors, numpy_ref
from repro.engine import SolverEngine, SolverPlan
from repro.linalg import interlace


def _sym(seed: int, n: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    return jnp.asarray((a + a.T) / 2)


def _oracle(a):
    lam, v = jnp.linalg.eigh(a)
    return lam, (v * v).T  # |v[i, j]|^2, rows = eigenvectors


VARIANTS = ["baseline", "cached", "vectorized", "batched", "parallel",
            "logspace"]


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("n", [2, 5, 12])
def test_single_component_matches_eigh(variant, n):
    a = _sym(n, n)
    _, ref = _oracle(a)
    for i, j in [(0, 0), (n // 2, n - 1), (n - 1, 0)]:
        got = identity.component(a, i, j, variant=variant, batch_size=3)
        np.testing.assert_allclose(float(got), float(ref[i, j]),
                                   rtol=1e-8, atol=1e-12)


@pytest.mark.parametrize("logspace", [True, False])
def test_full_matrix_matches_eigh(logspace):
    a = _sym(3, 16)
    _, ref = _oracle(a)
    got = identity.eigenmatrix_magnitudes(a, logspace=logspace)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-10)


def test_numpy_reference_matches_jax():
    """The paper-faithful NumPy Algorithm 1/2 agree with the JAX ladder."""
    rng = np.random.default_rng(4)
    a = rng.standard_normal((10, 10))
    a = (a + a.T) / 2
    aj = jnp.asarray(a)
    for i, j in [(0, 3), (9, 9), (5, 0)]:
        base = numpy_ref.eigen_component_baseline(a, i, j)
        opt = numpy_ref.eigen_component_optimized(a, i, j, batch_size=4)
        jax_v = float(identity.component(aj, i, j, variant="logspace"))
        np.testing.assert_allclose(base, opt, rtol=1e-10)
        np.testing.assert_allclose(base, jax_v, rtol=1e-8)


def test_batched_fixes_large_n_overflow():
    """Paper section 3: naive products over/underflow at n >~ 150; paired
    batching (Algorithm 2) and logspace stay finite."""
    n = 200
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n)) * 10
    a = (a + a.T) / 2
    lam = np.linalg.eigvalsh(a)
    minor = np.delete(np.delete(a, 0, 0), 0, 1)
    mu = np.linalg.eigvalsh(minor)
    naive_num = np.prod(lam[n // 2] - mu)
    naive_den = np.prod(np.delete(lam[n // 2] - lam, n // 2))
    assert (not np.isfinite(naive_num)) or (not np.isfinite(naive_den)) or \
        naive_num == 0.0 or naive_den == 0.0, "matrix too small to overflow"
    aj = jnp.asarray(a)
    batched = identity.component(aj, n // 2, 0, variant="batched")
    logsp = identity.component(aj, n // 2, 0, variant="logspace")
    _, ref = _oracle(aj)
    np.testing.assert_allclose(float(batched), float(ref[n // 2, 0]),
                               rtol=1e-6)
    np.testing.assert_allclose(float(logsp), float(ref[n // 2, 0]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Property-based invariants
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24))
def test_property_rows_are_unit_vectors(seed, n):
    """sum_j |v[i, j]|^2 == 1 for every eigenvector i."""
    a = _sym(seed, n)
    mags = identity.eigenmatrix_magnitudes(a)
    np.testing.assert_allclose(np.asarray(jnp.sum(mags, axis=1)),
                               np.ones(n), rtol=1e-5, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24))
def test_property_columns_are_unit_vectors(seed, n):
    """Orthogonal eigenbasis: sum_i |v[i, j]|^2 == 1 for every component j."""
    a = _sym(seed, n)
    mags = identity.eigenmatrix_magnitudes(a)
    np.testing.assert_allclose(np.asarray(jnp.sum(mags, axis=0)),
                               np.ones(n), rtol=1e-5, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 24),
       j=st.integers(0, 23))
def test_property_cauchy_interlacing(seed, n, j):
    a = _sym(seed, n)
    j = j % n
    lam = jnp.linalg.eigvalsh(a)
    mu = jnp.linalg.eigvalsh(minors.minor(a, jnp.asarray(j)))
    assert bool(interlace.interlacing_holds(lam, mu))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_degenerate_spectrum_is_finite(seed):
    """Repeated eigenvalues: EEI must stay finite (0/0 -> clamped)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((8, 8)))
    lam = np.array([1.0, 1.0, 1.0, 2.0, 2.0, 3.0, 4.0, 5.0])
    a = jnp.asarray(q @ np.diag(lam) @ q.T)
    mags = identity.eigenmatrix_magnitudes(a)
    assert bool(jnp.all(jnp.isfinite(mags)))


def test_dot_reductions_preserve_x64_dtype():
    """Regression: the ones-contraction forms used to hardcode float32 ones,
    silently downcasting the fused reduction under x64."""
    a = _sym(2, 12)
    lam = jnp.linalg.eigvalsh(a).astype(jnp.float64)
    mu = identity.minor_spectra(a).astype(jnp.float64)
    log_den = identity.logabs_denominator_dot(lam)
    log_num = identity.logabs_numerator_dot(lam, mu)
    assert log_den.dtype == jnp.float64
    assert log_num.dtype == jnp.float64
    # and the values agree with the unfused f64 reductions at f64 precision
    np.testing.assert_allclose(np.asarray(log_den),
                               np.asarray(identity.logabs_denominator(lam)),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(log_num),
                               np.asarray(identity.logabs_numerator(lam, mu)),
                               rtol=1e-12, atol=1e-12)


def test_minor_construction_traced_index():
    a = _sym(0, 9)
    for j in range(9):
        expected = np.delete(np.delete(np.asarray(a), j, 0), j, 1)
        got = minors.minor(a, jnp.asarray(j))
        np.testing.assert_array_equal(np.asarray(got), expected)


@pytest.mark.parametrize("method", ["eigh", "eei_dense", "eei_tridiag"])
def test_solver_engine_topk(method):
    a = _sym(7, 20)
    lam, v = jnp.linalg.eigh(a)
    eng = SolverEngine(SolverPlan(method=method))
    ev, vecs = eng.topk(a, 4)
    np.testing.assert_allclose(np.asarray(ev), np.asarray(lam[-4:]),
                               rtol=1e-8, atol=1e-8)
    vref = np.asarray(v[:, -4:].T)
    got = np.asarray(vecs)
    err = np.minimum(np.abs(got - vref), np.abs(got + vref)).max()
    assert err < 1e-6, err


def test_solver_engine_kernelized():
    a = _sym(11, 24)
    eng = SolverEngine(SolverPlan(method="eei_tridiag", backend="pallas"))
    ref = SolverEngine(SolverPlan(method="eigh"))
    ev, vecs = eng.topk(a, 3)
    ev_r, vecs_r = ref.topk(a, 3)
    np.testing.assert_allclose(np.asarray(ev), np.asarray(ev_r), rtol=1e-8,
                               atol=1e-8)
    err = np.minimum(np.abs(np.asarray(vecs) - np.asarray(vecs_r)),
                     np.abs(np.asarray(vecs) + np.asarray(vecs_r))).max()
    assert err < 1e-6, err
