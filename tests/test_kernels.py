"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import identity
from repro.kernels.prod_diff import ops as pd_ops
from repro.kernels.prod_diff import ref as pd_ref
from repro.kernels.sturm import ops as st_ops
from repro.kernels.sturm import ref as st_ref
from repro.linalg.householder import tridiagonal_matrix


# -- prod_diff ---------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("shape", [(4, 4, 3), (12, 12, 11), (40, 33, 17),
                                   (130, 5, 140), (8, 129, 64)])
def test_prod_diff_shape_dtype_sweep(shape, dtype):
    i_n, j_n, k_n = shape
    rng = np.random.default_rng(i_n * 100 + j_n)
    lam = jnp.asarray(np.sort(rng.standard_normal(i_n)), dtype)
    mu = jnp.asarray(rng.standard_normal((j_n, k_n)), dtype)
    out_k = pd_ops.logabs_sum(lam, mu, 1e-9)
    out_r = pd_ref.logabs_sum(lam, mu, 1e-9)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 8, 32), (128, 128, 128)])
def test_prod_diff_block_shapes(blocks):
    bi, bj, bk = blocks
    rng = np.random.default_rng(0)
    lam = jnp.asarray(np.sort(rng.standard_normal(20)))
    mu = jnp.asarray(rng.standard_normal((20, 19)))
    out_k = pd_ops.logabs_sum(lam, mu, 1e-9, block_i=bi, block_j=bj, block_k=bk)
    out_r = pd_ref.logabs_sum(lam, mu, 1e-9)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-10, atol=1e-10)


def test_prod_diff_eei_magnitudes_vs_eigh():
    rng = np.random.default_rng(3)
    n = 24
    a = rng.standard_normal((n, n))
    a = jnp.asarray((a + a.T) / 2)
    lam, v = jnp.linalg.eigh(a)
    mu = identity.minor_spectra(a)
    mags = pd_ops.eei_magnitudes(lam, mu)
    np.testing.assert_allclose(np.asarray(mags), np.asarray((v * v).T),
                               rtol=1e-6, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(i_n=st.integers(1, 40), j_n=st.integers(1, 40), k_n=st.integers(1, 40),
       seed=st.integers(0, 1000))
def test_property_prod_diff_any_shape(i_n, j_n, k_n, seed):
    rng = np.random.default_rng(seed)
    lam = jnp.asarray(rng.standard_normal(i_n))
    mu = jnp.asarray(rng.standard_normal((j_n, k_n)))
    out_k = pd_ops.logabs_sum(lam, mu, 1e-9)
    out_r = pd_ref.logabs_sum(lam, mu, 1e-9)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-9, atol=1e-9)


# -- natively batched grid vs vmapped legacy vs reference --------------------


@pytest.mark.parametrize("bn", [(1, 8), (3, 17), (16, 64)])
def test_batched_grid_matches_vmapped_and_reference(bn):
    """The 4-D (b, i, j, k) grid == vmap of the legacy 3-D grid == jnp ref."""
    b, n = bn
    rng = np.random.default_rng(b * 1000 + n)
    lam = jnp.asarray(np.sort(rng.standard_normal((b, n)), axis=-1))
    mu = jnp.asarray(rng.standard_normal((b, n, n - 1)))
    floor = 1e-9
    out_batched = pd_ops.logabs_sum_batched(lam, mu, floor)
    out_vmapped = jax.vmap(
        lambda l, m: pd_ops.logabs_sum(l, m, floor))(lam, mu)
    out_ref = jnp.stack([pd_ref.logabs_sum(lam[q], mu[q], floor)
                         for q in range(b)])
    assert out_batched.shape == (b, n, n)
    np.testing.assert_allclose(np.asarray(out_batched), np.asarray(out_ref),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(out_batched),
                               np.asarray(out_vmapped),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("bn", [(1, 8), (3, 17), (16, 64)])
def test_batched_eei_magnitudes_matches_vmapped(bn):
    b, n = bn
    rng = np.random.default_rng(b * 7 + n)
    a = rng.standard_normal((b, n, n))
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)
    lam, v = jax.vmap(jnp.linalg.eigh)(a)
    mu = jax.vmap(identity.minor_spectra)(a)
    out_batched = pd_ops.eei_magnitudes_batched(lam, mu)
    out_vmapped = jax.vmap(pd_ops.eei_magnitudes)(lam, mu)
    np.testing.assert_allclose(np.asarray(out_batched),
                               np.asarray(out_vmapped),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(out_batched),
                               np.asarray(jnp.swapaxes(v * v, -1, -2)),
                               rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("bb", [2, 4, 8])
@pytest.mark.parametrize("bn", [(3, 9), (8, 16), (6, 24)])
def test_b_tiled_batch_block_bitwise_matches_bb1(bn, bb):
    """bb > 1 stacks matrices into one grid step; every output element
    still depends only on its own batch row, so results are *bitwise*
    equal to the one-matrix-per-step (bb=1) grid and match the reference,
    including when bb does not divide b (batch rows pad with floor=1)."""
    b, n = bn
    rng = np.random.default_rng(b * 31 + n)
    lam = jnp.asarray(np.sort(rng.standard_normal((b, n)), axis=-1))
    mu = jnp.asarray(rng.standard_normal((b, n, n - 1)))
    out_bb = pd_ops.logabs_sum_batched(lam, mu, 1e-9, block_b=bb)
    out_b1 = pd_ops.logabs_sum_batched(lam, mu, 1e-9, block_b=1)
    assert out_bb.shape == (b, n, n)
    np.testing.assert_array_equal(np.asarray(out_bb), np.asarray(out_b1))
    out_ref = jnp.stack([pd_ref.logabs_sum(lam[q], mu[q], 1e-9)
                         for q in range(b)])
    np.testing.assert_allclose(np.asarray(out_bb), np.asarray(out_ref),
                               rtol=1e-10, atol=1e-10)


def test_b_tiled_eei_magnitudes_matches_eigh():
    b, n = 5, 12
    rng = np.random.default_rng(4)
    a = rng.standard_normal((b, n, n))
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)
    lam, v = jax.vmap(jnp.linalg.eigh)(a)
    mu = jax.vmap(identity.minor_spectra)(a)
    out = pd_ops.eei_magnitudes_batched(lam, mu, block_b=4)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(v * v, -1, -2)),
                               rtol=1e-6, atol=1e-9)


def test_block_clamping_small_problems():
    """A default 128 tile on a tiny problem must clamp, not pad 128x."""
    from repro.kernels.blocks import clamp_batch_block, clamp_block, \
        pow2_bucket

    assert clamp_block(128, 3) == 8  # pad 3 -> 8, not 3 -> 128
    assert clamp_block(128, 17) == 24  # aligned, single tile
    assert clamp_block(128, 64) == 64
    assert clamp_block(128, 130) == 128  # large dims keep the full tile
    assert clamp_block(8, 130, align=1) == 8  # batch axis: no alignment
    assert clamp_block(8, 3, align=1) == 3
    assert clamp_block(12, 64) == 16  # unaligned requests round up
    # batch-axis clamp snaps to powers of two (full grid steps after pow2
    # stack bucketing) and never exceeds the bucketed stack
    assert pow2_bucket(1) == 1 and pow2_bucket(5) == 8 and \
        pow2_bucket(64) == 64
    assert clamp_batch_block(8, 5) == 8  # 5 pads to one full step of 8
    assert clamp_batch_block(128, 6) == 8
    assert clamp_batch_block(1, 100) == 1
    assert clamp_batch_block(3, 100) == 4


# -- sturm --------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("bn", [(1, 4), (5, 23), (3, 64), (9, 130)])
def test_sturm_shape_dtype_sweep(bn, dtype):
    b, n = bn
    rng = np.random.default_rng(b * 10 + n)
    d = jnp.asarray(rng.standard_normal((b, n)), dtype)
    e = jnp.asarray(rng.standard_normal((b, n - 1)), dtype)
    ev_k = st_ops.sturm_eigenvalues(d, e)
    ev_r = st_ref.sturm_eigenvalues(d, e)
    tol = 2e-5 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(ev_k), np.asarray(ev_r),
                               rtol=tol, atol=tol)
    ref = jnp.stack([
        jnp.linalg.eigvalsh(tridiagonal_matrix(d[i].astype(jnp.float64),
                                               e[i].astype(jnp.float64)))
        for i in range(b)
    ])
    np.testing.assert_allclose(np.asarray(ev_k, dtype=np.float64),
                               np.asarray(ref), atol=3e-4 if
                               dtype == jnp.float32 else 1e-10)


def test_sturm_decoupled_and_degenerate():
    d = jnp.asarray([[1.0, 1.0, 1.0, 5.0, 5.0, 2.0]])
    e = jnp.asarray([[0.0, 0.5, 0.0, 0.0, 1.0]])
    ev = st_ops.sturm_eigenvalues(d, e)
    ref = jnp.linalg.eigvalsh(tridiagonal_matrix(d[0], e[0]))
    np.testing.assert_allclose(np.asarray(ev[0]), np.asarray(ref), atol=1e-10)


def test_sturm_stacked_minor_spectra():
    """sturm_minor_spectra flattens (b, n) minors into one tiled program."""
    from repro.core import minors
    from repro.linalg.householder import tridiagonalize_batched

    rng = np.random.default_rng(5)
    b, n = 3, 12
    a = rng.standard_normal((b, n, n))
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)
    d, e, _ = tridiagonalize_batched(a, with_q=False)
    dm, em = minors.all_tridiagonal_minor_bands_batched(d, e)
    mu = st_ops.sturm_minor_spectra(dm, em)
    assert mu.shape == (b, n, n - 1)
    flat = st_ops.sturm_eigenvalues(
        dm.reshape(b * n, n - 1), em.reshape(b * n, n - 2))
    np.testing.assert_allclose(np.asarray(mu),
                               np.asarray(flat.reshape(b, n, n - 1)),
                               rtol=1e-12, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 6), n=st.integers(2, 48), seed=st.integers(0, 1000))
def test_property_sturm_sorted_and_exact(b, n, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.standard_normal((b, n)))
    e = jnp.asarray(rng.standard_normal((b, n - 1)))
    ev = np.asarray(st_ops.sturm_eigenvalues(d, e))
    assert (np.diff(ev, axis=1) >= -1e-12).all(), "eigenvalues must be sorted"
    for i in range(b):
        ref = np.asarray(jnp.linalg.eigvalsh(tridiagonal_matrix(d[i], e[i])))
        np.testing.assert_allclose(ev[i], ref, atol=1e-9)


# ---------------------------------------------------------------------------
# Block / bucket invariants (the shapes serving buckets and kernel grids
# are built from) — property-based
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(x=st.integers(1, 1 << 20))
def test_property_pow2_bucket(x):
    from repro.kernels.blocks import pow2_bucket

    p = pow2_bucket(x)
    assert p >= x, "bucket never shrinks the request"
    assert p & (p - 1) == 0, "bucket is a power of two"
    assert p < 2 * x, "bucket is the *smallest* pow2 >= x"
    assert pow2_bucket(p) == p, "idempotent on powers of two"


def test_pow2_bucket_rejects_nonpositive():
    from repro.kernels.blocks import pow2_bucket

    for bad in (0, -1, -128):
        with pytest.raises(ValueError):
            pow2_bucket(bad)


@settings(max_examples=25, deadline=None)
@given(requested=st.integers(1, 512), dim=st.integers(1, 512),
       align=st.sampled_from([1, 8]))
def test_property_clamp_block(requested, dim, align):
    from repro.kernels.blocks import clamp_block

    block = clamp_block(requested, dim, align=align)
    rounded_dim = -(-dim // align) * align
    assert block % align == 0, "blocks stay on the hardware granule"
    assert align <= block <= max(rounded_dim, align), \
        "a block never overshoots the padded problem axis"
    assert block <= -(-requested // align) * align, \
        "a block never exceeds the aligned request"
    if requested >= rounded_dim:
        # Big requests clamp to one whole (aligned) axis: padding is then
        # bounded by align - 1, never by the requested tile.
        assert block == rounded_dim
        assert block - dim < align
    # monotone: asking for more never yields a smaller block
    assert clamp_block(requested + 1, dim, align=align) >= block


@settings(max_examples=25, deadline=None)
@given(requested=st.integers(1, 512), b=st.integers(1, 512))
def test_property_clamp_batch_block(requested, b):
    from repro.kernels.blocks import clamp_batch_block, pow2_bucket

    bb = clamp_batch_block(requested, b)
    assert bb >= 1
    assert bb & (bb - 1) == 0, "batch blocks are powers of two"
    assert bb <= pow2_bucket(b), "never exceeds the padded (pow2) stack"
    assert pow2_bucket(b) % bb == 0, \
        "a pow2-bucketed serving stack always runs full grid steps"
    assert bb <= pow2_bucket(requested), "never overshoots the request's pow2"
