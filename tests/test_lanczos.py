"""Lanczos partial tridiagonalization: basis orthonormality, Ritz
interlacing, the krylov stage compositions, and planner routing.

The robustness contract of the krylov reduce stage (see
``src/repro/linalg/lanczos.py``):

* full (CGS2) reorthogonalization keeps ``max |Q^T Q - I|`` at
  machine-epsilon level across random / SPD / clustered-spectrum /
  rank-deficient matrices — the property that rules out ghost Ritz values;
* the active band's Ritz values satisfy the Poincare separation bounds
  against the full spectrum (``lam[i] <= theta[i] <= lam[i + n - m]`` —
  Cauchy interlacing generalized to rank-(n-m) compression);
* breakdown (an exact invariant subspace) restarts in a fresh orthogonal
  direction through an exactly-zero band junction, so rank-deficient
  matrices still fill a k-window wider than their rank;
* the ``eei_krylov`` / ``eei_krylov_si`` compositions run the *existing*
  windowed chain on the band and match the ``eigh`` oracle through every
  backend library.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.engine import (
    CalibrationTable,
    SolverEngine,
    SolverPlan,
    available_compositions,
    get_composition,
    plan_for,
    set_table,
)
from repro.linalg import (
    default_m,
    default_si_m,
    krylov_reduce,
    lanczos_partial,
    ritz_interlacing_holds,
    shift_invert_sigma,
)

BACKENDS = ["reference", "jnp", "pallas"]


def _matrix(kind: str, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    a = (a + a.T) / 2
    if kind == "goe":
        return a
    q = np.linalg.qr(rng.standard_normal((n, n)))[0]
    if kind == "spd":
        lam = rng.uniform(0.1, 10.0, n)
    elif kind == "clustered":
        lam = np.concatenate([
            np.linspace(0.0, 1.0, n - 3),
            2.0 + 1e-8 * np.arange(3.0)])
    elif kind == "rank_deficient":
        lam = np.concatenate([
            np.zeros(n - max(2, n // 4)),
            rng.uniform(1.0, 5.0, max(2, n // 4))])
    else:
        raise ValueError(kind)
    return q @ np.diag(lam) @ q.T


_KINDS = ("goe", "spd", "clustered", "rank_deficient")


# ---------------------------------------------------------------------------
# Properties of the iteration itself
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    kind=st.sampled_from(_KINDS),
    n=st.integers(min_value=8, max_value=40),
    m_raw=st.integers(min_value=2, max_value=40),
    k_raw=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_reorthogonalization_keeps_basis_orthonormal(
        kind, n, m_raw, k_raw, seed):
    """``max |Q^T Q - I|`` over the retained basis stays at eps level for
    every matrix class — full CGS2 reorthogonalization's contract."""
    m = min(m_raw, n)
    k = min(k_raw, m)
    a = jnp.asarray(_matrix(kind, n, seed))
    res = lanczos_partial(a, m, k)
    steps = int(res.steps)
    assert 1 <= steps <= m
    q = np.asarray(res.q)[:, :steps]  # active columns only
    gram = q.T @ q
    assert np.max(np.abs(gram - np.eye(steps))) < 1e-12
    # Columns beyond the active block are exactly zero by construction.
    assert not np.any(np.asarray(res.q)[:, steps:])


@settings(max_examples=12, deadline=None)
@given(
    kind=st.sampled_from(_KINDS),
    n=st.integers(min_value=8, max_value=40),
    m_raw=st.integers(min_value=2, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ritz_values_interlace_full_spectrum(kind, n, m_raw, seed):
    """Active-band Ritz values obey the Poincare separation bounds
    ``lam[i] <= theta[i] <= lam[i + n - m]`` — orthonormality's spectral
    consequence (a ghost Ritz value from lost orthogonality breaks it)."""
    m = min(m_raw, n)
    a = _matrix(kind, n, seed)
    res = lanczos_partial(jnp.asarray(a), m, min(2, m))
    steps = int(res.steps)
    d = np.asarray(res.d)[:steps]
    e = np.asarray(res.e)[: steps - 1]
    theta = np.linalg.eigvalsh(
        np.diag(d) + np.diag(e, 1) + np.diag(e, -1))
    lam = np.linalg.eigvalsh(a)
    assert bool(ritz_interlacing_holds(
        jnp.asarray(lam), jnp.asarray(theta), rtol=1e-9))


def test_ritz_interlacing_holds_rejects_ghosts():
    lam = jnp.asarray(np.linspace(0.0, 1.0, 16))
    theta = jnp.asarray([0.2, 5.0])  # 5.0 sits far above lam[-1]
    assert not bool(ritz_interlacing_holds(lam, theta))
    assert bool(ritz_interlacing_holds(lam, jnp.asarray([0.2, 0.9])))


def test_breakdown_restart_fills_window_past_rank():
    """A rank-r matrix breaks down after ~r steps; the restart must keep
    filling the band so a k > r window still reports the near-zero tail."""
    n, r, k = 48, 4, 8
    rng = np.random.default_rng(3)
    low = rng.standard_normal((n, r))
    a = jnp.asarray(low @ low.T)
    lam = np.linalg.eigvalsh(np.asarray(a))
    out = SolverEngine(SolverPlan(method="eei_krylov",
                                  backend="jnp")).topk(a, k)
    np.testing.assert_allclose(
        np.asarray(out.eigenvalues), lam[-k:], atol=1e-8 * lam[-1])


def test_guard_filled_band_entries_stay_out_of_the_window():
    """Early convergence guard-fills unused band slots outside the active
    spectrum on the side away from the extreme — the windowed spectrum
    stage must never select one."""
    n, m, k = 24, 16, 4
    a = jnp.asarray(_matrix("rank_deficient", n, seed=7))
    res = lanczos_partial(a, m, k, largest=True)
    steps = int(res.steps)
    if steps < m:  # breakdown restarts can still fill all m slots
        active_min = float(np.min(np.asarray(res.d)[:steps]))
        guards = np.asarray(res.d)[steps:]
        assert np.all(guards < active_min)


def test_shift_invert_sigma_sits_outside_the_spectrum():
    a = jnp.asarray(_matrix("goe", 32, seed=11))
    lam = np.linalg.eigvalsh(np.asarray(a))
    assert float(shift_invert_sigma(a, largest=True)) > lam[-1]
    assert float(shift_invert_sigma(a, largest=False)) < lam[0]


def test_default_band_sizes():
    assert default_m(4096, 16) == 256
    assert default_m(4096, 1) == 128
    assert default_m(64, 16) == 64  # capped at n
    assert default_si_m(4096, 16) == 128
    # krylov_reduce honors an explicit m override (band shape = m).
    a = jnp.asarray(_matrix("goe", 32, seed=0))
    d, e, q = krylov_reduce(a, 2, True, m=8)
    assert d.shape == (8,) and e.shape == (7,) and q.shape == (32, 8)


# ---------------------------------------------------------------------------
# Stage-graph integration: compositions, backends, oracle conformance
# ---------------------------------------------------------------------------


def test_krylov_compositions_registered_and_validate():
    names = available_compositions()
    assert {"eei_krylov", "eei_krylov_si"} <= set(names)
    for name in ("eei_krylov", "eei_krylov_si"):
        comp = get_composition(name)
        comp.validate()
        assert comp.solve is None  # a partial basis has no full table


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["eei_krylov", "eei_krylov_si"])
def test_krylov_topk_matches_eigh_oracle(method, backend):
    rng = np.random.default_rng(5)
    b, n, k = 2, 96, 4
    a = rng.standard_normal((b, n, n))
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2)
    lam_o, v_o = jax.vmap(jnp.linalg.eigh)(a)
    span = float(jnp.max(lam_o[:, -1] - lam_o[:, 0]))
    out = SolverEngine(SolverPlan(method=method, backend=backend)).topk(a, k)
    assert out.eigenvalues.shape == (b, k)
    assert out.vectors.shape == (b, k, n)
    assert float(jnp.max(jnp.abs(
        out.eigenvalues - lam_o[:, -k:]))) / span < 1e-10
    dots = jnp.abs(jnp.einsum("bkn,bnk->bk", out.vectors, v_o[:, :, -k:]))
    assert float(jnp.min(dots)) > 1.0 - 1e-8


@pytest.mark.parametrize("method", ["eei_krylov", "eei_krylov_si"])
def test_krylov_smallest_window(method):
    a = jnp.asarray(_matrix("goe", 80, seed=9))
    lam = np.linalg.eigvalsh(np.asarray(a))
    out = SolverEngine(SolverPlan(method=method, backend="jnp")).topk(
        a, 3, largest=False)
    np.testing.assert_allclose(np.asarray(out.eigenvalues), lam[:3],
                               atol=1e-9 * (lam[-1] - lam[0]))


def test_krylov_clustered_spectrum_shift_invert():
    """The si mode's raison d'etre: a clustered extremal group resolved
    through the inverted operator's separated theta spectrum."""
    a = jnp.asarray(_matrix("clustered", 64, seed=13))
    lam = np.linalg.eigvalsh(np.asarray(a))
    out = SolverEngine(SolverPlan(method="eei_krylov_si",
                                  backend="jnp")).topk(a, 3)
    np.testing.assert_allclose(np.asarray(out.eigenvalues), lam[-3:],
                               atol=1e-9 * (lam[-1] - lam[0]))


@pytest.mark.parametrize("method", ["eei_krylov", "eei_krylov_si"])
def test_krylov_eigenvalues_program(method):
    a = jnp.asarray(_matrix("spd", 72, seed=2))
    lam = np.linalg.eigvalsh(np.asarray(a))
    ev = SolverEngine(SolverPlan(method=method, backend="jnp")).eigenvalues(
        a, k=4)
    np.testing.assert_allclose(np.asarray(ev), lam[-4:],
                               atol=1e-9 * (lam[-1] - lam[0]))


def test_krylov_solve_raises():
    """No full-table solve exists for a partial basis — the engine must
    say so, not silently produce an incomplete table."""
    a = jnp.asarray(_matrix("goe", 16, seed=0))
    eng = SolverEngine(SolverPlan(method="eei_krylov", backend="jnp"))
    with pytest.raises(ValueError, match="no 'solve' chain"):
        eng.solve(a)


def test_krylov_plan_hashable_and_m_override_runs():
    plan = SolverPlan(method="eei_krylov", backend="jnp", krylov_m=24)
    hash(plan)  # program caches key on the plan
    a = jnp.asarray(_matrix("goe", 48, seed=1))
    lam = np.linalg.eigvalsh(np.asarray(a))
    out = SolverEngine(plan).topk(a, 2)
    np.testing.assert_allclose(np.asarray(out.eigenvalues), lam[-2:],
                               atol=1e-6 * (lam[-1] - lam[0]))


# ---------------------------------------------------------------------------
# Planner routing
# ---------------------------------------------------------------------------


def test_planner_routes_narrow_large_windows_to_krylov():
    table = CalibrationTable(
        eigh_crossover_n=4, dense_crossover_n=8,
        prod_diff_blocks=(32, 32, 32), sturm_blocks=(8, 64),
        windowed_k_frac=1.0, krylov_n_min=64)
    set_table(table)
    try:
        # Past the calibrated crossover with a narrow window: krylov.
        assert plan_for((128, 128), k=4).method == "eei_krylov"
        # Below the size crossover: the dense Householder reduce.
        assert plan_for((32, 32), k=2).method == "eei_tridiag"
        # Window too wide relative to n (k > n/16): band ~ n, dense wins.
        assert plan_for((128, 128), k=32).method == "eei_tridiag"
        # No window at all: nothing to truncate the band for.
        assert plan_for((128, 128)).method == "eei_tridiag"
        # Explicit method always wins over the heuristics.
        assert plan_for((128, 128), k=4,
                        method="eei_tridiag").method == "eei_tridiag"
    finally:
        set_table(None)


def test_planner_krylov_n_min_falls_back_without_table():
    table = CalibrationTable(
        eigh_crossover_n=4, dense_crossover_n=8,
        prod_diff_blocks=(32, 32, 32), sturm_blocks=(8, 64),
        windowed_k_frac=1.0)  # v3-style: no krylov_n_min measured
    set_table(table)
    try:
        from repro.engine.plan import KRYLOV_N_MIN, resolved_krylov_n_min

        assert resolved_krylov_n_min() == KRYLOV_N_MIN
        # Below the static fallback: stays on the dense reduce.
        assert plan_for((256, 256), k=4).method == "eei_tridiag"
    finally:
        set_table(None)
