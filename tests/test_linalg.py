"""Householder tridiagonalization + Sturm bisection against LAPACK oracles."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import minors
from repro.linalg import householder, sturm


def _sym(seed, n, scale=1.0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)) * scale
    return jnp.asarray((a + a.T) / 2)


@pytest.mark.parametrize("n", [1, 2, 3, 8, 33])
def test_tridiagonalize_similarity(n):
    a = _sym(n, n)
    d, e, q = householder.tridiagonalize(a)
    t = householder.tridiagonal_matrix(d, e)
    np.testing.assert_allclose(np.asarray(q.T @ a @ q), np.asarray(t),
                               atol=1e-10)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(n), atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 32),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_property_tridiagonalize_preserves_spectrum(seed, n, scale):
    a = _sym(seed, n, scale)
    d, e, _ = householder.tridiagonalize(a, with_q=False)
    t = householder.tridiagonal_matrix(d, e)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.eigvalsh(t)), np.asarray(jnp.linalg.eigvalsh(a)),
        rtol=1e-9, atol=1e-9 * scale)


@pytest.mark.parametrize("n", [1, 2, 5, 16, 64])
def test_sturm_bisection_matches_eigvalsh(n):
    rng = np.random.default_rng(n)
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(max(n - 1, 0)))
    ev = sturm.bisect_eigenvalues(d, e)
    ref = jnp.linalg.eigvalsh(householder.tridiagonal_matrix(d, e))
    np.testing.assert_allclose(np.asarray(ev), np.asarray(ref), atol=1e-10)


def test_sturm_count_monotone():
    rng = np.random.default_rng(1)
    d = jnp.asarray(rng.standard_normal(12))
    e = jnp.asarray(rng.standard_normal(11))
    xs = jnp.linspace(-6, 6, 41)
    counts = np.asarray(sturm.sturm_count(d, e, xs))
    assert (np.diff(counts) >= 0).all()
    assert counts[0] == 0 and counts[-1] == 12


def test_sturm_decoupled_minors():
    """EEI minors of a tridiagonal are block-decoupled; Sturm handles the
    zero off-diagonal exactly."""
    rng = np.random.default_rng(2)
    n = 14
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(n - 1))
    dm, em = minors.all_tridiagonal_minor_bands(d, e)
    ev = sturm.bisect_eigenvalues_batched(dm, em)
    t = householder.tridiagonal_matrix(d, e)
    for j in range(n):
        ref = jnp.linalg.eigvalsh(minors.minor(t, jnp.asarray(j)))
        np.testing.assert_allclose(np.asarray(ev[j]), np.asarray(ref),
                                   atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24))
def test_property_tridiag_minor_bands_match_dense(seed, n):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(n - 1))
    t = householder.tridiagonal_matrix(d, e)
    j = seed % n
    dm, em = minors.tridiagonal_minor_bands(d, e, jnp.asarray(j))
    dense_minor = minors.minor(t, jnp.asarray(j))
    rebuilt = householder.tridiagonal_matrix(dm, em)
    np.testing.assert_allclose(np.asarray(dense_minor), np.asarray(rebuilt),
                               atol=1e-12)
