"""Householder tridiagonalization + Sturm bisection against LAPACK oracles."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import minors
from repro.linalg import householder, interlace, sturm


def _sym(seed, n, scale=1.0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)) * scale
    return jnp.asarray((a + a.T) / 2)


@pytest.mark.parametrize("n", [1, 2, 3, 8, 33])
def test_tridiagonalize_similarity(n):
    a = _sym(n, n)
    d, e, q = householder.tridiagonalize(a)
    t = householder.tridiagonal_matrix(d, e)
    np.testing.assert_allclose(np.asarray(q.T @ a @ q), np.asarray(t),
                               atol=1e-10)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(n), atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 32),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_property_tridiagonalize_preserves_spectrum(seed, n, scale):
    a = _sym(seed, n, scale)
    d, e, _ = householder.tridiagonalize(a, with_q=False)
    t = householder.tridiagonal_matrix(d, e)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.eigvalsh(t)), np.asarray(jnp.linalg.eigvalsh(a)),
        rtol=1e-9, atol=1e-9 * scale)


@pytest.mark.parametrize("n", [1, 2, 5, 16, 64])
def test_sturm_bisection_matches_eigvalsh(n):
    rng = np.random.default_rng(n)
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(max(n - 1, 0)))
    ev = sturm.bisect_eigenvalues(d, e)
    ref = jnp.linalg.eigvalsh(householder.tridiagonal_matrix(d, e))
    np.testing.assert_allclose(np.asarray(ev), np.asarray(ref), atol=1e-10)


def test_sturm_count_monotone():
    rng = np.random.default_rng(1)
    d = jnp.asarray(rng.standard_normal(12))
    e = jnp.asarray(rng.standard_normal(11))
    xs = jnp.linspace(-6, 6, 41)
    counts = np.asarray(sturm.sturm_count(d, e, xs))
    assert (np.diff(counts) >= 0).all()
    assert counts[0] == 0 and counts[-1] == 12


def test_sturm_decoupled_minors():
    """EEI minors of a tridiagonal are block-decoupled; Sturm handles the
    zero off-diagonal exactly."""
    rng = np.random.default_rng(2)
    n = 14
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(n - 1))
    dm, em = minors.all_tridiagonal_minor_bands(d, e)
    ev = sturm.bisect_eigenvalues_batched(dm, em)
    t = householder.tridiagonal_matrix(d, e)
    for j in range(n):
        ref = jnp.linalg.eigvalsh(minors.minor(t, jnp.asarray(j)))
        np.testing.assert_allclose(np.asarray(ev[j]), np.asarray(ref),
                                   atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24))
def test_property_tridiag_minor_bands_match_dense(seed, n):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(n - 1))
    t = householder.tridiagonal_matrix(d, e)
    j = seed % n
    dm, em = minors.tridiagonal_minor_bands(d, e, jnp.asarray(j))
    dense_minor = minors.minor(t, jnp.asarray(j))
    rebuilt = householder.tridiagonal_matrix(dm, em)
    np.testing.assert_allclose(np.asarray(dense_minor), np.asarray(rebuilt),
                               atol=1e-12)


# ---------------------------------------------------------------------------
# Warm-start brackets (interlacing / rank-1 / secular)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 24),
       n_unique=st.integers(1, 4))
def test_property_degenerate_brackets_are_bisectable(seed, n, n_unique):
    """Repeated eigenvalues make raw interlacing brackets zero-width;
    the clamp must floor every width at ``rtol * scale`` while only ever
    *widening* (containment of the minor spectrum is preserved)."""
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal(min(n_unique, n)) * 10.0
    lam = np.sort(vals[rng.integers(0, len(vals), n)])
    rtol = 1e-7
    lo, hi = interlace.interlacing_brackets(jnp.asarray(lam), rtol=rtol)
    lo, hi = np.asarray(lo), np.asarray(hi)
    scale = np.max(np.abs(lam))  # the implementation's width scale
    assert (hi - lo >= rtol * scale * (1 - 1e-6)).all(), \
        "a clamped bracket is still degenerate"
    # Widening only: the raw interlacing interval stays inside.
    assert (lo <= lam[:-1] + 1e-12 * scale).all()
    assert (hi >= lam[1:] - 1e-12 * scale).all()
    # rtol=0 recovers the raw (possibly zero-width) intervals.
    lo0, hi0 = interlace.interlacing_brackets(jnp.asarray(lam), rtol=0.0)
    np.testing.assert_array_equal(np.asarray(lo0), lam[:-1])
    np.testing.assert_array_equal(np.asarray(hi0), lam[1:])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 16),
       sign=st.sampled_from([-1.0, 1.0]),
       mag=st.sampled_from([1e-3, 0.3, 3.0]))
def test_property_rank1_brackets_contain_updated_spectrum(seed, n, sign,
                                                          mag):
    """Weyl + rank-1 interlacing: every eigenvalue of ``A + rho u u^T``
    lies in its ``rank1_update_brackets`` interval, for both signs and
    magnitudes from tiny drift to spectrum-reshuffling."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    a = (a + a.T) / 2
    u = rng.standard_normal(n)
    u /= np.linalg.norm(u)
    rho = sign * mag
    lam = np.linalg.eigvalsh(a)
    lam_new = np.linalg.eigvalsh(a + rho * np.outer(u, u))
    lo, hi = interlace.rank1_update_brackets(jnp.asarray(lam), rho)
    lo, hi = np.asarray(lo), np.asarray(hi)
    slack = 1e-9 * max(np.max(np.abs(lam)), 1.0)
    assert (lam_new >= lo - slack).all(), (lam_new, lo)
    assert (lam_new <= hi + slack).all(), (lam_new, hi)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 12),
       sign=st.sampled_from([-1.0, 1.0]))
def test_property_secular_refine_tightens_brackets(seed, n, sign):
    """Bisecting the secular equation shrinks rank-1 brackets toward the
    exact updated eigenvalues without ever losing containment."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    a = (a + a.T) / 2
    u = rng.standard_normal(n)
    u /= np.linalg.norm(u)
    rho = sign * 0.7
    lam, v = np.linalg.eigh(a)
    z = v.T @ u
    lam_new = np.linalg.eigvalsh(a + rho * np.outer(u, u))
    lo, hi = interlace.rank1_update_brackets(jnp.asarray(lam), rho)
    rlo, rhi = interlace.secular_bracket_refine(
        jnp.asarray(lam), jnp.asarray(z * z), rho, lo, hi)
    lo, hi = np.asarray(lo), np.asarray(hi)
    rlo, rhi = np.asarray(rlo), np.asarray(rhi)
    # Never escapes the input interval, and never grows.
    assert (rlo >= lo - 1e-12).all() and (rhi <= hi + 1e-12).all()
    assert ((rhi - rlo) <= (hi - lo) + 1e-12).all()
    # With the exact full-space z2 the secular roots ARE the updated
    # spectrum — refinement must keep containing it.
    slack = 1e-6 * max(np.max(np.abs(lam_new)), 1.0)
    assert (lam_new >= rlo - slack).all()
    assert (lam_new <= rhi + slack).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 16),
       k=st.integers(1, 3), shift=st.sampled_from([-5.0, 0.0, 5.0]))
def test_property_bracketed_bisection_distrusts_stale_brackets(seed, n, k,
                                                               shift):
    """Warm brackets are a hint, never trusted: feeding *wrong* brackets
    (shifted spectrum of a different matrix) must still return the
    index-correct extremal eigenvalues via the Gershgorin fallback."""
    rng = np.random.default_rng(seed)
    k = min(k, n)
    d = jnp.asarray(rng.standard_normal(n))
    e = jnp.asarray(rng.standard_normal(n - 1))
    t = householder.tridiagonal_matrix(d, e)
    ref = np.linalg.eigvalsh(np.asarray(t))[-k:]
    stale = np.sort(rng.standard_normal(n)) + shift
    lo, hi = interlace.rank1_update_brackets(jnp.asarray(stale), 0.1)
    got = sturm.bisect_eigenvalues_bracketed(
        d, e, lo[-k:], hi[-k:], k, largest=True)
    np.testing.assert_allclose(np.asarray(got), ref, atol=1e-8)


def test_degenerate_spectrum_bracketed_end_to_end():
    """All-equal spectrum: clamped brackets + bracketed bisection recover
    the repeated eigenvalue exactly (the degenerate ridge case that
    motivates the width floor)."""
    n = 10
    d = jnp.full((n,), 3.0)
    e = jnp.zeros((n - 1,))
    lam = np.full(n, 3.0)
    lo, hi = interlace.rank1_update_brackets(jnp.asarray(lam), 0.0)
    assert (np.asarray(hi) - np.asarray(lo) > 0).all()
    got = sturm.bisect_eigenvalues_bracketed(
        d, e, lo[-4:], hi[-4:], 4, largest=True)
    np.testing.assert_allclose(np.asarray(got), np.full(4, 3.0), atol=1e-10)
