"""Sequence-mixer unit tests: chunked forms vs sequential recurrences,
MoE routing invariants (hypothesis), attention masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.ssm import chunked_gla, gla_decode_step


# -- chunked GLA vs sequential reference --------------------------------------


def _gla_sequential(q, k, v, log_decay, gate, normalize):
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    st_ = np.zeros((b, h, dk, dv))
    n = np.zeros((b, h, dk))
    ys = []
    for t in range(s):
        d = np.exp(np.asarray(log_decay[:, t], np.float64))
        g = np.exp(np.asarray(gate[:, t], np.float64))
        st_ = d[..., None, None] * st_ + g[..., None, None] * np.einsum(
            "bhd,bhv->bhdv", np.asarray(k[:, t], np.float64),
            np.asarray(v[:, t], np.float64))
        n = d[..., None] * n + g[..., None] * np.asarray(k[:, t], np.float64)
        y = np.einsum("bhd,bhdv->bhv", np.asarray(q[:, t], np.float64), st_)
        if normalize:
            denom = np.abs(np.einsum("bhd,bhd->bh", np.asarray(q[:, t],
                                                               np.float64), n))
            y = y / np.maximum(denom, 1.0)[..., None]
        ys.append(y)
    return np.stack(ys, axis=1), st_


@pytest.mark.parametrize("normalize", [False, True])
@pytest.mark.parametrize("chunk", [2, 4, 16])
def test_chunked_gla_matches_sequential(normalize, chunk):
    rng = np.random.default_rng(0)
    b, s, h, dk, dv = 2, 16, 3, 4, 5
    q = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_decay = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))),
                            jnp.float32)
    gate = jnp.asarray(rng.standard_normal((b, s, h)) * 0.3, jnp.float32)
    y, (s_fin, _) = chunked_gla(q, k, v, log_decay, gate, chunk=chunk,
                                normalize=normalize)
    y_ref, s_ref = _gla_sequential(q, k, v, log_decay, gate, normalize)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=2e-4, atol=2e-4)


def test_gla_decode_step_matches_chunked_tail():
    rng = np.random.default_rng(1)
    b, s, h, dk, dv = 1, 8, 2, 3, 3
    mk = lambda *sh: jnp.asarray(rng.standard_normal(sh), jnp.float32)
    q, k, v = mk(b, s, h, dk), mk(b, s, h, dk), mk(b, s, h, dv)
    ld = -jnp.abs(mk(b, s, h))
    g = mk(b, s, h) * 0.3
    y_all, _ = chunked_gla(q, k, v, ld, g, chunk=4, normalize=True)
    _, state = chunked_gla(q[:, :-1], k[:, :-1], v[:, :-1], ld[:, :-1],
                           g[:, :-1], chunk=4, normalize=True)
    y_t, _ = gla_decode_step(q[:, -1], k[:, -1], v[:, -1], ld[:, -1],
                             g[:, -1], state, normalize=True)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_all[:, -1]),
                               rtol=1e-4, atol=1e-4)


# -- attention masking ----------------------------------------------------------


def _ref_attention(q, k, v, causal, window, softcap):
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    qr = np.asarray(q, np.float64).reshape(b, s, hkv, rep, dh)
    scores = np.einsum("bqgrd,bkgd->bgrqk", qr, np.asarray(k, np.float64))
    scores /= np.sqrt(dh)
    if softcap is not None:
        scores = softcap * np.tanh(scores / softcap)
    mask = np.ones((s, s), bool)
    if causal:
        mask &= np.tril(np.ones((s, s), bool))
    if window is not None:
        idx = np.arange(s)
        mask &= (idx[None, :] > idx[:, None] - window)
    scores = np.where(mask[None, None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bgrqk,bkgd->bgrqd", p, np.asarray(v, np.float64))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dh)


@pytest.mark.parametrize("window,softcap,causal", [
    (None, None, True), (4, None, True), (None, 30.0, True),
    (4, 50.0, True), (None, None, False),
])
def test_chunked_attention_vs_dense_reference(window, softcap, causal):
    rng = np.random.default_rng(2)
    b, s, hq, hkv, dh = 2, 16, 4, 2, 8
    cfg = attn.AttnConfig(d_model=32, n_heads=hq, n_kv_heads=hkv, head_dim=dh,
                          causal=causal, window=window, softcap=softcap,
                          chunk_q=4, chunk_k=4)
    mk = lambda *sh: jnp.asarray(rng.standard_normal(sh), jnp.float32)
    q, k, v = mk(b, s, hq, dh), mk(b, s, hkv, dh), mk(b, s, hkv, dh)
    out = attn.chunked_attention(cfg, q, k, v)
    ref = _ref_attention(q, k, v, causal, window, softcap)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_last_position():
    rng = np.random.default_rng(3)
    b, s, hq, hkv, dh = 2, 10, 4, 2, 8
    cfg = attn.AttnConfig(d_model=32, n_heads=hq, n_kv_heads=hkv, head_dim=dh,
                          chunk_q=5, chunk_k=5)
    mk = lambda *sh: jnp.asarray(rng.standard_normal(sh), jnp.float32)
    q, k, v = mk(b, s, hq, dh), mk(b, s, hkv, dh), mk(b, s, hkv, dh)
    full = attn.chunked_attention(cfg, q, k, v)
    smax = 16
    k_cache = jnp.zeros((b, smax, hkv, dh)).at[:, :s].set(k)
    v_cache = jnp.zeros((b, smax, hkv, dh)).at[:, :s].set(v)
    dec = attn.decode_attention(cfg, q[:, -1:], k_cache, v_cache,
                                jnp.asarray(s - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


# -- MoE invariants ---------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), e=st.sampled_from([4, 8]),
       k=st.integers(1, 3), tokens=st.sampled_from([8, 32]))
def test_property_moe_routing_invariants(seed, e, k, tokens):
    rng = np.random.default_rng(seed)
    d, f = 8, 16
    cfg = moe_lib.MoEConfig(d_model=d, d_ff=f, n_experts=e, top_k=k,
                            capacity_factor=1.5, group_size=16)
    p = {
        "router": jnp.asarray(rng.standard_normal((d, e)), jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((e, f, d)) * 0.1, jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((2, tokens // 2, d)), jnp.float32)
    y, aux = moe_lib.moe(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0
    # zero input -> zero routing contribution (experts see nothing)
    y0, _ = moe_lib.moe(cfg, p, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(y0), 0.0, atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens are dropped -> output near 0
    for them; with large capacity nothing is dropped."""
    rng = np.random.default_rng(0)
    d, f, e = 4, 8, 4
    p = {
        "router": jnp.asarray(rng.standard_normal((d, e)), jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((e, f, d)), jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((1, 64, d)), jnp.float32)
    big = moe_lib.MoEConfig(d, f, e, 1, capacity_factor=8.0, group_size=64)
    small = moe_lib.MoEConfig(d, f, e, 1, capacity_factor=0.1, group_size=64)
    y_big, _ = moe_lib.moe(big, p, x)
    y_small, _ = moe_lib.moe(small, p, x)
    nz_big = int(jnp.sum(jnp.any(jnp.abs(y_big) > 1e-7, axis=-1)))
    nz_small = int(jnp.sum(jnp.any(jnp.abs(y_small) > 1e-7, axis=-1)))
    assert nz_big == 64
    assert nz_small < 32
