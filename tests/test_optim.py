"""Optimizer tests: AdamW semantics, EigenPre spectral preconditioning."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, EigenPre
from repro.optim.schedule import warmup_cosine


def _quadratic_problem():
    """min ||W x - y||^2 over W (2-D param -> exercises the spectral path)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    w_true = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    y = w_true @ x

    def loss(params):
        return jnp.mean((params["w"] @ x - y) ** 2)

    return loss, {"w": jnp.zeros((8, 8), jnp.float32)}


def test_adamw_converges_on_quadratic():
    loss, params = _quadratic_problem()
    opt = AdamW(lr=5e-2, weight_decay=0.0)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2 * l0


def test_adamw_grad_clip_and_m_compression():
    params = {"w": jnp.ones((4, 4))}
    opt = AdamW(lr=1e-2, grad_clip=1.0)
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16  # compressed first moment
    huge = {"w": jnp.full((4, 4), 1e6)}
    new_params, state, metrics = opt.update(huge, state, params)
    assert float(metrics["grad_norm"]) > 1e6
    assert np.isfinite(np.asarray(new_params["w"])).all()
    # clipped step is bounded by ~lr
    assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) < 0.1


def test_eigenpre_converges_and_refreshes():
    loss, params = _quadratic_problem()
    opt = EigenPre(adamw=AdamW(lr=5e-2, weight_decay=0.0), rank=4,
                   refresh_every=5)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 0.1 * l0
    # eigenpairs were refreshed away from init
    assert float(jnp.max(jnp.abs(state.eigvecs["w"]))) > 0.0
    # gram factor is symmetric PSD-ish
    gram = np.asarray(state.gram["w"])
    np.testing.assert_allclose(gram, gram.T, atol=1e-6)
    assert np.linalg.eigvalsh(gram).min() > -1e-5


def test_eigenpre_skips_non_matrix_params():
    opt = EigenPre(max_dim=16)
    params = {"v": jnp.ones((8,)), "big": jnp.ones((64, 4))}
    state = opt.init(params)
    assert state.gram["v"].shape == (1, 1)
    assert state.gram["big"].shape == (1, 1)  # 64 > max_dim=16


def test_warmup_cosine_shape():
    s = np.array([warmup_cosine(jnp.asarray(i), warmup=10, total=100)
                  for i in range(100)])
    assert 0.0 < s[0] <= 0.2  # step 0 trains (non-zero warmup start)
    assert abs(s[10] - 1.0) < 0.2
    assert s[99] < s[50] < s[11]
