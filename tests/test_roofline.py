"""Roofline machinery: HLO collective parser, cost algebra, term math."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import (
    CostVector,
    Roofline,
    collective_bytes,
    cost_vector,
    extrapolate,
    model_flops,
    slstm_extra_flops,
)
from repro.roofline import constants as C


HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[128,256]{1,0} all-gather(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %rs = f32[32,256]{1,0} reduce-scatter(%y), replica_groups=[2,4]<=[8], dimensions={0}
  %cp = bf16[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ars = f32[16]{0} all-reduce-start(%w), replica_groups=[1,8]<=[8]
  %ard = f32[16]{0} all-reduce-done(%ars)
}
"""


def test_collective_parser_semantics():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 128 * 256 * 4 + 16 * 4  # sync + -start form
    assert out["all-gather"] == 128 * 256 * 4 // 4  # output / group size
    assert out["reduce-scatter"] == 32 * 256 * 4 * 4  # output * group size
    assert out["collective-permute"] == 64 * 2  # bf16
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_parser_ignores_done_and_noncollectives():
    out = collective_bytes("%d = f32[8]{0} dot(%a, %b)\n"
                           "%x = f32[8]{0} all-reduce-done(%y)\n")
    assert out["total"] == 0


def test_cost_vector_algebra_and_extrapolation():
    base = CostVector(10.0, 100.0, {"all-reduce": 5.0, "total": 5.0})
    g2 = CostVector(14.0, 160.0, {"all-reduce": 7.0, "total": 7.0})
    # repeats=[3]: total = base + (3-1)*(g2-base)
    total = extrapolate(base, [g2], [3])
    assert total.flops == 10 + 2 * 4
    assert total.bytes_accessed == 100 + 2 * 60
    assert total.collective["total"] == 5 + 2 * 2
    scaled = total.scale(2.0)
    assert scaled.flops == 2 * total.flops


def test_roofline_terms_and_dominant():
    rl = Roofline(flops=C.PEAK_FLOPS_BF16, bytes_accessed=0.0,
                  collective_bytes=0.0, chips=1, model_flops=C.PEAK_FLOPS_BF16)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert rl.dominant == "compute"
    assert abs(rl.roofline_fraction - 1.0) < 1e-9
    rl2 = Roofline(flops=0.0, bytes_accessed=C.HBM_BW * 2, collective_bytes=0.0,
                   chips=1, model_flops=C.PEAK_FLOPS_BF16)
    assert rl2.dominant == "memory"
    assert abs(rl2.bound_time - 2.0) < 1e-9


def test_model_flops_conventions():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.roofline import active_params

    dense = get_config("codeqwen1.5-7b")
    moe = get_config("deepseek-v3-671b")
    assert active_params(dense) == active_params(dense)  # deterministic
    # MoE active < total: 256 routed -> 8 active per token
    from repro.models.lm import LanguageModel
    assert active_params(moe) < 0.1 * LanguageModel(moe).n_params()
    train = SHAPES["train_4k"]
    decode = SHAPES["decode_32k"]
    assert model_flops(dense, train) > model_flops(dense, decode) * 1e4
    # decode counts one token per sequence
    assert model_flops(dense, decode) == 2.0 * active_params(dense) * 128


def test_slstm_correction_only_for_slstm_archs():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config

    assert slstm_extra_flops(get_config("codeqwen1.5-7b"),
                             SHAPES["train_4k"]) == 0.0
    x = slstm_extra_flops(get_config("xlstm-125m"), SHAPES["train_4k"])
    assert x > 0.0


def test_cost_vector_from_analysis_dict():
    cv = cost_vector({"flops": 7.0, "bytes accessed": 3.0}, {"total": 1.0})
    assert cv.flops == 7.0 and cv.bytes_accessed == 3.0
    cv0 = cost_vector({}, {})
    assert cv0.flops == 0.0
