"""EeiServer: continuous batching, shape buckets, program-cache bounds,
and the concurrent runtime (linger admission, producer races, close).

The serving machinery's contract: coalescing + bucket padding + slicing add
*zero* numerical change (server output is bit-identical to ``SolverEngine``
on the equivalent padded stack, and bit-identical k-slices of it), padded
rows/components never leak into results, and a mixed 100-request stream
executes through at most one compile per distinct shape bucket.

The property-based stream-conformance suite at the bottom locks the
threaded runtime down: random heterogeneous ``(n, k, largest)`` streams
with random pump/linger timing must stay bitwise-equal to the synchronous
``SolverEngine.topk`` oracle on every dispatched stack, every submitted
future must resolve exactly once, and the program-cache counters must
account for every dispatch.
"""

import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.engine import (
    EeiServer,
    ProgramCache,
    QueueFull,
    ServerClosed,
    ShapeBucket,
    SolverEngine,
    SolverPlan,
    verify_topk_host,
)
from repro.engine.server import PackedBucket, _bucket_n, make_eei_stream
from repro.kernels import blocks
from repro.runtime import ChaosConfig, ChaosMonkey

PLAN = SolverPlan(method="eei_tridiag", backend="jnp")

#: One cache across the whole module: fuzzer iterations and the thread
#: tests reuse compiled programs instead of recompiling per example (the
#: cache is documented shareable and thread-safe).
SHARED_CACHE = ProgramCache()


def _sym(rng, n: int) -> np.ndarray:
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _serve(server: EeiServer, stream):
    futs = [server.submit(a, k) for a, k in stream]
    server.flush()
    return [f.result() for f in futs]


def _assert_stream_conformant(server: EeiServer) -> None:
    """Every dispatched stack must be bitwise-equal to the synchronous
    ``SolverEngine.topk`` oracle run on the *same* padded stack under the
    *same* plan, sliced per request — coalescing, padding, threading and
    slicing add zero numerical change.  Needs ``record_dispatches=True``."""
    for rec in server.dispatch_log:
        ref = SolverEngine(rec.plan).topk(
            jnp.asarray(rec.stack), rec.bucket.k, rec.bucket.largest)
        lam, vec = np.asarray(ref.eigenvalues), np.asarray(ref.vectors)
        for row, req in enumerate(rec.requests):
            res = req.future.result(timeout=60)
            if req.largest:
                lam_e, vec_e = lam[row, -req.k:], vec[row, -req.k:, : req.n]
            else:
                lam_e, vec_e = lam[row, : req.k], vec[row, : req.k, : req.n]
            np.testing.assert_array_equal(res.eigenvalues, lam_e)
            np.testing.assert_array_equal(res.vectors, vec_e)


def _assert_accounting(server: EeiServer, futures, cache_before) -> None:
    """Future accounting (every submit resolves exactly once) and program
    cache accounting (hit + miss == dispatch count)."""
    assert all(f.done() for f in futures)
    stats = server.stats()
    assert stats["requests_submitted"] == len(futures)
    assert (stats["requests_completed"] + stats["requests_failed"]
            == len(futures))
    assert len({id(f) for f in futures}) == len(futures)  # no duplicates
    hits0, misses0 = cache_before
    assert (server.cache.hits - hits0) + (server.cache.misses - misses0) \
        == stats["stacks_dispatched"]


# ---------------------------------------------------------------------------
# Numerical contract
# ---------------------------------------------------------------------------


def test_full_stack_bit_identical_to_engine_program():
    """One full stack of mixed-k requests == engine.topk on the same stack.

    The server's value-add (queueing, bucketing, the program cache, async
    dispatch, per-request slicing) must be numerically invisible: for
    aligned n the padded stack *is* the engine's stack, and heterogeneous k
    rides the group-max program with per-request slices that are bitwise
    equal to what smaller-k programs produce (k-selected stages are
    per-pair independent).
    """
    rng = np.random.default_rng(0)
    mats = [_sym(rng, 16) for _ in range(8)]
    ks = [4, 2, 1, 3, 4, 4, 2, 3]
    server = EeiServer(PLAN, max_batch=8)
    results = _serve(server, list(zip(mats, ks)))
    assert server.stats()["stacks_dispatched"] == 1

    ref = SolverEngine(PLAN).topk(jnp.asarray(np.stack(mats)), 4)
    lam_ref = np.asarray(ref.eigenvalues)
    vec_ref = np.asarray(ref.vectors)
    for i, ((lam, vec), k) in enumerate(zip(results, ks)):
        assert lam.shape == (k,) and vec.shape == (k, 16)
        np.testing.assert_array_equal(lam, lam_ref[i, -k:])
        np.testing.assert_array_equal(vec, vec_ref[i, -k:])


def test_mixed_stream_matches_per_request_topk():
    """Heterogeneous (n, k) stream vs one engine.topk call per request.

    Per-request programs run at b=1 while the server batches, so float32
    XLA fusions may differ in the last bits — agreement is to tight
    tolerance, and eigenvalues/vectors land in the request's own shapes.
    """
    rng = np.random.default_rng(1)
    stream = [(_sym(rng, n), k)
              for n, k in [(16, 4), (24, 2), (16, 1), (32, 4), (24, 3),
                           (16, 2), (32, 1), (16, 4), (24, 4), (32, 2)]]
    server = EeiServer(PLAN, max_batch=4)
    results = _serve(server, stream)
    engine = SolverEngine(PLAN)
    for (a, k), (lam, vec) in zip(stream, results):
        ref = engine.topk(jnp.asarray(a), k)
        np.testing.assert_allclose(lam, np.asarray(ref.eigenvalues),
                                   rtol=1e-5, atol=1e-5)
        err = np.minimum(np.abs(vec - np.asarray(ref.vectors)),
                         np.abs(vec + np.asarray(ref.vectors))).max()
        assert err < 5e-3, err


@pytest.mark.parametrize("largest", [True, False])
def test_guard_padded_n_never_leaks(largest):
    """Unaligned n pads to the bucket via guard-diagonal embedding; results
    must carry only the request's own eigenpairs (vs an eigh oracle)."""
    rng = np.random.default_rng(2)
    stream = [(_sym(rng, n), 3) for n in (9, 13, 17, 21, 30, 9, 13, 11)]
    server = EeiServer(PLAN, max_batch=4)
    futs = [server.submit(a, k, largest=largest) for a, k in stream]
    server.flush()
    for (a, k), fut in zip(stream, futs):
        lam, vec = fut.result()
        n = a.shape[0]
        assert lam.shape == (k,) and vec.shape == (k, n)
        w, v = np.linalg.eigh(a.astype(np.float64))
        w_sel = w[-k:] if largest else w[:k]
        v_sel = (v[:, -k:] if largest else v[:, :k]).T
        np.testing.assert_allclose(lam, w_sel, rtol=1e-4, atol=1e-4)
        # guard eigenvalues sit outside the spectrum — none may appear
        assert np.all(lam >= w[0] - 1e-3) and np.all(lam <= w[-1] + 1e-3)
        err = np.abs(np.abs(vec) - np.abs(v_sel)).max()
        assert err < 5e-3, err


def test_batch_padding_rows_never_leak():
    """A partial stack (3 requests into a pow2-4 bucket) returns exactly 3
    results; the padded row is sliced off before futures resolve."""
    rng = np.random.default_rng(3)
    stream = [(_sym(rng, 16), 2) for _ in range(3)]
    server = EeiServer(PLAN, max_batch=8)
    results = _serve(server, stream)
    assert len(results) == 3
    assert server.stats()["requests_completed"] == 3
    bucket = server.cache.buckets()[0]
    assert bucket.b == 4  # 3 requests padded to the pow2 bucket
    engine = SolverEngine(PLAN)
    for (a, k), (lam, vec) in zip(stream, results):
        ref = engine.topk(jnp.asarray(a), k)
        np.testing.assert_allclose(lam, np.asarray(ref.eigenvalues),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Program-cache bounds (the compile-amortization contract)
# ---------------------------------------------------------------------------


def test_program_cache_bounded_by_buckets_on_100_request_stream():
    stream = make_eei_stream(100, 16, 4, seed=7, mixed=True)
    server = EeiServer(PLAN, max_batch=16)
    results = _serve(server, stream)
    assert len(results) == 100
    stats = server.stats()
    assert stats["requests_completed"] == 100
    # one compile per distinct bucket, nothing per-request / per-shape
    assert server.cache.compiles == stats["distinct_buckets"]
    assert server.cache.compiles == len(set(server.cache.buckets()))
    assert server.cache.compiles <= 8  # 100 requests, single-digit programs
    assert server.cache.hits == stats["stacks_dispatched"] - \
        server.cache.compiles
    # replaying the same stream is all hits, zero compiles
    before = server.cache.compiles
    _serve(server, stream)
    assert server.cache.compiles == before


def test_warm_server_replay_is_steady_state():
    stream = make_eei_stream(40, 16, 4, seed=8, mixed=True)
    server = EeiServer(PLAN, max_batch=8)
    _serve(server, stream)
    server.reset_stats()
    results = _serve(server, stream)
    assert len(results) == 40
    stats = server.stats()
    assert stats["program_compiles"] == 0  # warm: buckets bound compilation
    assert stats["program_hits"] == stats["stacks_dispatched"]
    assert stats["p99_latency_ms"] >= stats["p50_latency_ms"] >= 0.0


def test_shape_bucket_rounding():
    b = ShapeBucket.for_requests(5, 17, 3, True)
    assert b == ShapeBucket(b=8, n=24, k=4, largest=True)
    # k bucket never exceeds the padded n
    b = ShapeBucket.for_requests(1, 17, 17, False)
    assert b.n == 24 and b.k == 24 and b.b == 1
    assert ShapeBucket.for_requests(16, 16, 4, True) == \
        ShapeBucket(16, 16, 4, True)


def test_program_cache_counters():
    cache = ProgramCache()
    bucket = ShapeBucket(2, 16, 2, True)
    p1 = cache.get(bucket, PLAN, jnp.float32)
    p2 = cache.get(bucket, PLAN, jnp.float32)
    assert p1 is p2
    assert (cache.hits, cache.misses, cache.compiles, len(cache)) == \
        (1, 1, 1, 1)
    cache.get(ShapeBucket(2, 16, 2, False), PLAN, jnp.float32)
    assert cache.compiles == 2 and len(cache) == 2


def test_pad_waste_accounting_exact():
    """``pad_waste_frac`` counts exactly the grid cells the bucket padded
    on top of the requests' own ``n*n`` work, cumulatively and per bucket:
    an aligned full group wastes 0, an unaligned partial group wastes the
    batch-fill rows plus the n-guard ring."""
    rng = np.random.default_rng(7)
    server = EeiServer(PLAN, max_batch=4)
    futs = [server.submit(_sym(rng, 16), 2) for _ in range(4)]  # exact fit
    futs += [server.submit(_sym(rng, 17), 2) for _ in range(3)]  # pads both
    server.flush()
    [f.result() for f in futs]
    stats = server.stats()
    # bucket 1: b=4, n=16 — zero padding.  bucket 2: 3 requests of n=17
    # round to b=4, n=24 — one full batch-fill matrix + guard ring.
    real = 4 * 16 * 16 + 3 * 17 * 17
    total = 4 * 16 * 16 + 4 * 24 * 24
    assert stats["grid_cells_real"] == real
    assert stats["grid_cells_total"] == total
    assert stats["pad_waste_frac"] == pytest.approx(1.0 - real / total)
    per = stats["pad_waste_by_bucket"]
    assert per["b4n16k2L"] == 0.0
    assert per["b4n24k2L"] == pytest.approx(
        1.0 - (3 * 17 * 17) / (4 * 24 * 24), abs=1e-6)
    server.reset_stats()
    stats = server.stats()
    assert stats["grid_cells_total"] == 0
    assert stats["pad_waste_frac"] == 0.0
    assert stats["pad_waste_by_bucket"] == {}


def test_bucket_rounds_up_to_mesh_batch_axis(monkeypatch):
    """A sharded plan needs stacks divisible by the mesh batch axis; a
    partial group's pow2 bucket must round up to it (the engine pads its
    chunks the same way), not crash inside shard_map."""
    monkeypatch.setattr(SolverPlan, "batch_axis_size",
                        property(lambda self: 8))
    rng = np.random.default_rng(11)
    stream = [(_sym(rng, 16), 2) for _ in range(3)]
    server = EeiServer(PLAN, max_batch=16)
    results = _serve(server, stream)
    assert server.cache.buckets()[0].b == 8  # pow2(3)=4, padded to axis 8
    engine = SolverEngine(PLAN)
    for (a, k), (lam, vec) in zip(stream, results):
        np.testing.assert_allclose(
            lam, np.asarray(engine.topk(jnp.asarray(a), k).eigenvalues),
            rtol=1e-5, atol=1e-5)


def test_non_pow2_max_batch_floors_to_bound():
    """Stack buckets are pow2 — max_batch=48 must serve stacks of at most
    32, never round a full group up past the operator's bound."""
    server = EeiServer(PLAN, max_batch=48)
    assert server.max_batch == 32
    assert EeiServer(PLAN, max_batch=16).max_batch == 16
    assert EeiServer(PLAN, max_batch=1).max_batch == 1


def test_submit_validation():
    server = EeiServer(PLAN)
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        server.submit(rng.standard_normal((4, 5)), 1)
    with pytest.raises(ValueError):
        server.submit(_sym(rng, 4), 0)
    with pytest.raises(ValueError):
        server.submit(_sym(rng, 4), 5)
    with pytest.raises(ValueError):
        EeiServer(PLAN, max_batch=0)
    with pytest.raises(ValueError):
        EeiServer(PLAN, max_inflight=0)


def test_partial_group_does_not_block_other_full_stacks():
    """Head-of-line regression: a partial group in one coalesce key must
    not delay a full stack forming in another key."""
    rng = np.random.default_rng(10)
    server = EeiServer(PLAN, max_batch=4)
    f_head = server.submit(_sym(rng, 16), 2)  # partial n=16 group sits first
    futs = [server.submit(_sym(rng, 32), 2) for _ in range(4)]
    # the full n=32 stack dispatched despite the queued partial n=16 group
    assert server.stats()["stacks_dispatched"] == 1
    assert not f_head.done()
    server.flush()
    assert f_head.done() and all(f.done() for f in futs)
    assert server.stats()["stacks_dispatched"] == 2


def test_failed_dispatch_escalates_to_degraded_results(monkeypatch):
    """A persistent compile/launch failure must not strand callers or fail
    the stack: the server bisection-splits the group and every isolated
    request resolves through the fallback chain as a DegradedResult."""
    rng = np.random.default_rng(12)
    server = EeiServer(PLAN, max_batch=4)

    def boom(*a, **k):
        raise RuntimeError("synthetic compile failure")

    monkeypatch.setattr(server.cache, "get", boom)
    futs = [server.submit(_sym(rng, 16), 2) for _ in range(4)]
    assert all(f.done() for f in futs)  # resolved, not stranded
    for f in futs:
        res = f.result()
        assert res.degraded
        assert res.eigenvalues.shape == (2,)
        assert np.all(np.isfinite(res.vectors))
    stats = server.stats()
    assert stats["requests_failed"] == 0
    assert stats["requests_degraded"] == 4
    assert stats["stack_splits"] >= 1  # 4 -> 2+2 -> 1+1+1+1
    assert sum(stats["fallbacks_by_plan"].values()) == 4
    # the server keeps serving (non-degraded) after the failures
    monkeypatch.undo()
    ok = server.submit(_sym(rng, 16), 2)
    server.flush()
    assert ok.result().eigenvalues.shape == (2,)
    assert not ok.result().degraded


def test_failed_dispatch_fail_fast_without_fallback(monkeypatch):
    """With fallback=False the pre-robustness contract holds: the group's
    futures resolve with the error (never stranded), and the server keeps
    serving afterwards."""
    rng = np.random.default_rng(12)
    server = EeiServer(PLAN, max_batch=4, fallback=False)

    def boom(*a, **k):
        raise RuntimeError("synthetic compile failure")

    monkeypatch.setattr(server.cache, "get", boom)
    futs = [server.submit(_sym(rng, 16), 2) for _ in range(4)]
    assert all(f.done() for f in futs)  # resolved, not stranded
    with pytest.raises(RuntimeError, match="synthetic"):
        futs[0].result()
    assert server.stats()["requests_failed"] == 4
    monkeypatch.undo()
    ok = server.submit(_sym(rng, 16), 2)
    server.flush()
    assert ok.result().eigenvalues.shape == (2,)


def test_double_buffer_keeps_stacks_inflight():
    """With max_inflight=2, dispatching 3 full stacks retires only the
    oldest eagerly; the rest resolve on flush()."""
    rng = np.random.default_rng(9)
    server = EeiServer(PLAN, max_batch=2, max_inflight=2)
    futs = [server.submit(_sym(rng, 16), 2) for _ in range(6)]
    # 3 full stacks dispatched by pump(); at most one retired so far
    assert server.stats()["stacks_dispatched"] == 3
    assert sum(f.done() for f in futs) <= 2
    server.flush()
    assert all(f.done() for f in futs)
    assert server.stats()["requests_completed"] == 6


# ---------------------------------------------------------------------------
# Threaded runtime: linger admission, close semantics, backpressure
# ---------------------------------------------------------------------------


def test_linger_dispatches_partial_stack_without_flush():
    """A sparse stream (3 requests into a max_batch=8 server) must complete
    via the linger thread alone — no pump(), no flush() — within the
    timeout, bitwise-equal to the sync oracle."""
    rng = np.random.default_rng(20)
    with EeiServer(PLAN, max_batch=8, linger_ms=20, cache=SHARED_CACHE,
                   record_dispatches=True) as server:
        before = (server.cache.hits, server.cache.misses)
        futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
        results = [f.result(timeout=120) for f in futs]  # no flush!
        assert all(r.eigenvalues.shape == (2,) for r in results)
        assert server.stats()["stacks_dispatched"] >= 1
        _assert_accounting(server, futs, before)
        _assert_stream_conformant(server)


def test_linger_full_stack_dispatches_before_timeout():
    """Full stacks must not wait out the linger: with a huge linger, a
    full max_batch group still dispatches immediately."""
    rng = np.random.default_rng(21)
    with EeiServer(PLAN, max_batch=4, linger_ms=60_000,
                   cache=SHARED_CACHE) as server:
        futs = [server.submit(_sym(rng, 12), 2) for _ in range(4)]
        for f in futs:
            f.result(timeout=120)  # would time out if linger gated it
        assert server.stats()["stacks_dispatched"] == 1


def test_submit_after_close_resolves_with_error():
    """Late submits must get a resolved-with-error future, not a stranded
    one (and not an exception at the call site)."""
    rng = np.random.default_rng(22)
    for kwargs in ({}, {"linger_ms": 5.0, "cache": SHARED_CACHE}):
        server = EeiServer(PLAN, max_batch=4, **kwargs)
        server.close()
        fut = server.submit(_sym(rng, 8), 1)
        assert fut.done()
        with pytest.raises(ServerClosed):
            fut.result()
        assert server.stats()["requests_rejected"] == 1
        server.close()  # idempotent


def test_close_drains_queued_and_inflight():
    """close() before the linger expires must still drain the queued
    partial group: every future resolves with a real result."""
    rng = np.random.default_rng(23)
    server = EeiServer(PLAN, max_batch=8, linger_ms=60_000,
                       cache=SHARED_CACHE)
    futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
    server.close(timeout=120)
    for f in futs:
        assert f.done()
        assert f.result().eigenvalues.shape == (2,)
    assert server.stats()["requests_completed"] == 3


def test_close_without_drain_fails_queued_futures():
    """close(drain=False) must resolve still-queued requests with
    ServerClosed — resolved, never stranded."""
    rng = np.random.default_rng(24)
    server = EeiServer(PLAN, max_batch=8, linger_ms=60_000,
                       cache=SHARED_CACHE)
    futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
    server.close(drain=False, timeout=120)
    for f in futs:
        assert f.done()
        with pytest.raises(ServerClosed):
            f.result()
    assert server.stats()["requests_failed"] == 3


def test_flush_is_idempotent_and_reentrant():
    """Double flush() (sequential and from two racing threads) must be a
    safe no-op once drained — the double-flush idempotency guard."""
    rng = np.random.default_rng(25)
    server = EeiServer(PLAN, max_batch=4)
    server.flush()  # flush on an empty server
    futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
    server.flush()
    dispatched = server.stats()["stacks_dispatched"]
    server.flush()  # second flush: nothing new
    assert server.stats()["stacks_dispatched"] == dispatched
    assert all(f.done() for f in futs)
    # threaded mode: two concurrent flush barriers
    with EeiServer(PLAN, max_batch=8, linger_ms=10_000,
                   cache=SHARED_CACHE) as tserver:
        tfuts = [tserver.submit(_sym(rng, 12), 2) for _ in range(3)]
        threads = [threading.Thread(target=tserver.flush) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
            assert not t.is_alive(), "flush barrier deadlocked"
        assert all(f.done() for f in tfuts)


def test_backpressure_except_policy_raises_queue_full():
    rng = np.random.default_rng(26)
    server = EeiServer(PLAN, max_batch=8, max_pending=2,
                       pending_policy="except")
    server.submit(_sym(rng, 8), 1)
    server.submit(_sym(rng, 8), 1)
    with pytest.raises(QueueFull):
        server.submit(_sym(rng, 8), 1)
    server.flush()  # drains; submits admissible again
    f = server.submit(_sym(rng, 8), 1)
    server.flush()
    assert f.result().eigenvalues.shape == (1,)


def test_backpressure_block_policy_drains_via_linger_thread():
    """With pending_policy='block', producers stall at max_pending and the
    linger thread makes space — bounded by a watchdog so a regression shows
    as a failure, not a hang."""
    rng = np.random.default_rng(27)
    futs = []
    with EeiServer(PLAN, max_batch=2, linger_ms=1, max_pending=2,
                   pending_policy="block", cache=SHARED_CACHE) as server:
        def produce():
            for _ in range(8):
                futs.append(server.submit(_sym(rng, 12), 2))

        worker = threading.Thread(target=produce)
        worker.start()
        worker.join(timeout=120)
        assert not worker.is_alive(), "blocking submit deadlocked"
        for f in futs:
            f.result(timeout=120)
    assert server.stats()["requests_completed"] == 8


def test_backpressure_block_policy_sync_mode_drains_inline():
    """Caller-driven mode has no admission thread to free space: 'block'
    must drain inline instead of self-deadlocking a single thread."""
    rng = np.random.default_rng(28)
    server = EeiServer(PLAN, max_batch=8, max_pending=2,
                       pending_policy="block")
    futs = [server.submit(_sym(rng, 8), 1) for _ in range(5)]
    server.flush()
    assert all(f.result().eigenvalues.shape == (1,) for f in futs)


def test_validation_of_runtime_parameters():
    with pytest.raises(ValueError):
        EeiServer(PLAN, linger_ms=-1)
    with pytest.raises(ValueError):
        EeiServer(PLAN, max_pending=-1)
    with pytest.raises(ValueError):
        EeiServer(PLAN, pending_policy="drop")


# ---------------------------------------------------------------------------
# Concurrency: producer threads racing the linger thread, cache locking
# ---------------------------------------------------------------------------


def test_producer_threads_race_linger_admission():
    """N producer threads racing submit() against the linger thread: no
    deadlock (every join is timeout-bounded), no lost or duplicated
    futures, and ProgramCache hits + misses == dispatch count."""
    rng = np.random.default_rng(30)
    n_threads, per_thread = 4, 8
    mats = [[(_sym(rng, int(n)), int(k))
             for n, k in zip(rng.choice([6, 8, 12], per_thread),
                             rng.integers(1, 3, per_thread))]
            for _ in range(n_threads)]
    futs_per_thread = [[] for _ in range(n_threads)]
    with EeiServer(PLAN, max_batch=4, linger_ms=1, cache=SHARED_CACHE,
                   record_dispatches=True) as server:
        before = (server.cache.hits, server.cache.misses)

        def produce(i):
            for a, k in mats[i]:
                futs_per_thread[i].append(server.submit(a, k))

        threads = [threading.Thread(target=produce, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
            assert not t.is_alive(), "producer thread deadlocked"
        futs = [f for fs in futs_per_thread for f in fs]
        for f in futs:
            f.result(timeout=120)
        _assert_accounting(server, futs, before)
        assert server.stats()["requests_failed"] == 0
        _assert_stream_conformant(server)


def test_program_cache_concurrent_gets_compile_once():
    """Racing get()s for one bucket must compile exactly once and return
    the same executable — the cache lock covers the compile."""
    cache = ProgramCache()
    bucket = ShapeBucket(2, 16, 2, True)
    results = [None] * 4
    barrier = threading.Barrier(4)

    def work(i):
        barrier.wait()
        results[i] = cache.get(bucket, PLAN, jnp.float32)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive()
    assert all(r is results[0] and r is not None for r in results)
    assert cache.compiles == 1 and len(cache) == 1
    assert cache.hits + cache.misses == 4


# ---------------------------------------------------------------------------
# Property-based stream conformance (the fuzzer the runtime is locked by)
# ---------------------------------------------------------------------------

# One request: (n, k_raw, largest, action-after-submit). k = 1 + k_raw % n
# keeps k valid for any n. Actions: 0/3 nothing, 1 pump(), 2 sleep (lets
# the linger thread fire mid-stream / exercises odd pump timing).
_REQ = st.tuples(st.integers(4, 12), st.integers(0, 1), st.booleans(),
                 st.integers(0, 3))


def _run_stream(server, ops, seed):
    rng = np.random.default_rng(seed)
    futs = []
    for n, k_raw, largest, action in ops:
        futs.append(server.submit(_sym(rng, n), 1 + k_raw % n,
                                  largest=largest))
        if action == 1:
            server.pump()
        elif action == 2:
            time.sleep(0.002)
    return futs


@settings(max_examples=8, deadline=None)
@given(ops=st.lists(_REQ, min_size=1, max_size=20),
       max_batch=st.sampled_from([1, 2, 4]), seed=st.integers(0, 999))
def test_stream_conformance_fuzz_caller_driven(ops, max_batch, seed):
    """Random heterogeneous (n, k, largest) streams with random pump
    timing, caller-driven mode: bitwise identity against the synchronous
    SolverEngine.topk oracle on every dispatched stack, and every submit
    resolves exactly once."""
    server = EeiServer(PLAN, max_batch=max_batch, cache=SHARED_CACHE,
                       record_dispatches=True)
    before = (server.cache.hits, server.cache.misses)
    futs = _run_stream(server, ops, seed)
    server.flush()
    _assert_accounting(server, futs, before)
    assert server.stats()["requests_failed"] == 0
    assert sum(len(r.requests) for r in server.dispatch_log) == len(ops)
    _assert_stream_conformant(server)


@settings(max_examples=6, deadline=None)
@given(ops=st.lists(_REQ, min_size=1, max_size=16),
       max_batch=st.sampled_from([2, 4]),
       linger_ms=st.sampled_from([0.0, 1.0, 5.0]),
       seed=st.integers(0, 999))
def test_stream_conformance_fuzz_linger_thread(ops, max_batch, linger_ms,
                                               seed):
    """The same conformance contract under the threaded runtime: random
    linger timeouts and random sleeps decide how stacks form, but every
    grouping must stay bitwise-equal to the sync oracle, with no flush()
    ever called — futures resolve via the linger thread, and close()
    drains the tail."""
    server = EeiServer(PLAN, max_batch=max_batch, linger_ms=linger_ms,
                       cache=SHARED_CACHE, record_dispatches=True)
    before = (server.cache.hits, server.cache.misses)
    try:
        futs = _run_stream(server, ops, seed)
        for f in futs:
            f.result(timeout=120)  # linger thread must complete the stream
    finally:
        server.close(timeout=120)
    _assert_accounting(server, futs, before)
    assert server.stats()["requests_failed"] == 0
    assert sum(len(r.requests) for r in server.dispatch_log) == len(ops)
    _assert_stream_conformant(server)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 12), pad=st.integers(1, 8), seed=st.integers(0, 999),
       largest=st.booleans(), scale=st.sampled_from([1e-2, 1.0, 1e2]))
def test_property_guard_embedding_never_enters_window(n, pad, seed, largest,
                                                      scale):
    """Guard-diagonal embedding invariant at both spectrum extremes: the
    guard value sits strictly outside the spectrum on the far side, so the
    padded matrix's top-n (or bottom-n) eigenvalues are exactly A's and
    guard eigenpairs can never enter any requested k-window."""
    rng = np.random.default_rng(seed)
    a = (scale * _sym(rng, n)).astype(np.float32)
    server = EeiServer(PLAN)
    guard = server._guard_value(a, largest)
    w = np.linalg.eigvalsh(a.astype(np.float64))
    if largest:
        assert guard < w[0]  # strictly below: never in a top-k window
    else:
        assert guard > w[-1]  # strictly above: never in a bottom-k window
    padded = np.zeros((n + pad, n + pad), dtype=np.float64)
    padded[:n, :n] = a
    idx = np.arange(n, n + pad)
    padded[idx, idx] = guard
    wp = np.linalg.eigvalsh(padded)
    window = wp[pad:] if largest else wp[:n]
    guards = wp[:pad] if largest else wp[n:]
    np.testing.assert_allclose(window, w, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(guards, guard, rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# Chaos conformance: the stream contract must survive injected faults.
#
# Under chaos the *bitwise* oracle and the cache-accounting invariants do
# not apply (NaN-poisoned rows resolve through the fallback chain, and
# ``on_launch`` fires after ``cache.get`` so hits+misses can exceed
# dispatches) — the contract that must hold instead is the safety one:
# every future resolves exactly once, nothing non-finite or garbage ever
# reaches a caller, degraded results are marked, and the server never
# wedges (every wait below is timeout-bounded).
# ---------------------------------------------------------------------------


def _assert_chaos_safe(reqs, stats):
    """``reqs`` is ``[(a, k, future), ...]``; asserts the chaos-safety
    contract over resolved results + the server's counter accounting."""
    degraded = 0
    for a, k, fut in reqs:
        assert fut.done(), "a submitted future never resolved"
        res = fut.result(timeout=0)
        lam, vec = np.asarray(res.eigenvalues), np.asarray(res.vectors)
        assert lam.shape == (k,) and vec.shape == (k, a.shape[0])
        assert np.all(np.isfinite(lam)) and np.all(np.isfinite(vec))
        # Independent garbage check: healthy float32 residuals are ~3e-4
        # of ||A||_F and clamped-denominator garbage is >= ~0.1; 2e-2
        # cleanly separates them even after guard-padding skews the
        # device-side scale.
        flags = verify_topk_host(a, lam, vec)
        assert float(flags.residual) <= 2e-2, (
            f"garbage reached a caller: residual={float(flags.residual)}")
        if res.degraded:
            degraded += 1
            assert res.fallback, "degraded result missing its chain link"
    assert stats["requests_failed"] == 0
    assert stats["requests_completed"] == len(reqs)
    assert stats["requests_degraded"] == degraded


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(_REQ, min_size=4, max_size=16),
       max_batch=st.sampled_from([2, 4]),
       rate=st.sampled_from([0.05, 0.1]),
       seed=st.integers(0, 999), chaos_seed=st.integers(0, 999))
def test_chaos_stream_conformance_fuzz(ops, max_batch, rate, seed,
                                       chaos_seed):
    """Random heterogeneous streams under 5-10% injected faults (compile /
    launch failures, NaN-poisoned results, slow retires, thread crashes):
    the safety contract holds — every future resolves exactly once with a
    finite, non-garbage result, and the server survives to serve the whole
    stream."""
    chaos = ChaosMonkey(ChaosConfig(seed=chaos_seed, rate=rate,
                                    slow_s=0.001))
    server = EeiServer(PLAN, max_batch=max_batch, linger_ms=1.0,
                       cache=SHARED_CACHE, chaos=chaos)
    rng = np.random.default_rng(seed)
    reqs = []
    try:
        for n, k_raw, largest, action in ops:
            a, k = _sym(rng, n), 1 + k_raw % n
            reqs.append((a, k, server.submit(a, k, largest=largest)))
            if action == 1:
                server.pump()
            elif action == 2:
                time.sleep(0.002)
        for _, _, f in reqs:
            f.result(timeout=120)
    finally:
        server.close(timeout=120)
    stats = server.stats()
    _assert_chaos_safe(reqs, stats)
    assert stats["chaos_injected"] == chaos.counts()


# ---------------------------------------------------------------------------
# Sharded serving (forced 2-device host mesh in a subprocess: the device
# count must be set before jax initializes, which this process already did)
# ---------------------------------------------------------------------------

_SHARDED_SERVE_SCRIPT = """
import jax, numpy as np, jax.numpy as jnp
assert jax.device_count() == 2, jax.device_count()
from repro.engine import EeiServer, SolverEngine, SolverPlan, plan_for

mesh = jax.make_mesh((2, 1), ("data", "model"))
plan = SolverPlan(method="eei_tridiag", backend="sharded", mesh=mesh)
rng = np.random.default_rng(0)

def sym(n):
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2

# per-bucket auto-planning picks the sharded backend for big-enough stacks
auto = plan_for((4, 48, 48), k=2, mesh=mesh)
assert auto.backend == "sharded", auto

with EeiServer(plan, max_batch=4, linger_ms=5,
               record_dispatches=True) as server:
    futs = [server.submit(sym(n), 2) for n in (12, 12, 16, 12, 9)]
    for f in futs:
        f.result(timeout=240)  # no flush: linger thread drives dispatch
    assert server.stats()["requests_completed"] == 5
    # pow2 buckets round up to the mesh batch axis; bitwise vs the sync
    # sharded oracle on the same padded stack
    for rec in server.dispatch_log:
        assert rec.bucket.b % 2 == 0, rec.bucket
        ref = SolverEngine(rec.plan).topk(
            jnp.asarray(rec.stack), rec.bucket.k, rec.bucket.largest)
        lam = np.asarray(ref.eigenvalues)
        vec = np.asarray(ref.vectors)
        for row, req in enumerate(rec.requests):
            res = req.future.result()
            np.testing.assert_array_equal(res.eigenvalues, lam[row, -req.k:])
            np.testing.assert_array_equal(res.vectors,
                                          vec[row, -req.k:, : req.n])
print("sharded serve OK")
"""


def test_sharded_serve_on_forced_two_device_host_mesh():
    """The sharded backend through the full server path (linger thread,
    bucket rounding to the mesh batch axis) on a 2-device host mesh."""
    import repro.engine

    # repro is a namespace package (__file__ is None) — derive src/ from a
    # concrete module inside it.
    src_dir = str(Path(repro.engine.__file__).parents[2])
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SERVE_SCRIPT], env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "sharded serve OK" in proc.stdout


# ---------------------------------------------------------------------------
# Stress lane (-m slow): heavier thread stress + sparse-stream serve smoke
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_thread_stress_producers_vs_linger():
    """8 producer threads x 25 mixed requests racing the linger thread with
    backpressure on: timeout-bounded (deadlock fails, never hangs), full
    future/cache accounting, bitwise conformance on every stack."""
    rng = np.random.default_rng(40)
    n_threads, per_thread = 8, 25
    streams = [[(_sym(rng, int(n)), int(k), bool(largest))
                for n, k, largest in zip(
                    rng.choice([6, 8, 12, 16], per_thread),
                    rng.integers(1, 4, per_thread),
                    rng.integers(0, 2, per_thread))]
               for _ in range(n_threads)]
    futs_per_thread = [[] for _ in range(n_threads)]
    with EeiServer(PLAN, max_batch=8, linger_ms=1, max_pending=64,
                   pending_policy="block", cache=SHARED_CACHE,
                   record_dispatches=True) as server:
        before = (server.cache.hits, server.cache.misses)

        def produce(i):
            local_rng = np.random.default_rng(100 + i)
            for a, k, largest in streams[i]:
                if local_rng.random() < 0.2:
                    time.sleep(local_rng.random() * 0.002)
                futs_per_thread[i].append(
                    server.submit(a, min(k, a.shape[0]), largest=largest))

        threads = [threading.Thread(target=produce, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
            assert not t.is_alive(), "producer thread deadlocked"
        futs = [f for fs in futs_per_thread for f in fs]
        for f in futs:
            f.result(timeout=300)
        _assert_accounting(server, futs, before)
        assert server.stats()["requests_failed"] == 0
        _assert_stream_conformant(server)


@pytest.mark.slow
def test_sparse_stream_serve_smoke():
    """Sparse-stream serve smoke: a mixed stream with inter-arrival gaps
    completes through the linger thread alone (no flush anywhere), within
    the timeout, bitwise-equal to the sync oracle, with compiles bounded
    by distinct buckets."""
    stream = make_eei_stream(48, 16, 4, seed=41, mixed=True)
    rng = np.random.default_rng(42)
    with EeiServer(None, max_batch=8, linger_ms=3, cache=SHARED_CACHE,
                   record_dispatches=True) as server:
        before = (server.cache.hits, server.cache.misses)
        futs = []
        for a, k in stream:
            time.sleep(rng.exponential(0.001))
            futs.append(server.submit(a, k))
        for f in futs:
            f.result(timeout=300)
        stats = server.stats()
        assert stats["requests_completed"] == len(stream)
        _assert_accounting(server, futs, before)
        _assert_stream_conformant(server)


@pytest.mark.slow
def test_chaos_stress_threaded_producers():
    """Chaos soak: 4 producer threads x 40 mixed heterogeneous requests
    racing the linger thread with backpressure on and ~8% injected faults
    across every injection point.  Timeout-bounded end to end; asserts the
    full chaos-safety contract plus that the deterministic monkey actually
    fired (a silent no-injection run would vacuously pass)."""
    chaos = ChaosMonkey(ChaosConfig(seed=7, rate=0.08, slow_s=0.002))
    n_threads = 4
    streams = [make_eei_stream(40, 16, 4, seed=50 + i, mixed=True)
               for i in range(n_threads)]
    reqs_per_thread = [[] for _ in range(n_threads)]
    with EeiServer(PLAN, max_batch=8, linger_ms=1, max_pending=64,
                   pending_policy="block", chaos=chaos) as server:

        def produce(i):
            local_rng = np.random.default_rng(200 + i)
            for a, k in streams[i]:
                if local_rng.random() < 0.2:
                    time.sleep(local_rng.random() * 0.002)
                reqs_per_thread[i].append((a, k, server.submit(a, k)))

        threads = [threading.Thread(target=produce, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
            assert not t.is_alive(), "producer thread deadlocked"
        reqs = [r for rs in reqs_per_thread for r in rs]
        for _, _, f in reqs:
            f.result(timeout=300)
        stats = server.stats()
    _assert_chaos_safe(reqs, stats)
    assert sum(chaos.counts().values()) > 0, "chaos never fired"
    assert stats["chaos_injected"] == chaos.counts()


# ---------------------------------------------------------------------------
# Review regressions: cancellation, close(drain=False) retirement, fair
# key selection, cache compile-failure propagation
# ---------------------------------------------------------------------------


def test_sync_close_without_drain_still_retires_inflight():
    """Caller-driven close(drain=False): stacks already on device must
    retire (their futures resolve with results), queued ones fail."""
    rng = np.random.default_rng(50)
    server = EeiServer(PLAN, max_batch=2)
    inflight = [server.submit(_sym(rng, 12), 2) for _ in range(2)]  # full
    assert server.stats()["stacks_dispatched"] == 1
    queued = server.submit(_sym(rng, 12), 2)  # partial group stays queued
    server.close(drain=False)
    for f in inflight:
        assert f.result(timeout=60).eigenvalues.shape == (2,)
    with pytest.raises(ServerClosed):
        queued.result(timeout=60)


def test_cancelled_future_does_not_poison_the_stack():
    """A caller cancelling its future must not crash the retire path or
    lose the other requests riding the same stack.  Since PR 5 a cancel on
    a still-*pending* request also pulls it from its coalesce group, so it
    never pads a stack."""
    rng = np.random.default_rng(51)
    with EeiServer(PLAN, max_batch=8, linger_ms=60_000,
                   cache=SHARED_CACHE) as server:
        futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
        assert futs[1].cancel()  # still queued: cancellable + dequeued
        server.flush()
        for f in (futs[0], futs[2]):
            assert f.result(timeout=120).eigenvalues.shape == (2,)
        assert futs[1].cancelled()
    stats = server.stats()
    assert stats["requests_cancelled"] == 1
    assert stats["requests_completed"] == 2  # the cancelled one never rode


def test_cancel_pending_request_never_pads_a_stack():
    """PR-4 follow-up (cancellation): a cancel() on an undispatched request
    removes it from its group — the dispatched bucket shrinks to the live
    requests instead of carrying a dead row."""
    rng = np.random.default_rng(53)
    server = EeiServer(PLAN, max_batch=8)
    futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
    assert futs[1].cancel()
    server.flush()
    stats = server.stats()
    assert stats["requests_cancelled"] == 1
    assert stats["requests_completed"] == 2
    # pow2 bucket of the 2 surviving requests — not of the original 3.
    assert server.cache.buckets()[-1].b == 2
    for f in (futs[0], futs[2]):
        assert f.result(timeout=60).eigenvalues.shape == (2,)
    # A cancel landing *after* dispatch rides the stack: device work is
    # spent either way, and retirement tolerates the resolved future.
    futs2 = [server.submit(_sym(rng, 16), 1) for _ in range(8)]
    assert server.stats()["stacks_dispatched"] == 2  # full stack went out
    cancelled_late = futs2[0].cancel()
    server.flush()
    for f in futs2[1:]:
        assert f.result(timeout=60).eigenvalues.shape == (1,)
    stats = server.stats()
    assert stats["requests_cancelled"] == 1  # late cancel is not a dequeue
    if cancelled_late:
        assert futs2[0].cancelled()


def test_cancelled_backpressure_slot_is_released():
    """Cancelling a pending request frees its max_pending slot — a blocked
    producer must make progress without any dispatch happening."""
    rng = np.random.default_rng(54)
    server = EeiServer(PLAN, max_batch=8, max_pending=2,
                       pending_policy="except")
    f0 = server.submit(_sym(rng, 12), 1)
    server.submit(_sym(rng, 12), 1)
    with pytest.raises(QueueFull):
        server.submit(_sym(rng, 12), 1)
    assert f0.cancel()
    f3 = server.submit(_sym(rng, 12), 1)  # slot released by the cancel
    server.flush()
    assert f3.result(timeout=60).eigenvalues.shape == (1,)


def test_ready_key_selection_is_fifo_across_keys():
    """An expired partial group must outrank a younger full group: the
    oldest head request wins, so a hot key cannot starve a lingered one."""
    import collections
    from concurrent.futures import Future as _Future

    from repro.engine.server import _Request

    rng = np.random.default_rng(52)
    server = EeiServer(PLAN, max_batch=2)  # sync mode: threads stay out
    server.linger_ms = 10.0  # only _ready_key_locked reads it here
    now = time.monotonic()
    r_old = _Request(a=_sym(rng, 16), n=16, k=1, largest=True,
                     future=_Future(), t_submit=now - 1.0)
    r_new = [_Request(a=_sym(rng, 24), n=24, k=1, largest=True,
                      future=_Future(), t_submit=now) for _ in range(2)]
    with server._cv:
        server._queues[(24, True)] = collections.deque(r_new)  # full, young
        server._queues[(16, True)] = collections.deque([r_old])  # expired
        key, deadline = server._ready_key_locked(now)
    assert key == (16, True), "expired older head must win over full young"
    assert deadline is None


def test_program_cache_failed_compile_raises_everywhere_and_retries():
    """A failing compile must propagate to concurrent same-bucket waiters
    and be evicted so the next get() retries."""
    cache = ProgramCache()
    bucket = ShapeBucket(2, 16, 2, True)
    calls = {"n": 0}
    import repro.engine.engine as engine_mod
    real = engine_mod.topk_program

    def flaky(plan, k, largest, verify=False):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("synthetic compile failure")
        return real(plan, k, largest, verify)

    engine_mod.topk_program, orig = flaky, engine_mod.topk_program
    try:
        with pytest.raises(RuntimeError, match="synthetic"):
            cache.get(bucket, PLAN, jnp.float32)
        assert len(cache) == 0  # evicted: retry is possible
        prog = cache.get(bucket, PLAN, jnp.float32)  # retries and succeeds
        assert prog is not None and len(cache) == 1
    finally:
        engine_mod.topk_program = orig


# ---------------------------------------------------------------------------
# Packed ragged dispatch (PR 9): small-n requests coalesce into block-
# diagonal segment-packed rows.  The conformance contract is per-request
# (not per-stack-bitwise): every packed result must match the bucketed
# SolverEngine.topk oracle on the request's own unpadded matrix to float32
# tolerance, every future resolves exactly once, and the cancel/chaos
# safety lanes hold unchanged with packing on.
# ---------------------------------------------------------------------------

# Packable request: n small enough to pack (pack_row_n=64 default), k <= 4.
_PACK_REQ = st.tuples(st.integers(1, 32), st.integers(0, 3), st.booleans(),
                      st.integers(0, 3))


def _assert_packed_oracle_match(reqs):
    """``reqs`` is ``[(a, k, largest, future), ...]``: each packed result
    must agree with the bucketed oracle on the unpadded matrix — same
    eigenvalues to float32 tolerance, unit-norm vectors with small
    residuals (vector *entries* are not compared bitwise: a packed row
    solves a different — block-diagonal — matrix, so signs and degenerate
    rotations may differ while the eigenpairs are equally correct)."""
    for a, k, largest, fut in reqs:
        res = fut.result(timeout=120)
        lam = np.asarray(res.eigenvalues)
        vec = np.asarray(res.vectors)
        n = a.shape[0]
        assert lam.shape == (k,) and vec.shape == (k, n)
        # eigh-method oracle: the tridiag reference chain cannot solve
        # n=1 (minor bands need n >= 2), but packed rows accept it.
        ref = SolverEngine(SolverPlan(method="eigh", backend="jnp")).topk(
            jnp.asarray(a), k, largest)
        ref_lam = np.asarray(ref.eigenvalues)
        scale = max(1.0, float(np.max(np.abs(ref_lam))))
        np.testing.assert_allclose(lam, ref_lam, atol=5e-4 * scale, rtol=0)
        fro = max(1.0, float(np.linalg.norm(a)))
        res_norm = np.linalg.norm(a @ vec.T - vec.T * lam, axis=0)
        assert np.max(res_norm) <= 5e-3 * fro
        norms = np.linalg.norm(vec, axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=2e-3)


@settings(max_examples=6, deadline=None)
@given(ops=st.lists(_PACK_REQ, min_size=1, max_size=20),
       max_batch=st.sampled_from([1, 2, 4]), seed=st.integers(0, 999))
def test_packed_stream_conformance_fuzz_caller_driven(ops, max_batch, seed):
    """Random packable streams under pack='always', caller-driven mode:
    per-request oracle conformance, exactly-once future resolution, and
    every stack actually went down the packed path."""
    server = EeiServer(max_batch=max_batch, pack="always",
                       cache=SHARED_CACHE, record_dispatches=True)
    rng = np.random.default_rng(seed)
    reqs = []
    for n, k_raw, largest, action in ops:
        a, k = _sym(rng, n), 1 + k_raw % n
        reqs.append((a, k, largest, server.submit(a, k, largest=largest)))
        if action == 1:
            server.pump()
        elif action == 2:
            time.sleep(0.002)
    server.flush()
    stats = server.stats()
    assert stats["requests_failed"] == 0
    assert stats["requests_completed"] == len(ops)
    assert stats["packed_stacks_dispatched"] == stats["stacks_dispatched"]
    assert all(isinstance(rec.bucket, PackedBucket)
               for rec in server.dispatch_log)
    for rec in server.dispatch_log:  # layout parallels requests exactly
        assert len(rec.layout) == len(rec.requests)
    _assert_packed_oracle_match(reqs)


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(_PACK_REQ, min_size=1, max_size=16),
       linger_ms=st.sampled_from([0.0, 1.0, 5.0]),
       seed=st.integers(0, 999))
def test_packed_stream_conformance_fuzz_linger_thread(ops, linger_ms, seed):
    """The packed conformance contract under the threaded runtime: linger
    timing decides how rows fill, but every future must resolve with an
    oracle-conformant result and no flush() ever called."""
    server = EeiServer(max_batch=2, pack="always", linger_ms=linger_ms,
                       cache=SHARED_CACHE)
    rng = np.random.default_rng(seed)
    reqs = []
    try:
        for n, k_raw, largest, action in ops:
            a, k = _sym(rng, n), 1 + k_raw % n
            reqs.append((a, k, largest,
                         server.submit(a, k, largest=largest)))
            if action == 2:
                time.sleep(0.002)
        for _, _, _, f in reqs:
            f.result(timeout=120)
    finally:
        server.close(timeout=120)
    stats = server.stats()
    assert stats["requests_failed"] == 0
    assert stats["packed_stacks_dispatched"] == stats["stacks_dispatched"]
    _assert_packed_oracle_match(reqs)


@settings(max_examples=4, deadline=None)
@given(ops=st.lists(_PACK_REQ, min_size=4, max_size=12),
       rate=st.sampled_from([0.05, 0.1]),
       seed=st.integers(0, 999), chaos_seed=st.integers(0, 999))
def test_packed_chaos_stream_fuzz(ops, rate, seed, chaos_seed):
    """The chaos safety contract with packing on: injected compile/launch
    failures, NaN results, slow retires and thread crashes — every future
    still resolves exactly once with a finite, verified result."""
    chaos = ChaosMonkey(ChaosConfig(seed=chaos_seed, rate=rate,
                                    slow_s=0.001))
    server = EeiServer(max_batch=2, pack="always", linger_ms=1.0,
                       cache=SHARED_CACHE, chaos=chaos)
    rng = np.random.default_rng(seed)
    reqs = []
    try:
        for n, k_raw, largest, action in ops:
            a, k = _sym(rng, n), 1 + k_raw % n
            reqs.append((a, k, server.submit(a, k, largest=largest)))
            if action == 2:
                time.sleep(0.002)
        for _, _, f in reqs:
            f.result(timeout=120)
    finally:
        server.close(timeout=120)
    _assert_chaos_safe(reqs, server.stats())


def test_packed_cancel_lanes():
    """Cancellation with packing on: a pending cancel dequeues the request
    (it never rides a packed row); a post-dispatch cancel rides the stack
    and retirement tolerates the resolved future."""
    rng = np.random.default_rng(60)
    with EeiServer(max_batch=8, pack="always", pack_row_n=16,
                   linger_ms=60_000, cache=SHARED_CACHE) as server:
        futs = [server.submit(_sym(rng, 12), 2) for _ in range(3)]
        assert futs[1].cancel()
        server.flush()
        for f in (futs[0], futs[2]):
            assert f.result(timeout=120).eigenvalues.shape == (2,)
        assert futs[1].cancelled()
        stats = server.stats()
        assert stats["requests_cancelled"] == 1
        assert stats["requests_completed"] == 2
        assert stats["packed_stacks_dispatched"] == 1
        # Late cancel: the packed group is already on device.  16 n=8
        # requests fill the pack-group cap (max_batch=8 rows x 2 slots
        # at pack_row_n=16), so the admission thread dispatches without
        # waiting out the linger; poll for it (the dispatch is async).
        futs2 = [server.submit(_sym(rng, 8), 1) for _ in range(16)]
        deadline = time.monotonic() + 60
        while (server.stats()["stacks_dispatched"] < 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert server.stats()["stacks_dispatched"] == 2  # full pack group
        cancelled_late = futs2[0].cancel()
        server.flush()
        for f in futs2[1:]:
            assert f.result(timeout=120).eigenvalues.shape == (1,)
        if cancelled_late:
            assert futs2[0].cancelled()


def test_packed_stream_compiles_fewer_programs_than_bucketed():
    """The structural packing win: a mixed small-n stream executes through
    fewer distinct compiled programs packed than bucketed (one packed row
    shape covers every small n; the bucketed path compiles per distinct
    footprint)."""
    sizes = [8, 12, 16, 24, 32, 40]
    rng = np.random.default_rng(61)
    stream = [(_sym(rng, n), 2) for n in sizes for _ in range(4)]
    counts = {}
    for mode in ("never", "always"):
        server = EeiServer(max_batch=4, pack=mode, pack_row_n=64,
                           cache=ProgramCache())
        for a, k in stream:
            server.submit(a, k)
        server.flush()
        server.close()
        stats = server.stats()
        assert stats["requests_completed"] == len(stream)
        counts[mode] = (stats["distinct_buckets"],
                        stats["stacks_dispatched"])
    assert counts["always"][0] < counts["never"][0], counts
    assert counts["always"][1] <= counts["never"][1], counts


# ---------------------------------------------------------------------------
# Pad-waste accounting (PR 9 bugfix): cells are counted once per
# successfully *retired* stack, so retries / splits / redispatch cannot
# inflate the counters relative to a clean run of the same stream.
# ---------------------------------------------------------------------------


def test_pad_waste_counted_at_retire_not_dispatch():
    """Regression for the over-reporting bug: a dispatched-but-unretired
    stack contributes nothing; the cells land exactly when the stack
    retires."""
    rng = np.random.default_rng(62)
    server = EeiServer(PLAN, max_batch=2, max_inflight=2)
    futs = [server.submit(_sym(rng, 12), 2) for _ in range(2)]  # full stack
    assert server.stats()["stacks_dispatched"] == 1
    assert server.stats()["grid_cells_total"] == 0  # on device, not retired
    server.flush()
    stats = server.stats()
    assert stats["grid_cells_total"] == 2 * 16 * 16
    assert stats["grid_cells_real"] == 2 * 12 * 12
    for f in futs:
        assert f.result(timeout=60).eigenvalues.shape == (2,)
    server.close()


@pytest.mark.parametrize("pack", ["never", "always"])
def test_pad_waste_matches_clean_run_under_chaos_launch_failures(pack):
    """The satellite bugfix end-to-end: a stream served under injected
    *transient* launch failures (retried in place, never split) must report
    exactly the clean run's cell counters — before the fix every retried
    stack's cells were counted once per launch attempt path taken."""
    rng = np.random.default_rng(63)
    stream = [(_sym(rng, int(rng.integers(4, 25))), 2) for _ in range(12)]

    def run(chaos):
        server = EeiServer(max_batch=2, pack=pack, cache=SHARED_CACHE,
                           chaos=chaos, max_retries=64,
                           retry_backoff_s=1e-4, retry_backoff_cap_s=1e-3)
        futs = [server.submit(a, k) for a, k in stream]
        server.flush()
        for f in futs:
            f.result(timeout=120)
        server.close()
        return server.stats()

    clean = run(None)
    # High per-point rate: the packed mode serves the whole stream in only
    # a couple of launches, so a modest rate can (deterministically, by
    # seed) miss every one and leave the chaos-fired assertion vacuous.
    chaos = ChaosMonkey(ChaosConfig(seed=7, rate=0.0, launch_rate=0.75))
    chaotic = run(chaos)
    assert chaotic["chaos_injected"].get("launch", 0) > 0  # chaos did fire
    assert chaotic["retries"] > 0
    for key in ("grid_cells_total", "grid_cells_real", "pad_waste_frac",
                "pad_waste_by_bucket", "requests_completed"):
        assert chaotic[key] == clean[key], (key, chaotic[key], clean[key])
    # Launches are *supposed* to differ: stacks_dispatched counts launches.
    assert chaotic["stacks_dispatched"] == clean["stacks_dispatched"]


# ---------------------------------------------------------------------------
# Bucket-edge properties (PR 9 hardening): n=1, k=n, n below the align
# granule — the padded shape always covers the real one, the pow2 k window
# never truncates a request, and guards stay outside the requested window.
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 40), k_raw=st.integers(0, 63),
       count=st.integers(1, 9), largest=st.booleans())
def test_property_bucket_edges_cover_request(n, k_raw, count, largest):
    k = 1 + k_raw % n  # k in [1, n] — k=n hit whenever k_raw % n == n-1
    bn = _bucket_n(n, 8)
    assert bn >= n and bn % 8 == 0 and bn - n < 8
    bucket = ShapeBucket.for_requests(count, n, k, largest)
    assert bucket.n == bn
    assert bucket.b >= count
    assert k <= bucket.k <= bucket.n  # the pow2 window never truncates
    assert blocks.pow2_bucket(bucket.b) == bucket.b
    assert blocks.clamp_block(128, n) == bn  # block clamp = same granule


@settings(max_examples=40, deadline=None)
@given(lengths=st.lists(st.integers(1, 48), min_size=1, max_size=24),
       max_slots=st.integers(1, 8))
def test_property_pack_segments_layout(lengths, max_slots):
    """pack_segments structural invariants: every input appears exactly
    once, offsets are align-granular, footprints never overlap or overflow
    the row, and no row exceeds max_slots."""
    row_width = 64
    rows = blocks.pack_segments(lengths, row_width, max_slots, align=8)
    seen = []
    for row in rows:
        assert 1 <= len(row) <= max_slots
        end = 0
        for idx, off, length in row:
            seen.append(idx)
            assert length == lengths[idx]
            assert off % 8 == 0 and off >= end  # aligned, non-overlapping
            end = off + (-(-length // 8) * 8)
            assert end <= row_width
    assert sorted(seen) == list(range(len(lengths)))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 12), pad=st.integers(1, 8), seed=st.integers(0, 999),
       largest=st.booleans(), scale=st.sampled_from([1e-2, 1.0, 1e2]))
def test_property_guard_value_at_edges(n, pad, seed, largest, scale):
    """_guard_value stays strictly outside the spectrum down to n=1 (the
    degenerate Gershgorin radius-0 case the bucket-edge sweep covers)."""
    rng = np.random.default_rng(seed)
    a = (scale * _sym(rng, n)).astype(np.float32)
    server = EeiServer(PLAN)
    guard = server._guard_value(a, largest)
    w = np.linalg.eigvalsh(a.astype(np.float64))
    if largest:
        assert guard < w[0]
    else:
        assert guard > w[-1]


# ---------------------------------------------------------------------------
# serve.py CLI degenerate streams (PR 9 bugfix): a --requests 0 run must
# drain cleanly through every mode — the stats rollups guard their empty
# denominators and the final `futures[-1].result()` no longer IndexErrors.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("extra", [[], ["--sync"], ["--linger-ms", "1"],
                                   ["--pack", "always"]],
                         ids=["server", "sync", "linger", "packed"])
def test_serve_cli_zero_request_stream(extra):
    from repro.launch import serve as serve_cli

    assert serve_cli.main(["--eei", "--requests", "0", "--n", "12",
                           "--k", "2", *extra]) is None


def test_serve_cli_packed_stream_smoke():
    """--eei --pack always on a small mixed stream: the CLI serves it end
    to end and returns the final request's result."""
    from repro.launch import serve as serve_cli

    out = serve_cli.main(["--eei", "--requests", "6", "--n", "16", "--k",
                          "2", "--mixed", "--pack", "always"])
    assert out is not None and np.all(np.isfinite(np.asarray(out.eigenvalues)))
